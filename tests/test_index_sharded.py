"""Sharded IVF plane (src/repro/index/sharded.py): the exactness/parity
harness.  The claim under test is the §10 merge theorem — per-shard
local top-k over disjoint cluster subsets, widened per shard against
the same spherical-cap bound, stable-merged on ``(score desc, id asc)``
— returns *the same bits* as the flat single-device scan: ids, scores,
tie order, boost flags.  Sweeps shard counts (including shard counts
that don't divide N, and more shards than clusters), batch shapes,
β=0, duplicate-tie corpora, and the degenerate one-shard-owns-all
partition; then the operational planes on top: incremental maintenance
(restack + idf reweight), the serving runtime under live sync, and
delta-journal persistence with cross-shard-count adoption.

Multi-device (real ``shard_map`` mesh) legs run in subprocesses via
``run_with_devices`` so the main pytest process keeps its
single-device view; everything else exercises the logical per-shard
fallback, which shares every numeric with the mesh path.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core.engine import QueryEngine, pack_query_arrays
from repro.core import signature as sigmod
from repro.core.ingest import KnowledgeBase
from repro.data.corpus import make_corpus, write_corpus_dir
from repro.index import ShardedIVFIndex, partition_clusters

from conftest import assert_bit_identical
from test_sharded import run_with_devices

SHARD_COUNTS = (1, 2, 4, 8)


def _kb(n_docs=80, dim=512, n_entities=6, seed=0):
    docs, entities = make_corpus(n_docs=n_docs, n_entities=n_entities,
                                 seed=seed)
    kb = KnowledgeBase(dim=dim)
    for i, d in enumerate(docs):
        kb.add_text(f"doc_{i:05d}.txt", d)
    return kb, list(entities)


def _pack(kb, texts):
    pairs = [
        (kb.vectorizer.query_vector(t),
         sigmod.query_signature(t, width_words=kb.sig_words))
        for t in texts
    ]
    return pack_query_arrays(pairs, kb.vectorizer.dim, kb.sig_words)


# --------------------------------------------------------------------------
# the parity sweep: sharded-exact ≡ flat, bit for bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n_docs", [7, 83])  # 83 ∤ 2,4,8; 7 < sqrt-clusters
@pytest.mark.parametrize("beta", [1.0, 0.0])  # β=0: pure cosine ranking
def test_sharded_exact_bit_identical_to_flat_sweep(n_docs, beta):
    kb, entities = _kb(n_docs=n_docs, dim=512,
                       n_entities=min(4, max(1, n_docs // 4)))
    flat = QueryEngine(kb, beta=beta, scoring_path="map")
    queries = (entities + [f"lookup {c} record" for c in entities[:2]]
               + ["quarterly forecast", "unrelated text", ""])
    want = {b: flat.query_batch((queries * 3)[:b], k=5) for b in (1, 3, 8)}
    for shards in SHARD_COUNTS:
        sharded = QueryEngine(kb, beta=beta, scoring_path="map",
                              index="ivf-sharded", guarantee="exact",
                              nprobe=1, n_shards=shards)
        for b in (1, 3, 8):  # batch sizes (padding buckets 1/4/8)
            assert_bit_identical(
                want[b], sharded.query_batch((queries * 3)[:b], k=5),
                label=f"n_docs={n_docs} beta={beta} S={shards} b={b}")


def test_sharded_exact_k_exceeds_n_clamps():
    kb, entities = _kb(n_docs=23, dim=512, n_entities=3)
    flat = QueryEngine(kb, scoring_path="map")
    sharded = QueryEngine(kb, scoring_path="map", index="ivf-sharded",
                          guarantee="exact", n_shards=4)
    queries = entities[:2] + ["filler text"]
    got = sharded.query_batch(queries, k=500)
    assert all(len(r) == kb.n_docs for r in got)  # clamped, full ranking
    assert_bit_identical(flat.query_batch(queries, k=500), got)


def test_sharded_exact_with_duplicate_ties():
    """12 identical docs tie exactly at the k-th score; the sharded
    merge must reproduce the flat scan's global-id tie order even when
    the tied rows land on *different shards* — this is precisely where
    an unstable merge key (or per-shard truncation below k) shows up."""
    kb = KnowledgeBase(dim=512)
    for i in range(12):
        kb.add_text(f"dup_{i:02d}", "identical tie content INV-7777")
    for i in range(20):
        kb.add_text(f"filler_{i:02d}", f"unrelated filler number {i}")
    flat = QueryEngine(kb, scoring_path="map")
    want = flat.query_batch(["INV-7777"], k=6)
    assert len({r.score for r in want[0]}) == 1  # genuinely tied
    for shards in (2, 4, 8):
        sharded = QueryEngine(kb, scoring_path="map", index="ivf-sharded",
                              guarantee="exact", nprobe=1, n_shards=shards)
        assert_bit_identical(want, sharded.query_batch(["INV-7777"], k=6),
                             label=f"S={shards}")


def test_degenerate_partition_all_clusters_on_one_shard():
    """A pathological hand-built partition (every cluster owned by
    shard 0, three empty shards) must still merge to the flat answer —
    empty shards contribute only sentinel rows, which the stable merge
    drops."""
    kb, entities = _kb(n_docs=60, dim=512)
    eng = QueryEngine(kb, scoring_path="map", index="ivf-sharded",
                      guarantee="exact", n_shards=4)
    base = eng.ivf.base
    deg = ShardedIVFIndex.from_base(
        base, eng.doc_vecs, eng.doc_sigs, n_shards=4,
        shard_of_cluster=np.zeros(base.n_clusters, np.int32))
    queries = entities[:3] + ["plain filler prose"]
    qv, qs = _pack(kb, queries)
    kw = dict(b=len(queries), k=5, nprobe=2, guarantee="exact",
              scoring_path="map", alpha=eng.alpha, beta=eng.beta)
    v1, i1, *_ = deg.search(eng.doc_vecs, eng.doc_sigs, qv, qs, **kw)
    v2, i2, *_ = eng.ivf.search(eng.doc_vecs, eng.doc_sigs, qv, qs, **kw)
    assert_bit_identical((v1, i1), (v2, i2))


def test_partition_clusters_covers_disjointly_and_balances():
    rng = np.random.default_rng(0)
    sizes = rng.integers(1, 200, size=37).astype(np.int64)
    for n_shards in (1, 2, 4, 8):
        soc = partition_clusters(sizes, n_shards)
        assert soc.shape == (37,)
        assert soc.min() >= 0 and soc.max() < n_shards
        loads = np.bincount(soc, weights=sizes, minlength=n_shards)
        # greedy LPT bound: no shard exceeds mean + max item
        assert loads.max() <= sizes.sum() / n_shards + sizes.max()
        np.testing.assert_array_equal(soc, partition_clusters(sizes,
                                                              n_shards))
    # fewer clusters than shards: valid owners, high shards just empty
    soc = partition_clusters(np.array([5, 3]), 8)
    assert soc.min() >= 0 and soc.max() < 8


def test_sharded_engine_validation_errors():
    kb, _ = _kb(n_docs=10, dim=256, n_entities=2)
    with pytest.raises(ValueError, match="n_shards"):
        QueryEngine(kb, index="flat", n_shards=2)
    with pytest.raises(ValueError, match="n_shards"):
        QueryEngine(kb, index="ivf-sharded", n_shards=0)
    with pytest.raises(ValueError, match="map"):
        QueryEngine(kb, index="ivf-sharded", scoring_path="gemm")


def test_sharded_index_stats_plumbing():
    kb, entities = _kb(n_docs=40, dim=512)
    eng = QueryEngine(kb, scoring_path="map", index="ivf-sharded",
                      guarantee="exact", n_shards=4)
    eng.query_batch(entities[:2], k=3)
    st = eng.index_stats()
    assert st["n_shards"] == 4
    assert st["merge_seconds"] >= 0.0
    assert 0.0 < st["probed_fraction"] <= 1.0
    assert st["rounds"] >= 1


# --------------------------------------------------------------------------
# incremental maintenance: dirty rows route to their owning shard
# --------------------------------------------------------------------------

def test_sharded_restack_maintenance_parity(tmp_path):
    """touch 2 / delete 1 / add 2 through kb.sync — the dirty-row log
    drives per-shard block maintenance, and the restacked plane stays
    bit-identical to a flat engine over the same KB."""
    docs, ents = make_corpus(n_docs=90, n_entities=6, seed=3)
    entities = list(ents)
    src = str(tmp_path / "corpus")
    write_corpus_dir(src, docs)
    kb_f = KnowledgeBase(dim=512)
    kb_f.sync(src)
    kb_s = KnowledgeBase(dim=512)
    kb_s.sync(src)
    flat = QueryEngine(kb_f, scoring_path="map")
    sharded = QueryEngine(kb_s, scoring_path="map", index="ivf-sharded",
                          guarantee="exact", n_shards=4)
    queries = entities[:3] + ["quarterly forecast"]
    assert_bit_identical(flat.query_batch(queries, k=6),
                         sharded.query_batch(queries, k=6), label="cold")

    for i in (4, 9):
        with open(f"{src}/doc_{i:05d}.txt", "a") as f:
            f.write(f" appended about {entities[1]}")
    os.unlink(f"{src}/doc_00010.txt")
    with open(f"{src}/doc_90000.txt", "w") as f:
        f.write(f"entirely new corpus member about {entities[2]} QQ-7777")
    with open(f"{src}/doc_90001.txt", "w") as f:
        f.write("another fresh arrival ZZ-8888 plain prose")
    for kb in (kb_f, kb_s):
        st = kb.sync(src)
        assert (st.updated, st.removed, st.added) == (2, 1, 2)

    q2 = queries + ["QQ-7777 fresh", f"{entities[1]} appended"]
    assert_bit_identical(flat.query_batch(q2, k=6),
                         sharded.query_batch(q2, k=6), label="restacked")
    assert len(sharded.ivf.base.assign) == kb_s.n_docs


def test_sharded_inplace_rewrite_reweighted_parity():
    """An in-place rewrite moves idf → the engine rebuilds *every* doc
    vector, so the per-shard resident blocks must regather in full (the
    O(U) scatter patch is only valid when idf held still).  Parity
    after the rewrite proves the reweighted path regathers."""
    kb_f, entities = _kb(n_docs=50, dim=512, seed=5)
    kb_s, _ = _kb(n_docs=50, dim=512, seed=5)
    flat = QueryEngine(kb_f, scoring_path="map")
    sharded = QueryEngine(kb_s, scoring_path="map", index="ivf-sharded",
                          guarantee="exact", n_shards=4)
    queries = entities[:3]
    assert_bit_identical(flat.query_batch(queries, k=5),
                         sharded.query_batch(queries, k=5), label="cold")
    for kb in (kb_f, kb_s):  # same id, brand-new terms → idf moves
        kb.add_text("doc_00007.txt", "rewritten with a new code RW-4242")
    q2 = queries + ["RW-4242"]
    got = sharded.query_batch(q2, k=5)
    assert_bit_identical(flat.query_batch(q2, k=5), got, label="rewritten")
    assert got[-1][0].doc_id == "doc_00007.txt"


# --------------------------------------------------------------------------
# real mesh: shard_map over forced host devices (subprocess legs)
# --------------------------------------------------------------------------

def test_sharded_mesh_parity_across_shard_counts():
    """On an 8-device host the plane places one cluster subset per
    device (``eng.ivf.mesh is not None``) and per-device top-k merges
    to the flat scan's bits — across shard counts 2/4/8 on one
    indivisible corpus."""
    run_with_devices("""
        import jax, numpy as np
        assert jax.device_count() == 8, jax.device_count()
        from conftest import assert_bit_identical
        from repro.core.engine import QueryEngine
        from repro.core.ingest import KnowledgeBase
        from repro.data.corpus import make_corpus

        docs, ents = make_corpus(n_docs=83, n_entities=6, seed=1)
        kb = KnowledgeBase(dim=512)
        for i, d in enumerate(docs):
            kb.add_text(f"doc_{i:05d}.txt", d)
        flat = QueryEngine(kb, scoring_path="map")
        queries = [f"report about {e}" for e in list(ents)[:4]] + [
            "plain prose words", ""]
        for b in (1, 3, 8):
            want = flat.query_batch((queries * 2)[:b], k=6)
            for S in (2, 4, 8):
                sh = QueryEngine(kb, scoring_path="map",
                                 index="ivf-sharded", guarantee="exact",
                                 n_shards=S)
                assert sh.ivf.mesh is not None, f"S={S}: no mesh"
                assert sh.ivf.mesh.devices.shape == (S,)
                assert_bit_identical(
                    want, sh.query_batch((queries * 2)[:b], k=6),
                    label=f"S={S} b={b}")
        print("OK")
    """)


def test_sharded_mesh_matches_logical_fallback():
    """The logical per-shard loop (1 device) and the shard_map mesh
    (4 devices) are the same numerics — run both placements in
    subprocesses over an identical corpus and diff the serialized
    results bit-for-bit in the parent."""
    code = """
        import jax
        from repro.core.engine import QueryEngine
        from repro.core.ingest import KnowledgeBase
        from repro.data.corpus import make_corpus
        docs, ents = make_corpus(n_docs=61, n_entities=4, seed=7)
        kb = KnowledgeBase(dim=512)
        for i, d in enumerate(docs):
            kb.add_text(f"doc_{i:05d}.txt", d)
        eng = QueryEngine(kb, scoring_path="map", index="ivf-sharded",
                          guarantee="exact", n_shards=4)
        assert (eng.ivf.mesh is not None) == (jax.device_count() >= 4)
        for res in eng.query_batch(list(ents) + ["misc words"], k=5):
            for r in res:
                print(r.doc_id, repr(r.score), repr(r.cosine), r.boosted)
    """
    out1 = run_with_devices(code, n_devices=1)   # logical fallback
    out4 = run_with_devices(code, n_devices=4)   # real mesh
    assert out1 == out4 and out1.strip()


# --------------------------------------------------------------------------
# serving runtime: sharded index under live sync, pinned generations
# --------------------------------------------------------------------------

def test_serving_runtime_sharded_live_sync_bit_identical(tmp_path):
    """4 reader threads against a ServingRuntime on the sharded plane
    while the writer syncs/publishes: every served result must be
    bit-identical to a *flat* QueryEngine over the KB frozen at the
    same generation — the cross-plane version of test_serving.py's
    torn-read stress."""
    from repro.serving import ServingRuntime

    docs, ents = make_corpus(n_docs=60, n_entities=5, seed=2)
    entities = list(ents)
    src = str(tmp_path / "corpus")
    write_corpus_dir(src, docs)
    kb = KnowledgeBase(dim=512)
    kb.sync(src)
    runtime = ServingRuntime(kb, max_batch=4, flush_deadline=0.002,
                             scoring_path="map", index="ivf-sharded",
                             guarantee="exact", n_shards=4,
                             result_cache_size=0)  # force real scoring
    containers = {}

    def save_generation(gen):
        path = str(tmp_path / f"gen_{gen}.ragdb")
        kb.save(path, generation=gen)
        containers[gen] = path

    save_generation(runtime.generation)
    queries = entities + ["escalation runbook", "LIVE-7777"]
    with runtime:
        runtime.query_batch(queries[:2], k=3)  # warm the jit caches

        served, served_lock = [], threading.Lock()
        stop = threading.Event()

        def reader(rid):
            i = rid
            while not stop.is_set():
                q = queries[i % len(queries)]
                k = 3 if (i % 2) else 5
                i += 1
                res = runtime.submit(q, k=k).result(timeout=120)
                with served_lock:
                    served.append((q, k, res))

        threads = [threading.Thread(target=reader, args=(r,))
                   for r in range(4)]
        for t in threads:
            t.start()
        for rnd in range(6):
            with open(os.path.join(src, f"doc_{rnd:05d}.txt"), "a") as f:
                f.write(f" LIVE-7777 edit round {rnd}")
            if rnd == 3:
                os.unlink(os.path.join(src, "doc_00030.txt"))
            kb.sync(src)
            save_generation(kb.version)
            gen = runtime.publish()
            assert gen == kb.version
            time.sleep(0.05)
        stop.set()
        for t in threads:
            t.join()

    assert len(served) >= 4 * 6
    observed = {res.generation for _, _, res in served}
    assert observed <= set(containers)
    assert len(observed) >= 2  # the run really spanned generations
    references = {
        gen: QueryEngine(KnowledgeBase.load(containers[gen]),
                         scoring_path="map")
        for gen in observed
    }
    for q, k, res in served:
        want = references[res.generation].query_batch([q], k=k)[0]
        assert_bit_identical([res.results], [want], label=(
            f"{q!r}@k={k} vs the flat engine at pinned generation "
            f"{res.generation}"))


# --------------------------------------------------------------------------
# persistence: delta journal → load → sharded adopt (and rejection)
# --------------------------------------------------------------------------

def test_sharded_state_survives_delta_load_and_adopts(tmp_path,
                                                      monkeypatch):
    import repro.index.ivf as ivf_mod

    kb, entities = _kb(n_docs=70, dim=512, seed=4)
    eng = QueryEngine(kb, scoring_path="map", index="ivf-sharded",
                      guarantee="exact", n_shards=4)
    p = str(tmp_path / "kb.ragdb")
    kb.save(p)
    kb.add_text("late.txt", f"late doc about {entities[0]} LATE-1212")
    eng.refresh()  # reassigns + writes sharded index state back
    kb.save_delta(p, compact_ratio=None)

    calls = []
    orig = ivf_mod.spherical_kmeans
    monkeypatch.setattr(ivf_mod, "spherical_kmeans",
                        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])
    kb2 = KnowledgeBase.load(p)
    assert kb2.index_state is not None
    assert int(kb2.index_state["n_shards"]) == 4
    eng2 = QueryEngine(kb2, scoring_path="map", index="ivf-sharded",
                       guarantee="exact", n_shards=4)
    queries = entities[:3] + ["LATE-1212"]
    got = eng2.query_batch(queries, k=5)
    assert calls == []  # adopted — no cold retrain after the journal
    assert_bit_identical(eng.query_batch(queries, k=5), got)
    np.testing.assert_array_equal(eng2.ivf.shard_of_cluster,
                                  eng.ivf.shard_of_cluster)

    # same persisted state adopts across planes and shard counts: a
    # plain ivf engine and a 2-shard engine both reuse the clustering
    # (the 2-shard plane re-partitions but must not re-run k-means)
    for kwargs in (dict(index="ivf"),
                   dict(index="ivf-sharded", n_shards=2)):
        eng3 = QueryEngine(KnowledgeBase.load(p), scoring_path="map",
                           guarantee="exact", **kwargs)
        assert_bit_identical(eng.query_batch(queries, k=5),
                             eng3.query_batch(queries, k=5),
                             label=str(kwargs))
    assert calls == []


def test_sharded_stale_ids_sha_rejected(monkeypatch):
    """Persisted sharded state whose content digest no longer matches
    the live docs must be rejected → retrain, never silent adoption of
    stale per-shard bounds."""
    import repro.index.ivf as ivf_mod

    kb, _ = _kb(n_docs=40, dim=512)
    QueryEngine(kb, scoring_path="map", index="ivf-sharded",
                n_shards=4)  # writes kb.index_state (kind "ivf" + shards)
    kb.add_text("doc_00012.txt", "rewritten with a brand new code PJ-3131")

    calls = []
    orig = ivf_mod.spherical_kmeans
    monkeypatch.setattr(ivf_mod, "spherical_kmeans",
                        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])
    fresh = QueryEngine(kb, scoring_path="map", index="ivf-sharded",
                        guarantee="exact", n_shards=4)
    assert calls == [1]  # stale state rejected → retrained
    flat = QueryEngine(kb, scoring_path="map")
    assert_bit_identical(fresh.query_batch(["PJ-3131"], k=4),
                         flat.query_batch(["PJ-3131"], k=4))
