"""Observability plane contracts (obs/): the span tracer's
enable/disable/sampling semantics and O(1) ring buffer, the Chrome
trace-event round trip (write -> load lossless to ~1 ns), the
stage-breakdown CLI, the metrics registry (get-or-create, labels,
kind safety, exports), and the LogHistogram edge cases the serving
latency plane depends on (overflow bucket, percentile monotonicity,
single-sample clamp, concurrent record-vs-snapshot)."""
import json
import threading

import pytest

from repro.obs import (
    MetricsRegistry,
    SpanRecord,
    Tracer,
    chrome_trace,
    global_registry,
    load_chrome_trace,
    render_prometheus,
    request_decomposition,
    stage_breakdown,
    write_chrome_trace,
)
from repro.obs.__main__ import main as obs_main
from repro.obs.metrics import LogHistogram
from repro.serving import ServingMetrics


# ---- tracer ---------------------------------------------------------------


class TestTracer:
    def test_disabled_is_noop(self):
        tr = Tracer()
        assert not tr.enabled
        with tr.span("outer", k=1) as s:
            assert s.trace_id == 0
            with tr.span("inner"):
                pass
        assert tr.alloc_id() == 0
        assert tr.begin_trace() == 0
        assert tr.record("x", 0.0, 1.0) == 0
        tr.record_batch(7, [("x", 0.0, 1.0, 0, 0, None)])
        assert len(tr) == 0

    def test_span_nesting_and_parenting(self):
        tr = Tracer().enable()
        with tr.span("outer") as outer:
            with tr.span("inner") as inner:
                assert inner.trace_id == outer.trace_id
                assert inner.parent_id == outer.span_id
        spans = tr.drain()
        assert [s.name for s in spans] == ["inner", "outer"]  # exit order
        assert spans[0].parent_id == spans[1].span_id
        assert all(s.dur_ns >= 0 for s in spans)
        assert all(s.t0_ns > 0 for s in spans)

    def test_explicit_cross_thread_trace(self):
        tr = Tracer().enable()
        tid = tr.begin_trace()
        assert tid > 0
        out = []

        def worker():
            with tr.span("stage", trace=tid, parent=0):
                pass
            out.append(tr.record("manual", 1.0, 0.5, trace=tid))

        t = threading.Thread(target=worker)
        t.start()
        t.join()
        spans = tr.drain()
        assert {s.trace_id for s in spans} == {tid}
        assert out[0] > 0
        manual = next(s for s in spans if s.name == "manual")
        assert manual.t0_ns == 1_000_000_000
        assert manual.dur_ns == 500_000_000

    def test_suppressed_trace_suppresses_descendants(self):
        # trace=0 means "unsampled request": nested spans must not
        # start fresh orphan traces
        tr = Tracer().enable()
        with tr.span("request", trace=0):
            with tr.span("child"):
                with tr.span("grandchild"):
                    pass
        assert tr.drain() == []

    def test_sampling_period(self):
        tr = Tracer(sample=0.25).enable()
        ids = [tr.begin_trace() for _ in range(100)]
        assert sum(1 for i in ids if i) == 25
        # 1-in-4: every 4th decision samples, starting with the first
        assert ids[0] > 0 and ids[1] == 0

        with pytest.raises(ValueError):
            tr.configure(sample=0.0)
        with pytest.raises(ValueError):
            tr.configure(sample=1.5)

    def test_ring_buffer_bounded(self):
        tr = Tracer(capacity=16).enable()
        for i in range(100):
            with tr.span("s", i=i):
                pass
        assert len(tr) == 16
        spans = tr.spans()   # non-destructive
        assert len(tr) == 16
        assert [s.args["i"] for s in spans] == list(range(84, 100))
        assert len(tr.drain()) == 16
        assert len(tr) == 0

    def test_record_batch(self):
        tr = Tracer().enable()
        tid = tr.begin_trace()
        rid = tr.alloc_id()
        tr.record_batch(tid, [
            ("queue_wait", 0.0, 0.1, 0, rid, None),
            ("score", 0.1, 0.2, 0, rid, {"batch": 4}),
            ("request", 0.0, 0.3, rid, 0, {"cached": False}),
        ])
        spans = tr.drain()
        assert [s.name for s in spans] == ["queue_wait", "score", "request"]
        assert all(s.trace_id == tid for s in spans)
        # zero span_id allocates; explicit span_id is preserved
        assert spans[2].span_id == rid
        assert spans[0].span_id not in (0, rid)
        assert spans[0].parent_id == rid
        assert spans[1].args == {"batch": 4}
        assert spans[0].args == {}
        # unsampled trace: nothing emitted
        tr.record_batch(0, [("x", 0.0, 1.0, 0, 0, None)])
        assert tr.drain() == []

    def test_negative_duration_clamped(self):
        tr = Tracer().enable()
        tid = tr.begin_trace()
        tr.record("clock_skew", 5.0, -0.001, trace=tid)
        (s,) = tr.drain()
        assert s.dur_ns == 0


# ---- exporters ------------------------------------------------------------


def _sample_spans():
    tr = Tracer().enable()
    tid = tr.begin_trace()
    rid = tr.alloc_id()
    tr.record_batch(tid, [
        ("queue_wait", 1.0, 0.010, 0, rid, None),
        ("flush_wait", 1.010, 0.002, 0, rid, None),
        ("score", 1.012, 0.030, 0, rid, {"batch": 8}),
        ("merge", 1.042, 0.001, 0, rid, None),
        ("request", 1.0, 0.043, rid, 0,
         {"k": 5, "generation": 3, "cached": False}),
    ])
    return tr.drain()


class TestChromeTrace:
    def test_round_trip_lossless(self, tmp_path):
        spans = _sample_spans()
        path = str(tmp_path / "trace.json")
        assert write_chrome_trace(path, spans) == len(spans)
        loaded = load_chrome_trace(path)
        assert len(loaded) == len(spans)
        for a, b in zip(spans, loaded):
            assert isinstance(b, SpanRecord)
            assert b.name == a.name
            assert b.trace_id == a.trace_id
            assert b.span_id == a.span_id
            assert b.parent_id == a.parent_id
            assert b.args == a.args
            # ts/dur ride as microsecond floats: ~1 ns quantization
            assert abs(b.t0_ns - a.t0_ns) <= 1
            assert abs(b.dur_ns - a.dur_ns) <= 1

    def test_perfetto_schema(self):
        doc = chrome_trace(_sample_spans())
        assert set(doc) == {"traceEvents", "displayTimeUnit"}
        for ev in doc["traceEvents"]:
            assert ev["ph"] == "X"
            assert ev["cat"] == "ragdb"
            assert {"name", "ts", "dur", "pid", "tid", "args"} <= set(ev)
        json.dumps(doc)  # must be serializable as-is

    def test_foreign_events_skipped(self, tmp_path):
        path = str(tmp_path / "mixed.json")
        with open(path, "w") as f:
            json.dump({"traceEvents": [
                {"name": "other", "ph": "M", "ts": 0},
                {"name": "noids", "ph": "X", "ts": 0, "dur": 1, "args": {}},
            ]}, f)
        assert load_chrome_trace(path) == []


class TestBreakdown:
    def test_stage_breakdown_stats(self):
        br = stage_breakdown(_sample_spans())
        assert set(br) == {"queue_wait", "flush_wait", "score",
                           "merge", "request"}
        s = br["score"]
        assert s["count"] == 1
        assert s["p50_s"] == s["p99_s"] == s["max_s"] == pytest.approx(0.030)

    def test_request_decomposition_tiles(self):
        reqs = request_decomposition(_sample_spans())
        assert len(reqs) == 1
        r = reqs[0]
        assert r["stage_sum_s"] == pytest.approx(r["request_s"], abs=1e-9)
        assert set(r["stages_s"]) == {"queue_wait", "flush_wait",
                                      "score", "merge"}

    def test_cached_requests_excluded(self):
        tr = Tracer().enable()
        tid = tr.begin_trace()
        tr.record("request", 0.0, 0.001, trace=tid, cached=True)
        assert request_decomposition(tr.drain()) == []

    def test_cli(self, tmp_path, capsys):
        path = str(tmp_path / "trace.json")
        write_chrome_trace(path, _sample_spans())
        assert obs_main([path]) == 0
        out = capsys.readouterr().out
        assert "queue_wait" in out and "p50_ms" in out
        assert "100.0% of end-to-end" in out

        assert obs_main([path, "--json"]) == 0
        doc = json.loads(capsys.readouterr().out)
        assert "stages" in doc and "requests" in doc

        assert obs_main([str(tmp_path / "missing.json")]) == 2


# ---- metrics registry -----------------------------------------------------


class TestMetricsRegistry:
    def test_get_or_create_identity(self):
        reg = MetricsRegistry()
        a = reg.counter("reqs_total", "help text", outcome="ok")
        b = reg.counter("reqs_total", outcome="ok")
        assert a is b
        c = reg.counter("reqs_total", outcome="err")
        assert c is not a
        a.inc()
        a.inc(2)
        c.inc()
        snap = reg.snapshot()
        assert snap["reqs_total{outcome=ok}"] == 3
        assert snap["reqs_total{outcome=err}"] == 1

    def test_kind_mismatch_rejected(self):
        reg = MetricsRegistry()
        reg.counter("x_total")
        with pytest.raises(ValueError, match="counter"):
            reg.gauge("x_total")

    def test_gauge_and_histogram_snapshot_keys(self):
        reg = MetricsRegistry()
        reg.gauge("lag_seconds").set(1.5)
        reg.histogram("lat_seconds").record(0.01)
        snap = reg.snapshot()
        assert snap["lag_seconds"] == 1.5
        assert snap["lat_seconds_count"] == 1
        assert snap["lat_seconds_sum"] == pytest.approx(0.01)
        assert {"lat_seconds_p50", "lat_seconds_p99",
                "lat_seconds_max", "lat_seconds_mean"} <= set(snap)

    def test_prometheus_rendering(self):
        reg = MetricsRegistry()
        reg.counter("ragdb_x_total", "things", kind="a").inc(4)
        reg.gauge("ragdb_lag_seconds").set(0.25)
        h = reg.histogram("ragdb_lat_seconds")
        h.record(0.02)
        text = render_prometheus(reg)
        assert "# HELP ragdb_x_total things" in text
        assert "# TYPE ragdb_x_total counter" in text
        assert 'ragdb_x_total{kind="a"} 4' in text
        assert "ragdb_lag_seconds 0.25" in text
        # histograms render summary-style
        assert "# TYPE ragdb_lat_seconds summary" in text
        assert 'ragdb_lat_seconds{quantile="0.5"}' in text
        assert 'ragdb_lat_seconds{quantile="0.99"}' in text
        assert "ragdb_lat_seconds_count 1" in text
        assert "ragdb_lat_seconds_sum 0.02" in text
        assert text.endswith("\n")

    def test_multi_registry_rendering(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        a.counter("a_total").inc()
        b.counter("b_total").inc()
        text = render_prometheus(a, b)
        assert "a_total 1" in text and "b_total 1" in text

    def test_global_registry_is_singleton(self):
        assert global_registry() is global_registry()

    # ---- series lifecycle (tenant evict/remount churn) ------------------

    def test_concurrent_get_or_create_many_tenants(self):
        """Get-or-create under concurrent tenants: every thread racing
        on the same (name, labels) must land on the same object, and
        the family must end with exactly one series per tenant."""
        reg = MetricsRegistry()
        tenants = [f"t{i:02d}" for i in range(8)]
        got: dict = {t: [] for t in tenants}
        barrier = threading.Barrier(16)

        def worker(wid: int):
            barrier.wait()
            for _ in range(50):
                t = tenants[(wid + _) % len(tenants)]
                c = reg.counter("ragdb_reqs_total", tenant=t)
                c.inc()
                got[t].append(c)

        threads = [threading.Thread(target=worker, args=(w,))
                   for w in range(16)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        series = reg.series("ragdb_reqs_total")
        assert len(series) == len(tenants)
        for t in tenants:
            assert len({id(c) for c in got[t]}) == 1  # one object per tenant
        total = sum(c.value for c in series.values())
        assert total == 16 * 50

    def test_prune_on_evict(self):
        reg = MetricsRegistry()
        reg.counter("ragdb_reqs_total", tenant="a").inc()
        reg.counter("ragdb_reqs_total", tenant="b").inc()
        reg.gauge("ragdb_publish_lag_seconds", tenant="a").set(1.0)
        reg.gauge("ragdb_other").set(2.0)
        removed = reg.prune(tenant="a")
        assert removed == 2
        assert "tenant=a" not in "".join(reg.snapshot())
        # the other tenant and unlabeled series are untouched
        snap = reg.snapshot()
        assert snap["ragdb_reqs_total{tenant=b}"] == 1
        assert snap["ragdb_other"] == 2.0
        # name-restricted prune only touches that family
        reg.counter("ragdb_reqs_total", tenant="c").inc()
        reg.gauge("ragdb_publish_lag_seconds", tenant="c").set(3.0)
        assert reg.prune("ragdb_reqs_total", tenant="c") == 1
        assert "ragdb_publish_lag_seconds{tenant=c}" in reg.snapshot()

    def test_prune_forgets_kind(self):
        """A fully-pruned family's kind is forgotten with it: the same
        name can be recreated as a different kind without the
        kind-mismatch rejection (and the rejection still applies while
        any series survives)."""
        reg = MetricsRegistry()
        reg.counter("ragdb_x", tenant="a")
        reg.counter("ragdb_x", tenant="b")
        reg.prune(tenant="a")
        with pytest.raises(ValueError, match="counter"):
            reg.gauge("ragdb_x", tenant="c")  # b's series keeps the kind
        reg.prune(tenant="b")  # family now empty -> removed
        g = reg.gauge("ragdb_x", tenant="c")  # recreate as a gauge
        g.set(7)
        assert reg.snapshot()["ragdb_x{tenant=c}"] == 7


# ---- LogHistogram edge cases ---------------------------------------------


class TestLogHistogram:
    def test_overflow_bucket(self):
        # beyond the last bound (~79 s) lands in the overflow bucket;
        # percentiles there report the observed max, not a midpoint
        h = LogHistogram()
        assert 100.0 > h.bounds[-1]
        h.record(100.0)
        h.record(250.0)
        assert h.n == 2
        assert h.counts[h.N_BUCKETS] == 2
        assert h.percentile(50) == 250.0
        assert h.percentile(99) == 250.0

    def test_percentile_monotonic_in_q(self):
        h = LogHistogram()
        for i in range(1, 1001):
            h.record(i * 1e-4)  # 0.1 ms .. 100 ms
        prev = 0.0
        for q in range(0, 101, 5):
            p = h.percentile(q)
            assert p >= prev
            prev = p
        assert h.percentile(0) >= h.min
        assert h.percentile(100) <= h.max

    def test_single_sample_clamp(self):
        h = LogHistogram()
        h.record(0.0123)
        assert h.percentile(50) == 0.0123
        assert h.percentile(99) == 0.0123
        assert h.percentile(99) == h.max
        assert h.mean == 0.0123

    def test_empty(self):
        h = LogHistogram()
        assert h.percentile(50) == 0.0
        assert h.mean == 0.0
        assert h.snapshot()["count"] == 0

    def test_concurrent_record_vs_snapshot(self):
        # record() and snapshot() share one lock: a snapshot taken
        # mid-stream must always be internally coherent (count == sum
        # of bucket counts implied by sum/mean relationship holds)
        h = LogHistogram()
        stop = threading.Event()
        errors = []

        def writer():
            i = 0
            while not stop.is_set():
                h.record(0.001 * (1 + i % 50))
                i += 1

        def reader():
            try:
                for _ in range(200):
                    s = h.snapshot()
                    assert s["count"] >= 0
                    if s["count"]:
                        assert s["mean"] == pytest.approx(
                            s["sum"] / s["count"])
                        assert 0 < s["p50"] <= s["max"]
                        assert s["p50"] <= s["p99"] <= s["max"]
            except Exception as exc:  # surfaced to the main thread
                errors.append(exc)

        threads = [threading.Thread(target=writer) for _ in range(2)]
        threads += [threading.Thread(target=reader) for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads[2:]:
            t.join()
        stop.set()
        for t in threads[:2]:
            t.join()
        assert errors == []


# ---- ServingMetrics regression -------------------------------------------


class TestServingMetricsFormat:
    def test_format_includes_failed(self):
        m = ServingMetrics()
        m.on_submit()
        m.on_fail()
        text = m.format()
        assert "1 failed" in text
        assert m.snapshot()["failed"] == 1

    def test_render_prometheus_exposition(self):
        m = ServingMetrics()
        m.on_submit()
        m.on_complete(0.005)
        text = m.render()
        assert "ragdb_serving_requests_total 1" in text
        assert "ragdb_serving_completed_total 1" in text
        assert "ragdb_serving_latency_seconds_count 1" in text
