"""Optimizer substrate: AdamW, schedules, error-feedback compression."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.optim.compress import (
    dequantize, ef_roundtrip, quantize,
)


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - 1.0))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(g, opt, params, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), 1.0, atol=1e-2)
    assert int(opt["step"]) == 200


def test_grad_clip():
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-6, weight_decay=0.0)
    g = {"w": jnp.asarray([1e9, 1e9, 1e9])}
    p2, _ = adamw_update(g, opt, params, cfg)
    assert np.abs(np.asarray(p2["w"])).max() < 2.0  # clip kept it sane


def test_schedule_shape():
    lrs = [float(warmup_cosine(s, 1e-3, 10, 100)) for s in range(100)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-9  # peak at end of warmup
    assert lrs[99] < lrs[50] < lrs[11]
    assert lrs[99] >= 1e-4 * 0.99  # min_ratio floor


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=64).astype(np.float32) * 10)
    q, s = quantize(x)
    err = np.abs(np.asarray(dequantize(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6  # half-ULP of the int8 grid


def test_error_feedback_unbiased_over_time():
    """Accumulated compressed updates converge to accumulated true
    updates — the EF guarantee (residual is bounded, not growing)."""
    rng = np.random.default_rng(0)
    g_true = [rng.normal(size=32).astype(np.float32) for _ in range(50)]
    err = {"g": jnp.zeros(32)}
    acc_c = np.zeros(32)
    acc_t = np.zeros(32)
    for g in g_true:
        gq, err = ef_roundtrip({"g": jnp.asarray(g)}, err)
        acc_c += np.asarray(gq["g"])
        acc_t += g
    # total drift equals the final residual (telescoping sum), which is
    # bounded by one quantization step — NOT 50 of them
    drift = np.abs(acc_c - acc_t)
    assert drift.max() <= np.abs(np.asarray(err["g"])).max() + 1e-5


def test_ef_training_matches_uncompressed_loss():
    """Quadratic descent with int8+EF gradients reaches the same loss
    neighbourhood as exact gradients."""
    target = jnp.asarray(np.linspace(-2, 2, 16), jnp.float32)

    def run(compressed: bool):
        params = {"w": jnp.zeros(16)}
        opt = adamw_init(params)
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
        err = {"w": jnp.zeros(16)}
        for _ in range(150):
            g = jax.grad(lambda p: jnp.sum(jnp.square(p["w"] - target)))(
                params)
            if compressed:
                g, err = ef_roundtrip(g, err)
            params, opt = adamw_update(g, opt, params, cfg)
        return float(jnp.sum(jnp.square(params["w"] - target)))

    assert run(True) < run(False) + 1e-2
