"""Invariant-analyzer contracts (analysis/): every rule catches its
failing fixture and passes its clean one, pragmas suppress exactly what
they name (and are audited themselves), the CLI exit codes hold, the
runtime sanitizers catch forced retraces and injected NaNs, and — the
point of the whole plane — the repo itself is strict-clean, making the
analyzer a tier-1 gate."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.analysis import sanitizers
from repro.analysis.pragmas import parse_pragmas
from repro.analysis.runner import render_audit, run_analysis

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fixture_tree(tmp_path, files: dict[str, str]) -> str:
    """Materialize {relpath: source} under tmp_path and return the root
    (run_analysis treats a dir without src/repro as the package root,
    so fixture paths like core/hsf.py match the real rule scopes)."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return str(tmp_path)


def _findings(tmp_path, files, rule=None, strict=False):
    report = run_analysis(_fixture_tree(tmp_path, files), strict=strict)
    assert not report.errors, report.errors
    if rule is None:
        return report.findings
    return [f for f in report.findings if f.rule == rule]


@pytest.fixture(autouse=True)
def _reset_sanitizers():
    yield
    sanitizers._enabled = None  # back to env-driven


# --------------------------------------------------------------------------
# R1 unpinned-reduction
# --------------------------------------------------------------------------

def test_r1_flags_matmul_and_calls(tmp_path):
    found = _findings(tmp_path, {"core/engine.py": """
        import jax.numpy as jnp
        def score(q, dv):
            a = q @ dv.T
            b = jnp.dot(q, dv.T)
            c = jnp.einsum("bd,nd->bn", q, dv)
            return a + b + c
    """}, rule="unpinned-reduction")
    assert len(found) == 3
    assert {f.line for f in found} == {4, 5, 6}


def test_r1_clean_inside_stable_rowdot_and_out_of_scope(tmp_path):
    found = _findings(tmp_path, {
        # the pinned reduction itself may use whatever it wants
        "core/hsf.py": """
            import jax.numpy as jnp
            def stable_rowdot(mat, vec):
                return (mat @ vec).sum()
        """,
        # scoring-module scopes only: a model file may matmul freely
        "models/lm.py": """
            def fwd(x, w):
                return x @ w
        """,
    }, rule="unpinned-reduction")
    assert found == []


def test_r1_pragma_suppresses_trailing_and_comment_only(tmp_path):
    found = _findings(tmp_path, {"core/engine.py": """
        def score(q, dv):
            a = q @ dv.T  # analysis: allow[unpinned-reduction] -- fixture
            # analysis: allow[unpinned-reduction] -- spans the whole
            #   statement, continuation comments included
            b = (
                q @ dv.T
            )
            return a + b
    """})
    assert found == []


# --------------------------------------------------------------------------
# R2 writer-lock
# --------------------------------------------------------------------------

_R2_CLASS = """
    import contextlib

    class KnowledgeBase:
        @contextlib.contextmanager
        def _single_writer(self, op):
            yield

        def reader(self):
            return len(self.records)

        def locked_mutator(self, x):
            with self._single_writer("ok"):
                self.records[x] = x

        def _helper(self, x):
            self.records[x] = x
"""


def test_r2_flags_unlocked_public_mutator(tmp_path):
    found = _findings(tmp_path, {"core/ingest.py": _R2_CLASS + """
        def bad(self, x):
            self.records[x] = x
"""}, rule="writer-lock")
    assert [f for f in found if "bad" in f.message]
    assert not [f for f in found if "reader" in f.message
                or "locked_mutator" in f.message
                or "_helper" in f.message]


def test_r2_flags_transitive_mutation_via_helper(tmp_path):
    found = _findings(tmp_path, {"core/ingest.py": _R2_CLASS + """
        def bad_indirect(self, x):
            self._helper(x)
"""}, rule="writer-lock")
    assert [f for f in found if "bad_indirect" in f.message]


def test_r2_ignores_classes_without_the_lock(tmp_path):
    found = _findings(tmp_path, {"core/ingest.py": """
        class PlainBag:
            def put(self, x):
                self.records = x
    """}, rule="writer-lock")
    assert found == []


# --------------------------------------------------------------------------
# R3 durability
# --------------------------------------------------------------------------

def test_r3_flags_bare_write_rename_and_replace(tmp_path):
    found = _findings(tmp_path, {"serving/dump.py": """
        import os
        def publish(path, blob):
            with open(path + ".tmp", "w") as fh:
                fh.write(blob)
            os.rename(path + ".tmp", path)
            os.replace(path + ".tmp", path)
    """}, rule="durability")
    assert len(found) == 3


def test_r3_allows_reads_and_blessed_helpers(tmp_path):
    found = _findings(tmp_path, {"core/container.py": """
        import os
        def _atomic_write_json(path, obj):
            fd = os.open(path + ".tmp", os.O_WRONLY)
            with os.fdopen(fd, "w") as fh:
                fh.write(obj)
            os.replace(path + ".tmp", path)
        def load(path):
            with open(path) as fh:
                return fh.read()
    """}, rule="durability")
    assert found == []


def test_r3_pragma_suppressed(tmp_path):
    found = _findings(tmp_path, {"checkpoint/scratch.py": """
        def debug_dump(path, blob):
            with open(path, "w") as fh:  # analysis: allow[durability] -- fixture
                fh.write(blob)
    """})
    assert found == []


# --------------------------------------------------------------------------
# R4 snapshot-mutation
# --------------------------------------------------------------------------

def test_r4_flags_unfrozen_class_and_mutation(tmp_path):
    found = _findings(tmp_path, {"serving/snap.py": """
        from dataclasses import dataclass

        @dataclass
        class EngineSnapshot:
            generation: int

        def touch(mgr):
            snap = EngineSnapshot(generation=0)
            snap.generation = 1
            object.__setattr__(snap, "generation", 2)
    """}, rule="snapshot-mutation")
    assert len(found) == 3  # unfrozen decl, attr store, __setattr__


def test_r4_clean_frozen_capture_and_swap(tmp_path):
    found = _findings(tmp_path, {"serving/snap.py": """
        from dataclasses import dataclass

        @dataclass(frozen=True)
        class EngineSnapshot:
            generation: int

        class Manager:
            def publish(self):
                snap = EngineSnapshot(generation=1)
                self._current = snap  # swapping the ref is the protocol
                return self._current
    """}, rule="snapshot-mutation")
    assert found == []


def test_r4_flags_store_on_manager_current(tmp_path):
    found = _findings(tmp_path, {"apps/consumer.py": """
        def poke(mgr):
            snap = mgr.current
            snap.doc_ids = ()
    """}, rule="snapshot-mutation")
    assert len(found) == 1


# --------------------------------------------------------------------------
# R5 host-sync
# --------------------------------------------------------------------------

def test_r5_flags_host_syncs_in_jitted_fns_only(tmp_path):
    found = _findings(tmp_path, {"core/score.py": """
        import jax, numpy as np
        from functools import partial

        @jax.jit
        def bad_item(x):
            return x.sum().item()

        @partial(jax.jit, static_argnames=("k",))
        def bad_asarray(x, *, k):
            return np.asarray(x)[:k]

        def _core(x):
            return float(x.sum())
        worse = jax.jit(_core)

        def host_boundary(x):
            return float(x.sum())  # not jitted: fine
    """}, rule="host-sync")
    assert len(found) == 3
    assert {f.line for f in found} == {7, 11, 14}


def test_r5_pragma_suppressed(tmp_path):
    found = _findings(tmp_path, {"core/score.py": """
        import jax

        @jax.jit
        def fn(x):
            return int(x.shape[0])  # analysis: allow[host-sync] -- static shape
    """})
    assert found == []


# --------------------------------------------------------------------------
# R6 tenant-pin
# --------------------------------------------------------------------------

def test_r6_flags_unguarded_mutation_and_missing_pins_check(tmp_path):
    found = _findings(tmp_path, {"tenancy/pool.py": """
        class ContainerPool:
            def __init__(self):
                self._resident = {}   # construction: exempt

            def sneak_mount(self, t, mt):
                self._resident[t] = mt  # no guard, not *_locked

            def evict(self, t):
                with self._pool_guard("evict"):
                    self._resident.pop(t)  # guarded but no pins check
    """}, rule="tenant-pin")
    msgs = [f.message for f in found]
    assert len(found) == 2, msgs
    assert any("without `with self._pool_guard" in m for m in msgs)
    assert any("pins == 0" in m for m in msgs)


def test_r6_clean_pool_passes_and_outside_mutation_flagged(tmp_path):
    clean = _findings(tmp_path, {"tenancy/pool.py": """
        class ContainerPool:
            def __init__(self):
                self._resident = {}

            def pin(self, t):
                with self._pool_guard("pin"):
                    mt = self._resident.get(t)
                    if mt is None:
                        mt = self._mount_locked(t)
                    mt.pins += 1
                    self._resident.move_to_end(t)
                    return mt

            def _mount_locked(self, t):
                self._resident[t] = object()

            def _evict_locked(self, mt):
                assert mt.pins == 0
                self._resident.pop(mt.tenant)
    """}, rule="tenant-pin")
    assert clean == []
    outside = _findings(tmp_path, {"serving/hack.py": """
        def tear_down(pool, t):
            pool._resident.pop(t)

        def overwrite(pool, t, mt):
            pool._resident[t] = mt
    """}, rule="tenant-pin")
    assert len(outside) == 2
    assert all("outside" in f.message for f in outside)


# --------------------------------------------------------------------------
# pragma hygiene
# --------------------------------------------------------------------------

def test_unknown_rule_pragma_is_a_finding(tmp_path):
    found = _findings(tmp_path, {"core/x.py": """
        x = 1  # analysis: allow[unpinned-reductionz] -- typo
    """}, rule="pragma")
    assert len(found) == 1 and "unknown rule" in found[0].message


def test_unused_pragma_is_a_finding(tmp_path):
    found = _findings(tmp_path, {"core/x.py": """
        x = 1  # analysis: allow[durability] -- nothing here to excuse
    """}, rule="pragma")
    assert len(found) == 1 and "unused" in found[0].message


def test_strict_requires_justification(tmp_path):
    files = {"core/engine.py": """
        def score(q, dv):
            return q @ dv.T  # analysis: allow[unpinned-reduction]
    """}
    assert _findings(tmp_path, files, rule="pragma", strict=False) == []
    found = _findings(tmp_path, files, rule="pragma", strict=True)
    assert len(found) == 1 and "justification" in found[0].message


def test_pragma_statement_span_stops_at_bracket_close(tmp_path):
    src = textwrap.dedent("""
        # analysis: allow[unpinned-reduction] -- first statement only
        a = (
            q @ dv.T
        )
        b = q @ dv.T
    """)
    pragmas = parse_pragmas("core/x.py", src.splitlines())
    assert len(pragmas) == 1
    assert (pragmas[0].applies_to, pragmas[0].applies_end) == (3, 5)


# --------------------------------------------------------------------------
# the repo itself is the final fixture: strict-clean, audited
# --------------------------------------------------------------------------

def test_repo_is_strict_clean():
    report = run_analysis(REPO_ROOT, strict=True)
    assert report.ok, "\n" + report.format()
    # every suppression in the tree carries a justification
    used = [p for p in report.pragmas if p.used]
    assert used, "expected the documented suppressions to be present"
    assert all(p.justification for p in used)


def test_checked_in_audit_is_current():
    report = run_analysis(REPO_ROOT, strict=True)
    audit_path = os.path.join(REPO_ROOT, "docs", "ANALYSIS_AUDIT.md")
    with open(audit_path, encoding="utf-8") as fh:
        assert fh.read() == render_audit(report), (
            "docs/ANALYSIS_AUDIT.md is stale — regenerate with "
            "PYTHONPATH=src python -m repro.analysis "
            "--write-audit docs/ANALYSIS_AUDIT.md"
        )


# --------------------------------------------------------------------------
# CLI exit-code contract
# --------------------------------------------------------------------------

def _cli(*args, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=cwd or REPO_ROOT,
    )


def test_cli_exit0_on_clean_repo_strict():
    proc = _cli("--strict", "--root", REPO_ROOT)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_exit1_on_failing_fixture(tmp_path):
    root = _fixture_tree(tmp_path, {"core/engine.py": """
        def score(q, dv):
            return q @ dv.T
    """})
    proc = _cli("--root", root)
    assert proc.returncode == 1
    assert "unpinned-reduction" in proc.stdout


def test_cli_exit3_on_audit_drift(tmp_path):
    root = _fixture_tree(tmp_path, {"core/clean.py": "x = 1\n"})
    stale = tmp_path / "audit.md"
    stale.write_text("# not the audit\n")
    proc = _cli("--root", root, "--check-audit", str(stale))
    assert proc.returncode == 3
    # and --write-audit repairs it
    proc = _cli("--root", root, "--write-audit", str(stale))
    assert proc.returncode == 0
    proc = _cli("--root", root, "--check-audit", str(stale))
    assert proc.returncode == 0


# --------------------------------------------------------------------------
# runtime sanitizers: NaN guard
# --------------------------------------------------------------------------

def test_nan_guard_off_by_default():
    vals = np.array([[1.0, np.nan]], np.float32)
    sanitizers.check_finite_scores(vals, 1, "test")  # silently passes


def test_nan_guard_catches_injection_and_ignores_padding():
    sanitizers.enable(True)
    ok = np.array([[1.0, 0.5], [-np.inf, -np.inf]], np.float32)
    # row 1 is bucket padding (n_rows=1): -inf sentinels are legitimate
    sanitizers.check_finite_scores(ok, 1, "test")
    for poison in (np.nan, np.inf, -np.inf):
        bad = np.array([[1.0, poison]], np.float32)
        with pytest.raises(sanitizers.SanitizerError, match="non-finite"):
            sanitizers.check_finite_scores(bad, 1, "test")


def test_nan_guard_fires_through_results_from_topk():
    from repro.core.engine import results_from_topk
    sanitizers.enable(True)
    vals = np.array([[1.0, np.nan]], np.float32)
    idx = np.array([[0, 1]], np.int32)
    cos = np.zeros_like(vals)
    ind = np.zeros_like(vals)
    with pytest.raises(sanitizers.SanitizerError):
        results_from_topk(["a", "b"], 1, vals, idx, cos, ind)
    # same call with the padded row poisoned instead: clean
    vals2 = np.array([[1.0, 0.5], [np.nan, np.nan]], np.float32)
    out = results_from_topk(
        ["a", "b"], 1, vals2, np.array([[0, 1], [0, 0]], np.int32),
        np.zeros((2, 2), np.float32), np.zeros((2, 2), np.float32),
    )
    assert len(out) == 1


# --------------------------------------------------------------------------
# runtime sanitizers: retrace guard
# --------------------------------------------------------------------------

def test_retrace_guard_detects_forced_retrace():
    import jax.numpy as jnp
    import jax
    sanitizers.enable(True)
    traced = jax.jit(lambda x: x * 2)
    sanitizers.register_jit("test.traced_fn", traced)
    try:
        traced(jnp.zeros((4,), jnp.float32))  # warm one shape
        guard = sanitizers.RetraceGuard()
        guard.arm()
        guard.check("steady")  # no growth: clean
        traced(jnp.zeros((8,), jnp.float32))  # forced retrace
        with pytest.raises(sanitizers.SanitizerError,
                           match="test.traced_fn"):
            guard.check("after-retrace")
        # baseline rebased: one regression raises once
        guard.check("rebased")
        assert guard.report() == {}
    finally:
        sanitizers._registry.pop("test.traced_fn", None)


def test_retrace_guard_disarmed_and_reset_paths():
    sanitizers.enable(True)
    guard = sanitizers.RetraceGuard()
    guard.check("unarmed")  # never raises before arm()
    guard.arm()
    assert guard.armed
    guard.reset()
    assert not guard.armed
    guard.check("after-reset")


# --------------------------------------------------------------------------
# steady-state serving loop: zero recompiles across bucket transitions
# (the satellite regression test — _warm/arm_sanitizers pins the
# bucket set; any flush size 1..max_batch must reuse compiled shapes)
# --------------------------------------------------------------------------

def test_serving_steady_state_has_zero_recompiles():
    from repro.core.ingest import KnowledgeBase
    from repro.data.corpus import make_corpus
    from repro.serving import ServingRuntime

    docs, entities = make_corpus(n_docs=24, n_entities=4, seed=3)
    kb = KnowledgeBase(dim=256)
    for i, d in enumerate(docs):
        kb.add_text(f"doc_{i:05d}.txt", d)
    queries = [f"lookup {e} status report" for e in entities]

    sanitizers.enable(True)
    rt = ServingRuntime(kb, max_batch=8, flush_deadline=0.001,
                        result_cache_size=0)
    with rt:
        rt.arm_sanitizers(k=3)
        assert rt.retrace_guard.armed
        # drive every batch size 1..max_batch through the scheduler —
        # each flush buckets to a warmed power-of-two shape, so the
        # armed guard must stay silent
        for size in range(1, rt.scheduler.max_batch + 1):
            futs = [rt.submit(queries[j % len(queries)], k=3)
                    for j in range(size)]
            for f in futs:
                f.result(timeout=60)  # raises if the guard tripped
        assert rt.retrace_guard.report() == {}
        # publish disarms (new generation may trace new shapes)
        kb.add_text("doc_new.txt", "fresh content about " + queries[0])
        rt.publish()
        assert not rt.retrace_guard.armed
