"""Paper §3.2–3.3: multimodal sniffing and O(U) incremental ingestion."""
import json
import os

import numpy as np

from repro.core import ingest
from repro.core.ingest import KnowledgeBase
from repro.core.retrieval import Retriever


def test_sniffing():
    assert ingest.sniff_modality(b"%PDF-1.7 ...") == "pdf"
    assert ingest.sniff_modality(b"\x89PNG\r\n") == "image"
    assert ingest.sniff_modality(b"\xff\xd8\xff\xe0") == "image"
    assert ingest.sniff_modality(b"PK\x03\x04") == "zip"
    assert ingest.sniff_modality(b'{"a": 1}') == "json"
    assert ingest.sniff_modality(b"a,b\n1,2", "t.csv") == "csv"
    assert ingest.sniff_modality(b"plain words") == "text"


def test_sniffing_whitespace_padded_json():
    """JSON behind >15 bytes of leading whitespace used to fall out of
    the 16-byte probe window and route to text."""
    data = b" " * 40 + b'{"deep": {"key": 1}}'
    assert ingest.sniff_modality(data[: ingest.SNIFF_WINDOW]) == "json"
    text, kind = ingest.extract(data)
    assert kind == "json" and "deep.key: 1" in text


def test_sniffing_csv_with_bracket_cell():
    """A CSV whose first cell starts with '[' used to hit the JSON
    structural probe before the extension hint."""
    data = b"[tag],value\n[a],1\n[b],2"
    assert ingest.sniff_modality(data, "rows.csv") == "csv"
    text, kind = ingest.extract(data, "rows.csv")
    assert kind == "csv" and "[tag]=[a]" in text and "value=2" in text
    # without the extension hint the structural probe still applies
    assert ingest.sniff_modality(b'["x", "y"]') == "json"


def test_sniffing_json_extension_hint():
    assert ingest.sniff_modality(b"  \n 1234", "data.json") == "json"
    assert ingest.sniff_modality(b"whatever", "log.jsonl") == "json"


def test_csv_overflow_cells_preserved():
    """Rows longer than the header keep their tail as positional colN=
    cells instead of being zip-truncated away."""
    data = b"a,b\n1,2,OVERFLOW-77,9"
    text, kind = ingest.extract(data, "t.csv")
    assert kind == "csv"
    assert text == "a=1, b=2, col2=OVERFLOW-77, col3=9"


def test_extractors():
    text, kind = ingest.extract(b'{"name": "ada", "tags": ["x", "y"]}')
    assert kind == "json" and "name: ada" in text and "tags[0]: x" in text
    text, kind = ingest.extract(b"id,amount\n7,42\n8,99", "x.csv")
    assert kind == "csv"
    assert "id=7" in text and "amount=42" in text  # headers preserved
    text, kind = ingest.extract(b"%PDF-1.4 binarybits")
    assert kind == "pdf" and "pdf-frontend-stub" in text


def _write(d, name, content):
    with open(os.path.join(d, name), "w") as f:
        f.write(content)


def test_incremental_o_of_u(tmp_path):
    src = str(tmp_path / "src")
    os.makedirs(src)
    for i in range(30):
        _write(src, f"f{i}.txt", f"document number {i} about topic{i % 5}")
    kb = KnowledgeBase(dim=512)
    s_cold = kb.sync(src)
    assert s_cold.added == 30 and s_cold.skipped == 0

    s_warm = kb.sync(src)
    assert s_warm.processed == 0 and s_warm.skipped == 30

    _write(src, "f3.txt", "totally new content INV-2024")
    _write(src, "f31.txt", "a brand new file")
    os.unlink(os.path.join(src, "f9.txt"))
    s_delta = kb.sync(src)
    assert s_delta.updated == 1 and s_delta.added == 1
    assert s_delta.removed == 1 and s_delta.skipped == 28
    assert kb.n_docs == 30

    # retrieval reflects the delta
    r = Retriever(kb)
    assert r.query("INV-2024", k=1)[0].doc_id == "f3.txt"
    assert all(x.doc_id != "f9.txt" for x in r.query("topic4", k=30))


def test_same_content_rename_reprocessed_as_new_path(tmp_path):
    src = str(tmp_path / "src")
    os.makedirs(src)
    _write(src, "a.txt", "same content")
    kb = KnowledgeBase(dim=512)
    kb.sync(src)
    os.rename(os.path.join(src, "a.txt"), os.path.join(src, "b.txt"))
    s = kb.sync(src)
    assert s.added == 1 and s.removed == 1


def test_container_roundtrip_arms_stat_fast_path(tmp_path, monkeypatch):
    """Regression: save() used to drop DocRecord.size/mtime_ns, so the
    first sync() after reopening a container re-hashed every file.  A
    save → load → sync round-trip on an unchanged directory must skip
    every doc without a single file read (O(stat) fast path armed)."""
    import builtins

    src = str(tmp_path / "src")
    os.makedirs(src)
    for i in range(12):
        _write(src, f"f{i}.txt", f"document number {i}")
    kb = KnowledgeBase(dim=512)
    kb.sync(src)
    path = str(tmp_path / "kb.ragdb")
    kb.save(path)

    kb2 = KnowledgeBase.load(path)
    for rec in kb2.records.values():
        assert rec.size >= 0 and rec.mtime_ns >= 0  # persisted, not -1

    reads = []
    real_open = builtins.open

    def counting_open(file, mode="r", *a, **k):
        if "r" in mode and "b" in mode:
            reads.append(file)
        return real_open(file, mode, *a, **k)

    monkeypatch.setattr(builtins, "open", counting_open)
    stats = kb2.sync(src)
    monkeypatch.undo()
    assert stats.skipped == 12 and stats.processed == 0
    assert reads == []  # zero file reads: stat-only


def test_pre_size_container_loads_and_rearms(tmp_path):
    """Backward compat: containers written before size/mtime_ns were
    persisted load with the fast path unarmed (-1), fall back to content
    hashing once, and re-arm it for the next sync."""
    from repro.core.container import Container, write_container

    src = str(tmp_path / "src")
    os.makedirs(src)
    for i in range(5):
        _write(src, f"f{i}.txt", f"document number {i}")
    kb = KnowledgeBase(dim=512)
    kb.sync(src)
    path = str(tmp_path / "kb.ragdb")
    kb.save(path)

    # strip the new meta keys to simulate an old container
    c = Container.open(path)
    meta = c.meta
    for d in meta["docs"]:
        d.pop("size", None)
        d.pop("mtime_ns", None)
    old = str(tmp_path / "old.ragdb")
    write_container(old, c.read_all(), meta, 0)

    kb2 = KnowledgeBase.load(old)
    assert all(r.size == -1 and r.mtime_ns == -1
               for r in kb2.records.values())
    s1 = kb2.sync(src)  # hash fallback: everything skipped by sha256
    assert s1.skipped == 5 and s1.processed == 0
    assert all(r.size >= 0 and r.mtime_ns >= 0
               for r in kb2.records.values())  # re-armed


def test_generation_roundtrip_and_monotonic_continuation(tmp_path):
    """Regression: Container.open parses the generation but load() used
    to discard it — a save/load round-trip reset the lineage the serving
    plane pins snapshots against.  It must survive the round-trip, and
    save()/save_delta() must continue it monotonically by default."""
    src = str(tmp_path / "src")
    os.makedirs(src)
    _write(src, "a.txt", "alpha")
    kb = KnowledgeBase(dim=512)
    kb.sync(src)
    path = str(tmp_path / "kb.ragdb")
    kb.save(path, generation=7)
    assert kb.loaded_generation == 7

    kb2 = KnowledgeBase.load(path)
    assert kb2.loaded_generation == 7  # restored, not dropped
    kb2.add_text("b.txt", "beta")
    kb2.save(path)  # default: continue the lineage
    assert kb2.loaded_generation == 8
    kb3 = KnowledgeBase.load(path)
    assert kb3.loaded_generation == 8
    kb3.add_text("c.txt", "gamma")
    assert kb3.save_delta(path) == 9  # delta continues it too
    assert KnowledgeBase.load(path).loaded_generation == 9


def test_fresh_kb_save_defaults_to_generation_zero(tmp_path):
    kb = KnowledgeBase(dim=512)
    kb.add_text("a.txt", "alpha")
    path = str(tmp_path / "kb.ragdb")
    kb.save(path)
    from repro.core.container import Container
    assert Container.open(path).generation == 0


def test_container_roundtrip_preserves_everything(tmp_path):
    src = str(tmp_path / "src")
    os.makedirs(src)
    _write(src, "a.txt", "alpha beta UNIQUE_CODE_7")
    _write(src, "b.json", json.dumps({"k": "gamma"}))
    kb = KnowledgeBase(dim=512)
    kb.sync(src)
    path = str(tmp_path / "kb.ragdb")
    kb.save(path, generation=3)

    kb2 = KnowledgeBase.load(path)
    assert kb2.n_docs == kb.n_docs
    assert kb2.records["a.txt"].sha256 == kb.records["a.txt"].sha256
    assert kb2.records["b.json"].modality == "json"
    m1, s1, i1 = kb.materialize()
    m2, s2, i2 = kb2.materialize()
    np.testing.assert_array_equal(m1, m2)
    np.testing.assert_array_equal(s1, s2)
    assert i1 == i2
    # and incremental sync continues to work post-restore
    s = kb2.sync(src)
    assert s.processed == 0 and s.skipped == 2
