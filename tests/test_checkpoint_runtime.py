"""Fault-tolerance substrate: checkpoint exactness, crash atomicity,
restart planning, elastic re-sharding, straggler detection, and the full
kill-restore-replay determinism cycle."""
import os

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.checkpoint import Checkpointer
from repro.data.pipeline import DataCursor, lm_batch
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.runtime.elastic import rebalance_corpus
from repro.runtime.fault import HeartbeatTable, plan_restart
from repro.runtime.straggler import StragglerDetector


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
                   "b": jnp.asarray(rng.normal(size=4).astype(np.float32))},
        "opt": {"m": jnp.zeros((8, 4)), "step": jnp.asarray(7, jnp.int32)},
    }


def test_checkpoint_bit_exact(tmp_path):
    ck = Checkpointer(str(tmp_path / "ck"))
    state = _state()
    ck.save(42, state)
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
    )
    restored, step = ck.restore(template)
    assert step == 42
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_async_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path / "ck"))
    ck.save_async(1, _state(1))
    ck.save_async(2, _state(2))
    ck.wait()
    assert ck.latest_step() == 2
    restored, step = ck.restore(_state(2))
    assert step == 2
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(_state(2)["params"]["w"]),
    )


def test_crash_during_save_preserves_previous(tmp_path):
    """Partial shard files never corrupt the published generation."""
    root = str(tmp_path / "ck")
    ck = Checkpointer(root)
    ck.save(1, _state(1))
    # simulate a crash: stray temp + partial shard dropped into the dir
    open(os.path.join(root, ".shard-9-0.ragdb"), "wb").write(b"partial")
    open(os.path.join(root, ".manifest-tmp-x"), "w").write("{}")
    restored, step = ck.restore(_state(1))
    assert step == 1


def test_restart_replay_determinism(tmp_path):
    """Kill at step 5, restore, replay data from cursor → identical
    params at step 8 as the uninterrupted run."""
    def train(upto, ck=None, resume_from=None):
        params = {"w": jnp.zeros((16,))}
        opt = adamw_init(params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        cursor = DataCursor(seed=123)
        start = 0
        if resume_from is not None:
            template = {"params": params, "opt": opt}
            state, step = resume_from.restore(template)
            params, opt = state["params"], state["opt"]
            cursor.step = step  # replay data stream from the cursor
            start = step
        for s in range(start, upto):
            toks, tgts = lm_batch(cursor, batch=2, seq=8, vocab=16)
            g = jax.grad(
                lambda p: jnp.mean(
                    jnp.square(p["w"][tgts.reshape(-1) % 16].sum()
                               - toks.sum())
                )
            )(params)
            params, opt = adamw_update(g, opt, params, cfg)
            if ck is not None and s == 4:
                ck.save(5, {"params": params, "opt": opt})
        return params

    straight = train(8)
    ck = Checkpointer(str(tmp_path / "ck"))
    train(5, ck=ck)
    resumed = train(8, resume_from=ck)
    np.testing.assert_array_equal(np.asarray(straight["w"]),
                                  np.asarray(resumed["w"]))


def test_heartbeat_and_restart_plan():
    t = HeartbeatTable(timeout=10.0)
    for w in ["w0", "w1", "w2", "w3"]:
        t.beat(w, now=100.0)
    t.beat("w1", now=105.0)
    assert t.dead_workers(now=112.0) == ["w0", "w2", "w3"]
    plan = plan_restart(t, chips_per_worker=64, model_parallel=16,
                        latest_ckpt_step=500, now=112.0)
    assert plan.survivors == ("w1",)
    assert plan.mesh_shape == (4, 16)  # 64 chips → dp=4
    assert plan.restore_step == 500
    assert plan.data_cursor_step == 500


@settings(max_examples=40, deadline=None)
@given(
    n_shards=st.integers(1, 40),
    n_old=st.integers(1, 10),
    n_new=st.integers(1, 10),
    seed=st.integers(0, 999),
)
def test_elastic_rebalance_properties(n_shards, n_old, n_new, seed):
    rng = np.random.default_rng(seed)
    old_workers = [f"w{i}" for i in range(n_old)]
    new_workers = [f"w{i}" for i in rng.choice(
        range(n_old + n_new), size=max(1, n_new), replace=False)]
    owners = {i: old_workers[rng.integers(0, n_old)] for i in range(n_shards)}
    moves = rebalance_corpus(owners, new_workers)
    final = dict(owners)
    for mv in moves:
        final[mv.shard_index] = mv.dst
    # every shard ends on a live worker
    assert all(w in new_workers for w in final.values())
    # balanced: max load − min load ≤ 1
    loads = {w: 0 for w in new_workers}
    for w in final.values():
        loads[w] += 1
    assert max(loads.values()) - min(loads.values()) <= 1
    # shards already on surviving, under-target workers did not move
    surviving = set(new_workers)
    for mv in moves:
        assert not (owners[mv.shard_index] == mv.dst)


def test_straggler_detection():
    d = StragglerDetector(alpha=0.5, threshold=1.4, min_samples=3)
    for step in range(10):
        for w in ["a", "b", "c", "d"]:
            d.observe(w, 1.0 if w != "c" else 2.5)
    assert d.stragglers() == ["c"]
