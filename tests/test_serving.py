"""Serving runtime contracts (serving/): the micro-batching scheduler
returns exactly what a direct engine call would, generation-pinned
snapshots give torn-read-free serving under live ingest, the result
cache never crosses generations, backpressure is explicit, and the
KnowledgeBase single-writer contract is asserted."""
import os
import threading
import time

import pytest

from repro.core.engine import QueryEngine
from repro.core.ingest import KnowledgeBase
from repro.data.corpus import make_corpus, write_corpus_dir
from repro.serving import (
    MicroBatchScheduler,
    RequestRejected,
    ResultCache,
    ServingMetrics,
    ServingRuntime,
    SnapshotManager,
)
from repro.serving.metrics import LatencyHistogram

from conftest import assert_bit_identical


def _kb(n_docs=40, dim=256, n_entities=6, seed=0):
    docs, entities = make_corpus(n_docs=n_docs, n_entities=n_entities,
                                 seed=seed)
    kb = KnowledgeBase(dim=dim)
    for i, d in enumerate(docs):
        kb.add_text(f"doc_{i:05d}.txt", d)
    return kb, entities


# --------------------------------------------------------------------------
# scheduler: results identical to a direct engine call
# --------------------------------------------------------------------------

def test_scheduled_results_match_direct_engine():
    kb, entities = _kb()
    runtime = ServingRuntime(kb, max_batch=8, flush_deadline=0.002,
                             result_cache_size=0)
    engine = QueryEngine(kb)
    queries = [*entities, "quarterly forecast", "unrelated text", ""]
    with runtime:
        futs = [(q, k, runtime.submit(q, k=k))
                for k in (3, 5) for q in queries]
        for q, k, fut in futs:
            served = fut.result(timeout=60)
            want = engine.query_batch([q], k=k)[0]
            assert_bit_identical([served.results], [want],
                                 label=f"{q!r} k={k}")
            assert served.generation == runtime.generation


def test_runtime_query_batch_blocking_facade():
    kb, entities = _kb(n_docs=20)
    engine = QueryEngine(kb)
    queries = list(entities)[:4]
    with ServingRuntime(kb, max_batch=4) as runtime:
        got = runtime.query_batch(queries, k=2)
    assert_bit_identical(got, engine.query_batch(queries, k=2))


def test_scheduler_coalesces_duplicate_queries():
    kb, entities = _kb(n_docs=20)
    code = next(iter(entities))
    sched = MicroBatchScheduler(SnapshotManager(kb), max_batch=16,
                                flush_deadline=0.01)
    # fill the queue before starting the flusher: one flush, one batch
    futs = [sched.submit(code, k=3) for _ in range(5)]
    futs.append(sched.submit(code.lower(), k=3))  # same canonical text
    futs.append(sched.submit("something else", k=3))
    with sched:
        done = [f.result(timeout=60) for f in futs]
    m = sched.metrics.snapshot()
    assert m["batches"] == 1
    assert m["batch_occupancy_mean"] == 7.0
    assert m["scored_queries"] == 2  # 7 requests, 2 distinct queries
    for d in done[:6]:
        assert_bit_identical([d.results], [done[0].results])


def test_scheduler_backpressure_rejects_when_full():
    kb, _ = _kb(n_docs=10)
    sched = MicroBatchScheduler(SnapshotManager(kb), max_batch=4,
                                max_queue=2)
    ok = [sched.submit("q1"), sched.submit("q2")]  # queue now full
    with pytest.raises(RequestRejected):
        sched.submit("q3")
    assert sched.metrics.snapshot()["rejected"] == 1
    with sched:  # admitted requests still complete
        for f in ok:
            assert f.result(timeout=60).results


def test_scheduler_stop_rejects_queued_and_new_requests():
    kb, _ = _kb(n_docs=10)
    sched = MicroBatchScheduler(SnapshotManager(kb))
    fut = sched.submit("never served")
    sched.stop()  # never started: queued request must not hang forever
    with pytest.raises(RequestRejected):
        fut.result(timeout=5)
    with pytest.raises(RequestRejected):
        sched.submit("after stop")


# --------------------------------------------------------------------------
# generation-pinned snapshots
# --------------------------------------------------------------------------

def test_snapshot_pins_generation_across_mutations():
    kb, entities = _kb(n_docs=25)
    code = next(iter(entities))
    manager = SnapshotManager(kb)
    snap0 = manager.current
    before = snap0.query_batch([code, "TORN-1111"], k=3)

    kb.add_text("torn_doc", "fresh document about TORN-1111 exactly")
    snap1 = manager.publish()
    assert snap1.generation > snap0.generation
    assert manager.current is snap1

    # the pinned snapshot still serves generation g bit-identically …
    again = snap0.query_batch([code, "TORN-1111"], k=3)
    assert_bit_identical(before, again)
    assert all(r.doc_id != "torn_doc" for r in again[1])
    # … while the published one sees the new generation
    top = snap1.query_batch(["TORN-1111"], k=1)[0][0]
    assert top.doc_id == "torn_doc" and top.boosted


def test_snapshot_matches_engine_frozen_at_same_generation():
    """A snapshot's query vectors come from its own idf copy: results
    equal a direct engine on a KB frozen at that generation, even after
    the live KB's df statistics move on."""
    kb, entities = _kb(n_docs=30)
    queries = [*list(entities)[:3], "generic filler query"]
    frozen = QueryEngine(kb)
    want = frozen.query_batch(queries, k=4)  # engine at generation g

    manager = SnapshotManager(kb)
    snap = manager.current
    for i in range(5):  # shift idf hard after the pin
        kb.add_text(f"noise_{i}", f"noise document {i} about filler query")
    got = snap.query_batch(queries, k=4)
    assert_bit_identical(got, want)


def test_publish_is_noop_without_mutations():
    kb, _ = _kb(n_docs=10)
    manager = SnapshotManager(kb)
    snap = manager.current
    assert manager.publish() is snap  # same object: no spurious swap


def test_snapshot_pins_frozen_ivf_index_per_generation():
    """The clustered index is pinned exactly like the doc arrays: a
    snapshot captured at generation g keeps serving g's IVFIndex object
    (maintenance only rebinds engine.ivf), so readers never observe a
    half-retrained index and pinned results stay bit-stable."""
    kb, entities = _kb(n_docs=60)
    code = next(iter(entities))
    manager = SnapshotManager(kb, scoring_path="map", index="ivf",
                              nprobe=2, guarantee="exact")
    snap0 = manager.current
    assert snap0.index_kind == "ivf" and snap0.ivf is not None
    before = snap0.query_batch([code, "PINNED-9090"], k=3)

    kb.add_text("pinned_doc", "fresh document about PINNED-9090 exactly")
    snap1 = manager.publish()
    assert snap1.ivf is not snap0.ivf  # maintenance rebound the index
    assert snap1.ivf is manager.engine.ivf  # the live reference moved on

    again = snap0.query_batch([code, "PINNED-9090"], k=3)
    assert_bit_identical(before, again)  # g's index still serves g's results
    assert all(r.doc_id != "pinned_doc" for r in again[1])
    top = snap1.query_batch(["PINNED-9090"], k=1)[0][0]
    assert top.doc_id == "pinned_doc" and top.boosted
    # the pinned snapshots match a flat engine frozen at each generation
    flat_now = QueryEngine(kb, scoring_path="map")
    assert_bit_identical(snap1.query_batch([code], k=3),
                         flat_now.query_batch([code], k=3))


# --------------------------------------------------------------------------
# result cache: (query, k, generation) keying
# --------------------------------------------------------------------------

def test_result_cache_generation_keying_and_lru():
    cache = ResultCache(capacity=2)
    cache.put("Q", 5, 1, ["r1"])
    assert cache.get("q", 5, 1) == ["r1"]  # canonicalized text
    assert cache.get("Q", 5, 2) is None    # new generation → miss
    assert cache.get("Q", 3, 1) is None    # different k → miss
    cache.put("other", 5, 1, ["r2"])
    cache.put("third", 5, 2, ["r3"])       # evicts LRU ("Q")
    assert cache.get("Q", 5, 1) is None
    assert cache.evict_generations_before(2) == 1  # drops "other"@gen1
    assert len(cache) == 1


def test_result_cache_evict_generations_before():
    """The hygiene hook drops exactly the entries pinned below the
    cutoff, keeps the rest queryable, and is idempotent."""
    cache = ResultCache(capacity=16)
    for gen in (1, 1, 2, 3):
        cache.put(f"q{gen}", 5, gen, [f"r{gen}"])
    cache.put("q1b", 5, 1, ["r1b"])
    assert len(cache) == 4  # ("q1",1) was overwritten by the dup put
    assert cache.evict_generations_before(3) == 3  # both gen-1 + gen-2
    assert len(cache) == 1
    assert cache.get("q3", 5, 3) == ["r3"]
    assert cache.get("q1", 5, 1) is None
    assert cache.evict_generations_before(3) == 0  # idempotent
    # eviction never touches the hit/miss counters' consistency
    s = cache.stats()
    assert s["hits"] == 1 and s["misses"] == 1


def test_result_cache_capacity_eviction_is_lru_ordered():
    """Capacity pressure evicts least-recently-*used*, not
    least-recently-inserted: a get() refreshes recency, and a put() to
    an existing key does too."""
    cache = ResultCache(capacity=3)
    cache.put("a", 5, 1, ["a"])
    cache.put("b", 5, 1, ["b"])
    cache.put("c", 5, 1, ["c"])
    assert cache.get("a", 5, 1) == ["a"]   # a → most recent
    cache.put("d", 5, 1, ["d"])            # evicts b (LRU), not a
    assert cache.get("b", 5, 1) is None
    assert cache.get("a", 5, 1) == ["a"]
    cache.put("c", 5, 1, ["c2"])           # refresh c by re-put
    cache.put("e", 5, 1, ["e"])            # evicts d (now LRU)
    assert cache.get("d", 5, 1) is None
    assert cache.get("c", 5, 1) == ["c2"]
    assert len(cache) == 3


def test_result_cache_keyspaces_scope_generation_eviction():
    """Regression: ``evict_generations_before`` used to be global —
    one tenant's publish would sweep another tenant's entries pinned
    to *its own* (unrelated) generation counter.  Scoped semantics:
    only the named keyspace is swept, even with interleaved puts."""
    cache = ResultCache(capacity=16)
    # interleave two keyspaces across the same generation numbers
    for gen in (1, 2, 3):
        cache.put(f"qa{gen}", 5, gen, [f"a{gen}"], keyspace="alice")
        cache.put(f"qb{gen}", 5, gen, [f"b{gen}"], keyspace="bob")
    assert cache.evict_generations_before(3, keyspace="alice") == 2
    # alice keeps only gen-3; bob is untouched at every generation
    assert cache.get("qa3", 5, 3, keyspace="alice") == ["a3"]
    assert cache.get("qa1", 5, 1, keyspace="alice") is None
    for gen in (1, 2, 3):
        assert cache.get(f"qb{gen}", 5, gen, keyspace="bob") == [f"b{gen}"]
    # same (text, k, generation) key in two keyspaces: distinct entries
    cache.put("shared", 5, 3, ["alice's"], keyspace="alice")
    cache.put("shared", 5, 3, ["bob's"], keyspace="bob")
    assert cache.get("shared", 5, 3, keyspace="alice") == ["alice's"]
    assert cache.get("shared", 5, 3, keyspace="bob") == ["bob's"]
    assert cache.stats()["keyspaces"] == 2


def test_result_cache_capacity_is_per_keyspace():
    """A hot keyspace filling its own LRU never evicts a cold
    keyspace's entries (capacity accounting is scoped too)."""
    cache = ResultCache(capacity=2)
    cache.put("cold", 5, 1, ["kept"], keyspace="bob")
    for i in range(10):  # alice churns way past capacity
        cache.put(f"hot{i}", 5, 1, [i], keyspace="alice")
    assert cache.get("cold", 5, 1, keyspace="bob") == ["kept"]
    assert len(cache) == 3  # 2 alice + 1 bob
    # drop_keyspace removes wholesale and reports the count
    assert cache.drop_keyspace("alice") == 2
    assert cache.drop_keyspace("alice") == 0
    assert cache.get("cold", 5, 1, keyspace="bob") == ["kept"]


def test_result_cache_counters_consistent_under_concurrent_access():
    """hits + misses must equal total get() calls even under concurrent
    get/put from many threads (the counters sit inside the lock)."""
    cache = ResultCache(capacity=32)
    n_threads, n_ops = 8, 400
    errors = []

    def worker(tid):
        try:
            for i in range(n_ops):
                key = f"q{(tid * n_ops + i) % 16}"  # overlap across threads
                if cache.get(key, 5, 1) is None:
                    cache.put(key, 5, 1, [key])
        except Exception as exc:  # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    s = cache.stats()
    assert s["hits"] + s["misses"] == n_threads * n_ops
    assert s["hits"] > 0 and s["misses"] > 0
    assert len(cache) <= 32


def test_runtime_cache_hit_serves_same_generation_results():
    kb, entities = _kb(n_docs=20)
    code = next(iter(entities))
    with ServingRuntime(kb, flush_deadline=0.001) as runtime:
        first = runtime.submit(code, k=3).result(timeout=60)
        second = runtime.submit(code, k=3).result(timeout=60)
        assert second.cached and not first.cached
        assert_bit_identical([first.results], [second.results])
        assert second.generation == first.generation

        # a publish invalidates naturally: new generation → fresh miss
        kb.add_text("shift", f"new doc mentioning {code} loudly")
        runtime.publish()
        third = runtime.submit(code, k=3).result(timeout=60)
        assert not third.cached
        assert third.generation > first.generation
    m = runtime.metrics.snapshot()
    assert m["cache_hits"] == 1 and m["cache_misses"] == 2


# --------------------------------------------------------------------------
# metrics plane
# --------------------------------------------------------------------------

def test_latency_histogram_percentiles():
    h = LatencyHistogram()
    for ms in range(1, 101):  # 1..100 ms uniform
        h.record(ms / 1e3)
    assert h.n == 100
    # log-bucket quantization error is bounded by one growth step
    assert 0.050 * 0.8 <= h.percentile(50) <= 0.050 * 1.25
    assert 0.099 * 0.8 <= h.percentile(99) <= 0.100 * 1.25
    assert h.percentile(100) == pytest.approx(h.max)
    assert h.mean == pytest.approx(0.0505)


def test_metrics_snapshot_counters():
    m = ServingMetrics()
    m.on_submit()
    m.on_submit()
    m.on_batch(2, 1)
    m.on_complete(0.010)
    m.on_complete(0.020)
    m.on_reject()
    s = m.snapshot()
    assert s["requests"] == 2 and s["completed"] == 2
    assert s["rejected"] == 1
    assert s["batches"] == 1 and s["batch_occupancy_mean"] == 2.0
    assert s["scored_queries"] == 1
    assert 0 < s["latency_p50_ms"] < 30
    m.reset()
    assert m.snapshot()["requests"] == 0


# --------------------------------------------------------------------------
# KnowledgeBase single-writer contract
# --------------------------------------------------------------------------

def test_kb_mutations_assert_single_writer(tmp_path):
    kb = KnowledgeBase(dim=256)
    kb.add_text("a", "first document")
    # simulate a second in-flight writer holding the mutation lock
    assert kb._write_lock.acquire(blocking=False)
    try:
        with pytest.raises(RuntimeError, match="single-writer"):
            kb.add_text("b", "competing writer")
        with pytest.raises(RuntimeError, match="single-writer"):
            kb.sync(str(tmp_path))
    finally:
        kb._write_lock.release()
    kb.add_text("b", "writer released: fine again")
    assert kb.n_docs == 2


def test_kb_concurrent_second_writer_raises(tmp_path, monkeypatch):
    """A real second thread mutating mid-sync trips the guard."""
    src = str(tmp_path / "corpus")
    docs, _ = make_corpus(n_docs=5, n_entities=2, seed=2)
    write_corpus_dir(src, docs)
    kb = KnowledgeBase(dim=256)

    in_sync = threading.Event()
    release = threading.Event()
    orig_walk = os.walk

    def stalled_walk(d):
        in_sync.set()
        assert release.wait(timeout=30)
        return orig_walk(d)

    monkeypatch.setattr(os, "walk", stalled_walk)
    t = threading.Thread(target=kb.sync, args=(src,))
    t.start()
    try:
        assert in_sync.wait(timeout=30)
        with pytest.raises(RuntimeError, match="single-writer"):
            kb.add_text("intruder", "second writer while sync runs")
    finally:
        release.set()
        t.join()
    assert kb.n_docs == 5  # the legitimate sync completed


# --------------------------------------------------------------------------
# THE stress test: concurrent queries + live sync, zero torn reads
# --------------------------------------------------------------------------

N_READERS = 4
N_ROUNDS = 6


def test_concurrent_serving_with_live_sync_is_torn_read_free(tmp_path):
    """≥4 reader threads query through the scheduler while a single
    writer thread continuously mutates the corpus, syncs, and publishes.
    Every served result must be (a) bit-identical to a direct
    ``QueryEngine.query_batch`` on the KB state at the pinned
    generation, and (b) attributable to a *published* generation — a
    partially refreshed snapshot would fail both."""
    src = str(tmp_path / "corpus")
    docs, entities = make_corpus(n_docs=40, n_entities=6, seed=1)
    write_corpus_dir(src, docs)
    kb = KnowledgeBase(dim=256)
    kb.sync(src)

    runtime = ServingRuntime(kb, max_batch=8, flush_deadline=0.002,
                             result_cache_size=0)  # force real scoring
    containers: dict[int, str] = {}  # generation → frozen KB container

    def save_generation(gen: int) -> None:
        path = str(tmp_path / f"gen_{gen}.ragdb")
        kb.save(path, generation=gen)
        containers[gen] = path

    save_generation(runtime.generation)
    queries = [*entities, "escalation runbook", "quarterly forecast",
               "LIVE-7777"]
    # warm the jit caches so readers overlap every generation below
    with runtime:
        runtime.query_batch(queries[:2], k=3)

        served = []  # (query, k, ServedResult)
        served_lock = threading.Lock()
        stop = threading.Event()

        def reader(rid: int):
            i = rid
            while not stop.is_set():
                q = queries[i % len(queries)]
                k = 3 if (i % 2) else 5
                i += 1
                res = runtime.submit(q, k=k).result(timeout=120)
                with served_lock:
                    served.append((q, k, res))

        threads = [threading.Thread(target=reader, args=(r,))
                   for r in range(N_READERS)]
        for t in threads:
            t.start()

        # the single writer: mutate files → sync → freeze → publish
        for rnd in range(N_ROUNDS):
            with open(os.path.join(src, f"doc_{rnd:05d}.txt"), "a") as f:
                f.write(f" LIVE-7777 edit round {rnd}")
            if rnd % 2:
                with open(os.path.join(src, f"extra_{rnd}.txt"), "w") as f:
                    f.write(f"brand new doc in round {rnd}")
            if rnd == 4:
                os.unlink(os.path.join(src, "doc_00030.txt"))
            kb.sync(src)
            save_generation(kb.version)
            gen = runtime.publish()
            assert gen == kb.version
            time.sleep(0.05)  # let readers overlap this generation

        stop.set()
        for t in threads:
            t.join()

    assert len(served) >= 4 * N_ROUNDS  # readers really overlapped
    observed = {res.generation for _, _, res in served}
    # (b) every request came from a published generation
    assert observed <= set(containers), (
        f"torn read: generations {observed - set(containers)} were never "
        "published"
    )
    assert len(observed) >= 2  # the run actually spanned generations

    # (a) bit-identical to a direct engine call at the pinned generation
    references = {
        gen: QueryEngine(KnowledgeBase.load(containers[gen]))
        for gen in observed
    }
    for q, k, res in served:
        want = references[res.generation].query_batch([q], k=k)[0]
        assert_bit_identical([res.results], [want], label=(
            f"torn read: {q!r}@k={k} vs the engine at pinned "
            f"generation {res.generation}"
        ))
