"""Durable incremental persistence: journaled delta saves, crash
recovery, compaction, and the serving plane's durable publish.

The load-bearing contract: ``KnowledgeBase.load(path)`` after any mix of
``save``/``save_delta`` is **bit-identical** to a load after one full
``save()`` of the same state — matrix, signatures, postings, df, doc
order, texts, records and generation all match — and any torn/corrupted
journal tail replays cleanly to the last intact record.
"""
import os

import numpy as np
import pytest

from repro.core import container as C
from repro.core.engine import QueryEngine
from repro.core.ingest import KnowledgeBase
from repro.serving import ServingRuntime, SnapshotManager, results_equal

DIM = 256


def _mk_kb(n=30, dim=DIM):
    kb = KnowledgeBase(dim=dim)
    for i in range(n):
        kb.add_text(f"doc{i:03d}.txt", f"document number {i} about topic{i % 7}")
    return kb


def _fingerprint(kb):
    matrix, sigs, ids = kb.materialize()
    p = kb.postings()
    return {
        "ids": ids,
        "matrix": matrix,
        "sigs": sigs,
        "df": kb.vectorizer.df.copy(),
        "n_docs_vec": kb.vectorizer.n_docs,
        "texts": dict(kb.texts),
        "records": {k: vars(r).copy() for k, r in kb.records.items()},
        "post_terms": p.term_hashes,
        "post_offsets": p.offsets,
        "post_docs": p.doc_ids,
        "generation": kb.loaded_generation,
    }


def _assert_identical(a, b, *, compare_generation=True):
    assert a["ids"] == b["ids"]
    np.testing.assert_array_equal(a["matrix"], b["matrix"])
    np.testing.assert_array_equal(a["sigs"], b["sigs"])
    np.testing.assert_array_equal(a["df"], b["df"])
    assert a["n_docs_vec"] == b["n_docs_vec"]
    assert a["texts"] == b["texts"]
    assert a["records"] == b["records"]
    np.testing.assert_array_equal(a["post_terms"], b["post_terms"])
    np.testing.assert_array_equal(a["post_offsets"], b["post_offsets"])
    np.testing.assert_array_equal(a["post_docs"], b["post_docs"])
    if compare_generation:
        assert a["generation"] == b["generation"]


def _apply_ops(kbs, rng, round_no):
    """Apply an identical random add/update/remove mix to every KB."""
    n_ops = int(rng.integers(1, 5))
    for op_no in range(n_ops):
        existing = sorted(kbs[0].records)
        op = rng.choice(["add", "update", "remove"])
        if op == "remove" and len(existing) > 3:
            victim = existing[int(rng.integers(len(existing)))]
            for kb in kbs:
                kb._remove_doc(victim)
        elif op == "update" and existing:
            victim = existing[int(rng.integers(len(existing)))]
            text = f"updated r{round_no} o{op_no} CODE-{rng.integers(1e6)}"
            for kb in kbs:
                kb.add_text(victim, text)
        else:
            name = f"new-r{round_no}-o{op_no}.txt"
            text = f"brand new content {rng.integers(1e6)} topic{op_no}"
            for kb in kbs:
                kb.add_text(name, text)


# --------------------------------------------------------------------------
# delta-vs-full bit identity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_delta_vs_full_save_bit_identity_sweep(tmp_path, seed):
    """Property-style sweep: after every round of random mutations, a
    load through the delta-journal chain equals a load of a fresh full
    save of the same state — including the container generation (both
    lineages advance one generation per publish)."""
    rng = np.random.default_rng(seed)
    p_delta = str(tmp_path / "delta.ragdb")
    p_full = str(tmp_path / "full.ragdb")
    kb_a = _mk_kb(20)
    kb_b = _mk_kb(20)
    kb_a.save(p_delta)  # generation 0 base
    for round_no in range(5):
        _apply_ops([kb_a, kb_b], rng, round_no)
        kb_a.save_delta(p_delta, compact_ratio=None)
        kb_b.save(p_full, generation=kb_a.loaded_generation)
        _assert_identical(
            _fingerprint(KnowledgeBase.load(p_delta)),
            _fingerprint(KnowledgeBase.load(p_full)),
        )


def test_removal_only_delta(tmp_path):
    p = str(tmp_path / "kb.ragdb")
    kb = _mk_kb(10)
    kb.save(p)
    kb._remove_doc("doc003.txt")
    kb._remove_doc("doc007.txt")
    gen = kb.save_delta(p, compact_ratio=None)
    out = KnowledgeBase.load(p)
    assert gen == 1 and out.loaded_generation == 1
    assert out.n_docs == 8
    assert "doc003.txt" not in out.records and "doc007.txt" not in out.records
    _assert_identical(_fingerprint(out), _fingerprint(kb) | {"generation": 1})


def test_delta_removals_survive_bounded_removal_log(tmp_path, monkeypatch):
    """save_delta derives removals from the persisted id set, not the
    advisory in-memory removal log — removals beyond REMOVED_LOG_MAX
    still persist."""
    monkeypatch.setattr(KnowledgeBase, "REMOVED_LOG_MAX", 2)
    p = str(tmp_path / "kb.ragdb")
    kb = _mk_kb(12)
    kb.save(p)
    for i in range(6):  # 6 removals through a 2-entry log
        kb._remove_doc(f"doc{i:03d}.txt")
    kb.save_delta(p, compact_ratio=None)
    out = KnowledgeBase.load(p)
    assert out.n_docs == 6
    assert not any(f"doc{i:03d}.txt" in out.records for i in range(6))


def test_rearmed_stat_keys_persist_through_delta(tmp_path, monkeypatch):
    """A touched-but-unchanged file re-arms its O(stat) fast-path keys
    in memory; save_delta must persist that metadata (content segments
    unchanged) or every load() re-hashes the file forever."""
    import builtins

    src = str(tmp_path / "src")
    os.makedirs(src)
    for i in range(6):
        with open(os.path.join(src, f"f{i}.txt"), "w") as f:
            f.write(f"document number {i}")
    p = str(tmp_path / "kb.ragdb")
    kb = KnowledgeBase(dim=DIM)
    kb.sync(src)
    kb.save(p)

    # touch: content identical, mtime_ns moves → stat check misses once
    now = os.stat(os.path.join(src, "f2.txt"))
    os.utime(os.path.join(src, "f2.txt"),
             ns=(now.st_atime_ns, now.st_mtime_ns + 1_000_000_000))
    s = kb.sync(src)
    assert s.skipped == 6 and s.processed == 0
    gen = kb.save_delta(p, compact_ratio=None)
    assert gen == 1  # the metadata change is worth a (tiny) record

    # recovery: the reloaded KB must sync with zero file reads
    kb2 = KnowledgeBase.load(p)
    reads = []
    real_open = builtins.open

    def counting_open(file, mode="r", *a, **k):
        if "r" in mode and "b" in mode:
            reads.append(file)
        return real_open(file, mode, *a, **k)

    monkeypatch.setattr(builtins, "open", counting_open)
    s2 = kb2.sync(src)
    monkeypatch.undo()
    assert s2.skipped == 6 and s2.processed == 0
    assert reads == []  # stat-only: the re-armed keys survived the delta


# --------------------------------------------------------------------------
# O(U) bytes contract
# --------------------------------------------------------------------------

def test_delta_bytes_are_o_of_u(tmp_path):
    p = str(tmp_path / "kb.ragdb")
    kb = _mk_kb(200)
    kb.save(p)
    full_bytes = os.path.getsize(p)
    kb.add_text("doc003.txt", "a one-doc update CODE-777")
    before = C.journal_size(p)
    kb.save_delta(p, compact_ratio=None)
    delta_bytes = C.journal_size(p) - before
    assert delta_bytes * 10 < full_bytes, (delta_bytes, full_bytes)
    # and the journaled state still loads to the updated content
    out = KnowledgeBase.load(p)
    assert "CODE-777" in out.texts["doc003.txt"]


def test_no_change_no_write(tmp_path):
    p = str(tmp_path / "kb.ragdb")
    kb = _mk_kb(5)
    kb.save(p)
    gen0 = kb.loaded_generation
    base = os.path.getsize(p)
    assert kb.save_delta(p) == gen0  # nothing changed
    assert C.journal_size(p) == 0 and os.path.getsize(p) == base


def test_save_delta_without_base_full_saves(tmp_path):
    p = str(tmp_path / "kb.ragdb")
    kb = _mk_kb(5)
    gen = kb.save_delta(p)
    assert gen == 0 and os.path.exists(p) and C.journal_size(p) == 0
    assert KnowledgeBase.load(p).n_docs == 5


# --------------------------------------------------------------------------
# crash recovery
# --------------------------------------------------------------------------

def _two_delta_setup(tmp_path):
    """Base + two committed delta records; returns (path, fingerprints
    after record 1 and record 2)."""
    p = str(tmp_path / "kb.ragdb")
    kb = _mk_kb(15)
    kb.save(p)
    kb.add_text("doc001.txt", "first delta CODE-111")
    kb.save_delta(p, compact_ratio=None)
    fp1 = _fingerprint(KnowledgeBase.load(p))
    kb.add_text("extra.txt", "second delta CODE-222")
    kb.save_delta(p, compact_ratio=None)
    fp2 = _fingerprint(KnowledgeBase.load(p))
    assert fp1["generation"] == 1 and fp2["generation"] == 2
    return p, fp1, fp2


def test_torn_append_truncated_tail_replays_to_last_intact(tmp_path):
    p, fp1, _ = _two_delta_setup(tmp_path)
    jp = C.journal_path(p)
    with open(jp, "r+b") as f:
        f.truncate(os.path.getsize(jp) - 7)  # torn mid-record-2
    _assert_identical(_fingerprint(KnowledgeBase.load(p)), fp1)


def test_flipped_byte_in_last_record_replays_to_last_intact(tmp_path):
    p, fp1, _ = _two_delta_setup(tmp_path)
    jp = C.journal_path(p)
    data = bytearray(open(jp, "rb").read())
    data[-3] ^= 0xFF
    open(jp, "wb").write(bytes(data))
    _assert_identical(_fingerprint(KnowledgeBase.load(p)), fp1)


def test_uncommitted_tail_is_invisible_and_reclaimed(tmp_path):
    """Bytes past the manifest's committed_bytes (a crash after the
    journal append but before the manifest rename) are ignored on
    replay and truncated away by the next successful append."""
    p, fp1, fp2 = _two_delta_setup(tmp_path)
    jp = C.journal_path(p)
    committed = C.read_journal_manifest(p)["committed_bytes"]
    with open(jp, "ab") as f:
        f.write(b"\xde\xad\xbe\xef" * 16)  # torn, never committed
    _assert_identical(_fingerprint(KnowledgeBase.load(p)), fp2)
    # next append truncates the garbage then commits cleanly
    kb = KnowledgeBase.load(p)
    kb.add_text("post-crash.txt", "third delta CODE-333")
    kb.save_delta(p, compact_ratio=None)
    man = C.read_journal_manifest(p)
    assert man["committed_bytes"] == os.path.getsize(jp) > committed
    out = KnowledgeBase.load(p)
    assert "post-crash.txt" in out.records and out.loaded_generation == 3


def test_stale_journal_from_previous_base_is_ignored(tmp_path):
    """A journal left beside a re-saved base (its manifest pins the old
    base's data_sha256) must not replay."""
    import shutil

    p, _, fp2 = _two_delta_setup(tmp_path)
    jp, mp = C.journal_path(p), C.journal_manifest_path(p)
    shutil.copy(jp, jp + ".bak")
    shutil.copy(mp, mp + ".bak")
    kb = KnowledgeBase.load(p)
    kb.add_text("doc002.txt", "content after the full re-save CODE-444")
    kb.save(p)  # folds + resets the journal
    fp_full = _fingerprint(KnowledgeBase.load(p))
    shutil.copy(jp + ".bak", jp)  # "crash" resurrects the stale chain
    shutil.copy(mp + ".bak", mp)
    _assert_identical(_fingerprint(KnowledgeBase.load(p)), fp_full)


# --------------------------------------------------------------------------
# compaction
# --------------------------------------------------------------------------

def test_explicit_compact_folds_journal_and_keeps_generation(tmp_path):
    p, _, fp2 = _two_delta_setup(tmp_path)
    assert C.journal_size(p) > 0
    kb = KnowledgeBase.load(p)
    kb.compact(p)
    assert C.journal_size(p) == 0
    assert kb.loaded_generation == 2  # state unchanged → generation kept
    _assert_identical(_fingerprint(KnowledgeBase.load(p)), fp2)


def test_auto_compaction_on_ratio(tmp_path):
    p = str(tmp_path / "kb.ragdb")
    kb = _mk_kb(10)
    kb.save(p)
    kb.add_text("doc001.txt", "update CODE-555")
    # ratio 0: any journal at all exceeds the threshold → immediate fold
    gen = kb.save_delta(p, compact_ratio=0.0)
    assert gen == 1 and C.journal_size(p) == 0
    out = KnowledgeBase.load(p)
    assert out.loaded_generation == 1
    assert "CODE-555" in out.texts["doc001.txt"]


# --------------------------------------------------------------------------
# serving plane: durable publish
# --------------------------------------------------------------------------

def test_durable_publish_survives_crash(tmp_path):
    p = str(tmp_path / "kb.ragdb")
    kb = _mk_kb(20)
    mgr = SnapshotManager(kb, container_path=p, scoring_path="map")
    mgr.publish(durable=True)  # first durable publish: full save
    assert os.path.exists(p)
    kb.add_text("fresh.txt", "pinned generation content INV-2077")
    snap = mgr.publish(durable=True)  # O(U) delta append
    assert C.journal_size(p) > 0

    # "crash": recover purely from disk; the published generation is there
    kb2 = KnowledgeBase.load(p)
    assert "fresh.txt" in kb2.records
    assert kb2.loaded_generation == kb.loaded_generation
    # recovered engine serves bit-identical results to the pinned snapshot
    eng = QueryEngine(kb2, scoring_path="map")
    assert results_equal(
        snap.query_batch(["INV-2077"], k=3)[0],
        eng.query_batch(["INV-2077"], k=3)[0],
    )


def test_durable_publish_requires_container_path():
    kb = _mk_kb(3)
    mgr = SnapshotManager(kb, scoring_path="map")
    with pytest.raises(ValueError, match="container_path"):
        mgr.publish(durable=True)


def test_ivf_index_survives_delta_load_publish_cycle(tmp_path, monkeypatch):
    """Acceptance bar for the clustered index plane: an IVF-indexed KB
    survives ``save_delta`` → ``load`` → ``publish(durable=True)`` with
    the index state replayed **bit-identically** — centroids,
    assignments, bounds, drift — and the loaded engine adopts it
    without a cold k-means retrain."""
    import repro.index.ivf as ivf_mod

    p = str(tmp_path / "kb.ragdb")
    kb = _mk_kb(40)
    eng = QueryEngine(kb, scoring_path="map", index="ivf", nprobe=2)
    kb.save(p)  # base: full save carries the ivf_* segments

    kb.add_text("doc005.txt", "rewritten five IDX-1111")
    kb.add_text("fresh.txt", "brand new doc IDX-2222")
    kb._remove_doc("doc011.txt")
    eng.refresh()  # reassigns rows + writes index state back to the KB
    kb.save_delta(p, compact_ratio=None)

    kb2 = KnowledgeBase.load(p)
    st1, st2 = kb.index_state, kb2.index_state
    assert st2 is not None
    for key in ("centroids", "assign", "radius", "sig_union"):
        np.testing.assert_array_equal(st1[key], st2[key])
    assert (st1["drift"], st1["trained_n"], st1["ids_sha"]) == \
        (st2["drift"], st2["trained_n"], st2["ids_sha"])

    # the loaded engine must adopt, never retrain (the whole point of
    # persisting the index): any k-means call here is a failure
    calls = []
    orig = ivf_mod.spherical_kmeans
    monkeypatch.setattr(ivf_mod, "spherical_kmeans",
                        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])
    eng2 = QueryEngine(kb2, scoring_path="map", index="ivf", nprobe=2)
    queries = ["IDX-1111", "IDX-2222", "topic3"]
    got = eng2.query_batch(queries, k=4)
    assert calls == []  # no cold retrain on load
    np.testing.assert_array_equal(eng2.ivf.assign, eng.ivf.assign)
    for a, b in zip(got, eng.query_batch(queries, k=4)):
        assert results_equal(a, b)

    # durable publish continues the chain: the published index replays
    mgr = SnapshotManager(kb2, engine=eng2, container_path=p,
                          compact_ratio=None)
    kb2.add_text("late.txt", "late doc IDX-3333")
    snap = mgr.publish(durable=True)
    kb3 = KnowledgeBase.load(p)
    assert kb3.loaded_generation == kb2.loaded_generation
    for key in ("centroids", "assign", "radius", "sig_union"):
        np.testing.assert_array_equal(kb3.index_state[key],
                                      kb2.index_state[key])
    eng3 = QueryEngine(kb3, scoring_path="map", index="ivf", nprobe=2)
    assert calls == []  # adoption again, not retraining
    assert results_equal(
        snap.query_batch(["IDX-3333"], k=3)[0],
        eng3.query_batch(["IDX-3333"], k=3)[0],
    )


def test_index_delta_omits_unchanged_centroids(tmp_path):
    """Centroids only change on retrain, so a reassign-only delta
    record must not re-journal the ~√N·D centroid segment — the
    replayed chain inherits it from the base (and still loads the full
    state bit-identically)."""
    p = str(tmp_path / "kb.ragdb")
    kb = _mk_kb(30)
    eng = QueryEngine(kb, scoring_path="map", index="ivf")
    kb.save(p)
    kb.add_text("doc004.txt", "reassign-only update IDX-4444")
    eng.refresh()
    assert not eng.refresh().index_retrained  # just a reassign
    kb.save_delta(p, compact_ratio=None)

    records = C.read_journal(p, C.Container.open(p).uid)
    assert len(records) == 1
    _, rmeta, rsegs = records[0]
    assert "index" in rmeta
    assert "ivf_centroids" not in rsegs       # omitted: chain carries it
    assert "ivf_assign" in rsegs
    out = KnowledgeBase.load(p)
    np.testing.assert_array_equal(out.index_state["centroids"],
                                  kb.index_state["centroids"])
    np.testing.assert_array_equal(out.index_state["assign"],
                                  kb.index_state["assign"])

    # a retrain re-journals the centroids in its delta record (corpus
    # growth past retrain_drift × trained_n deterministically triggers)
    for i in range(20):
        kb.add_text(f"grown{i:03d}.txt", f"fresh doc for retrain {i}")
    stats = eng.refresh()
    assert stats.index_retrained
    kb.save_delta(p, compact_ratio=None)
    records = C.read_journal(p, C.Container.open(p).uid)
    assert "ivf_centroids" in records[-1][2]
    np.testing.assert_array_equal(
        KnowledgeBase.load(p).index_state["centroids"],
        kb.index_state["centroids"])


def test_index_only_delta_record_persists_first_train(tmp_path):
    """Training an IVF engine over an already-persisted corpus changes
    *only* the index — save_delta must still emit a (tiny) record so a
    restart adopts instead of retraining."""
    p = str(tmp_path / "kb.ragdb")
    kb = _mk_kb(15)
    kb.save(p)
    assert KnowledgeBase.load(p).index_state is None  # no index yet
    QueryEngine(kb, scoring_path="map", index="ivf")  # trains + writes back
    gen = kb.save_delta(p, compact_ratio=None)
    assert gen == 1  # index-only mutation is worth a record
    out = KnowledgeBase.load(p)
    assert out.index_state is not None
    np.testing.assert_array_equal(out.index_state["assign"],
                                  kb.index_state["assign"])
    # replayed docs are still bit-identical to a plain full save
    _assert_identical(_fingerprint(out),
                      _fingerprint(kb) | {"generation": 1})


def test_serving_runtime_durable_passthrough(tmp_path):
    p = str(tmp_path / "kb.ragdb")
    kb = _mk_kb(10)
    with ServingRuntime(kb, container_path=p, scoring_path="map") as rt:
        rt.publish(durable=True)
        kb.add_text("late.txt", "late addition INV-31337")
        rt.publish(durable=True)
        assert rt.query_batch(["INV-31337"], k=1)[0][0].doc_id == "late.txt"
    out = KnowledgeBase.load(p)
    assert "late.txt" in out.records


# --------------------------------------------------------------------------
# crash matrix: durable publish triggered by tenant eviction
# --------------------------------------------------------------------------
#
# The tenancy pool's eviction contract (docs/ARCHITECTURE.md §13) is
# durability-before-teardown: evicting a tenant with unpersisted state
# runs a durable publish *first*.  A crash anywhere inside that publish
# must leave the container replayable to an exact prior generation —
# the matrix below kills the process (simulated: exception + the pool
# object discarded) at each window of the append protocol.

def _pool_with_pending(tmp_path):
    """A mounted tenant with one durable generation on disk plus
    pending (unpersisted) mutations; returns (pool, container_path,
    durable_fingerprint, pending_doc_id)."""
    from repro.tenancy import ContainerPool

    from repro.obs.metrics import MetricsRegistry

    pool = ContainerPool(str(tmp_path / "tenants"), kb_kwargs={"dim": DIM},
                         registry=MetricsRegistry(), scoring_path="map")
    with pool.pinned("t") as mt:
        for i in range(8):
            mt.kb.add_text(f"base{i}.txt", f"durable doc {i} CODE-{i}")
        mt.snapshots.publish(durable=True)
    p = pool.container_path("t")
    fp_durable = _fingerprint(KnowledgeBase.load(p))
    with pool.pinned("t") as mt:
        mt.kb.add_text("pending.txt", "unpersisted tail INV-9999")
        mt.snapshots.publish(durable=False)  # in-memory only
    return pool, p, fp_durable, "pending.txt"


def test_evict_crash_before_journal_append_loses_only_pending(
        tmp_path, monkeypatch):
    """Window (a): die before any journal byte is written.  The
    container replays to exactly the last durable generation."""
    import repro.core.ingest as ingest_mod

    pool, p, fp_durable, pending = _pool_with_pending(tmp_path)

    def die(*a, **kw):
        raise OSError("simulated crash before append")
    monkeypatch.setattr(ingest_mod, "append_journal_record", die)
    with pytest.raises(OSError, match="before append"):
        pool.evict("t")
    monkeypatch.undo()
    # "reboot": a fresh mount sees the durable generation, not the tail
    out = KnowledgeBase.load(p)
    _assert_identical(_fingerprint(out), fp_durable)
    assert pending not in out.records


def test_evict_crash_between_append_and_manifest_rename(
        tmp_path, monkeypatch):
    """Window (b): die after the journal frames hit disk but before the
    manifest rename commits them.  The uncommitted tail is invisible on
    replay and reclaimed by the next successful append."""
    import repro.core.container as container_mod

    pool, p, fp_durable, pending = _pool_with_pending(tmp_path)
    # the first durable publish full-saved: no journal on disk yet, so
    # the evict-triggered delta is the journal's very first record
    size_before = C.journal_size(p)

    def die(base_path, man):
        raise OSError("simulated crash before manifest rename")
    monkeypatch.setattr(container_mod, "_publish_journal_manifest", die)
    with pytest.raises(OSError, match="manifest rename"):
        pool.evict("t")
    monkeypatch.undo()
    # frames were appended but never committed
    assert os.path.getsize(C.journal_path(p)) > size_before
    man = C.read_journal_manifest(p)
    assert man is None or man["committed_bytes"] <= size_before
    out = KnowledgeBase.load(p)
    _assert_identical(_fingerprint(out), fp_durable)
    assert pending not in out.records
    # recovery: the next durable save truncates the orphan bytes and
    # commits the pending generation cleanly
    out.add_text("pending.txt", "unpersisted tail INV-9999")
    out.save_delta(p, compact_ratio=None)
    man = C.read_journal_manifest(p)
    assert man["committed_bytes"] == os.path.getsize(C.journal_path(p))
    assert "pending.txt" in KnowledgeBase.load(p).records


def test_evict_crash_after_commit_is_equivalent_to_clean_evict(tmp_path):
    """Window (c): die after the manifest commit but before the pool
    drops its resident entry.  Disk already owns the generation — a
    remount serves the pending docs; nothing is lost or doubled."""
    pool, p, fp_durable, pending = _pool_with_pending(tmp_path)

    def die(tenant):
        raise OSError("simulated crash after commit")
    pool.on_evict = die
    with pytest.raises(OSError, match="after commit"):
        pool.evict("t")
    out = KnowledgeBase.load(p)
    assert pending in out.records
    assert out.n_docs == len(fp_durable["ids"]) + 1
    # the journal chain stays single-headed: loading twice is stable
    _assert_identical(_fingerprint(out), _fingerprint(KnowledgeBase.load(p)))
