import os
import sys
import types

# Tests run on the single real CPU device (the dry-run, and only the
# dry-run, uses the 512-device XLA flag).  Sharded-equivalence tests
# spawn subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Optional-dependency gate: hypothesis is not in every deployment image.
# When absent, install a stub so test modules still import — property
# tests then skip individually at call time instead of erroring the
# whole file out of collection (deterministic tests keep running).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import pytest

    def _given(*_a, **_k):
        def deco(fn):
            # NOTE: no functools.wraps — copying fn's signature would make
            # pytest resolve the strategy kwargs as fixtures and error.
            def wrapper():
                pytest.skip("hypothesis not installed (optional dep)")

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def _settings(*_a, **_k):
        return lambda fn: fn

    def _strategy(*_a, **_k):
        return None

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _strategy  # integers, text, characters, …

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
