import os
import sys
import types

import numpy as np

# Tests run on the single real CPU device (the dry-run, and only the
# dry-run, uses the 512-device XLA flag).  Sharded-equivalence tests
# spawn subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def assert_bit_identical(a, b, *, score_rtol=None, score_atol=0.0,
                         label=""):
    """Assert two retrieval outputs are bit-identical.

    The repo's central correctness claim (ARCHITECTURE §6/§10) is that
    every optimized plane — IVF probe/rerank, the sharded mesh plane,
    generation-pinned snapshots — returns *the same bits* as the flat
    scan: same ids, same tie order, same scores, same boost flags.
    This is the one comparator every suite uses to state that claim.

    Accepts either shape of output:

    - two lists of per-query ``RetrievalResult`` lists (what
      ``QueryEngine.query_batch`` / ``EngineSnapshot.query_batch``
      return), or
    - two ``(vals, ids)`` array pairs (raw top-k planes).

    Scores compare with ``==`` by default.  ``score_rtol`` (plus
    optional ``score_atol``) loosens *only* the score comparison — for
    kernel-path tests where fused-multiply ordering shifts the last
    ulps; ids and tie order must still match exactly.
    """
    if isinstance(a, tuple):
        (av, ai), (bv, bi) = a, b
        np.testing.assert_array_equal(
            np.asarray(ai), np.asarray(bi), err_msg=f"{label}: ids")
        if score_rtol is None:
            np.testing.assert_array_equal(
                np.asarray(av), np.asarray(bv), err_msg=f"{label}: scores")
        else:
            np.testing.assert_allclose(
                np.asarray(av), np.asarray(bv), rtol=score_rtol,
                atol=score_atol, err_msg=f"{label}: scores")
        return
    assert len(a) == len(b), (label, len(a), len(b))
    for qi, (ra, rb) in enumerate(zip(a, b)):
        assert len(ra) == len(rb), (label, qi, len(ra), len(rb))
        for rank, (x, y) in enumerate(zip(ra, rb)):
            where = f"{label} query {qi} rank {rank}"
            assert x.doc_id == y.doc_id, (where, x.doc_id, y.doc_id)
            if score_rtol is None:
                assert x.score == y.score, (where, x.score, y.score)
                assert x.cosine == y.cosine, (where, x.cosine, y.cosine)
            else:
                np.testing.assert_allclose(x.score, y.score,
                                           rtol=score_rtol,
                                           atol=score_atol, err_msg=where)
                np.testing.assert_allclose(x.cosine, y.cosine,
                                           rtol=score_rtol,
                                           atol=score_atol, err_msg=where)
            assert x.boosted == y.boosted, (where, x.boosted, y.boosted)

# Optional-dependency gate: hypothesis is not in every deployment image.
# When absent, install a stub so test modules still import — property
# tests then skip individually at call time instead of erroring the
# whole file out of collection (deterministic tests keep running).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import pytest

    def _given(*_a, **_k):
        def deco(fn):
            # NOTE: no functools.wraps — copying fn's signature would make
            # pytest resolve the strategy kwargs as fixtures and error.
            def wrapper():
                pytest.skip("hypothesis not installed (optional dep)")

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def _settings(*_a, **_k):
        return lambda fn: fn

    def _strategy(*_a, **_k):
        return None

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _strategy  # integers, text, characters, …

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
