import os
import sys

# Tests run on the single real CPU device (the dry-run, and only the
# dry-run, uses the 512-device XLA flag).  Sharded-equivalence tests
# spawn subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
