"""QueryEngine contracts: batched scoring is bit-identical to the
single-query Retriever, incremental materialization equals a cold
rebuild bit-exactly, and the query cache never changes results."""
import numpy as np
import pytest

from repro.core import engine as engine_mod
from repro.core.engine import QueryEngine, _bucket, resolve_scoring_path
from repro.core.ingest import KnowledgeBase
from repro.core.retrieval import Retriever
from repro.data.corpus import make_corpus


def _kb(n_docs=80, dim=1024, n_entities=6, seed=0):
    docs, entities = make_corpus(n_docs=n_docs, n_entities=n_entities,
                                 seed=seed)
    kb = KnowledgeBase(dim=dim)
    for i, d in enumerate(docs):
        kb.add_text(f"doc_{i:05d}.txt", d)
    return kb, entities


def _queries(entities):
    return (
        [code for code in entities]
        + [f"lookup {code} record" for code in entities]
        + ["quarterly forecast", "unrelated text", ""]
    )


# --------------------------------------------------------------------------
# batched == looped (scores, ids, tie order — bit-identical)
# --------------------------------------------------------------------------

def test_query_batch_bit_identical_to_looped_retriever():
    kb, entities = _kb()
    engine = QueryEngine(kb)
    retriever = Retriever(kb)
    queries = _queries(entities)

    batch = engine.query_batch(queries, k=5)
    assert len(batch) == len(queries)
    for q, got in zip(queries, batch):
        want = retriever.query(q, k=5)
        assert [r.doc_id for r in got] == [r.doc_id for r in want], q
        # bit-identical, not approx: same floats out of both paths
        assert [r.score for r in got] == [r.score for r in want], q
        assert [r.cosine for r in got] == [r.cosine for r in want], q
        assert [r.boosted for r in got] == [r.boosted for r in want], q


def test_query_batch_independent_of_batch_composition():
    """A query's results don't depend on what else is in the batch (the
    padding-bucket contract)."""
    kb, entities = _kb()
    engine = QueryEngine(kb)
    queries = _queries(entities)
    alone = [engine.query_batch([q], k=3)[0] for q in queries]
    together = engine.query_batch(queries, k=3)
    for q, a, t in zip(queries, alone, together):
        assert [(r.doc_id, r.score) for r in a] == \
            [(r.doc_id, r.score) for r in t], q


def test_query_batch_kernel_path_bit_identical():
    kb, entities = _kb(n_docs=64)
    engine = QueryEngine(kb, use_kernel=True)
    retriever = Retriever(kb, use_kernel=True)
    for q in list(entities)[:3]:
        got = engine.query_batch([q, "decoy query"], k=4)[0]
        want = retriever.query(q, k=4)
        assert [(r.doc_id, r.score) for r in got] == \
            [(r.doc_id, r.score) for r in want]


def test_kernel_path_matches_default_ranking_batched():
    """The fused batched kernel (in-kernel top-k) returns the same
    ranking, boosted flags, and near-identical scores as the bit-stable
    lax.map path, across batch sizes and for tie-heavy corpora."""
    kb, entities = _kb(n_docs=60)
    for i in range(10):
        kb.add_text(f"tie_{i:02d}", "identical tie content ZZ-4242")
    default = QueryEngine(kb)
    kernel = QueryEngine(kb, use_kernel=True)
    queries = _queries(entities) + ["ZZ-4242"]
    a = default.query_batch(queries, k=6)
    b = kernel.query_batch(queries, k=6)
    for q, ra, rb in zip(queries, a, b):
        assert [r.doc_id for r in ra] == [r.doc_id for r in rb], q
        assert [r.boosted for r in ra] == [r.boosted for r in rb], q
        np.testing.assert_allclose([r.score for r in ra],
                                   [r.score for r in rb], rtol=1e-5)
        np.testing.assert_allclose([r.cosine for r in ra],
                                   [r.cosine for r in rb],
                                   rtol=1e-5, atol=1e-6)


def test_kernel_operand_cache_reused_until_refresh():
    """The block-aligned kernel operands are padded once per refresh,
    not per dispatch (the hot loop never pays the O(N·D) pad copy),
    and are rebuilt when a KB mutation rebinds the device arrays."""
    kb, entities = _kb(n_docs=30)  # 30 docs → ragged vs the 32-block
    engine = QueryEngine(kb, use_kernel=True)
    code = next(iter(entities))
    engine.query_batch([code], k=3)
    dv1, ds1 = engine._kernel_operands()
    assert dv1.shape[0] % 8 == 0 and dv1.shape[0] >= 30
    engine.query_batch([code, "other"], k=3)
    dv2, ds2 = engine._kernel_operands()
    assert dv2 is dv1 and ds2 is ds1  # cache hit across dispatches

    kb.add_text("doc_00003.txt", "rewritten content AB-1212")
    res = engine.query_batch(["AB-1212"], k=1)[0]
    assert res[0].doc_id == "doc_00003.txt" and res[0].boosted
    dv3, _ = engine._kernel_operands()
    assert dv3 is not dv1  # refresh rebound the arrays → re-padded


@pytest.mark.parametrize("make_engine", [
    lambda kb: QueryEngine(kb, beta=0.0),
    lambda kb: QueryEngine(kb, beta=0.0, gemm_batch=True),
    lambda kb: QueryEngine(kb, beta=0.0, use_kernel=True),
])
def test_boosted_flag_exact_at_beta_zero(make_engine):
    """β=0 regression: ``boosted`` used to be inferred as
    score − α·cos > 0.5·β, which any positive rounding noise satisfies
    when β=0.  It must now reflect the exact containment indicator:
    True for the doc containing the query substring, False elsewhere."""
    kb = KnowledgeBase(dim=512)
    kb.add_text("with_code", "the target document mentions QX-9090 here")
    for i in range(15):
        kb.add_text(f"filler_{i:02d}", f"unrelated filler text number {i}")
    engine = make_engine(kb)
    res = engine.query_batch(["QX-9090"], k=16)[0]
    flags = {r.doc_id: r.boosted for r in res}
    assert flags["with_code"] is True  # indicator fires even at β=0
    assert not any(v for d, v in flags.items() if d != "with_code")


def test_boosted_flag_exact_at_beta_zero_prefiltered():
    """Same β=0 regression for the Retriever postings-prefilter path."""
    kb = KnowledgeBase(dim=512)
    kb.add_text("with_code", "the target document mentions QX-9090 here")
    for i in range(15):
        kb.add_text(f"filler_{i:02d}", f"unrelated filler text number {i}")
    r = Retriever(kb, beta=0.0, prefilter=True)
    res = r.query("QX-9090", k=5)
    flags = {x.doc_id: x.boosted for x in res}
    assert flags["with_code"] is True
    assert not any(v for d, v in flags.items() if d != "with_code")


def test_tie_order_matches_between_batch_and_single():
    """Duplicate docs produce exact score ties; both paths must break
    them identically (lax.top_k order)."""
    kb = KnowledgeBase(dim=512)
    for i in range(12):
        kb.add_text(f"dup_{i:02d}", "identical tie content INV-7777")
    engine = QueryEngine(kb)
    retriever = Retriever(kb)
    got = engine.query_batch(["INV-7777"], k=6)[0]
    want = retriever.query("INV-7777", k=6)
    assert [r.doc_id for r in got] == [r.doc_id for r in want]
    assert len({r.score for r in got}) == 1  # genuinely tied


# --------------------------------------------------------------------------
# incremental materialization == cold rebuild (bit-exact device arrays)
# --------------------------------------------------------------------------

def _assert_matches_cold(engine, kb):
    matrix, sigs, ids = kb.materialize()
    assert engine.doc_ids == ids
    np.testing.assert_array_equal(np.asarray(engine.doc_vecs), matrix)
    np.testing.assert_array_equal(np.asarray(engine.doc_sigs), sigs)


def test_incremental_refresh_add_update_remove_equals_cold():
    kb, _ = _kb(n_docs=50)
    engine = QueryEngine(kb)
    v0 = kb.version

    kb.add_text("zz_new_doc", "a brand new document QQ-1111")   # add
    stats = engine.refresh()
    assert stats.changed == 1 and stats.restacked
    _assert_matches_cold(engine, kb)

    kb.add_text("doc_00007.txt", "doc seven rewritten RR-2222")  # update
    stats = engine.refresh()
    assert stats.changed == 1 and stats.removed == 0
    assert not stats.restacked  # same layout: rows patched, not restacked
    _assert_matches_cold(engine, kb)

    kb._remove_doc("doc_00003.txt")                              # remove
    stats = engine.refresh()
    assert stats.removed == 1 and stats.restacked
    _assert_matches_cold(engine, kb)

    assert kb.version > v0
    assert engine.refresh().no_op  # converged: next refresh does nothing


def test_refresh_does_not_revectorize_unchanged_docs(monkeypatch):
    kb, _ = _kb(n_docs=40)
    engine = QueryEngine(kb)
    kb.add_text("doc_00001.txt", "updated content for doc one SS-3333")

    calls = []
    orig = kb.vectorizer.unweighted_row
    monkeypatch.setattr(
        kb.vectorizer, "unweighted_row",
        lambda tc: (calls.append(1), orig(tc))[1],
    )
    stats = engine.refresh()
    assert stats.changed == 1
    assert len(calls) == 1  # exactly the dirty doc, nothing else
    _assert_matches_cold(engine, kb)


def test_sync_driven_refresh_equals_cold(tmp_path):
    from repro.data.corpus import write_corpus_dir

    docs, _ = make_corpus(n_docs=30, seed=4)
    src = str(tmp_path / "corpus")
    write_corpus_dir(src, docs)
    kb = KnowledgeBase(dim=512)
    kb.sync(src)
    engine = QueryEngine(kb)

    # touch 3 files, delete 1, add 1 — the paper's incremental loop
    for i in range(3):
        with open(f"{src}/doc_{i:05d}.txt", "a") as f:
            f.write(" appended TT-4444")
    import os
    os.unlink(f"{src}/doc_00010.txt")
    with open(f"{src}/doc_99999.txt", "w") as f:
        f.write("entirely new corpus member UU-5555")
    stats_sync = kb.sync(src)
    assert stats_sync.updated == 3 and stats_sync.removed == 1 \
        and stats_sync.added == 1

    stats = engine.refresh()
    assert stats.changed == 4 and stats.removed == 1
    _assert_matches_cold(engine, kb)


def test_queries_see_kb_mutations_automatically():
    kb, _ = _kb(n_docs=20)
    engine = QueryEngine(kb)
    assert not any(
        r.doc_id == "late_doc" for r in engine.query_batch(["VV-6666"], k=3)[0]
    )
    kb.add_text("late_doc", "late arrival about VV-6666 exactly")
    top = engine.query_batch(["VV-6666"], k=1)[0][0]
    assert top.doc_id == "late_doc" and top.boosted


# --------------------------------------------------------------------------
# query-vector LRU cache
# --------------------------------------------------------------------------

def test_cache_hits_return_identical_results():
    kb, entities = _kb()
    engine = QueryEngine(kb)
    code = next(iter(entities))
    first = engine.query_batch([code], k=5)[0]
    assert engine.cache_stats()["hits"] == 0
    second = engine.query_batch([code], k=5)[0]
    assert engine.cache_stats()["hits"] == 1
    assert [(r.doc_id, r.score, r.cosine) for r in first] == \
        [(r.doc_id, r.score, r.cosine) for r in second]
    # case-insensitive: normalization is the cache key
    third = engine.query_batch([code.lower()], k=5)[0]
    assert engine.cache_stats()["hits"] == 2
    assert [(r.doc_id, r.score) for r in third] == \
        [(r.doc_id, r.score) for r in first]


def test_query_vector_cache_not_stale_after_explicit_refresh():
    """Regression (PR 3): the query-vector LRU must not serve vectors
    weighted with pre-refresh idf statistics.  An *explicit*
    ``refresh()`` (the serving runtime's publish path — no query in
    between) has to invalidate it just like the query-driven refresh."""
    kb, entities = _kb(n_docs=30)
    engine = QueryEngine(kb)
    code = next(iter(entities))
    engine.query_batch([code, "generic filler"], k=3)
    assert engine.cache_stats()["entries"] == 2

    kb.add_text("fresh_doc", "completely fresh document shifting idf")
    stats = engine.refresh()  # idf moved → cached vectors are stale
    assert stats.reweighted
    assert engine.cache_stats()["entries"] == 0  # invalidated, not kept

    got = engine.query_batch([code], k=3)[0]
    want = QueryEngine(kb).query_batch([code], k=3)[0]  # cold: no cache
    assert [(r.doc_id, r.score, r.cosine) for r in got] == \
        [(r.doc_id, r.score, r.cosine) for r in want]


def test_cache_invalidated_when_idf_changes():
    kb, entities = _kb(n_docs=30)
    engine = QueryEngine(kb)
    code = next(iter(entities))
    engine.query_batch([code], k=3)
    kb.add_text("fresh", "completely fresh doc shifting idf")
    engine.query_batch([code], k=3)  # auto-refresh must drop stale vecs
    retriever = Retriever(kb)
    got = engine.query_batch([code], k=3)[0]
    want = retriever.query(code, k=3)
    assert [(r.doc_id, r.score) for r in got] == \
        [(r.doc_id, r.score) for r in want]


def test_cache_eviction_is_lru():
    kb, _ = _kb(n_docs=10)
    engine = QueryEngine(kb, cache_size=2)
    engine.query_batch(["alpha", "beta"], k=1)
    engine.query_batch(["alpha"], k=1)        # alpha now most-recent
    engine.query_batch(["gamma"], k=1)        # evicts beta
    stats0 = engine.cache_stats()
    engine.query_batch(["alpha"], k=1)        # still cached
    assert engine.cache_stats()["hits"] == stats0["hits"] + 1
    engine.query_batch(["beta"], k=1)         # was evicted → miss
    assert engine.cache_stats()["misses"] == stats0["misses"] + 1


# --------------------------------------------------------------------------
# edges
# --------------------------------------------------------------------------

def test_empty_kb_and_empty_batch():
    kb = KnowledgeBase(dim=512)
    engine = QueryEngine(kb)
    assert engine.query_batch(["anything"], k=3) == [[]]
    assert engine.query_batch([], k=3) == []


@pytest.mark.parametrize("make_engine", [
    lambda kb: QueryEngine(kb, scoring_path="map"),
    lambda kb: QueryEngine(kb, scoring_path="gemm"),
    lambda kb: QueryEngine(kb, use_kernel=True),
    lambda kb: QueryEngine(kb, scoring_path="auto"),
    lambda kb: QueryEngine(kb, scoring_path="map", index="ivf"),
    lambda kb: QueryEngine(kb, scoring_path="map", index="ivf-sharded",
                           n_shards=2),
    lambda kb: QueryEngine(kb, scoring_path="auto", index="ivf-sharded",
                           n_shards=2),
])
def test_empty_container_on_every_path_and_index(make_engine):
    """Regression: an n=0 container (fresh tenant mount, or every doc
    removed) must return empty result lists on every scoring path and
    index kind — the padded-bucket dispatch used to ask top_k for k of
    0 candidate columns and trip inside the jitted function."""
    kb = KnowledgeBase(dim=512)
    engine = make_engine(kb)
    assert engine.query_batch(["anything", "else"], k=3) == [[], []]
    assert engine.query_batch([], k=3) == []


def test_all_docs_removed_returns_to_empty_path(tmp_path):
    """A corpus whose every document was removed (sync against an
    emptied source dir) must serve [] too, not trip padded top-k."""
    src = tmp_path / "docs"
    src.mkdir()
    (src / "only.txt").write_text("transient invoice forecast")
    kb = KnowledgeBase(dim=512)
    kb.sync(str(src))
    engine = QueryEngine(kb)
    assert len(engine.query_batch(["invoice"], k=3)[0]) == 1
    (src / "only.txt").unlink()
    kb.sync(str(src))
    assert kb.n_docs == 0
    assert engine.query_batch(["invoice"], k=3) == [[]]


def test_score_batch_arrays_zero_docs_short_circuits():
    """Direct contract at the dispatch layer: n_docs=0 yields [B, 0]
    arrays on every scoring path, not a top-k shape error."""
    import jax.numpy as jnp

    from repro.core.engine import score_batch_arrays

    qv = np.zeros((2, 512), dtype=np.float32)
    qs = np.zeros((2, 4), dtype=np.uint32)
    docs = jnp.zeros((0, 512), dtype=jnp.float32)
    sigs = jnp.zeros((0, 4), dtype=jnp.uint32)
    for path in ("map", "gemm"):
        vals, idx, cos, ind = score_batch_arrays(
            docs, sigs, qv, qs, scoring_path=path, k=3,
            alpha=0.2, beta=0.3, n_docs=0)
        assert vals.shape == (2, 0) and idx.shape == (2, 0)
        assert cos.shape == (2, 0) and ind.shape == (2, 0)


def test_empty_container_save_load_roundtrip(tmp_path):
    """An empty KB persists and reloads to a queryable empty engine."""
    path = str(tmp_path / "empty.ragdb")
    KnowledgeBase(dim=512).save(path)
    kb = KnowledgeBase.load(path)
    assert kb.n_docs == 0
    assert QueryEngine(kb).query_batch(["anything"], k=5) == [[]]


def test_k_larger_than_corpus():
    kb, _ = _kb(n_docs=4, n_entities=2)
    engine = QueryEngine(kb)
    res = engine.query_batch(["whatever text"], k=50)[0]
    assert len(res) == 4


@pytest.mark.parametrize("bad_k", [0, -1, -50])
def test_query_batch_rejects_non_positive_k(bad_k):
    """k ≤ 0 raises a clear ValueError instead of falling through to
    the padded top-k machinery (regression for the silent k=0 case)."""
    kb, _ = _kb(n_docs=6, n_entities=2)
    engine = QueryEngine(kb)
    with pytest.raises(ValueError, match="k must be"):
        engine.query_batch(["anything"], k=bad_k)
    with pytest.raises(ValueError, match="k must be"):
        engine.query(  # single-query wrapper shares the contract
            "anything", k=bad_k)
    # the snapshot read plane enforces the same contract
    from repro.serving import SnapshotManager

    snap = SnapshotManager(kb, scoring_path="map").current
    with pytest.raises(ValueError, match="k must be"):
        snap.query_batch(["anything"], k=bad_k)
    # and the prefiltered Retriever path
    from repro.core.retrieval import Retriever

    with pytest.raises(ValueError, match="k must be"):
        Retriever(kb, prefilter=True).query("anything", k=bad_k)


@pytest.mark.parametrize("make_engine", [
    lambda kb: QueryEngine(kb),
    lambda kb: QueryEngine(kb, gemm_batch=True),
    lambda kb: QueryEngine(kb, use_kernel=True),
    lambda kb: QueryEngine(kb, scoring_path="map", index="ivf",
                           guarantee="exact"),
])
def test_k_larger_than_corpus_clamps_on_every_path(make_engine):
    """k > n_docs clamps to the corpus size on every scoring path and
    on the clustered index plane — results stay full-length-n and
    identical to an exact-k query."""
    kb, _ = _kb(n_docs=5, n_entities=2)
    engine = make_engine(kb)
    res = engine.query_batch(["invoice forecast"], k=50)[0]
    assert len(res) == 5
    exact = engine.query_batch(["invoice forecast"], k=5)[0]
    assert [(r.doc_id, r.score) for r in res] == \
        [(r.doc_id, r.score) for r in exact]


def test_bucket_boundaries():
    assert [_bucket(b) for b in (1, 2, 3, 4, 5, 8, 9, 16, 17)] == \
        [1, 2, 4, 4, 8, 8, 16, 16, 32]


def test_oversized_batch_chunks():
    kb, entities = _kb(n_docs=30)
    engine = QueryEngine(kb, max_batch=4)
    queries = [f"q {i} {code}" for i, code in
               enumerate(list(entities) * 3)]  # 18 queries, chunked by 4
    batch = engine.query_batch(queries, k=2)
    assert len(batch) == len(queries)
    retriever = Retriever(kb)
    for q, got in zip(queries, batch):
        want = retriever.query(q, k=2)
        assert [(r.doc_id, r.score) for r in got] == \
            [(r.doc_id, r.score) for r in want]


def test_engine_adopts_persisted_matrix_without_revectorizing(
        tmp_path, monkeypatch):
    """A container saved with include_matrix=True exists to skip the
    O(N·D) rebuild at load time (RQ3 trade) — the engine must honor it,
    and its lazy u-cache must still make later deltas bit-exact."""
    kb, _ = _kb(n_docs=40)
    path = str(tmp_path / "kb.ragdb")
    kb.save(path, include_matrix=True)
    kb2 = KnowledgeBase.load(path)

    calls = []
    orig = kb2.vectorizer.build_unweighted_matrix
    monkeypatch.setattr(
        kb2.vectorizer, "build_unweighted_matrix",
        lambda tcs: (calls.append(len(tcs)), orig(tcs))[1],
    )
    engine = QueryEngine(kb2)
    assert calls == []  # persisted ⟨V⟩ adopted, nothing re-vectorized
    _assert_matches_cold(engine, kb2)

    kb2.add_text("doc_00002.txt", "rewritten after load WW-7777")
    kb2._remove_doc("doc_00009.txt")
    engine.refresh()  # u-cache builds lazily here
    _assert_matches_cold(engine, kb2)


# --------------------------------------------------------------------------
# scoring-path auto-selection
# --------------------------------------------------------------------------

def test_scoring_path_auto_picks_kernel_only_on_tpu(monkeypatch):
    """PR 2's shoot-out: the kernel path is ~4x slower than gemm in CPU
    interpret mode — "auto" must route it only on real TPU backends,
    with explicit overrides as the escape hatch."""
    kb, _ = _kb(n_docs=8, n_entities=2)

    monkeypatch.setattr(engine_mod, "_default_backend", lambda: "cpu")
    assert QueryEngine(kb).scoring_path == "map"
    assert resolve_scoring_path("auto") == "map"
    # explicit overrides win regardless of backend
    assert QueryEngine(kb, scoring_path="kernel").scoring_path == "kernel"
    assert QueryEngine(kb, use_kernel=True).scoring_path == "kernel"
    assert QueryEngine(kb, gemm_batch=True).scoring_path == "gemm"

    monkeypatch.setattr(engine_mod, "_default_backend", lambda: "tpu")
    eng = QueryEngine(kb)
    assert eng.scoring_path == "kernel" and eng.use_kernel
    assert resolve_scoring_path("auto") == "kernel"
    # the escape hatch: force the bit-stable path on TPU
    assert QueryEngine(kb, scoring_path="map").scoring_path == "map"

    with pytest.raises(ValueError):
        resolve_scoring_path("bogus")
    with pytest.raises(ValueError):
        resolve_scoring_path(use_kernel=True, gemm_batch=True)


def test_scoring_path_auto_agrees_between_engine_and_retriever(monkeypatch):
    """A default Retriever over a default engine must not trip the
    shared-engine validation on any backend (both resolve "auto" the
    same way)."""
    kb, entities = _kb(n_docs=16, n_entities=2)
    for backend in ("cpu", "tpu"):
        monkeypatch.setattr(engine_mod, "_default_backend", lambda b=backend: b)
        engine = QueryEngine(kb)
        retriever = Retriever(kb, engine=engine)  # must not raise
        assert retriever.engine is engine
        code = next(iter(entities))
        # the resolved path actually serves queries (kernel runs in
        # interpret mode on the CPU host)
        assert retriever.query(code, k=1)[0].doc_id == \
            engine.query_batch([code], k=1)[0][0].doc_id


def test_retriever_rejects_mismatched_shared_engine():
    kb, _ = _kb(n_docs=10)
    with pytest.raises(ValueError):
        Retriever(kb, beta=0.0, engine=QueryEngine(kb))
    with pytest.raises(ValueError):
        Retriever(kb, engine=QueryEngine(kb, gemm_batch=True))


def test_retriever_is_thin_wrapper_over_engine():
    kb, entities = _kb(n_docs=20)
    engine = QueryEngine(kb)
    retriever = Retriever(kb, engine=engine)
    assert retriever.engine is engine
    code = next(iter(entities))
    assert retriever.query(code, k=1)[0].doc_id == \
        engine.query_batch([code], k=1)[0][0].doc_id
    assert retriever.doc_ids == engine.doc_ids


def test_rag_answer_batch_matches_serial_answers():
    import jax

    from repro.configs import ARCHS
    from repro.core.rag import RAGPipeline
    from repro.models import transformer as T

    kb, entities = _kb(n_docs=20, dim=512)
    cfg = ARCHS["llama3.2-3b"].smoke_config
    params = T.init(jax.random.PRNGKey(0), cfg)
    rag = RAGPipeline(kb, params, cfg, max_context_tokens=64)
    questions = [f"what is {code}?" for code in list(entities)[:3]]
    batched = rag.answer_batch(questions, max_new_tokens=3, top_k_docs=2)
    for q, out in zip(questions, batched):
        serial = rag.answer(q, max_new_tokens=3, top_k_docs=2)
        assert out.token_ids == serial.token_ids
        assert [r.doc_id for r in out.retrieved] == \
            [r.doc_id for r in serial.retrieved]
