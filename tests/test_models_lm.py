"""Per-arch LM smoke tests (reduced configs) + serving-path parity.

Every assigned LM architecture: instantiate the SMOKE config, run one
forward + one train step on CPU, assert output shapes and no NaNs; then
check prefill+decode reproduces the training forward logits exactly
(the strongest cheap integration test of attention/cache/rope/MoE/MLA).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import transformer as T

LM_ARCHS = [a for a, s in ARCHS.items() if s.family == "lm"]


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_smoke_forward_and_train_step(arch_id, rng):
    cfg = ARCHS[arch_id].smoke_config
    params = T.init(rng, cfg)
    B, L = 2, 32
    tokens = jax.random.randint(rng, (B, L), 0, cfg.vocab)
    logits, aux = T.forward(params, tokens, cfg)
    assert logits.shape == (B, L, cfg.vocab)
    assert not np.isnan(np.asarray(logits)).any()
    assert float(aux) >= 0.0

    loss, grads = jax.value_and_grad(T.lm_loss)(
        params, tokens[:, :-1], tokens[:, 1:], cfg
    )
    assert np.isfinite(float(loss))
    gleaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in gleaves)
    # at least the embedding must receive gradient
    gn = float(sum(jnp.sum(jnp.abs(g)) for g in gleaves))
    assert gn > 0


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_prefill_decode_matches_forward(arch_id, rng):
    cfg = ARCHS[arch_id].smoke_config
    params = T.init(rng, cfg)
    B, L, max_len = 2, 31, 40
    tokens = jax.random.randint(rng, (B, L), 0, cfg.vocab)

    full_logits, _ = T.forward(params, tokens, cfg)

    pre_logits, caches, lengths = T.prefill(params, tokens[:, :L - 2], cfg,
                                            max_len)
    # prefill last-position logits == forward at that position
    ref = np.asarray(full_logits[:, L - 3])
    scale = np.abs(ref).max() + 1e-6
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, -1]) / scale, ref / scale, atol=5e-4
    )
    # two decode steps
    for t in range(L - 2, L):
        lengths = lengths + 1
        logits_d, caches = T.decode_step(
            params, caches, tokens[:, t: t + 1], lengths, cfg
        )
        ref = np.asarray(full_logits[:, t])
        scale = np.abs(ref).max() + 1e-6
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]) / scale, ref / scale, atol=5e-4,
            err_msg=f"{arch_id} decode step {t}",
        )


def test_ring_cache_equals_full_for_window(rng):
    """gemma3 smoke has window 16 < max_len: the ring cache must match
    the full-cache decode bit-for-bit."""
    cfg = ARCHS["gemma3-27b"].smoke_config
    params = T.init(rng, cfg)
    B, L = 1, 30
    tokens = jax.random.randint(rng, (B, L + 1), 0, cfg.vocab)
    full_logits, _ = T.forward(params, tokens, cfg)
    _, caches, lengths = T.prefill(params, tokens[:, :L], cfg, max_len=64)
    # verify local-layer caches are ring-sized (== window)
    k0 = caches["scan"]["l0"]["k"]
    assert k0.shape[3] == cfg.window, k0.shape
    logits_d, _ = T.decode_step(params, caches, tokens[:, L:L + 1],
                                lengths + 1, cfg)
    ref = np.asarray(full_logits[:, L])
    scale = np.abs(ref).max()
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]) / scale,
                               ref / scale, atol=5e-4)


def test_param_count_matches_tree():
    for arch_id in LM_ARCHS:
        cfg = ARCHS[arch_id].smoke_config
        params = T.init(jax.random.PRNGKey(1), cfg)
        actual = sum(x.size for x in jax.tree.leaves(params))
        assert cfg.param_count() == actual, arch_id


def test_full_config_param_counts():
    """Full configs hit their published parameter counts (±3 %)."""
    expected = {
        "gemma3-27b": 27e9, "gemma2-9b": 9.2e9, "llama3.2-3b": 3.2e9,
        "qwen3-moe-30b-a3b": 30.5e9, "deepseek-v2-lite-16b": 15.7e9,
    }
    for arch_id, target in expected.items():
        n = ARCHS[arch_id].config.param_count()
        assert abs(n - target) / target < 0.10, (arch_id, n, target)


def test_moe_active_params():
    cfg = ARCHS["qwen3-moe-30b-a3b"].config
    active = cfg.active_param_count()
    assert 2.5e9 < active < 4.0e9, active  # "A3B"
    cfg = ARCHS["deepseek-v2-lite-16b"].config
    active = cfg.active_param_count()
    assert 1.5e9 < active < 3.5e9, active  # ~2.4B active
