"""Clustered index plane (src/repro/index/): deterministic k-means,
IVF probe/rerank vs the flat scan (bit-identity under the exactness
guarantee), incremental cluster maintenance off the engine's dirty-row
log, and the candidate-gather helper shared with the postings
prefilter."""
import numpy as np
import pytest

from repro.core.engine import QueryEngine
from repro.core.ingest import KnowledgeBase
from repro.data.corpus import make_corpus
from repro.index import IVFIndex, spherical_kmeans
from repro.index.ivf import score_candidate_rows
from repro.index.kmeans import default_n_clusters

from conftest import assert_bit_identical


def _kb(n_docs=80, dim=1024, n_entities=6, seed=0):
    docs, entities = make_corpus(n_docs=n_docs, n_entities=n_entities,
                                 seed=seed)
    kb = KnowledgeBase(dim=dim)
    for i, d in enumerate(docs):
        kb.add_text(f"doc_{i:05d}.txt", d)
    return kb, entities


def _scores(results):
    return [[r.score for r in res] for res in results]


# --------------------------------------------------------------------------
# k-means: determinism + degenerate corpora
# --------------------------------------------------------------------------

def test_kmeans_deterministic_from_seed():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(200, 64)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    c1, a1 = spherical_kmeans(x, 14, seed=7)
    c2, a2 = spherical_kmeans(x, 14, seed=7)
    np.testing.assert_array_equal(c1, c2)  # bit-identical refit
    np.testing.assert_array_equal(a1, a2)
    c3, _ = spherical_kmeans(x, 14, seed=8)
    assert not np.array_equal(c1, c3)  # the seed actually matters


def test_kmeans_centroids_are_unit_norm_and_assignments_valid():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(100, 32)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    cent, assign = spherical_kmeans(x, 10, seed=0)
    np.testing.assert_allclose(np.linalg.norm(cent, axis=1), 1.0, rtol=1e-5)
    assert assign.shape == (100,)
    assert assign.min() >= 0 and assign.max() < 10


def test_kmeans_survives_duplicate_points():
    """Empty-cluster reseeding: more clusters than distinct points must
    still terminate with finite centroids and in-range assignments."""
    x = np.tile(np.eye(2, 16, dtype=np.float32), (5, 1))  # 10 rows, 2 unique
    cent, assign = spherical_kmeans(x, 8, seed=0)
    assert np.all(np.isfinite(cent))
    assert assign.min() >= 0 and assign.max() < 8


def test_kmeans_clamps_k_to_n_and_handles_empty():
    x = np.eye(3, 8, dtype=np.float32)
    cent, assign = spherical_kmeans(x, 50, seed=0)
    assert cent.shape[0] == 3
    cent, assign = spherical_kmeans(np.zeros((0, 8), np.float32), None)
    assert cent.shape[0] == 0 and assign.shape == (0,)


def test_default_n_clusters_is_sqrt_n():
    assert default_n_clusters(0) == 1
    assert default_n_clusters(100) == 10
    assert default_n_clusters(50_000) == 224


# --------------------------------------------------------------------------
# the exactness guarantee: ivf@exact is bit-identical to the flat scan
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n_docs", [7, 33, 100])   # ragged corpus sizes
@pytest.mark.parametrize("beta", [1.0, 0.0])       # β=0: pure cosine
def test_ivf_exact_bit_identical_to_flat_sweep(n_docs, beta):
    kb, entities = _kb(n_docs=n_docs, dim=512,
                       n_entities=min(4, max(1, n_docs // 4)))
    flat = QueryEngine(kb, beta=beta, scoring_path="map")
    ivf = QueryEngine(kb, beta=beta, scoring_path="map",
                      index="ivf", guarantee="exact", nprobe=1)
    queries = (list(entities)
               + [f"lookup {c} record" for c in list(entities)[:2]]
               + ["quarterly forecast", "unrelated text", ""])
    for b in (1, 3, 8):  # batch sizes (padding buckets 1/4/8)
        batch = (queries * 3)[:b]
        assert_bit_identical(flat.query_batch(batch, k=5),
                             ivf.query_batch(batch, k=5),
                             label=f"n_docs={n_docs} beta={beta} b={b}")


def test_ivf_exact_with_duplicate_ties():
    """Duplicate docs tie exactly; the exact guarantee must reproduce
    the flat scan's doc-index tie order (ties at the k-th score force
    further probing — a '>' vs '>=' bug shows up precisely here)."""
    kb = KnowledgeBase(dim=512)
    for i in range(12):
        kb.add_text(f"dup_{i:02d}", "identical tie content INV-7777")
    for i in range(20):
        kb.add_text(f"filler_{i:02d}", f"unrelated filler number {i}")
    flat = QueryEngine(kb, scoring_path="map")
    ivf = QueryEngine(kb, scoring_path="map", index="ivf",
                      guarantee="exact", nprobe=1)
    got = ivf.query_batch(["INV-7777"], k=6)
    assert_bit_identical(flat.query_batch(["INV-7777"], k=6), got)
    assert len(set(_scores(got)[0])) == 1  # genuinely tied


def test_ivf_probe_mode_recall_and_sublinear_scan():
    kb, entities = _kb(n_docs=400, dim=512, n_entities=8)
    ivf = QueryEngine(kb, scoring_path="map", index="ivf", nprobe=1)
    for code, target in entities.items():
        top = ivf.query_batch([code], k=1)[0][0]
        assert top.doc_id == f"doc_{target:05d}.txt", code
        stats = ivf.index_stats()
        assert stats["probed_fraction"] < 0.5  # genuinely pruned
        assert stats["clusters_probed"] < stats["n_clusters"]


def test_ivf_k_larger_than_corpus_clamps():
    kb, _ = _kb(n_docs=5, dim=512, n_entities=1)
    ivf = QueryEngine(kb, scoring_path="map", index="ivf",
                      guarantee="exact")
    assert len(ivf.query_batch(["anything"], k=50)[0]) == 5


# --------------------------------------------------------------------------
# incremental maintenance: reassign / restack / drift-triggered retrain
# --------------------------------------------------------------------------

def test_ivf_tracks_mutations_and_stays_exact():
    kb, entities = _kb(n_docs=120, dim=512)
    flat = QueryEngine(kb, scoring_path="map")
    ivf = QueryEngine(kb, scoring_path="map", index="ivf",
                      guarantee="exact", nprobe=2)
    idx0 = ivf.ivf

    kb.add_text("doc_00004.txt", "rewritten four ZZ-1111")   # in-place
    stats = ivf.refresh()
    assert stats.index_reassigned == 1 and not stats.restacked
    assert ivf.ivf is not idx0  # maintenance rebinds, never mutates

    kb.add_text("brand_new.txt", "fresh doc YY-2222")        # restack
    kb._remove_doc("doc_00050.txt")
    stats = ivf.refresh()
    assert stats.restacked and stats.index_reassigned >= 1
    assert len(ivf.ivf.assign) == kb.n_docs

    queries = ["ZZ-1111", "YY-2222"] + list(entities)[:3]
    assert_bit_identical(flat.query_batch(queries, k=4),
                         ivf.query_batch(queries, k=4))


def test_ivf_drift_counter_triggers_retrain():
    kb, _ = _kb(n_docs=60, dim=512)
    ivf = QueryEngine(kb, scoring_path="map", index="ivf",
                      retrain_drift=0.1)  # retrain after ~6 moved rows
    assert ivf.ivf.drift == 0
    for i in range(30):  # churn enough rows to cross the threshold
        kb.add_text(f"doc_{i:05d}.txt",
                    f"totally different content now {i} XK-{i:04d}")
    stats = ivf.refresh()
    assert stats.index_retrained
    assert ivf.ivf.drift == 0 and ivf.ivf.trained_n == kb.n_docs


def test_ivf_reassign_keeps_bounds_conservative():
    """Incremental updates may only widen cluster bounds: the receiving
    cluster's signature union gains the row's bits and its radius never
    rises — the exactness bound stays safe without a rebuild."""
    kb, _ = _kb(n_docs=80, dim=512)
    ivf = QueryEngine(kb, scoring_path="map", index="ivf")
    before = ivf.ivf
    kb.add_text("doc_00007.txt", "mutated seven with novel terms WQ-4242")
    ivf.refresh()
    after = ivf.ivf
    c = after.assign[ivf._row_of["doc_00007.txt"]]
    assert after.radius[c] <= before.radius[c] + 1e-7
    # the union can only gain bits (bitwise superset of the old union)
    assert np.all((before.sig_union[c] & after.sig_union[c])
                  == before.sig_union[c])


def test_ivf_state_roundtrip_is_bit_identical():
    kb, _ = _kb(n_docs=50, dim=512)
    ivf = QueryEngine(kb, scoring_path="map", index="ivf")
    st = ivf.ivf.state_dict(ivf.doc_ids)
    clone = IVFIndex.from_state(st)
    np.testing.assert_array_equal(clone.centroids, ivf.ivf.centroids)
    np.testing.assert_array_equal(clone.assign, ivf.ivf.assign)
    np.testing.assert_array_equal(clone.radius, ivf.ivf.radius)
    np.testing.assert_array_equal(clone.sig_union, ivf.ivf.sig_union)
    for a, b in zip(clone.members, ivf.ivf.members):
        np.testing.assert_array_equal(a, b)
    assert (clone.drift, clone.trained_n, clone.seed) == \
        (ivf.ivf.drift, ivf.ivf.trained_n, ivf.ivf.seed)


def test_stale_index_state_is_not_adopted_after_inplace_rewrite(monkeypatch):
    """Regression: the persisted state's key covers doc *content*, not
    just ids.  An in-place rewrite with no live index maintenance
    leaves stale sig_union/radius bounds that could underestimate a
    cluster — adoption must refuse and retrain, and exact mode must
    still match the flat scan."""
    import repro.index.ivf as ivf_mod

    kb, _ = _kb(n_docs=40, dim=512)
    QueryEngine(kb, scoring_path="map", index="ivf")  # writes kb.index_state
    # rewrite in place: id set unchanged, content (and signature) moved
    kb.add_text("doc_00012.txt", "rewritten with a brand new code PJ-3131")

    calls = []
    orig = ivf_mod.spherical_kmeans
    monkeypatch.setattr(ivf_mod, "spherical_kmeans",
                        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])
    fresh = QueryEngine(kb, scoring_path="map", index="ivf",
                        guarantee="exact")
    assert calls == [1]  # stale state rejected → retrained
    flat = QueryEngine(kb, scoring_path="map")
    assert_bit_identical(fresh.query_batch(["PJ-3131"], k=4),
                         flat.query_batch(["PJ-3131"], k=4))


# --------------------------------------------------------------------------
# candidate-gather helper (shared with the postings prefilter)
# --------------------------------------------------------------------------

def test_score_candidate_rows_matches_flat_subset():
    from repro.core.engine import pack_query_arrays, score_batch_arrays

    kb, entities = _kb(n_docs=90, dim=512)
    eng = QueryEngine(kb, scoring_path="map")
    code = next(iter(entities))
    qv, qs = eng._query_arrays(code)
    qvp, qsp = pack_query_arrays([(qv, qs)], kb.dim, kb.sig_words)
    n = len(eng.doc_ids)
    fv, fi, fc, fd = score_batch_arrays(
        eng.doc_vecs, eng.doc_sigs, qvp, qsp,
        scoring_path="map", k=n, alpha=eng.alpha, beta=eng.beta, n_docs=n,
    )
    cand = np.sort(np.random.default_rng(0).choice(n, 40, replace=False)
                   ).astype(np.int32)
    sv, si, sc, sd = score_candidate_rows(
        eng.doc_vecs, eng.doc_sigs, cand, qvp, qsp,
        scoring_path="map", k=10, alpha=eng.alpha, beta=eng.beta,
    )
    # subset results == the flat ranking restricted to the subset
    in_cand = np.isin(fi[0], cand)
    np.testing.assert_array_equal(si[0], fi[0][in_cand][:10])
    np.testing.assert_array_equal(sv[0], fv[0][in_cand][:10])


def test_prefilter_uses_shared_gather_and_matches_full_scan():
    from repro.core.retrieval import Retriever

    kb, entities = _kb(n_docs=100, dim=512)
    pre = Retriever(kb, prefilter=True, scoring_path="map")
    full = Retriever(kb, prefilter=False, scoring_path="map")
    for code in list(entities)[:3]:
        got = pre.query(code, k=5)
        want = full.query(code, k=5)
        # whole-token entity queries: prefilter is exact over its
        # candidate set (the caveat is substring-only matches, which
        # these are not) — scores bit-match the full scan's ranking
        # prefix; the unique code's postings may hold < k candidates
        assert len(got) >= 1
        assert [(r.doc_id, r.score, r.cosine, r.boosted) for r in got] == \
            [(r.doc_id, r.score, r.cosine, r.boosted)
             for r in want[:len(got)]]


# --------------------------------------------------------------------------
# parameter validation
# --------------------------------------------------------------------------

def test_ivf_parameter_validation():
    kb, _ = _kb(n_docs=10, dim=512, n_entities=1)
    with pytest.raises(ValueError, match="index"):
        QueryEngine(kb, index="bogus")
    with pytest.raises(ValueError, match="guarantee"):
        QueryEngine(kb, index="ivf", guarantee="bogus")
    with pytest.raises(ValueError, match="nprobe"):
        QueryEngine(kb, index="ivf", nprobe=0)
    with pytest.raises(ValueError, match="alpha"):
        QueryEngine(kb, index="ivf", alpha=-1.0)
