"""Knowledge-container format: integrity, atomicity, generations."""
import json
import os

import numpy as np
import pytest

from repro.core import container as C


def _segs(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "vec": rng.normal(size=(4, 8)).astype(np.float32),
        "sig": rng.integers(0, 100, size=(4, 16)).astype(np.int32),
        **C.encode_texts(["hello", "world", "", "κόσμος"]),
    }


def test_roundtrip(tmp_path):
    p = str(tmp_path / "k.ragdb")
    segs = _segs()
    C.write_container(p, segs, meta={"x": 1}, generation=7)
    c = C.Container.open(p)
    assert c.generation == 7 and c.meta == {"x": 1}
    out = c.read_all()
    for k in segs:
        np.testing.assert_array_equal(out[k], segs[k])
    texts = C.decode_texts(out["content_blob"], out["content_offsets"])
    assert texts == ["hello", "world", "", "κόσμος"]


def test_corruption_detected(tmp_path):
    p = str(tmp_path / "k.ragdb")
    C.write_container(p, _segs())
    c = C.Container.open(p)
    data = bytearray(open(p, "rb").read())
    data[-3] ^= 0xFF  # flip a bit in the last segment
    open(p, "wb").write(bytes(data))
    c = C.Container.open(p)
    with pytest.raises(IOError, match="sha256 mismatch"):
        c.read_all(verify=True)


@pytest.mark.parametrize("verify", [True, False])
def test_truncated_file_detected(tmp_path, verify):
    """A short read (file truncated mid-segment) raises a clean IOError
    naming the segment in BOTH verify modes — it used to surface as an
    opaque frombuffer/reshape error (or silently wrong data)."""
    p = str(tmp_path / "k.ragdb")
    C.write_container(p, _segs())
    c = C.Container.open(p)
    last = max(c.segment_names(), key=lambda n: c._segments[n]["offset"])
    with open(p, "r+b") as f:
        f.truncate(os.path.getsize(p) - 5)  # cut into the last segment
    with pytest.raises(IOError, match=f"{last}: truncated segment"):
        c.read(last, verify=verify)


def test_bad_magic(tmp_path):
    p = str(tmp_path / "k.ragdb")
    open(p, "wb").write(b"NOTRAGDB" + b"\0" * 64)
    with pytest.raises(ValueError, match="bad magic"):
        C.Container.open(p)


def test_atomic_write_never_torn(tmp_path, monkeypatch):
    """A crash mid-write leaves the previous container byte-identical
    and no temp litter behind."""
    p = str(tmp_path / "k.ragdb")
    C.write_container(p, _segs(0))
    before = open(p, "rb").read()

    class Boom(Exception):
        pass

    def boom(_fd):
        raise Boom("simulated crash before publish")

    monkeypatch.setattr(os, "fsync", boom)
    with pytest.raises(Boom):
        C.write_container(p, _segs(1))
    monkeypatch.undo()
    assert open(p, "rb").read() == before
    assert not [f for f in os.listdir(tmp_path) if f.startswith(".ragdb-tmp")]


def test_sharded_generations(tmp_path):
    root = str(tmp_path / "kc")
    g0 = C.publish_sharded(root, [_segs(0), _segs(1)], meta={"v": 0})
    reader = C.ShardedContainer.open(root)  # pin generation 0
    g1 = C.publish_sharded(root, [_segs(2), _segs(3)], meta={"v": 1})
    assert (g0, g1) == (0, 1)
    # pinned reader still reads its generation's files
    assert reader.generation == 0
    np.testing.assert_array_equal(
        reader.open_shard(0).read("vec"), _segs(0)["vec"]
    )
    fresh = C.ShardedContainer.open(root)
    assert fresh.generation == 1 and fresh.meta == {"v": 1}


def test_content_addressing(tmp_path):
    """Identical shard data → identical file name (dedup-by-hash)."""
    root = str(tmp_path / "kc")
    C.publish_sharded(root, [_segs(5)])
    m1 = json.load(open(os.path.join(root, "manifest.json")))
    C.publish_sharded(root, [_segs(5)])
    m2 = json.load(open(os.path.join(root, "manifest.json")))
    assert m1["shards"][0]["file"] == m2["shards"][0]["file"]


def _shard_files(root):
    return sorted(f for f in os.listdir(root)
                  if f.startswith("shard-") and f.endswith(".ragdb"))


def test_sharded_gc_collects_stale_generations(tmp_path):
    """Repeated publishes no longer grow the directory without bound:
    files unreferenced by the new manifest (and outside the grace
    window) are collected."""
    root = str(tmp_path / "kc")
    C.publish_sharded(root, [_segs(0), _segs(1)], gc_grace=0)
    gen0_files = set(_shard_files(root))
    C.publish_sharded(root, [_segs(2), _segs(3)], gc_grace=0)
    C.publish_sharded(root, [_segs(4), _segs(6)], gc_grace=0)
    live = set(_shard_files(root))
    assert len(live) == 2  # only the current generation remains
    assert not (gen0_files & live)


def test_sharded_gc_grace_spares_prior_generation(tmp_path):
    """gc_grace=1 keeps the immediately prior generation's files so a
    pinned reader keeps working across one publish; two publishes later
    they are collected."""
    root = str(tmp_path / "kc")
    C.publish_sharded(root, [_segs(0)], gc_grace=1)
    reader = C.ShardedContainer.open(root)  # pin generation 0
    C.publish_sharded(root, [_segs(1)], gc_grace=1)
    # grace window: the pinned reader's file survived the publish
    np.testing.assert_array_equal(
        reader.open_shard(0).read("vec"), _segs(0)["vec"]
    )
    C.publish_sharded(root, [_segs(2)], gc_grace=1)
    assert len(_shard_files(root)) == 2  # gen 2 + graced gen 1; gen 0 gone
    with pytest.raises(FileNotFoundError):
        reader.open_shard(0).read("vec")


def test_publish_sharded_delta_journal_windows(tmp_path):
    """A delta publish appends per-shard journal patches (no shard-file
    rewrite); pinned readers see their generation's byte window only."""
    root = str(tmp_path / "kc")
    C.publish_sharded(root, [_segs(0), _segs(1)])
    base_files = set(_shard_files(root))
    r0 = C.ShardedContainer.open(root)

    patch = {"vec": np.full((4, 8), 7.0, np.float32)}
    g1 = C.publish_sharded_delta(root, {0: patch})
    assert g1 == 1
    assert set(_shard_files(root)) == base_files  # no new shard files
    r1 = C.ShardedContainer.open(root)

    # patched segment overlays; untouched segments fall through
    np.testing.assert_array_equal(r1.open_shard(0).read("vec"), patch["vec"])
    np.testing.assert_array_equal(
        r1.open_shard(0).read("sig"), _segs(0)["sig"]
    )
    np.testing.assert_array_equal(
        r1.open_shard(1).read("vec"), _segs(1)["vec"]
    )
    # the generation-0 reader still sees pre-patch data (window pinning)
    np.testing.assert_array_equal(r0.open_shard(0).read("vec"),
                                  _segs(0)["vec"])

    # a second delta chains on the first
    patch2 = {"sig": np.full((4, 16), 3, np.int32)}
    assert C.publish_sharded_delta(root, {0: patch2}) == 2
    r2 = C.ShardedContainer.open(root)
    np.testing.assert_array_equal(r2.open_shard(0).read("vec"), patch["vec"])
    np.testing.assert_array_equal(r2.open_shard(0).read("sig"),
                                  patch2["sig"])
    # r1 remains pinned to its window
    np.testing.assert_array_equal(r1.open_shard(0).read("sig"),
                                  _segs(0)["sig"])


def test_publish_sharded_delta_read_all_merges(tmp_path):
    root = str(tmp_path / "kc")
    C.publish_sharded(root, [_segs(0)])
    C.publish_sharded_delta(root, {0: {"extra": np.arange(3, dtype=np.int64)}})
    sc = C.ShardedContainer.open(root)
    out = sc.open_shard(0).read_all()
    assert "extra" in out and "vec" in out
    np.testing.assert_array_equal(out["extra"], np.arange(3, dtype=np.int64))


def test_full_publish_after_delta_drops_journal_overlay(tmp_path):
    """A full publish re-anchors the shard: new readers must not see the
    old journal patches, and once the grace window ages out the journal
    files are collected."""
    root = str(tmp_path / "kc")
    C.publish_sharded(root, [_segs(0)])
    C.publish_sharded_delta(
        root, {0: {"vec": np.full((4, 8), 9.0, np.float32)}}
    )
    C.publish_sharded(root, [_segs(0)])  # same content → same file name
    sc = C.ShardedContainer.open(root)
    np.testing.assert_array_equal(sc.open_shard(0).read("vec"),
                                  _segs(0)["vec"])
    C.publish_sharded(root, [_segs(7)], gc_grace=0)  # age the journal out
    assert not [f for f in os.listdir(root) if f.endswith(".ragdbj")]
