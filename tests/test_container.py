"""Knowledge-container format: integrity, atomicity, generations."""
import json
import os

import numpy as np
import pytest

from repro.core import container as C


def _segs(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "vec": rng.normal(size=(4, 8)).astype(np.float32),
        "sig": rng.integers(0, 100, size=(4, 16)).astype(np.int32),
        **C.encode_texts(["hello", "world", "", "κόσμος"]),
    }


def test_roundtrip(tmp_path):
    p = str(tmp_path / "k.ragdb")
    segs = _segs()
    C.write_container(p, segs, meta={"x": 1}, generation=7)
    c = C.Container.open(p)
    assert c.generation == 7 and c.meta == {"x": 1}
    out = c.read_all()
    for k in segs:
        np.testing.assert_array_equal(out[k], segs[k])
    texts = C.decode_texts(out["content_blob"], out["content_offsets"])
    assert texts == ["hello", "world", "", "κόσμος"]


def test_corruption_detected(tmp_path):
    p = str(tmp_path / "k.ragdb")
    C.write_container(p, _segs())
    c = C.Container.open(p)
    data = bytearray(open(p, "rb").read())
    data[-3] ^= 0xFF  # flip a bit in the last segment
    open(p, "wb").write(bytes(data))
    c = C.Container.open(p)
    with pytest.raises(IOError, match="sha256 mismatch"):
        c.read_all(verify=True)


def test_bad_magic(tmp_path):
    p = str(tmp_path / "k.ragdb")
    open(p, "wb").write(b"NOTRAGDB" + b"\0" * 64)
    with pytest.raises(ValueError, match="bad magic"):
        C.Container.open(p)


def test_atomic_write_never_torn(tmp_path, monkeypatch):
    """A crash mid-write leaves the previous container byte-identical
    and no temp litter behind."""
    p = str(tmp_path / "k.ragdb")
    C.write_container(p, _segs(0))
    before = open(p, "rb").read()

    class Boom(Exception):
        pass

    def boom(_fd):
        raise Boom("simulated crash before publish")

    monkeypatch.setattr(os, "fsync", boom)
    with pytest.raises(Boom):
        C.write_container(p, _segs(1))
    monkeypatch.undo()
    assert open(p, "rb").read() == before
    assert not [f for f in os.listdir(tmp_path) if f.startswith(".ragdb-tmp")]


def test_sharded_generations(tmp_path):
    root = str(tmp_path / "kc")
    g0 = C.publish_sharded(root, [_segs(0), _segs(1)], meta={"v": 0})
    reader = C.ShardedContainer.open(root)  # pin generation 0
    g1 = C.publish_sharded(root, [_segs(2), _segs(3)], meta={"v": 1})
    assert (g0, g1) == (0, 1)
    # pinned reader still reads its generation's files
    assert reader.generation == 0
    np.testing.assert_array_equal(
        reader.open_shard(0).read("vec"), _segs(0)["vec"]
    )
    fresh = C.ShardedContainer.open(root)
    assert fresh.generation == 1 and fresh.meta == {"v": 1}


def test_content_addressing(tmp_path):
    """Identical shard data → identical file name (dedup-by-hash)."""
    root = str(tmp_path / "kc")
    C.publish_sharded(root, [_segs(5)])
    m1 = json.load(open(os.path.join(root, "manifest.json")))
    C.publish_sharded(root, [_segs(5)])
    m2 = json.load(open(os.path.join(root, "manifest.json")))
    assert m1["shards"][0]["file"] == m2["shards"][0]["file"]
