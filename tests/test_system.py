"""End-to-end behaviour of the paper's system: corpus → incremental
ingestion → hybrid retrieval → RAG generation handoff (tiny LM decode),
plus the paper's RQ claims at test scale."""
import os

import jax
import numpy as np

from repro.configs import ARCHS
from repro.core.ingest import KnowledgeBase
from repro.core.rag import RAGPipeline
from repro.core.retrieval import Retriever
from repro.data.corpus import make_corpus, write_corpus_dir
from repro.models import transformer as T


def test_rq2_entity_recall_at_1(tmp_path):
    """Paper §5.3: hybrid search retrieves the injected entity doc at
    rank 1 — for every entity, by construction."""
    docs, entities = make_corpus(n_docs=200, n_entities=8, seed=3)
    src = str(tmp_path / "corpus")
    write_corpus_dir(src, docs)
    kb = KnowledgeBase(dim=2048)
    kb.sync(src)

    hybrid = Retriever(kb, alpha=1.0, beta=1.0)
    for code, doc_idx in entities.items():
        res = hybrid.query(code, k=1)[0]
        assert res.doc_id == f"doc_{doc_idx:05d}.txt", code
        assert res.boosted and res.score > 1.0


def test_rq1_incremental_speedup(tmp_path):
    """Paper §5.2: warm re-sync is at least 5× faster than cold ingest
    even at test scale (paper reports 31.6× at 1000 docs)."""
    docs, _ = make_corpus(n_docs=150, seed=1)
    src = str(tmp_path / "corpus")
    write_corpus_dir(src, docs)
    kb = KnowledgeBase(dim=1024)
    cold = kb.sync(src)
    warm = kb.sync(src)
    assert warm.processed == 0
    assert cold.seconds / max(warm.seconds, 1e-9) > 5.0


def test_rag_end_to_end_decode(tmp_path):
    """retrieve → pack context → prefill → decode a few tokens."""
    docs, entities = make_corpus(n_docs=50, n_entities=2, seed=5)
    src = str(tmp_path / "corpus")
    write_corpus_dir(src, docs)
    kb = KnowledgeBase(dim=1024)
    kb.sync(src)

    cfg = ARCHS["llama3.2-3b"].smoke_config
    params = T.init(jax.random.PRNGKey(0), cfg)
    rag = RAGPipeline(kb, params, cfg, max_context_tokens=96)

    code = next(iter(entities))
    out = rag.answer(f"what is {code}?", max_new_tokens=4, top_k_docs=2)
    assert len(out.retrieved) == 2
    assert out.retrieved[0].doc_id == f"doc_{entities[code]:05d}.txt"
    assert len(out.token_ids) == 4
    assert all(0 <= t < cfg.vocab for t in out.token_ids)
    # deterministic: same question → same tokens
    out2 = rag.answer(f"what is {code}?", max_new_tokens=4, top_k_docs=2)
    assert out.token_ids == out2.token_ids


def test_container_single_file_is_the_whole_state(tmp_path):
    """Paper §6.1 'right to be forgotten': one file holds everything;
    restoring from it reproduces retrieval exactly."""
    docs, entities = make_corpus(n_docs=60, n_entities=3, seed=9)
    src = str(tmp_path / "corpus")
    write_corpus_dir(src, docs)
    kb = KnowledgeBase(dim=1024)
    kb.sync(src)
    code = next(iter(entities))
    before = Retriever(kb).query(code, k=3)

    path = str(tmp_path / "knowledge.ragdb")
    kb.save(path)
    assert "knowledge.ragdb" in os.listdir(tmp_path)

    kb2 = KnowledgeBase.load(path)
    after = Retriever(kb2).query(code, k=3)
    assert [r.doc_id for r in before] == [r.doc_id for r in after]
    np.testing.assert_allclose([r.score for r in before],
                               [r.score for r in after], rtol=1e-6)


def test_hsf_kernel_path_matches_reference_retrieval(tmp_path):
    """Retriever(use_kernel=True) — the Pallas scoring path — returns
    the same ranking as the jnp path."""
    docs, entities = make_corpus(n_docs=64, n_entities=2, seed=11)
    src = str(tmp_path / "corpus")
    write_corpus_dir(src, docs)
    kb = KnowledgeBase(dim=1024)
    kb.sync(src)
    code = next(iter(entities))
    a = Retriever(kb, use_kernel=False).query(code, k=5)
    b = Retriever(kb, use_kernel=True).query(code, k=5)
    assert [r.doc_id for r in a] == [r.doc_id for r in b]
    np.testing.assert_allclose([r.score for r in a], [r.score for r in b],
                               rtol=1e-5)
