"""MACE equivariance properties, neighbor sampler, recsys smoke tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS
from repro.models.gnn import mace as M
from repro.models.gnn.sampler import CSRGraph, sample_subgraph
from repro.models.recsys import autoint, deepfm, dlrm, embedding
from repro.models.recsys.base import bce_with_logits

RNG = np.random.default_rng(0)


def _random_rotation(seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(3, 3))
    q, r = np.linalg.qr(a)
    q *= np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return q.astype(np.float32)


@pytest.fixture(scope="module")
def mace_setup():
    cfg = ARCHS["mace"].smoke_config
    params = M.init(jax.random.PRNGKey(0), cfg)
    N, E = 24, 80
    feats = jnp.asarray(RNG.normal(size=(N, cfg.d_feat)).astype(np.float32))
    pos = jnp.asarray(RNG.normal(size=(N, 3)).astype(np.float32) * 2)
    snd = jnp.asarray(RNG.integers(0, N, size=E).astype(np.int32))
    rcv = jnp.asarray(RNG.integers(0, N, size=E).astype(np.int32))
    return cfg, params, feats, pos, snd, rcv


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_mace_e3_invariance(seed):
    """Energy invariant under any rotation + translation (exact property
    of the invariant product basis)."""
    cfg = ARCHS["mace"].smoke_config
    params = M.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    N, E = 16, 40
    feats = jnp.asarray(rng.normal(size=(N, cfg.d_feat)).astype(np.float32))
    pos = rng.normal(size=(N, 3)).astype(np.float32)
    snd = jnp.asarray(rng.integers(0, N, size=E).astype(np.int32))
    rcv = jnp.asarray(rng.integers(0, N, size=E).astype(np.int32))
    R = _random_rotation(seed)
    t = rng.normal(size=(1, 3)).astype(np.float32)
    _, e0 = M.forward(params, feats, jnp.asarray(pos), snd, rcv, cfg)
    _, e1 = M.forward(params, feats, jnp.asarray(pos @ R.T + t), snd, rcv,
                      cfg)
    np.testing.assert_allclose(float(e0[0]), float(e1[0]), rtol=2e-4,
                               atol=2e-4)


def test_mace_force_equivariance(mace_setup):
    cfg, params, feats, pos, snd, rcv = mace_setup
    R = jnp.asarray(_random_rotation(3))
    e1, f1 = M.energy_and_forces(params, feats, pos, snd, rcv, cfg)
    e2, f2 = M.energy_and_forces(params, feats, pos @ R.T, snd, rcv, cfg)
    np.testing.assert_allclose(float(e1), float(e2), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f1 @ R.T),
                               rtol=1e-3, atol=1e-3)


def test_mace_edge_mask_drops_edges(mace_setup):
    cfg, params, feats, pos, snd, rcv = mace_setup
    E = snd.shape[0]
    mask = jnp.ones((E,)).at[10:].set(0.0)
    _, e_masked = M.forward(params, feats, pos, snd, rcv, cfg,
                            edge_mask=mask)
    _, e_trunc = M.forward(params, feats, pos, snd[:10], rcv[:10], cfg)
    np.testing.assert_allclose(float(e_masked[0]), float(e_trunc[0]),
                               rtol=1e-5)


def test_mace_batched_graphs_independent(mace_setup):
    """Energies of disjoint graphs don't leak into each other."""
    cfg, params, feats, pos, snd, rcv = mace_setup
    N = feats.shape[0]
    gid = jnp.asarray((np.arange(N) >= N // 2).astype(np.int32))
    # edges only within first half
    snd2 = snd % (N // 2)
    rcv2 = rcv % (N // 2)
    _, both = M.forward(params, feats, pos, snd2, rcv2, cfg,
                        graph_ids=gid, n_graphs=2)
    _, first = M.forward(params, feats[: N // 2], pos[: N // 2],
                         snd2, rcv2, cfg)
    np.testing.assert_allclose(float(both[0]), float(first[0]), rtol=1e-5)


def test_sampler_shapes_and_validity():
    n, e = 200, 1200
    snd = RNG.integers(0, n, size=e)
    rcv = RNG.integers(0, n, size=e)
    g = CSRGraph(n, snd, rcv)
    sub = sample_subgraph(g, np.arange(16), (5, 3), np.random.default_rng(1))
    assert sub.node_ids.shape == (16 * (1 + 5 + 15),)
    assert sub.senders.shape == (16 * (5 + 15),)
    # every valid edge points at a valid node slot
    ok = sub.edge_mask
    assert (sub.receivers[ok] < len(sub.node_mask)).all()
    assert sub.node_mask[sub.receivers[ok]].all()
    assert sub.node_mask[sub.senders[ok]].all()
    assert sub.seed_mask.sum() == 16


def test_sampler_deterministic():
    g = CSRGraph(50, RNG.integers(0, 50, 300), RNG.integers(0, 50, 300))
    s1 = sample_subgraph(g, np.arange(4), (3, 2), np.random.default_rng(7))
    s2 = sample_subgraph(g, np.arange(4), (3, 2), np.random.default_rng(7))
    np.testing.assert_array_equal(s1.node_ids, s2.node_ids)
    np.testing.assert_array_equal(s1.senders, s2.senders)


# ---------------------------------------------------------------------------
# recsys
# ---------------------------------------------------------------------------

RECSYS = {"dlrm-rm2": dlrm, "dlrm-mlperf": dlrm, "deepfm": deepfm,
          "autoint": autoint}


@pytest.mark.parametrize("arch_id", sorted(RECSYS))
def test_recsys_smoke_train_step(arch_id):
    cfg = ARCHS[arch_id].smoke_config
    mod = RECSYS[arch_id]
    params = mod.init(jax.random.PRNGKey(0), cfg)
    B = 16
    sparse = jnp.asarray(np.stack(
        [RNG.integers(0, v, size=B) for v in cfg.vocab_sizes], axis=1
    ).astype(np.int32))
    dense = jnp.asarray(RNG.normal(size=(B, cfg.n_dense)).astype(np.float32)) \
        if cfg.n_dense else None
    labels = jnp.asarray(RNG.integers(0, 2, size=B).astype(np.float32))

    def loss_fn(p):
        return bce_with_logits(mod.forward(p, dense, sparse, cfg), labels)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(g)).all()
               for g in jax.tree.leaves(grads))
    out = mod.forward(params, dense, sparse, cfg)
    assert out.shape == (B,)
    # training for a few steps reduces loss on a fixed batch
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    opt = adamw_init(params)
    acfg = AdamWConfig(lr=3e-2, weight_decay=0.0)
    l0 = float(loss_fn(params))
    for _ in range(8):
        _, g = jax.value_and_grad(loss_fn)(params)
        params, opt = adamw_update(g, opt, params, acfg)
    assert float(loss_fn(params)) < l0


@pytest.mark.parametrize("arch_id", sorted(RECSYS))
def test_recsys_retrieval_scores(arch_id):
    cfg = ARCHS[arch_id].smoke_config
    mod = RECSYS[arch_id]
    params = mod.init(jax.random.PRNGKey(0), cfg)
    n_cand = 100
    if cfg.n_dense:
        q = jnp.asarray(RNG.normal(size=(1, cfg.n_dense)).astype(np.float32))
    else:
        q = jnp.asarray(np.stack(
            [RNG.integers(0, v, size=1) for v in cfg.vocab_sizes], axis=1
        ).astype(np.int32))
    scores = mod.retrieval_scores(params, q, jnp.arange(n_cand), cfg)
    assert scores.shape == (n_cand,)
    v, i = jax.lax.top_k(scores, 5)
    assert np.unique(np.asarray(i)).size == 5


def test_embedding_bag_path_matches_lookup():
    """Multi-hot bag with one index per bag == one-hot lookup."""
    vocabs = (20, 30)
    table = embedding.init_tables(jax.random.PRNGKey(0), vocabs, 16)["table"]
    offs = embedding.field_offsets(vocabs)
    idx = jnp.asarray([[3, 7], [11, 2]], jnp.int32)  # [B=2, F=2]
    ref = embedding.lookup(table, offs, idx).sum(axis=1)
    flat_idx = idx.reshape(-1)
    field_ids = jnp.asarray([0, 1, 0, 1], jnp.int32)
    bag_ids = jnp.asarray([0, 0, 1, 1], jnp.int32)
    out = embedding.lookup_bags(table, offs, flat_idx, field_ids, bag_ids, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)
    # kernel path agrees
    out_k = embedding.lookup_bags(table, offs, flat_idx, field_ids, bag_ids,
                                  2, use_kernel=True)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(ref), rtol=1e-4)
