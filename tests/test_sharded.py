"""Distribution-layer tests that need >1 device: run in subprocesses
with XLA_FLAGS=--xla_force_host_platform_device_count so the main pytest
process keeps its single-device view."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
TESTS = os.path.abspath(os.path.dirname(__file__))


def run_with_devices(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    # tests dir too, so subprocess snippets can use conftest helpers
    # (assert_bit_identical) for the same comparisons the in-process
    # suites make
    env["PYTHONPATH"] = SRC + os.pathsep + TESTS
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_sharded_retrieval_equals_single_device():
    run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import retrieval
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        rng = np.random.default_rng(1)
        n, D, W = 173, 512, 128
        vecs = rng.normal(size=(n, D)).astype(np.float32)
        vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
        sigs = rng.integers(0, 2**31, size=(n, W)).astype(np.int32)
        pv, ps, nd = retrieval.pad_corpus(vecs, sigs, 8)
        qv = rng.normal(size=(5, D)).astype(np.float32)
        qs = np.stack([sigs[i] for i in [0, 50, 100, 150, 172]]).astype(np.int32)
        ret = retrieval.build_sharded_retrieve(mesh, ("data", "model"), nd, k=7)
        pv_d = jax.device_put(pv, NamedSharding(mesh, P(("data","model"), None)))
        ps_d = jax.device_put(ps, NamedSharding(mesh, P(("data","model"), None)))
        vals, ids = jax.jit(ret)(pv_d, ps_d, jnp.asarray(qv), jnp.asarray(qs))
        rv, ri = retrieval.single_device_reference(pv, ps, qv, qs, nd, 7)
        from conftest import assert_bit_identical
        assert_bit_identical((vals, ids), (rv, ri), score_rtol=1e-6)
        print("OK")
    """)


def test_sharded_retrieval_kernel_path_equals_single_device():
    """The fused batched Pallas kernel per shard (in-kernel top-k +
    SMEM n_valid padding mask) merges to the same global top-k as the
    unsharded oracle — ids exact, scores to f32 resolution."""
    run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import retrieval
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        rng = np.random.default_rng(2)
        n, D, W = 173, 512, 128
        vecs = rng.normal(size=(n, D)).astype(np.float32)
        vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
        sigs = rng.integers(0, 2**31, size=(n, W)).astype(np.int32)
        pv, ps, nd = retrieval.pad_corpus(vecs, sigs, 8)
        qv = rng.normal(size=(5, D)).astype(np.float32)
        qs = np.stack([sigs[i] for i in [0, 50, 100, 150, 172]]).astype(np.int32)
        ret = retrieval.build_sharded_retrieve(mesh, ("data", "model"), nd,
                                               k=7, use_kernel=True)
        pv_d = jax.device_put(pv, NamedSharding(mesh, P(("data","model"), None)))
        ps_d = jax.device_put(ps, NamedSharding(mesh, P(("data","model"), None)))
        vals, ids = jax.jit(ret)(pv_d, ps_d, jnp.asarray(qv), jnp.asarray(qs))
        rv, ri = retrieval.single_device_reference(pv, ps, qv, qs, nd, 7)
        from conftest import assert_bit_identical
        assert_bit_identical((vals, ids), (rv, ri),
                             score_rtol=1e-5, score_atol=1e-6)
        print("OK")
    """)


def test_sharded_lm_train_step_runs_and_matches_single():
    """One real train step on a 4×2 mesh == the same step on 1 device."""
    run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.launch import steps
        from repro.configs import ARCHS
        from repro.models import transformer as T
        from repro.optim import adamw_init

        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = ARCHS["llama3.2-3b"].smoke_config
        params = T.init(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab, size=(2, 8, 32)).astype(np.int32)
        tgts = rng.integers(0, cfg.vocab, size=(2, 8, 32)).astype(np.int32)

        # sharded
        step = steps.make_lm_train_step(cfg, mesh, n_micro=2)
        p1, o1, loss1 = jax.jit(step)(params, opt, jnp.asarray(toks),
                                      jnp.asarray(tgts))
        # single-device reference
        mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                              axis_types=(jax.sharding.AxisType.Auto,)*2)
        step1 = steps.make_lm_train_step(cfg, mesh1, n_micro=2)
        p2, o2, loss2 = jax.jit(step1)(params, opt, jnp.asarray(toks),
                                       jnp.asarray(tgts))
        assert abs(float(loss1) - float(loss2)) < 1e-4, (loss1, loss2)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-3)
        print("loss", float(loss1))
    """)


def test_sharded_moe_matches_unsharded():
    run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.models import moe as moe_mod
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = moe_mod.MoEConfig(n_experts=8, top_k=2, d_ff_expert=32)
        params = moe_mod.init(jax.random.PRNGKey(0), cfg, 64)
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(16, 64)).astype(np.float32))
        out_plain, aux_plain = moe_mod.apply(params, x, cfg)
        with moe_mod.sharding_ctx(mesh, ("data",)):
            out_shard, aux_shard = jax.jit(
                lambda p, x: moe_mod.apply(p, x, cfg))(params, x)
        np.testing.assert_allclose(np.asarray(out_plain),
                                   np.asarray(out_shard),
                                   rtol=1e-4, atol=1e-5)
        assert abs(float(aux_plain) - float(aux_shard)) < 1e-6
        print("OK")
    """)


def test_sharded_embedding_lookup_matches():
    run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.models.recsys import embedding as E
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        vocabs = (100, 200, 50)
        table = E.init_tables(jax.random.PRNGKey(0), vocabs, 16)["table"]
        offs = E.field_offsets(vocabs)
        idx = jnp.asarray(np.random.default_rng(0).integers(
            0, 50, size=(24, 3)).astype(np.int32))
        plain = E.lookup(table, offs, idx)
        with E.sharding_ctx(mesh, "model"):
            sharded = jax.jit(lambda t, i: E.lookup(t, offs, i))(table, idx)
        np.testing.assert_allclose(np.asarray(plain), np.asarray(sharded),
                                   rtol=1e-6)
        # gradients flow through the psum lookup
        with E.sharding_ctx(mesh, "model"):
            g = jax.grad(lambda t: E.lookup(t, offs, idx).sum())(table)
        assert float(jnp.abs(g).sum()) > 0
        print("OK")
    """)


def test_multipod_mesh_builds_and_lowers():
    """3-axis (pod, data, model) mesh: the pod axis shards."""
    run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.launch import steps
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*3)
        cell = steps.build_cell("llama3.2-3b", "train_4k", mesh, smoke=True)
        compiled = cell.fn.lower(*cell.args).compile()
        txt = compiled.as_text()
        assert "all-reduce" in txt or "all-gather" in txt
        print("OK")
    """, n_devices=8)


def test_compressed_psum_wire_int8():
    """optim.compress.compressed_psum: the all-reduce payload really is
    int8/int32 on the wire, and the result ≈ the mean of shard grads."""
    run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.optim.compress import compressed_psum
        mesh = jax.make_mesh((8,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(0)
        g = rng.normal(size=(8, 64)).astype(np.float32)

        def body(gs):
            return compressed_psum({"g": gs[0]}, "pod")["g"]

        f = jax.jit(jax.shard_map(
            lambda gs: body(gs), mesh=mesh,
            in_specs=P("pod"), out_specs=P("pod"), check_vma=False))
        gd = jax.device_put(g, NamedSharding(mesh, P("pod")))
        out = np.asarray(f(gd)).reshape(8, 64)  # out_specs stacks shards
        mean = g.mean(axis=0)
        # every shard holds the same (approximate) mean
        for i in range(8):
            np.testing.assert_allclose(out[i], mean, atol=0.05)
        # wire dtype check: int32 (packed int8 accum) collective in HLO
        txt = f.lower(gd).compile().as_text()
        assert "s32" in txt and "all-reduce" in txt
        print("OK")
    """)
