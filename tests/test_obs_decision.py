"""Decision-plane observability contracts (obs/explain, obs/ledger,
obs/health): EXPLAIN plans agree with ``index_stats()`` and tile the
request span decomposition; the resource ledger's per-plane accounting
matches what the container pool evicts on; and the SLO health monitor
transitions ok → degraded → critical under injected faults."""
import json
import threading

import pytest

from repro.core.engine import QueryEngine
from repro.core.ingest import KnowledgeBase
from repro.obs import trace as obs_trace
from repro.obs.explain import QueryPlan, load_plans, write_plans
from repro.obs.health import HealthMonitor, SLOTargets
from repro.obs.ledger import (
    DEVICE_PLANES,
    RESIDENT_PLANES,
    ResourceLedger,
    measure_engine_planes,
)
from repro.obs.metrics import LogHistogram, MetricsRegistry
from repro.serving import ServingRuntime

DIM = 256


def _kb(n_docs: int = 40) -> KnowledgeBase:
    kb = KnowledgeBase(dim=DIM)
    for i in range(n_docs):
        kb.add_text(f"doc_{i:03d}.txt",
                    f"alpha beta entity INV-{i:04d} report gamma {i}")
    return kb


# ---- EXPLAIN --------------------------------------------------------------


class TestExplain:
    def test_plain_path_unchanged(self):
        """explain=False returns the bare results (no tuple) and the
        stats carry no per-query explain payload."""
        eng = QueryEngine(_kb(), index="ivf", nprobe=2)
        out = eng.query_batch(["alpha INV-0003"], k=3)
        assert isinstance(out, list) and len(out[0]) == 3
        assert eng._last_index_stats.probe_order == ()

    def test_ivf_exact_plan_matches_index_stats(self):
        """The acceptance criterion: an ivf exact-mode plan's
        probed/widened/bound values are consistent with
        ``index_stats()``, and the kth score dominates the unprobed
        bound (the exactness certificate)."""
        eng = QueryEngine(_kb(60), index="ivf", nprobe=2,
                          guarantee="exact")
        out, plans = eng.query_batch(
            ["lookup INV-0007 status", "alpha gamma report"],
            k=3, explain=True)
        stats = eng.index_stats()
        assert len(plans) == 2
        for p, rows in zip(plans, out):
            assert p.index == "ivf" and p.guarantee == "exact"
            assert p.clusters_probed == stats["clusters_probed"]
            assert p.n_clusters == stats["n_clusters"]
            assert p.rounds == stats["rounds"]
            assert p.rows_gathered == stats["candidate_rows"]
            assert len(p.probe_order) >= 1
            assert len(rows) == 3
            if p.unprobed_bound is not None:
                assert p.kth_score >= p.unprobed_bound
            assert p.stages  # engine stage durations captured
            assert "EXPLAIN" in p.render()

    def test_probe_mode_plan(self):
        eng = QueryEngine(_kb(60), index="ivf", nprobe=1)
        _, plans = eng.query_batch(["alpha INV-0001"], k=2, explain=True)
        p = plans[0]
        assert p.guarantee == "probe"
        assert p.clusters_probed <= p.n_clusters
        assert p.kth_score is not None

    def test_flat_plan_and_vector_cache(self):
        eng = QueryEngine(_kb())
        eng.query_batch(["alpha INV-0001"], k=2)  # warm the vector LRU
        _, plans = eng.query_batch(
            ["alpha INV-0001", "never seen before"], k=2, explain=True)
        assert plans[0].vector_cache == "hit"
        assert plans[1].vector_cache == "miss"
        assert plans[0].index == "flat"
        assert plans[0].n_docs == 40

    def test_plan_roundtrip_and_cli(self, tmp_path, capsys):
        eng = QueryEngine(_kb(), index="ivf", nprobe=2, guarantee="exact")
        _, plans = eng.query_batch(["alpha INV-0002"], k=2, explain=True)
        path = tmp_path / "plans.json"
        write_plans(str(path), plans, extra={"rendered": plans[0].render()})
        loaded = load_plans(str(path))
        assert loaded[0].to_dict() == plans[0].to_dict()
        from repro.obs.__main__ import main as obs_main
        assert obs_main(["explain", str(path)]) == 0
        out = capsys.readouterr().out
        assert "EXPLAIN" in out and "probe:" in out
        assert obs_main(["explain", str(path / "missing")]) == 2

    def test_no_tracer_spans_leak_from_collector(self):
        """EXPLAIN stage collection with the tracer disabled must not
        buffer spans (plan capture is collector-only)."""
        tracer = obs_trace.get()
        tracer.disable()
        tracer.drain()
        eng = QueryEngine(_kb())
        eng.query_batch(["alpha"], k=2, explain=True)
        assert tracer.drain() == []


class TestServingExplain:
    def test_request_stages_tile_and_caches(self):
        kb = _kb()
        rt = ServingRuntime(kb, max_batch=4, flush_deadline=0.002)
        with rt:
            served = rt.submit("lookup INV-0007 status", k=3,
                               explain=True).result(timeout=60)
            p = served.plan
            assert p is not None and p.result_cache == "miss"
            assert p.generation == served.generation
            names = [n for n, _ in p.request_stages]
            assert names == ["queue_wait", "flush_wait", "score", "merge"]
            residual = abs(sum(d for _, d in p.request_stages) - p.total_s)
            # the stages share the exact timestamps the span plane
            # records, so they tile end-to-end latency by construction
            assert residual < 1e-9
            # second submit: result-cache hit plan, no scoring dispatch
            served2 = rt.submit("lookup INV-0007 status", k=3,
                                explain=True).result(timeout=60)
            assert served2.cached
            assert served2.plan.result_cache == "hit"
            assert served2.plan.stages == ()
            assert "HIT" in served2.plan.render()

    def test_coalesced_fanout(self):
        """Two identical in-flight requests coalesce into one scoring
        dispatch; both plans report the fanout."""
        rt = ServingRuntime(_kb(), max_batch=2, flush_deadline=0.5,
                            result_cache_size=0)
        with rt:
            f1 = rt.submit("alpha INV-0001", k=2, explain=True)
            f2 = rt.submit("alpha INV-0001", k=2, explain=True)
            p1, p2 = f1.result(timeout=60).plan, f2.result(timeout=60).plan
        assert p1.coalesced == 2 and p2.coalesced == 2
        assert p1.result_cache == "bypass"  # cache disabled for this run

    def test_submit_without_explain_has_no_plan(self):
        rt = ServingRuntime(_kb(), max_batch=4, flush_deadline=0.002)
        with rt:
            served = rt.submit("alpha", k=2).result(timeout=60)
        assert served.plan is None


# ---- resource ledger ------------------------------------------------------


class TestLedger:
    def test_update_and_drop(self):
        reg = MetricsRegistry()
        led = ResourceLedger(registry=reg)
        led.update("a", {"doc_matrix": 1000, "result_cache": 50},
                   generation=3)
        led.update("a", {"ivf_state": 200}, generation=4)  # merge
        assert led.tenant_bytes("a") == 1250
        assert led.tenant_bytes("a", planes=DEVICE_PLANES) == 1200
        snap = led.snapshot()
        assert snap["tenants"]["a"]["generation"] == 4
        assert snap["resident_bytes"] == 1250
        assert reg.snapshot()["ragdb_resident_bytes{plane=doc_matrix,tenant=a}"] == 1000
        led.drop_tenant("a")
        assert led.tenant_bytes("a") == 0
        assert "ragdb_resident_bytes" not in "".join(reg.snapshot())

    def test_measure_engine_planes(self):
        kb = _kb()
        eng = QueryEngine(kb, index="ivf", nprobe=2)
        eng.query_batch(["alpha"], k=2)  # materialize device state
        planes = measure_engine_planes(eng)
        assert planes["doc_matrix"] > 0
        assert planes["ivf_state"] > 0
        assert planes["container"] > 0
        assert set(planes) <= set(RESIDENT_PLANES)

    def test_runtime_resources_snapshot(self):
        rt = ServingRuntime(_kb(), max_batch=4, flush_deadline=0.002)
        with rt:
            rt.submit("alpha INV-0001", k=2).result(timeout=60)
            rt.submit("alpha INV-0001", k=2).result(timeout=60)  # cache it
            res = rt.resources()
        t = res["tenants"]["default"]
        assert t["planes"]["doc_matrix"] > 0
        assert t["planes"]["result_cache"] > 0  # one cached entry
        assert res["resident_bytes"] >= res["device_bytes"] > 0


# ---- SLO health monitor ---------------------------------------------------


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class _FakeMetrics:
    def __init__(self):
        self.hist = LogHistogram()
        self.s = dict(requests=0, completed=0, rejected=0, failed=0,
                      cache_hits=0, cache_misses=0)

    def health_sample(self):
        return dict(self.s, latency_buckets=self.hist.bucket_snapshot())


def _monitor(**targets):
    clock = _FakeClock()
    fm = _FakeMetrics()
    t = SLOTargets(**{**dict(error_rate=0.2, p99_ms=None, reject_rate=None,
                             fast_window_s=1.0, slow_window_s=10.0,
                             min_samples=5), **targets})
    return HealthMonitor(fm, targets=t, registries=(), clock=clock), fm, clock


class TestHealthMonitor:
    def test_ok_degraded_critical_transitions(self):
        """The acceptance criterion: injected failures walk the monitor
        ok → degraded (fast-window burn ≥ 1x) → critical (fast ≥ 2x
        with slow-window confirmation)."""
        mon, fm, clock = _monitor()

        def tick(n_req, n_fail):
            clock.t += 1.0
            fm.s["requests"] += n_req
            fm.s["completed"] += n_req - n_fail
            fm.s["failed"] += n_fail
            fm.hist.record(0.01)
            return mon.check()

        for _ in range(10):
            out = tick(10, 0)
        assert out["status"] == "ok"
        for _ in range(2):
            out = tick(10, 3)  # 30% failures: burn 1.5x in fast window
        assert out["status"] == "degraded"
        assert any("error_rate" in r for r in out["reasons"])
        for _ in range(3):
            out = tick(10, 10)  # sustained 100% failures
        assert out["status"] == "critical"

    def test_latency_burn(self):
        mon, fm, clock = _monitor(error_rate=None, p99_ms=50.0)

        def tick(lat_s):
            clock.t += 1.0
            fm.s["requests"] += 10
            fm.s["completed"] += 10
            for _ in range(10):
                fm.hist.record(lat_s)
            return mon.check()

        for _ in range(5):
            out = tick(0.01)
        assert out["status"] == "ok"
        for _ in range(3):
            out = tick(0.5)  # p99 10x the 50 ms target, sustained
        assert out["status"] == "critical"
        assert any("p99" in r for r in out["reasons"])

    def test_min_samples_guard(self):
        """Thin traffic never judges the rate SLOs (no flapping on
        2-request windows)."""
        mon, fm, clock = _monitor(min_samples=50)
        for _ in range(5):
            clock.t += 1.0
            fm.s["requests"] += 2
            fm.s["failed"] += 2  # 100% failures, but thin
            out = mon.check()
        assert out["status"] == "ok"
        assert "min_samples" in out["signals"].get("note", "")

    def test_sanitizer_trip_is_critical(self):
        reg = MetricsRegistry()
        clock = _FakeClock()
        fm = _FakeMetrics()
        mon = HealthMonitor(
            fm, targets=SLOTargets(fast_window_s=1.0, slow_window_s=10.0),
            registries=(reg,), clock=clock)
        clock.t = 1.0
        mon.check()
        reg.counter("ragdb_sanitizer_trips_total", kind="nonfinite").inc()
        clock.t = 2.0
        out = mon.check()
        assert out["status"] == "critical"
        assert any("sanitizer" in r for r in out["reasons"])

    def test_widen_spike_degrades(self):
        reg = MetricsRegistry()
        clock = _FakeClock()
        fm = _FakeMetrics()
        mon = HealthMonitor(
            fm, targets=SLOTargets(widen_rounds_mean=3.0,
                                   fast_window_s=1.0, slow_window_s=10.0),
            registries=(reg,), clock=clock)
        clock.t = 1.0
        mon.check()
        for _ in range(4):
            reg.histogram("ragdb_ivf_widen_rounds").record(6.0)
        clock.t = 2.0
        out = mon.check()
        assert out["status"] == "degraded"
        assert any("widen" in r for r in out["reasons"])

    def test_publish_lag_detector(self):
        reg = MetricsRegistry()
        clock = _FakeClock()
        fm = _FakeMetrics()
        mon = HealthMonitor(
            fm, targets=SLOTargets(publish_lag_s=5.0, fast_window_s=1.0,
                                   slow_window_s=10.0),
            registries=(reg,), clock=clock)
        clock.t = 1.0
        mon.check()
        reg.gauge("ragdb_publish_lag_seconds", tenant="a").set(30.0)
        clock.t = 2.0
        out = mon.check()
        assert out["status"] == "degraded"
        assert any("publish lag" in r and "a" in r for r in out["reasons"])

    def test_runtime_health_exports(self):
        """ServingRuntime.health() returns a verdict and exports the
        status gauge into the runtime registry (Prometheus-visible)."""
        rt = ServingRuntime(_kb(), max_batch=4, flush_deadline=0.002,
                            slo=SLOTargets(p99_ms=10_000.0))
        with rt:
            rt.submit("alpha", k=2).result(timeout=60)
            h1 = rt.health()
            h2 = rt.health()
            text = rt.render_metrics()
        assert h1["status"] == "ok" and h2["status"] == "ok"
        assert "ragdb_health_status 0" in text
        assert json.dumps(h2)  # verdict is JSON-serializable


# ---- tenant trace filter (the --tenant CLI plane) -------------------------


class TestTenantTraces:
    def _spans(self):
        from repro.obs import SpanRecord
        mk = SpanRecord
        return [
            mk("request", 1, 10, 0, 0, 5_000_000, 0, {"tenant": "a"}),
            mk("score", 1, 11, 10, 0, 4_000_000, 0, {}),
            mk("request", 2, 20, 0, 0, 7_000_000, 0, {"tenant": "b"}),
            mk("request", 3, 30, 0, 0, 1_000_000, 0, {}),
        ]

    def test_filter_keeps_whole_traces(self):
        from repro.obs.export import filter_tenant_traces
        kept = filter_tenant_traces(self._spans(), "a")
        assert {r.trace_id for r in kept} == {1}
        assert {r.name for r in kept} == {"request", "score"}

    def test_tenant_breakdown(self):
        from repro.obs.export import tenant_breakdown
        tb = tenant_breakdown(self._spans())
        assert set(tb) == {"a", "b", "-"}
        assert tb["a"]["count"] == 1
        assert tb["b"]["p99_s"] == pytest.approx(0.007)

    def test_format_breakdown_has_tenant_table(self):
        from repro.obs.export import format_breakdown
        out = format_breakdown(self._spans())
        assert "tenant" in out  # the per-tenant table header
        tenant_rows = [ln for ln in out.splitlines()
                       if ln.startswith(("a ", "b ", "- "))]
        assert len(tenant_rows) == 3

    def test_no_tenant_table_for_unlabeled_traces(self):
        from repro.obs import SpanRecord
        from repro.obs.export import format_breakdown
        spans = [SpanRecord("request", 1, 10, 0, 0, 5_000_000, 0, {})]
        assert "tenant" not in format_breakdown(spans)
