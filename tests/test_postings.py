"""⟨I⟩-region postings index + prefiltered retrieval path."""
import time

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.ingest import KnowledgeBase
from repro.core.postings import PostingsIndex
from repro.core.retrieval import Retriever
from repro.core.tokenizer import TermCounts
from repro.data.corpus import make_corpus


def test_postings_build_and_lookup():
    docs = ["alpha beta", "beta gamma", "alpha gamma delta"]
    tcs = [TermCounts.from_text(d) for d in docs]
    pi = PostingsIndex.build(tcs)
    assert list(pi.docs_with_term("alpha")) == [0, 2]
    assert list(pi.docs_with_term("beta")) == [0, 1]
    assert list(pi.docs_with_term("nothere")) == []
    assert list(pi.candidates("alpha beta")) == [0, 1, 2]
    assert list(pi.candidates("alpha gamma", mode="intersect")) == [2]


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_postings_complete_per_doc(seed):
    """Every (term, doc) pair is recoverable — the index is lossless."""
    rng = np.random.default_rng(seed)
    words = [f"w{i}" for i in range(30)]
    docs = [" ".join(rng.choice(words, size=rng.integers(1, 20)))
            for _ in range(rng.integers(1, 15))]
    tcs = [TermCounts.from_text(d) for d in docs]
    pi = PostingsIndex.build(tcs)
    for i, d in enumerate(docs):
        for w in set(d.split()):
            assert i in pi.docs_with_term(w), (w, i)


def test_prefiltered_query_matches_full_scan():
    """For whole-token queries (entity codes), prefilter returns the
    same top-1 as the full HSF scan."""
    docs, entities = make_corpus(n_docs=300, n_entities=10, seed=2)
    kb = KnowledgeBase(dim=2048)
    for i, d in enumerate(docs):
        kb.add_text(f"doc_{i:05d}.txt", d)
    full = Retriever(kb)
    fast = Retriever(kb, prefilter=True)
    for code, idx in entities.items():
        a = full.query(code, k=1)[0]
        b = fast.query(code, k=1)[0]
        assert a.doc_id == b.doc_id == f"doc_{idx:05d}.txt"
        assert abs(a.score - b.score) < 1e-5


def test_postings_survive_container_roundtrip(tmp_path):
    kb = KnowledgeBase(dim=512)
    kb.add_text("a", "alpha CODE9 beta")
    kb.add_text("b", "gamma delta")
    p = str(tmp_path / "k.ragdb")
    kb.save(p)
    kb2 = KnowledgeBase.load(p)
    assert list(kb2.postings().docs_with_term("code9")) == [0]
    r = Retriever(kb2, prefilter=True)
    assert r.query("CODE9", k=1)[0].doc_id == "a"


def test_postings_rebuilt_when_container_lacks_segments(tmp_path):
    """Regression: a container carrying a matrix but no postings
    segments (pre-postings format) loads with `_postings=None` and a
    clean matrix, so materialize() skips the rebuild —
    `KnowledgeBase.postings()` must rebuild instead of returning None
    (which broke `Retriever(prefilter=True)`)."""
    from repro.core.container import Container, write_container

    kb = KnowledgeBase(dim=512)
    kb.add_text("a", "alpha CODE9 beta")
    kb.add_text("b", "gamma delta")
    p = str(tmp_path / "k.ragdb")
    kb.save(p)

    c = Container.open(p)
    segs = {k: v for k, v in c.read_all().items()
            if not k.startswith("post_")}
    old = str(tmp_path / "old.ragdb")
    write_container(old, segs, c.meta, 0)

    kb2 = KnowledgeBase.load(old)
    assert kb2._postings is None and not kb2._dirty  # the broken state
    pi = kb2.postings()
    assert pi is not None
    assert list(pi.docs_with_term("code9")) == [0]
    r = Retriever(kb2, prefilter=True)
    assert r.query("CODE9", k=1)[0].doc_id == "a"


def test_unselective_query_falls_back():
    """A query hitting most docs returns None from candidates() (full
    scan is cheaper) and the retriever still answers correctly."""
    kb = KnowledgeBase(dim=512)
    for i in range(50):
        kb.add_text(f"d{i}", f"common filler words item{i}")
    pi = kb.postings()
    assert pi.candidates("common", max_candidates=10) is None
    r = Retriever(kb, prefilter=True)
    assert r.query("common item7", k=1)[0].doc_id == "d7"
