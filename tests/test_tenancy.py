"""Tenancy-plane contracts (tenancy/ + the multi-tenant serving mode):
lazy mounts, LRU eviction under budget with durability-before-teardown,
refcount pins as the teardown barrier, token-bucket admission, tenant
keyspace isolation in the result cache, per-tenant metrics — and the
two parity anchors: per-tenant results bit-identical to a direct
engine over that tenant's KB, and the single-tenant path bit-identical
through the pool machinery.
"""
import threading

import pytest

from repro.analysis import sanitizers
from repro.core.engine import QueryEngine
from repro.core.ingest import KnowledgeBase
from repro.data.corpus import make_corpus
from repro.obs.metrics import MetricsRegistry
from repro.serving import RequestRejected, ServingRuntime
from repro.tenancy import (
    ContainerPool,
    DEFAULT_TENANT,
    TenantQuotas,
    TenantRouter,
    TokenBucket,
    validate_tenant,
)

DIM = 128  # hashed dims must stay lane-aligned (x128)


def _docs(n=12, seed=0):
    docs, entities = make_corpus(n_docs=n, n_entities=4, seed=seed)
    return docs, list(entities)


def _fill(kb: KnowledgeBase, docs, tag: str):
    for i, d in enumerate(docs):
        kb.add_text(f"{tag}_{i:03d}.txt", f"{d} tenant {tag}")


def _pool(tmp_path, **kw):
    kw.setdefault("kb_kwargs", {"dim": DIM})
    kw.setdefault("registry", MetricsRegistry())
    return ContainerPool(str(tmp_path / "tenants"), **kw)


def _seed_tenant(pool, tenant, docs):
    """Mount, ingest, durably publish, leave resident."""
    with pool.pinned(tenant) as mt:
        _fill(mt.kb, docs, tenant)
        mt.snapshots.publish(durable=True)


# --------------------------------------------------------------------------
# pool: mount / pin / LRU evict
# --------------------------------------------------------------------------

def test_pool_lazy_mount_and_lru_eviction(tmp_path):
    docs, _ = _docs()
    pool = _pool(tmp_path, max_resident=2)
    for t in ("a", "b", "c"):
        _seed_tenant(pool, t, docs)
    # budget 2: "a" (LRU-coldest) was evicted when "c" mounted
    assert pool.resident_tenants() == ["b", "c"]
    # touching "b" bumps recency, so mounting "d" evicts "c"
    with pool.pinned("b"):
        pass
    _seed_tenant(pool, "d", docs)
    assert pool.resident_tenants() == ["b", "d"]
    # remount of an evicted tenant replays its durable container
    with pool.pinned("a") as mt:
        assert mt.kb.n_docs == len(docs)


def test_pool_pinned_tenant_is_never_evicted(tmp_path):
    docs, _ = _docs()
    pool = _pool(tmp_path, max_resident=1)
    mt_a = pool.pin("a")
    _fill(mt_a.kb, docs, "a")
    # mounting "b" while "a" is pinned exceeds the budget: "a" must
    # survive (pinned), so the pool rides over budget temporarily
    _seed_tenant(pool, "b", docs)
    assert "a" in pool.resident_tenants()
    with pytest.raises(RuntimeError, match="pins"):
        pool.evict("a")
    pool.unpin("a")
    # unpinned now: explicit eviction durably publishes and unmounts
    pool.evict("a")
    assert "a" not in pool.resident_tenants()
    with pool.pinned("a") as mt:
        assert mt.kb.n_docs == len(docs)  # nothing lost


def test_pool_eviction_durably_publishes_pending_generations(tmp_path):
    docs, entities = _docs()
    pool = _pool(tmp_path, max_resident=8)
    with pool.pinned("a") as mt:
        _fill(mt.kb, docs, "a")
        # in-memory publish only: the snapshot generation advances but
        # nothing reaches the container
        mt.snapshots.publish(durable=False)
        want = mt.snapshots.current.query_batch([entities[0]], k=3)
    pool.evict("a")  # must flush the pending state durably first
    with pool.pinned("a") as mt:
        assert mt.kb.n_docs == len(docs)
        got = mt.snapshots.current.query_batch([entities[0]], k=3)
    from conftest import assert_bit_identical
    assert_bit_identical(got, want, label="post-evict remount")


def test_pool_eviction_skips_untouched_tenants(tmp_path):
    import os
    pool = _pool(tmp_path, max_resident=8)
    with pool.pinned("ghost"):
        pass  # mounted, never mutated
    pool.evict("ghost")
    # no container written for a tenant that never held state
    assert not os.path.exists(pool.container_path("ghost"))


def test_pool_byte_budget_evicts(tmp_path):
    docs, _ = _docs()
    pool = _pool(tmp_path, max_resident=100, max_resident_bytes=1)
    _seed_tenant(pool, "a", docs)
    # "a" alone exceeds one byte, but it was pinned during seeding; the
    # next pin transition collects it
    _seed_tenant(pool, "b", docs)
    assert "a" not in pool.resident_tenants()


def test_pool_unpin_without_pin_raises(tmp_path):
    pool = _pool(tmp_path)
    with pytest.raises(RuntimeError, match="unpin"):
        pool.unpin("nope")


def test_tenant_id_validation(tmp_path):
    pool = _pool(tmp_path)
    for bad in ("", "../escape", "a/b", ".hidden", "x" * 65, None, 7):
        with pytest.raises((ValueError, TypeError)):
            validate_tenant(bad)
        with pytest.raises((ValueError, TypeError)):
            pool.pin(bad)
    assert validate_tenant("team-7.alpha_X") == "team-7.alpha_X"


def test_pool_metrics_accounting(tmp_path):
    docs, _ = _docs()
    reg = MetricsRegistry()
    pool = _pool(tmp_path, max_resident=1, registry=reg)
    _seed_tenant(pool, "a", docs)
    _seed_tenant(pool, "b", docs)  # evicts "a"
    text = __import__("repro.obs.export", fromlist=["render_prometheus"])\
        .render_prometheus(reg)
    # the resident tenant's series exist; the evicted tenant's were
    # pruned wholesale (bounded label cardinality under churn) and the
    # eviction shows up in the unlabeled aggregate counter
    assert 'ragdb_tenant_mounts_total{tenant="b"} 1' in text
    assert 'tenant="a"' not in text
    assert "ragdb_tenant_evictions_total 1" in text
    assert "ragdb_tenant_resident_bytes" in text
    assert "ragdb_resident_bytes" in text  # the ledger's per-plane gauges
    assert pool.stats()["resident"] == 1


def test_pool_evict_clears_ledger_and_series(tmp_path):
    from repro.obs import ledger as ledger_mod

    docs, _ = _docs()
    reg = MetricsRegistry()
    pool = _pool(tmp_path, max_resident=1, registry=reg)
    _seed_tenant(pool, "a", docs)
    assert pool.ledger.tenant_bytes(
        "a", planes=ledger_mod.DEVICE_PLANES) > 0
    _seed_tenant(pool, "b", docs)  # evicts "a"
    assert pool.ledger.tenant_bytes("a") == 0
    assert "a" not in pool.ledger.snapshot()["tenants"]
    # remount recreates the series fresh (no stale carryover)
    with pool.pinned("a"):
        assert pool.ledger.tenant_bytes(
            "a", planes=ledger_mod.DEVICE_PLANES) > 0


def test_pool_resident_bytes_matches_ledger(tmp_path):
    """Eviction decisions consume ledger bytes: the pool's reported
    resident total must equal the ledger's device-plane sum."""
    from repro.obs import ledger as ledger_mod

    docs, _ = _docs()
    pool = _pool(tmp_path, max_resident=4, registry=MetricsRegistry())
    for t in ("a", "b", "c"):
        _seed_tenant(pool, t, docs)
    ledger_sum = sum(
        pool.ledger.tenant_bytes(t, planes=ledger_mod.DEVICE_PLANES)
        for t in ("a", "b", "c"))
    assert pool.stats()["resident_bytes"] == ledger_sum > 0


# --------------------------------------------------------------------------
# quotas
# --------------------------------------------------------------------------

def test_token_bucket_deterministic_refill():
    b = TokenBucket(rate=10.0, burst=2)
    t0 = 100.0
    assert b.try_acquire(t0) and b.try_acquire(t0)   # burst of 2
    assert not b.try_acquire(t0)                     # empty
    assert not b.try_acquire(t0 + 0.05)              # only 0.5 tokens back
    assert b.try_acquire(t0 + 0.15)                  # 1.5 accrued
    # refill never exceeds burst
    assert b.try_acquire(t0 + 100.0) and b.try_acquire(t0 + 100.0)
    assert not b.try_acquire(t0 + 100.0)


def test_tenant_quotas_default_and_override():
    q = TenantQuotas(default_rate=1.0, default_burst=1)
    q.set("vip", rate=1000.0, burst=100)
    t0 = 50.0
    assert q.try_acquire("joe", t0)
    assert not q.try_acquire("joe", t0)      # default burst spent
    assert all(q.try_acquire("vip", t0) for _ in range(100))
    # no default at all -> unlimited
    assert all(TenantQuotas().try_acquire("any") for _ in range(10))


def test_runtime_quota_rejection_carries_tenant(tmp_path):
    docs, entities = _docs()
    pool = _pool(tmp_path)
    quotas = TenantQuotas()
    quotas.set("greedy", rate=0.001, burst=1)
    rt = ServingRuntime(pool=pool, quotas=quotas, max_batch=4,
                        flush_deadline=0.0)
    with rt:
        with rt.tenant_writer("greedy") as kb:
            _fill(kb, docs, "greedy")
        rt.publish(tenant="greedy")
        assert rt.submit(entities[0], k=2, tenant="greedy")\
            .result(timeout=30)
        with pytest.raises(RequestRejected) as exc:
            rt.submit(entities[0], k=2, tenant="greedy")
            rt.submit(entities[1], k=2, tenant="greedy")
        assert exc.value.tenant == "greedy"
        # an unthrottled tenant is unaffected
        assert rt.submit("hello", k=2, tenant="calm")\
            .result(timeout=30).results == []
        assert rt.metrics.tenant_snapshot()["greedy"]["rejected"] >= 1


# --------------------------------------------------------------------------
# router
# --------------------------------------------------------------------------

def test_router_publish_and_peek(tmp_path):
    docs, _ = _docs()
    pool = _pool(tmp_path)
    router = TenantRouter(pool)
    assert router.peek_generation("a") is None  # cold: no mount
    assert pool.resident_tenants() == []        # peek never mounts
    with router.writer("a") as mt:
        _fill(mt.kb, docs, "a")
    gen = router.publish("a", durable=True)
    assert gen == len(docs)
    assert router.peek_generation("a") == gen


# --------------------------------------------------------------------------
# multi-tenant runtime: parity, isolation, eviction hygiene
# --------------------------------------------------------------------------

def test_multi_tenant_results_match_direct_engines(tmp_path):
    from conftest import assert_bit_identical
    docs_a, entities = _docs(seed=0)
    docs_b, _ = _docs(seed=1)
    pool = _pool(tmp_path)
    rt = ServingRuntime(pool=pool, max_batch=8, flush_deadline=0.0,
                        result_cache_size=0)
    ref = {}
    for t, docs in (("a", docs_a), ("b", docs_b)):
        kb = KnowledgeBase(dim=DIM)
        _fill(kb, docs, t)
        ref[t] = QueryEngine(kb)
    with rt:
        for t, docs in (("a", docs_a), ("b", docs_b)):
            with rt.tenant_writer(t) as kb:
                _fill(kb, docs, t)
            rt.publish(tenant=t)
        queries = [*entities, "quarterly forecast", ""]
        futs = [(t, q, rt.submit(q, k=3, tenant=t))
                for t in ("a", "b") for q in queries]
        for t, q, fut in futs:
            served = fut.result(timeout=60)
            want = ref[t].query_batch([q], k=3)[0]
            assert_bit_identical([served.results], [want],
                                 label=f"tenant={t} {q!r}")


def test_result_cache_keyspaces_isolate_tenants(tmp_path):
    """Two tenants at the SAME generation with the SAME query text must
    not share cache entries — the keyspace is the isolation boundary."""
    docs_a, entities = _docs(seed=0)
    docs_b, _ = _docs(seed=1)
    pool = _pool(tmp_path)
    rt = ServingRuntime(pool=pool, max_batch=4, flush_deadline=0.0,
                        result_cache_size=64)
    q = entities[0]
    with rt:
        for t, docs in (("a", docs_a), ("b", docs_b)):
            with rt.tenant_writer(t) as kb:
                _fill(kb, docs, t)
            rt.publish(tenant=t)
        first_a = rt.submit(q, k=3, tenant="a").result(timeout=30)
        first_b = rt.submit(q, k=3, tenant="b").result(timeout=30)
        assert first_a.generation == first_b.generation  # same gen number!
        hit_a = rt.submit(q, k=3, tenant="a").result(timeout=30)
        hit_b = rt.submit(q, k=3, tenant="b").result(timeout=30)
        assert hit_a.cached and hit_b.cached
        assert [r.doc_id for r in hit_a.results] == \
            [r.doc_id for r in first_a.results]
        assert [r.doc_id for r in hit_b.results] == \
            [r.doc_id for r in first_b.results]
        # different corpora -> the hits must differ across tenants
        assert [r.doc_id for r in hit_a.results] != \
            [r.doc_id for r in hit_b.results]


def test_eviction_drops_cache_keyspace(tmp_path):
    docs, entities = _docs()
    pool = _pool(tmp_path, max_resident=8)
    rt = ServingRuntime(pool=pool, max_batch=4, flush_deadline=0.0,
                        result_cache_size=64)
    q = entities[0]
    with rt:
        with rt.tenant_writer("a") as kb:
            _fill(kb, docs, "a")
        rt.publish(tenant="a", durable=True)
        rt.submit(q, k=3, tenant="a").result(timeout=30)
        assert rt.submit(q, k=3, tenant="a").result(timeout=30).cached
        assert len(rt.cache) > 0
        pool.evict("a")
        assert len(rt.cache) == 0  # keyspace dropped with the mount
        # remount serves fresh (no stale hit), same results
        res = rt.submit(q, k=3, tenant="a").result(timeout=30)
        assert not res.cached and res.results


def test_empty_tenant_serves_empty_results(tmp_path):
    pool = _pool(tmp_path)
    rt = ServingRuntime(pool=pool, max_batch=4, flush_deadline=0.0)
    with rt:
        res = rt.submit("anything at all", k=5, tenant="fresh")\
            .result(timeout=30)
        assert res.results == [] and res.generation == 0


def test_flush_failure_isolated_to_one_tenant_group(tmp_path):
    """A scoring failure in tenant A's group fails A's futures only;
    tenant B's requests in the same flush still resolve."""
    docs, entities = _docs()
    pool = _pool(tmp_path)
    rt = ServingRuntime(pool=pool, max_batch=8, flush_deadline=0.05,
                        result_cache_size=0)
    with rt:
        for t in ("a", "b"):
            with rt.tenant_writer(t) as kb:
                _fill(kb, docs, t)
            rt.publish(tenant=t)
        # poison tenant a's mounted snapshot stack
        mt_a = pool.pin("a")

        def boom(texts, k):
            raise RuntimeError("poisoned tenant")
        mt_a.snapshots._current = _Poisoned(boom, mt_a.snapshots.current)
        pool.unpin("a")
        fa = rt.submit(entities[0], k=2, tenant="a")
        fb = rt.submit(entities[0], k=2, tenant="b")
        with pytest.raises(RuntimeError, match="poisoned"):
            fa.result(timeout=30)
        assert fb.result(timeout=30).results  # b unaffected


class _Poisoned:
    """Snapshot stand-in whose query_batch raises (failure-isolation
    fixture)."""

    def __init__(self, fn, real):
        self._fn = fn
        self.generation = real.generation

    def query_batch(self, texts, k):
        return self._fn(texts, k)


# --------------------------------------------------------------------------
# single-tenant parity: the pool path is bit-identical to the classic one
# --------------------------------------------------------------------------

def test_single_tenant_path_bit_identical_through_pool(tmp_path):
    from conftest import assert_bit_identical
    docs, entities = _docs(n=20)
    queries = [*entities, "quarterly forecast", "unrelated text"]

    kb_classic = KnowledgeBase(dim=DIM)
    _fill(kb_classic, docs, "t")
    classic = ServingRuntime(kb_classic, max_batch=8, flush_deadline=0.0,
                             result_cache_size=0)

    pool = _pool(tmp_path)
    pooled = ServingRuntime(pool=pool, max_batch=8, flush_deadline=0.0,
                            result_cache_size=0)

    engine = QueryEngine(kb_classic)
    with classic, pooled:
        with pooled.tenant_writer(DEFAULT_TENANT) as kb:
            _fill(kb, docs, "t")
        pooled.publish()  # default tenant wraps today's behavior
        for q in queries:
            want = engine.query_batch([q], k=3)[0]
            got_classic = classic.submit(q, k=3).result(timeout=60)
            got_pooled = pooled.submit(q, k=3).result(timeout=60)
            assert_bit_identical([got_classic.results], [want],
                                 label=f"classic {q!r}")
            assert_bit_identical([got_pooled.results], [want],
                                 label=f"pooled {q!r}")
            assert got_classic.generation == got_pooled.generation


# --------------------------------------------------------------------------
# sanitizers: steady state stays recompile-free per tenant bucket set
# --------------------------------------------------------------------------

@pytest.fixture
def _sanitizers_on():
    sanitizers.enable(True)
    yield
    sanitizers._enabled = None  # back to env-driven


def test_multi_tenant_steady_state_zero_recompiles(tmp_path, _sanitizers_on):
    """Equal-shaped tenants pin one shared jit bucket set: after
    warming each resident tenant and arming the guard, serving (and
    even an evict + remount at the same shapes) must not retrace."""
    docs_a, entities = _docs(n=12, seed=0)
    docs_b, _ = _docs(n=12, seed=1)  # same doc count -> same buckets
    pool = _pool(tmp_path, max_resident=8)
    rt = ServingRuntime(pool=pool, max_batch=4, flush_deadline=0.0,
                        result_cache_size=0)
    with rt:
        for t, docs in (("a", docs_a), ("b", docs_b)):
            with rt.tenant_writer(t) as kb:
                _fill(kb, docs, t)
            rt.publish(tenant=t, durable=True)
        rt.arm_sanitizers(k=3)  # warms every resident tenant's buckets
        for _ in range(3):
            for t in ("a", "b"):
                for q in entities[:2]:
                    rt.submit(q, k=3, tenant=t).result(timeout=30)
        # evict + lazy remount at identical shapes: still no retrace
        pool.evict("a")
        rt.submit(entities[0], k=3, tenant="a").result(timeout=30)


# --------------------------------------------------------------------------
# concurrency: hot serving against one tenant while another mounts/evicts
# --------------------------------------------------------------------------

def test_concurrent_serving_while_tenants_churn(tmp_path):
    docs, entities = _docs(n=16)
    pool = _pool(tmp_path, max_resident=2)
    rt = ServingRuntime(pool=pool, max_batch=8, flush_deadline=0.001,
                        result_cache_size=0)
    errors = []
    with rt:
        with rt.tenant_writer("hot") as kb:
            _fill(kb, docs, "hot")
        rt.publish(tenant="hot", durable=True)

        def serve_hot():
            try:
                for i in range(40):
                    res = rt.submit(entities[i % len(entities)], k=2,
                                    tenant="hot").result(timeout=60)
                    assert res.results, "hot tenant lost its corpus"
            except Exception as exc:  # pragma: no cover
                errors.append(exc)

        def churn():
            try:
                for i in range(6):
                    t = f"cold{i}"
                    with rt.tenant_writer(t) as kb:
                        _fill(kb, docs[:4], t)
                    rt.publish(tenant=t, durable=True)
            except Exception as exc:  # pragma: no cover
                errors.append(exc)

        threads = [threading.Thread(target=serve_hot),
                   threading.Thread(target=churn)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors
        # even if churn LRU-evicted "hot" between its requests, durable
        # publish + lazy remount means the next request still serves it
        res = rt.submit(entities[0], k=2, tenant="hot").result(timeout=60)
        assert res.results
