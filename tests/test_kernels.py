"""Per-kernel shape/dtype sweeps against the ref.py oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import hsf
from repro.kernels.embedding_bag import ops as bag_ops
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hsf_score import ops as hsf_ops
from repro.kernels.hsf_score.ref import hsf_score_ref, hsf_score_topk_ref
from repro.kernels.topk import ops as topk_ops
from repro.kernels.topk.ref import top_k_ref

RNG = np.random.default_rng(0)


def _hsf_corpus(n, d, w, b, rng):
    dv = rng.normal(size=(n, d)).astype(np.float32)
    dv /= np.linalg.norm(dv, axis=1, keepdims=True) + 1e-30
    ds = rng.integers(0, 2**31, size=(n, w)).astype(np.int32)
    qv = rng.normal(size=(b, d)).astype(np.float32)
    qs = np.stack(
        [ds[i % n] & ds[(i + 1) % n] for i in range(b)]
    ).astype(np.int32) if n else np.zeros((b, w), np.int32)
    return dv, ds, qv, qs


# ---------------------------------------------------------------------------
# hsf_score
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,w", [
    (64, 256, 128), (100, 512, 128), (1024, 1024, 256), (5, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hsf_score_sweep(n, d, w, dtype):
    dv = RNG.normal(size=(n, d)).astype(np.float32)
    dv /= np.linalg.norm(dv, axis=1, keepdims=True)
    ds = RNG.integers(0, 2**31, size=(n, w)).astype(np.int32)
    qv = RNG.normal(size=(d,)).astype(np.float32)
    qs = (ds[0] & ds[min(1, n - 1)]).astype(np.int32)
    out = hsf_ops.hsf_score(
        jnp.asarray(dv, dtype), jnp.asarray(ds), jnp.asarray(qv, dtype),
        jnp.asarray(qs), alpha=0.9, beta=1.3,
    )
    ref = hsf_score_ref(jnp.asarray(dv, dtype), jnp.asarray(ds),
                        jnp.asarray(qv, dtype), jnp.asarray(qs), 0.9, 1.3)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_hsf_score_boost_exactness():
    """The boost term is exactly β — never approximated by the kernel."""
    n, d, w = 32, 128, 128
    dv = np.zeros((n, d), np.float32)
    ds = RNG.integers(0, 2**31, size=(n, w)).astype(np.int32)
    qs = ds[7]
    out = np.asarray(hsf_ops.hsf_score(
        jnp.asarray(dv), jnp.asarray(ds), jnp.zeros(d, jnp.float32),
        jnp.asarray(qs), alpha=1.0, beta=1.0,
    ))
    assert out[7] == 1.0


def test_hsf_score_empty_corpus():
    """n=0 must not reach pallas_call (a zero grid is invalid)."""
    out = hsf_ops.hsf_score(
        jnp.zeros((0, 128), jnp.float32), jnp.zeros((0, 128), jnp.int32),
        jnp.zeros(128, jnp.float32), jnp.zeros(128, jnp.int32),
    )
    assert out.shape == (0,) and out.dtype == jnp.float32


@pytest.mark.parametrize("n", [1, 3, 7, 9, 100])
def test_hsf_score_small_and_ragged_n(n):
    """n below / straddling the 8-sublane tile pads then slices back."""
    dv, ds, qv, qs = _hsf_corpus(n, 128, 128, 1, np.random.default_rng(n))
    out = hsf_ops.hsf_score(
        jnp.asarray(dv), jnp.asarray(ds), jnp.asarray(qv[0]),
        jnp.asarray(qs[0]), alpha=1.1, beta=0.7,
    )
    assert out.shape == (n,)
    ref = hsf.numpy_reference(dv, ds, qv[0], qs[0], 1.1, 0.7)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# hsf_score_batched (fused multi-query + in-kernel top-k)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b", [1, 3, 8])
@pytest.mark.parametrize("n,k", [(64, 5), (100, 7), (1024, 16), (5, 3)])
@pytest.mark.parametrize("beta", [1.3, 0.0])
def test_hsf_score_batched_sweep(b, n, k, beta):
    """Interpret-mode parity: ids bit-identical to the
    `_stable_top_k` lexicographic order on the full score matrix
    (`hsf_score_topk_ref`), selected scores within f32 resolution of the
    pure-numpy float64 oracle (`hsf.numpy_reference`) per query."""
    d, w = 256, 128
    dv, ds, qv, qs = _hsf_corpus(n, d, w, b, np.random.default_rng(n * b))
    vals, ids = hsf_ops.hsf_score_batched(
        jnp.asarray(dv), jnp.asarray(ds), jnp.asarray(qv), jnp.asarray(qs),
        k=k, alpha=0.9, beta=beta,
    )
    k_eff = min(k, n)
    assert vals.shape == (b, k_eff) and ids.shape == (b, k_eff)
    rv, ri = hsf_score_topk_ref(
        jnp.asarray(dv), jnp.asarray(ds), jnp.asarray(qv), jnp.asarray(qs),
        0.9, beta, k_eff,
    )
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rv),
                               rtol=1e-6, atol=1e-6)
    for i in range(b):
        oracle = hsf.numpy_reference(dv, ds, qv[i], qs[i], 0.9, beta)
        np.testing.assert_allclose(
            np.asarray(vals)[i], oracle[np.asarray(ids)[i]],
            rtol=1e-6, atol=1e-6,
        )


def test_hsf_score_batched_duplicate_ties_stable():
    """An all-duplicate corpus produces exact score ties in every block;
    the in-kernel merge must surface ascending doc ids — the
    `retrieval._stable_top_k` rule — across block boundaries."""
    n, d, w, b, k = 96, 128, 128, 4, 9
    rng = np.random.default_rng(7)
    row = rng.normal(size=(1, d)).astype(np.float32)
    sig = rng.integers(0, 2**31, size=(1, w)).astype(np.int32)
    dv = np.tile(row, (n, 1))
    ds = np.tile(sig, (n, 1))
    qv = rng.normal(size=(b, d)).astype(np.float32)
    qs = np.tile(sig & sig[0], (b, 1))
    vals, ids = hsf_ops.hsf_score_batched(
        jnp.asarray(dv), jnp.asarray(ds), jnp.asarray(qv), jnp.asarray(qs),
        k=k, alpha=1.0, beta=1.0, block_docs=16,  # force multi-block merge
    )
    np.testing.assert_array_equal(np.asarray(ids),
                                  np.tile(np.arange(k), (b, 1)))
    for i in range(b):
        assert len(set(np.asarray(vals)[i].tolist())) == 1


def test_hsf_score_batched_empty_and_tiny():
    zf = jnp.zeros((0, 128), jnp.float32)
    zi = jnp.zeros((0, 128), jnp.int32)
    qv = jnp.zeros((2, 128), jnp.float32)
    qs = jnp.zeros((2, 128), jnp.int32)
    vals, ids = hsf_ops.hsf_score_batched(zf, zi, qv, qs, k=5)
    assert vals.shape == (2, 0) and ids.shape == (2, 0)
    # n=1: k clamps to the corpus
    dv, ds, qv1, qs1 = _hsf_corpus(1, 128, 128, 2, np.random.default_rng(3))
    vals, ids = hsf_ops.hsf_score_batched(
        jnp.asarray(dv), jnp.asarray(ds), jnp.asarray(qv1), jnp.asarray(qs1),
        k=5,
    )
    assert vals.shape == (2, 1)
    np.testing.assert_array_equal(np.asarray(ids), np.zeros((2, 1)))


def test_hsf_score_batched_k_beyond_carry_width_falls_back():
    """k > KPAD (the VMEM carry width) takes the unfused fallback with
    the same (score desc, id asc) contract."""
    n, b, k = 300, 2, 150
    dv, ds, qv, qs = _hsf_corpus(n, 128, 128, b, np.random.default_rng(5))
    vals, ids = hsf_ops.hsf_score_batched(
        jnp.asarray(dv), jnp.asarray(ds), jnp.asarray(qv), jnp.asarray(qs),
        k=k, alpha=1.0, beta=1.0,
    )
    rv, ri = hsf_score_topk_ref(
        jnp.asarray(dv), jnp.asarray(ds), jnp.asarray(qv), jnp.asarray(qs),
        1.0, 1.0, k,
    )
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rv),
                               rtol=1e-6, atol=1e-6)


def test_hsf_score_batched_unfillable_rows_get_sentinel_ids():
    """k > n_valid with a multi-block grid: the slots that cannot fill
    must carry (-inf, ID_SENTINEL) — regression for the merge re-picking
    an exhausted carry slot and emitting a duplicate real doc id."""
    from repro.kernels.hsf_score.hsf_score import ID_SENTINEL

    n, b, k, keep = 64, 2, 6, 3
    dv, ds, qv, qs = _hsf_corpus(n, 128, 128, b, np.random.default_rng(13))
    vals, ids = hsf_ops.hsf_score_batched(
        jnp.asarray(dv), jnp.asarray(ds), jnp.asarray(qv), jnp.asarray(qs),
        k=k, n_valid=keep, block_docs=16,  # 4 grid steps
    )
    vals, ids = np.asarray(vals), np.asarray(ids)
    assert np.all(np.isfinite(vals[:, :keep]))
    assert np.all(ids[:, :keep] < keep)
    for row in ids[:, :keep]:
        assert len(set(row.tolist())) == keep  # no duplicate docs
    assert np.all(np.isneginf(vals[:, keep:]))
    assert np.all(ids[:, keep:] == ID_SENTINEL)


def test_hsf_score_batched_n_valid_masks_suffix():
    """The SMEM n_valid scalar (sharded callers' padding mask) excludes
    the suffix exactly: results equal the truncated corpus's."""
    n, keep, b, k = 64, 40, 3, 6
    dv, ds, qv, qs = _hsf_corpus(n, 128, 128, b, np.random.default_rng(11))
    v_mask, i_mask = hsf_ops.hsf_score_batched(
        jnp.asarray(dv), jnp.asarray(ds), jnp.asarray(qv), jnp.asarray(qs),
        k=k, n_valid=keep,
    )
    v_trunc, i_trunc = hsf_ops.hsf_score_batched(
        jnp.asarray(dv[:keep]), jnp.asarray(ds[:keep]), jnp.asarray(qv),
        jnp.asarray(qs), k=k,
    )
    np.testing.assert_array_equal(np.asarray(i_mask), np.asarray(i_trunc))
    np.testing.assert_array_equal(np.asarray(v_mask), np.asarray(v_trunc))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,l,dh,causal,window,softcap", [
    (2, 4, 2, 128, 64, True, None, None),
    (1, 8, 1, 256, 32, True, None, None),
    (2, 4, 4, 128, 64, True, 32, None),
    (1, 2, 2, 160, 64, True, None, 50.0),
    (1, 4, 2, 96, 64, False, None, None),
    (1, 2, 1, 100, 32, True, 24, 30.0),
])
def test_flash_attention_sweep(b, hq, hkv, l, dh, causal, window, softcap):
    q = RNG.normal(size=(b, hq, l, dh)).astype(np.float32)
    k = RNG.normal(size=(b, hkv, l, dh)).astype(np.float32)
    v = RNG.normal(size=(b, hkv, l, dh)).astype(np.float32)
    out = fa_ops.flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=causal, window=window, softcap=softcap,
        block_q=64, block_k=64,
    )
    ref = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        scale=dh**-0.5, causal=causal, window=window,
                        softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    q = RNG.normal(size=(1, 2, 128, 64)).astype(np.float32)
    out = fa_ops.flash_attention(
        jnp.asarray(q, jnp.bfloat16), jnp.asarray(q, jnp.bfloat16),
        jnp.asarray(q, jnp.bfloat16), block_q=64, block_k=64)
    ref = attention_ref(jnp.asarray(q, jnp.bfloat16),
                        jnp.asarray(q, jnp.bfloat16),
                        jnp.asarray(q, jnp.bfloat16), scale=64**-0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_matches_xla_path():
    """Kernel and XLA-scan attention implement the same semantics."""
    from repro.models.attention import flash_attention_xla

    q = jnp.asarray(RNG.normal(size=(2, 4, 128, 32)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(2, 2, 128, 32)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(2, 2, 128, 32)).astype(np.float32))
    a = fa_ops.flash_attention(q, k, v, causal=True, window=48,
                               block_q=64, block_k=64)
    b = flash_attention_xla(q, k, v, scale=32**-0.5, causal=True, window=48,
                            block_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# embedding bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("v,e,n,bags,mode", [
    (128, 128, 64, 16, "sum"), (1000, 64, 300, 50, "sum"),
    (64, 256, 40, 8, "mean"), (32, 128, 5, 10, "sum"),
])
def test_embedding_bag_sweep(v, e, n, bags, mode):
    table = RNG.normal(size=(v, e)).astype(np.float32)
    idx = RNG.integers(0, v, size=n).astype(np.int32)
    seg = RNG.integers(0, bags, size=n).astype(np.int32)
    w = RNG.normal(size=n).astype(np.float32)
    out = bag_ops.embedding_bag(jnp.asarray(table), jnp.asarray(idx),
                                jnp.asarray(seg), bags, jnp.asarray(w),
                                mode=mode)
    seg_s = np.sort(seg)
    order = np.argsort(seg, kind="stable")
    ref = embedding_bag_ref(jnp.asarray(table), jnp.asarray(idx[order]),
                            jnp.asarray(seg_s), bags,
                            jnp.asarray(w[order]), mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), bags=st.integers(1, 12))
def test_embedding_bag_property_matches_dense(seed, bags):
    """bag(table, idx, seg) == one_hot-matmul reference."""
    rng = np.random.default_rng(seed)
    v, e, n = 20, 128, 30
    table = rng.normal(size=(v, e)).astype(np.float32)
    idx = rng.integers(0, v, size=n).astype(np.int32)
    seg = rng.integers(0, bags, size=n).astype(np.int32)
    out = np.asarray(bag_ops.embedding_bag(
        jnp.asarray(table), jnp.asarray(idx), jnp.asarray(seg), bags))
    dense = np.zeros((bags, v), np.float32)
    for i, s in zip(idx, seg):
        dense[s, i] += 1
    np.testing.assert_allclose(out, dense @ table, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# topk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k", [(512, 4), (3000, 17), (128, 128), (129, 1)])
def test_topk_sweep(n, k):
    s = RNG.normal(size=n).astype(np.float32)
    v, i = topk_ops.top_k(jnp.asarray(s), k)
    rv, ri = top_k_ref(jnp.asarray(s), k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(1, 32))
def test_topk_property(seed, k):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(k, 2000))
    # duplicate-heavy distribution to stress tie-breaking
    s = rng.integers(0, 5, size=n).astype(np.float32)
    v, i = topk_ops.top_k(jnp.asarray(s), k)
    rv, ri = top_k_ref(jnp.asarray(s), k)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv))
