"""Paper §4 semantics: tokenizer, hashed TF-IDF, Bloom signatures, HSF."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import hashing, hsf, signature as sigmod, tokenizer
from repro.core.vectorizer import HashedTfIdf
from repro.core.tokenizer import TermCounts

TEXTS = st.text(
    alphabet=st.characters(min_codepoint=32, max_codepoint=126),
    min_size=0, max_size=200,
)


def test_tokenize_basic():
    assert tokenizer.tokenize("Hello, World! INV-2024") == \
        ["hello", "world", "inv", "2024"]


def test_fnv_deterministic():
    assert hashing.fnv1a64("token") == hashing.fnv1a64("token")
    assert hashing.fnv1a64("a") != hashing.fnv1a64("b")
    # reference value of FNV-1a 64 for empty input is the offset basis
    assert hashing.fnv1a64_bytes(b"") == 0xCBF29CE484222325


def test_rolling_hash_matches_position_independent():
    h1 = hashing.rolling_ngram_hashes(b"abcdef", 3)
    h2 = hashing.rolling_ngram_hashes(b"xxabcdefyy", 3)
    # every gram of the substring appears among the grams of the superstring
    assert set(h1.tolist()) <= set(h2.tolist())


@settings(max_examples=50, deadline=None)
@given(doc=TEXTS, start=st.integers(0, 199), length=st.integers(4, 60))
def test_bloom_never_false_negative(doc, start, length):
    """The paper's guarantee: a true substring is never missed."""
    if len(doc) < 8:
        doc = doc + "padding-padding"
    start = start % max(len(doc) - 4, 1)
    query = doc[start: start + length]
    d = sigmod.signature_of_text(doc)
    q = sigmod.query_signature(query)
    assert sigmod.contains(d[None, :], q)[0]


def test_bloom_discriminates():
    d = sigmod.signature_of_text("the quick brown fox INVOICE_777")
    q_in = sigmod.query_signature("INVOICE_777")
    q_out = sigmod.query_signature("COMPLETELY_DIFFERENT_CODE_123456")
    assert sigmod.contains(d[None, :], q_in)[0]
    assert not sigmod.contains(d[None, :], q_out)[0]


def test_tfidf_formulas():
    """tf = 1 + ln f; idf = ln(N/(1+df)) + 1 — checked against a manual
    two-doc corpus."""
    v = HashedTfIdf(dim=512)
    tc1 = TermCounts.from_text("alpha alpha beta")
    tc2 = TermCounts.from_text("beta gamma")
    v.add_doc(tc1)
    v.add_doc(tc2)
    idf = v.idf()
    from repro.core.vectorizer import bucket_sign

    b_alpha = bucket_sign(hashing.hash_tokens(["alpha"]), 512)[0][0]
    b_beta = bucket_sign(hashing.hash_tokens(["beta"]), 512)[0][0]
    np.testing.assert_allclose(idf[b_alpha], np.log(2 / 2) + 1, rtol=1e-6)
    np.testing.assert_allclose(idf[b_beta], np.log(2 / 3) + 1, rtol=1e-6)
    vec = v.doc_vector(tc1)
    np.testing.assert_allclose(np.linalg.norm(vec), 1.0, rtol=1e-5)


def test_incremental_df_matches_batch():
    """add_doc/remove_doc incremental df == recomputed-from-scratch df."""
    docs = [f"word{i} word{(i*7) % 13} common" for i in range(20)]
    tcs = [TermCounts.from_text(d) for d in docs]
    v1 = HashedTfIdf(dim=256)
    for tc in tcs:
        v1.add_doc(tc)
    v1.remove_doc(tcs[3])
    v1.remove_doc(tcs[7])
    v2 = HashedTfIdf(dim=256)
    for i, tc in enumerate(tcs):
        if i not in (3, 7):
            v2.add_doc(tc)
    np.testing.assert_array_equal(v1.df, v2.df)
    assert v1.n_docs == v2.n_docs


def test_build_matrix_matches_doc_vector():
    docs = ["alpha beta", "gamma delta epsilon", "alpha alpha gamma"]
    tcs = [TermCounts.from_text(d) for d in docs]
    v = HashedTfIdf(dim=256)
    for tc in tcs:
        v.add_doc(tc)
    mat = v.build_matrix(tcs)
    for i, tc in enumerate(tcs):
        np.testing.assert_allclose(mat[i], v.doc_vector(tc), rtol=1e-5,
                                   atol=1e-7)


def test_hsf_score_decomposition():
    """Score = α·cos + β·indicator, exactly (paper eq. in §4.2)."""
    rng = np.random.default_rng(0)
    dv = rng.normal(size=(10, 256)).astype(np.float32)
    dv /= np.linalg.norm(dv, axis=1, keepdims=True)
    ds = rng.integers(0, 2**31, size=(10, 128)).astype(np.int32)
    qv = dv[4]
    qs = ds[4]  # contained in doc 4 by construction
    scores = np.asarray(hsf.hsf_scores(
        jnp.asarray(dv), jnp.asarray(ds), jnp.asarray(qv), jnp.asarray(qs),
        alpha=0.7, beta=2.0,
    ))
    ref = hsf.numpy_reference(dv, ds, qv, qs, 0.7, 2.0)
    np.testing.assert_allclose(scores, ref, rtol=1e-5, atol=1e-6)
    assert scores[4] == pytest.approx(0.7 * 1.0 + 2.0, rel=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_entity_always_top1(seed):
    """Property behind RQ2: an injected unique entity code is ALWAYS
    rank 1 for its own query, whatever the corpus (β ≥ α bounds cosine)."""
    from repro.core.ingest import KnowledgeBase
    from repro.core.retrieval import Retriever

    rng = np.random.default_rng(seed)
    words = [f"w{i}" for i in range(50)]
    kb = KnowledgeBase(dim=512)
    n = int(rng.integers(3, 30))
    target = int(rng.integers(0, n))
    code = f"UNIQUE_ENTITY_{seed % 100000}_X"
    for i in range(n):
        text = " ".join(rng.choice(words, size=30))
        if i == target:
            text += " " + code
        kb.add_text(f"doc{i}", text)
    res = Retriever(kb).query(code, k=1)
    assert res[0].doc_id == f"doc{target}"
    assert res[0].boosted
