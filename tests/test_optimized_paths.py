"""Beyond-paper optimization paths: exactness + build coverage.

Every §Perf optimization must be semantics-preserving; these tests pin
that: KV replication, scatter cache updates (covered by decode parity),
fused lookup-and-score, bf16-master training step, optimized cell
builders (smoke configs, host mesh).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import ARCHS
from repro.models import transformer as T
from repro.models.recsys import embedding as E
from repro.optim import adamw_init


def test_kv_repeat_exact():
    cfg1 = ARCHS["gemma2-9b"].smoke_config
    cfg2 = replace(cfg1, kv_repeat=2)
    params = T.init(jax.random.PRNGKey(0), cfg1)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0,
                                cfg1.vocab)
    ref, _ = T.forward(params, tokens, cfg1)
    out, _ = T.forward(params, tokens, cfg2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # serving path with replicated-KV caches
    _, caches, lengths = T.prefill(params, tokens[:, :19], cfg2, 32)
    assert caches["scan"]["l0"]["k"].shape[2] == cfg2.n_kv_eff
    ld, _ = T.decode_step(params, caches, tokens[:, 19:20], lengths + 1,
                          cfg2)
    scale = np.abs(np.asarray(ref[:, -1])).max()
    np.testing.assert_allclose(np.asarray(ld[:, 0]) / scale,
                               np.asarray(ref[:, -1]) / scale, atol=5e-4)


def test_lookup_scores_matches_rows_dot():
    vocabs = (40, 60)
    table = E.init_tables(jax.random.PRNGKey(0), vocabs, 16)["table"]
    idx = jnp.asarray(np.random.default_rng(0).integers(0, 40, size=50),
                      jnp.int32)
    q = jnp.asarray(np.random.default_rng(1).normal(size=16)
                    .astype(np.float32))
    fused = E.lookup_scores(table, idx, q)
    ref = E.lookup_rows(table, idx) @ q
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_bf16_master_step_tracks_f32_step():
    """bf16-working-copy training follows full-f32 training closely on
    a smoke config for a few steps."""
    from repro.launch import mesh as meshlib, steps

    mesh = meshlib.make_host_mesh(1)
    cfg = ARCHS["llama3.2-3b"].smoke_config
    master = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 4, 32)),
                       jnp.int32)
    tgts = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 4, 32)),
                       jnp.int32)

    # f32 reference
    step32 = jax.jit(steps.make_lm_train_step(cfg, mesh, 2))
    p32, o32 = master, adamw_init(master)
    # bf16 working copy
    step16 = jax.jit(steps.make_lm_train_step(cfg, mesh, 2,
                                              bf16_params=True))
    p16 = jax.tree.map(lambda x: x.astype(jnp.bfloat16), master)
    o16 = {**adamw_init(master), "master": master}

    for _ in range(3):
        p32, o32, loss32 = step32(p32, o32, toks, tgts)
        p16, o16, loss16 = step16(p16, o16, toks, tgts)
    assert abs(float(loss32) - float(loss16)) < 0.05 * abs(float(loss32))
    # master copies track the f32 params
    for a, b in zip(jax.tree.leaves(p32), jax.tree.leaves(o16["master"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("arch_id,shape_id", [
    ("gemma2-9b", "decode_32k"),
    ("qwen3-moe-30b-a3b", "train_4k"),
    ("deepseek-v2-lite-16b", "long_500k"),
    ("dlrm-mlperf", "retrieval_cand"),
])
def test_optimized_cells_build_on_host_mesh(arch_id, shape_id):
    """Optimized builders construct (trace-time) on the 1-device mesh
    with smoke configs — guards the builder plumbing itself."""
    from repro.launch import mesh as meshlib, steps

    mesh = meshlib.make_host_mesh(1)
    cell = steps.build_cell(arch_id, shape_id, mesh, smoke=True,
                            optimized=True)
    lowered = cell.fn.lower(*cell.args)
    assert lowered is not None


def test_baseline_cells_still_build():
    from repro.launch import mesh as meshlib, steps

    mesh = meshlib.make_host_mesh(1)
    cell = steps.build_cell("gemma2-9b", "decode_32k", mesh, smoke=True,
                            optimized=False)
    assert cell.fn.lower(*cell.args) is not None


def test_expert_parallel_matches_dropless_when_capacity_ample():
    """moe.apply_expert_parallel == the dropless path when no tokens
    drop (capacity_factor high) — the EP variant is semantics-
    preserving up to GShard capacity."""
    from repro.launch import mesh as meshlib
    from repro.models import moe as moe_mod

    mesh = meshlib.make_host_mesh(1)
    cfg = moe_mod.MoEConfig(n_experts=8, top_k=2, d_ff_expert=32)
    params = moe_mod.init(jax.random.PRNGKey(0), cfg, 64)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(16, 64)).astype(np.float32))
    ref, aux_ref = moe_mod.apply(params, x, cfg)
    out, aux = jax.jit(lambda p, x: moe_mod.apply_expert_parallel(
        p, x, cfg, mesh, ("data",), "model", capacity_factor=16.0)
    )(params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert abs(float(aux) - float(aux_ref)) < 1e-6
