"""'Live Sync' (paper §3.3) under real concurrency: a single ingest
thread watches a directory and republishes the serving snapshot after
every delta, while concurrent reader threads keep querying through the
micro-batching scheduler the whole time.  Readers are pinned to
immutable generations (docs/ARCHITECTURE.md §7), so continuous ingest
never blocks serving and no query ever observes a half-refreshed
matrix — the script verifies zero torn reads at the end.

Publishes are **durable** (docs/ARCHITECTURE.md §8): each one appends
an O(changed docs) delta record to the container's journal, so a crash
never loses a published generation.  The script finishes by simulating
that crash — reloading the knowledge base purely from disk and
checking it matches the live writer's final state.

    PYTHONPATH=src python examples/live_sync.py
"""
import os
import tempfile
import threading
import time

from repro.core.container import journal_size
from repro.core.ingest import KnowledgeBase
from repro.data.corpus import make_corpus, write_corpus_dir
from repro.serving import ServingRuntime

N_READERS = 4


def main():
    with tempfile.TemporaryDirectory() as work:
        corpus_dir = os.path.join(work, "docs")
        docs, entities = make_corpus(n_docs=400, seed=0)
        write_corpus_dir(corpus_dir, docs)
        kb = KnowledgeBase(dim=2048)
        container = os.path.join(work, "kb.ragdb")
        runtime = ServingRuntime(kb, max_batch=16, flush_deadline=0.002,
                                 container_path=container)
        published = {runtime.generation}
        queries = [*entities, "escalation runbook", "quarterly forecast"]

        events = [
            ("initial scan", lambda: None),
            ("no changes", lambda: None),
            ("edit 2 files", lambda: [
                open(os.path.join(corpus_dir, f"doc_{i:05d}.txt"), "a")
                .write(f" EDIT_{i}") for i in (3, 9)
            ]),
            ("add a file", lambda: open(
                os.path.join(corpus_dir, "new_note.txt"), "w"
            ).write("TICKET-4821 escalation runbook")),
            ("delete a file", lambda: os.unlink(
                os.path.join(corpus_dir, "doc_00000.txt"))),
        ]

        stop = threading.Event()
        observed: list[int] = []  # generations readers were served from
        obs_lock = threading.Lock()

        def reader(seed: int):
            i = seed
            while not stop.is_set():
                q = queries[i % len(queries)]
                i += 1
                served = runtime.submit(q, k=1).result(timeout=60)
                with obs_lock:
                    observed.append(served.generation)

        with runtime:
            threads = [threading.Thread(target=reader, args=(i,), daemon=True)
                       for i in range(N_READERS)]
            for t in threads:
                t.start()

            # the single writer: mutate → sync → publish, atomically
            # swapping the snapshot readers pin — they never wait
            for label, mutate in events:
                mutate()
                s = kb.sync(corpus_dir)
                gen = runtime.publish(durable=True)
                published.add(gen)
                print(f"{label:15s} → scanned={s.scanned:4d} "
                      f"skipped={s.skipped:4d} +{s.added} ~{s.updated} "
                      f"-{s.removed}  (sync {s.seconds * 1e3:.1f} ms, "
                      f"published generation {gen})")
                time.sleep(0.05)  # let readers overlap this generation

            top = runtime.submit("TICKET-4821", k=1).result(timeout=60)
            stop.set()
            for t in threads:
                t.join()

        print(f"\nquery TICKET-4821 → {top.results[0].doc_id} "
              f"(boosted={top.results[0].boosted}, "
              f"generation {top.generation}) — the live delta is queryable")
        torn = [g for g in observed if g not in published]
        print(f"{N_READERS} readers served {len(observed)} queries across "
              f"generations {sorted(set(observed))}; "
              f"torn reads: {len(torn)}")
        assert not torn, "a query observed an unpublished generation"
        assert top.results[0].doc_id == "new_note.txt"
        print(f"metrics: {runtime.metrics.format()}")

        # simulated crash: rebuild purely from base + journal on disk.
        # The first durable publish full-saved the base; every later one
        # appended an O(changed docs) delta record, and replay restores
        # exactly the last published generation.
        recovered = KnowledgeBase.load(container)
        assert set(recovered.records) == set(kb.records)
        assert recovered.loaded_generation == kb.loaded_generation
        assert "TICKET-4821" in recovered.texts["new_note.txt"]
        print(f"durable: base={os.path.getsize(container)}B "
              f"journal={journal_size(container)}B — crash recovery "
              f"restored {recovered.n_docs} docs at container generation "
              f"{recovered.loaded_generation}")


if __name__ == "__main__":
    main()
