"""'Live Sync' (paper §3.3): the container as a continuous background
process — watch a directory, re-index only the delta each round, and
keep the serving plane hot: the QueryEngine patches its device-resident
arrays from the same delta (O(changed docs), not O(corpus)).

    PYTHONPATH=src python examples/live_sync.py
"""
import os
import tempfile

from repro.core.engine import QueryEngine
from repro.core.ingest import KnowledgeBase
from repro.data.corpus import make_corpus, write_corpus_dir


def main():
    with tempfile.TemporaryDirectory() as work:
        corpus_dir = os.path.join(work, "docs")
        docs, _ = make_corpus(n_docs=400, seed=0)
        write_corpus_dir(corpus_dir, docs)
        kb = KnowledgeBase(dim=2048)
        engine = QueryEngine(kb)  # serving plane, built once

        events = [
            ("initial scan", lambda: None),
            ("no changes", lambda: None),
            ("edit 2 files", lambda: [
                open(os.path.join(corpus_dir, f"doc_{i:05d}.txt"), "a")
                .write(f" EDIT_{i}") for i in (3, 9)
            ]),
            ("add a file", lambda: open(
                os.path.join(corpus_dir, "new_note.txt"), "w"
            ).write("TICKET-4821 escalation runbook")),
            ("delete a file", lambda: os.unlink(
                os.path.join(corpus_dir, "doc_00000.txt"))),
        ]
        for label, mutate in events:
            mutate()
            s = kb.sync(corpus_dir)
            r = engine.refresh()
            print(f"{label:15s} → scanned={s.scanned:4d} "
                  f"skipped={s.skipped:4d} +{s.added} ~{s.updated} "
                  f"-{s.removed}  (sync {s.seconds * 1e3:.1f} ms, "
                  f"engine refresh {r.changed} rows "
                  f"{r.seconds * 1e3:.1f} ms)")

        top = engine.query_batch(["TICKET-4821"], k=1)[0][0]
        print(f"\nquery TICKET-4821 → {top.doc_id} "
              f"(boosted={top.boosted}) — the live delta is queryable")


if __name__ == "__main__":
    main()
