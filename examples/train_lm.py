"""Train a small LM for a few hundred steps with the production train
step (grad-accumulation scan + remat + sharding machinery), including a
mid-run checkpoint + kill + exact restart-replay.

    PYTHONPATH=src python examples/train_lm.py
"""
import tempfile

from repro.launch import train


def main():
    with tempfile.TemporaryDirectory() as work:
        print("=== phase 1: train 60 steps (checkpoint every 20) ===")
        train.main([
            "--arch", "llama3.2-3b", "--smoke",
            "--steps", "60", "--batch", "8", "--seq", "64",
            "--ckpt-dir", work, "--ckpt-every", "20",
        ])
        print("\n=== phase 2: 'failure' — restart from checkpoint, "
              "train to 100 ===")
        loss = train.main([
            "--arch", "llama3.2-3b", "--smoke",
            "--steps", "100", "--batch", "8", "--seq", "64",
            "--ckpt-dir", work, "--ckpt-every", "20",
        ])
        print(f"\nfinal loss {loss:.4f} — deterministic replay from the "
              "DataCursor means this equals an uninterrupted 100-step run")


if __name__ == "__main__":
    main()
