"""Multi-tenant serving quickstart: N isolated knowledge containers
behind one runtime (docs/ARCHITECTURE.md §13).

One ``ContainerPool`` owns every tenant's container under a single
root directory; the runtime routes each request to its tenant's
mounted engine+snapshot stack.  Mounts are lazy (first request pays a
delta-journal load), residency is LRU-bounded — here 3 tenants over a
budget of 2, so serving the third tenant evicts the coldest, durably
publishing its pending generations first — and a per-tenant token
bucket turns overload into ``RequestRejected(tenant)`` backpressure
instead of cross-tenant latency.

    PYTHONPATH=src python examples/multi_tenant.py
"""
import tempfile

from repro.data.corpus import make_corpus
from repro.serving import RequestRejected, ServingRuntime
from repro.tenancy import ContainerPool, TenantQuotas

TENANTS = ("acme", "globex", "initech")


def main():
    with tempfile.TemporaryDirectory() as root:
        pool = ContainerPool(root, kb_kwargs={"dim": 1024},
                             max_resident=2)          # LRU beyond 2
        quotas = TenantQuotas()
        quotas.set("initech", rate=0.5, burst=2)      # throttled tenant

        runtime = ServingRuntime(pool=pool, quotas=quotas,
                                 max_batch=8, flush_deadline=0.002)
        with runtime:
            # each tenant gets its own corpus — and its own container
            # file, journal lineage, snapshot generations, result-cache
            # keyspace, and metric series
            codes = {}
            for seed, tenant in enumerate(TENANTS):
                docs, entities = make_corpus(n_docs=80, n_entities=4,
                                             seed=seed)
                with runtime.tenant_writer(tenant) as kb:
                    for i, d in enumerate(docs):
                        kb.add_text(f"{tenant}_{i:03d}.txt", d)
                gen = runtime.publish(tenant=tenant, durable=True)
                codes[tenant] = next(iter(entities))
                print(f"[{tenant}] published generation {gen} "
                      f"→ {pool.container_path(tenant)}")
            print(f"resident after ingest: {pool.resident_tenants()} "
                  f"(budget 2 — the coldest tenant was evicted, its "
                  f"state durably on disk)\n")

            # serve every tenant — the evicted one lazily remounts
            for tenant in TENANTS:
                res = runtime.submit(codes[tenant], k=2,
                                     tenant=tenant).result(timeout=60)
                top = res.results[0]
                print(f"[{tenant}] {codes[tenant]} → {top.doc_id} "
                      f"(score {top.score:.3f})")

            # overload the throttled tenant: the bucket admits the
            # burst, then rejects with the tenant attached
            rejected = 0
            for _ in range(6):
                try:
                    runtime.submit("flood query", k=2,
                                   tenant="initech").result(timeout=60)
                except RequestRejected as exc:
                    assert exc.tenant == "initech"
                    rejected += 1
            print(f"\n[initech] quota rejected {rejected}/6 flood "
                  f"requests (burst 2, rate 0.5/s)")

            for tenant, m in sorted(runtime.tenant_metrics().items()):
                print(f"  [{tenant}] completed={m['completed']} "
                      f"rejected={m['rejected']} "
                      f"p99={m['latency_p99_ms']:.2f}ms")
        pool.drain()  # durable publish + unmount everything


if __name__ == "__main__":
    main()
