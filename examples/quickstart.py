"""Quickstart: the paper's core loop in ~40 lines.

Builds a synthetic corpus with injected entity codes (§5.1), ingests it
into a single-file knowledge container, runs hybrid queries through the
batched serving entry point (``QueryEngine.query_batch``), compares the
clustered IVF index against the flat scan (probed fraction + recall),
runs the mesh-sharded index plane with its bit-exactness guarantee,
then shows the O(U) incremental sync (§3.3).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

from repro.core.engine import QueryEngine
from repro.core.ingest import KnowledgeBase
from repro.data.corpus import make_corpus, write_corpus_dir


def main():
    with tempfile.TemporaryDirectory() as work:
        corpus_dir = os.path.join(work, "docs")
        docs, entities = make_corpus(n_docs=500, n_entities=5, seed=42)
        write_corpus_dir(corpus_dir, docs)

        # --- cold ingestion -------------------------------------------
        kb = KnowledgeBase(dim=4096)
        stats = kb.sync(corpus_dir)
        print(f"cold ingest : {stats.added} docs in {stats.seconds:.2f}s "
              f"({stats.added / stats.seconds:.0f} docs/s)")

        # --- hybrid retrieval (HSF: α·cos + β·substring), batched ------
        # QueryEngine is the serving entry point: one dispatch scores
        # the whole query batch (scoring_path="auto" picks the fused
        # Pallas kernel on TPU, the bit-stable map path elsewhere)
        engine = QueryEngine(kb, alpha=1.0, beta=1.0)
        code, target = next(iter(entities.items()))
        print(f"\nquery: {code!r}")
        for r in engine.query_batch([code], k=3)[0]:
            mark = "BOOSTED" if r.boosted else "       "
            print(f"  {mark} {r.doc_id:22s} score={r.score:.4f} "
                  f"cos={r.cosine:.4f}")
        assert engine.query_batch([code], k=1)[0][0].doc_id == \
            f"doc_{target:05d}.txt"

        # --- one dispatch, many queries --------------------------------
        codes = list(entities)[:3]
        for code_, results in zip(codes, engine.query_batch(codes, k=1)):
            print(f"batched query {code_!r} → {results[0].doc_id}")

        # --- clustered index: probe √N centroids, rerank exactly -------
        # index="ivf" scores ~√N centroids, probes the top-nprobe
        # clusters, and reranks the gathered rows with the exact HSF —
        # sublinear scan cost; guarantee="exact" would widen probes
        # until the top-k provably matches the flat scan bit-for-bit
        ivf = QueryEngine(kb, alpha=1.0, beta=1.0, index="ivf", nprobe=2)
        codes = list(entities)
        flat_top = engine.query_batch(codes, k=1)
        ivf_top = ivf.query_batch(codes, k=1)
        recall = sum(
            f[0].doc_id == v[0].doc_id for f, v in zip(flat_top, ivf_top)
        ) / len(codes)
        stats = ivf.index_stats()
        print(f"\nivf index   : {stats['n_clusters']} clusters, "
              f"probed {stats['probed_fraction']:.0%} of the corpus "
              f"(nprobe=2), Recall@1 vs flat scan: {recall:.0%}")

        # --- sharded index: the cluster plane across the device mesh ---
        # index="ivf-sharded" gives each device (or logical shard, on a
        # single-device host) its own clusters' resident rows; only
        # per-shard [B, k] top-k candidates cross the interconnect, and
        # guarantee="exact" keeps the merged answer bit-identical to
        # the flat scan at any shard count
        sharded = QueryEngine(kb, alpha=1.0, beta=1.0,
                              index="ivf-sharded", guarantee="exact",
                              n_shards=4)
        flat_map = QueryEngine(kb, alpha=1.0, beta=1.0,
                               scoring_path="map")
        a = flat_map.query_batch(codes, k=3)
        b = sharded.query_batch(codes, k=3)
        assert all(
            [(r.doc_id, r.score) for r in x]
            == [(r.doc_id, r.score) for r in y]
            for x, y in zip(a, b)
        )
        st = sharded.index_stats()
        placement = "mesh" if sharded.ivf.mesh is not None else "logical"
        print(f"sharded     : {st['n_shards']} shards ({placement}), "
              f"exact top-k bit-identical to the flat scan ✓ "
              f"(merge {st['merge_seconds'] * 1e3:.2f} ms)")

        # --- incremental sync: O(U), not O(N) --------------------------
        with open(os.path.join(corpus_dir, "doc_00007.txt"), "a") as f:
            f.write(" freshly added INV-2026 reference")
        stats = kb.sync(corpus_dir)
        refresh = engine.refresh()  # patches 1 device row, not 500
        print(f"\nincremental : {stats.updated} updated, "
              f"{stats.skipped} skipped in {stats.seconds:.3f}s "
              f"(engine refresh: {refresh.changed} row, "
              f"{refresh.seconds * 1e3:.1f} ms)")
        top = engine.query_batch(["INV-2026"], k=1)[0][0]
        print(f"query INV-2026 → {top.doc_id} (score {top.score:.3f})")

        # --- single-file container (§3.1) -------------------------------
        path = os.path.join(work, "knowledge.ragdb")
        kb.save(path)
        print(f"\ncontainer   : {os.path.getsize(path) / 1e6:.2f} MB "
              f"(single file, SHA-256 verified segments)")
        kb2 = KnowledgeBase.load(path)
        assert QueryEngine(kb2).query_batch([code], k=1)[0][0].doc_id == \
            f"doc_{target:05d}.txt"
        print("restore     : retrieval identical after round-trip ✓")


if __name__ == "__main__":
    main()
