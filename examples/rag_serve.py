"""End-to-end RAG serving through the concurrent runtime: many
independent callers submit single requests; the micro-batching
scheduler coalesces them into batched scoring dispatches against a
generation-pinned snapshot (docs/ARCHITECTURE.md §7), then the
generation plane decodes per request.

    PYTHONPATH=src python examples/rag_serve.py
"""
import os
import tempfile
import threading
import time

import jax

from repro.configs import ARCHS
from repro.core.ingest import KnowledgeBase
from repro.core.rag import RAGPipeline
from repro.data.corpus import make_corpus, write_corpus_dir
from repro.models import transformer as T
from repro.serving import ServingRuntime


def main():
    with tempfile.TemporaryDirectory() as work:
        corpus_dir = os.path.join(work, "docs")
        docs, entities = make_corpus(n_docs=300, n_entities=6, seed=7)
        write_corpus_dir(corpus_dir, docs)
        kb = KnowledgeBase(dim=2048)
        kb.sync(corpus_dir)

        cfg = ARCHS["gemma2-9b"].smoke_config  # local+global, softcaps
        params = T.init(jax.random.PRNGKey(0), cfg)
        runtime = ServingRuntime(kb, max_batch=8, flush_deadline=0.002)
        rag = RAGPipeline(kb, params, cfg, max_context_tokens=128,
                          engine=runtime.engine)

        requests = [f"lookup {code} status" for code in entities] + [
            "quarterly revenue forecast",
            "kubernetes deployment latency",
        ]
        print(f"serving {len(requests)} concurrent requests through the "
              f"micro-batching scheduler ({cfg.name}, "
              f"{cfg.param_count() / 1e6:.1f} M params)\n")

        served = {}
        with runtime:
            t0 = time.perf_counter()

            # each request arrives from its own caller thread — the
            # scheduler, not the callers, decides the batch shapes
            def call(q):
                served[q] = runtime.submit(q, k=2).result(timeout=60)

            threads = [threading.Thread(target=call, args=(q,))
                       for q in requests]
            for t in threads:
                t.start()
            for t in threads:
                t.join()

            outs = [
                (q, rag.generate(q, served[q].results, max_new_tokens=6))
                for q in requests
            ]
            for q, out in outs:
                top = out.retrieved[0]
                print(f"  {q[:40]:42s} → {top.doc_id} "
                      f"(score {top.score:.3f}"
                      f"{'*' if top.boosted else ''}) "
                      f"tokens={out.token_ids}")
            dt = time.perf_counter() - t0
        print(f"\n{len(requests)} requests in {dt:.1f}s "
              f"({dt / len(requests) * 1e3:.0f} ms/request, CPU)")
        print(f"metrics: {runtime.metrics.format()}")
        occupancy = runtime.metrics.snapshot()["batch_occupancy_mean"]
        assert occupancy > 1.0, "scheduler never coalesced a batch"

        # entity queries must hit their documents (paper RQ2)
        for code, idx in entities.items():
            top = rag.answer(code, max_new_tokens=1, top_k_docs=1)
            assert top.retrieved[0].doc_id == f"doc_{idx:05d}.txt"
        print("RQ2 check: all entity requests retrieved their doc ✓")


if __name__ == "__main__":
    main()
