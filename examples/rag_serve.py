"""End-to-end RAG serving: batched requests against the integrated
retrieval + generation planes (deliverable (b): serve a small model
with batched requests).

    PYTHONPATH=src python examples/rag_serve.py
"""
import os
import tempfile
import time

import jax

from repro.configs import ARCHS
from repro.core.ingest import KnowledgeBase
from repro.core.rag import RAGPipeline
from repro.data.corpus import make_corpus, write_corpus_dir
from repro.models import transformer as T


def main():
    with tempfile.TemporaryDirectory() as work:
        corpus_dir = os.path.join(work, "docs")
        docs, entities = make_corpus(n_docs=300, n_entities=6, seed=7)
        write_corpus_dir(corpus_dir, docs)
        kb = KnowledgeBase(dim=2048)
        kb.sync(corpus_dir)

        cfg = ARCHS["gemma2-9b"].smoke_config  # local+global, softcaps
        params = T.init(jax.random.PRNGKey(0), cfg)
        rag = RAGPipeline(kb, params, cfg, max_context_tokens=128)

        requests = [f"lookup {code} status" for code in entities] + [
            "quarterly revenue forecast",
            "kubernetes deployment latency",
        ]
        print(f"serving {len(requests)} requests as ONE batch "
              f"({cfg.name}, {cfg.param_count() / 1e6:.1f} M params)\n")
        t0 = time.perf_counter()
        outs = rag.answer_batch(requests, max_new_tokens=6, top_k_docs=2)
        for q, out in zip(requests, outs):
            top = out.retrieved[0]
            print(f"  {q[:40]:42s} → {top.doc_id} "
                  f"(score {top.score:.3f}{'*' if top.boosted else ''}) "
                  f"tokens={out.token_ids}")
        dt = time.perf_counter() - t0
        print(f"\n{len(requests)} requests in {dt:.1f}s "
              f"({dt / len(requests) * 1e3:.0f} ms/request, CPU; "
              f"retrieval batched through QueryEngine.query_batch)")

        # entity queries must hit their documents (paper RQ2)
        for code, idx in entities.items():
            top = rag.answer(code, max_new_tokens=1, top_k_docs=1)
            assert top.retrieved[0].doc_id == f"doc_{idx:05d}.txt"
        print("RQ2 check: all entity requests retrieved their doc ✓")


if __name__ == "__main__":
    main()
