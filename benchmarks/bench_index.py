"""Index-plane benchmarks: IVF clustered retrieval vs the flat scan.

Every flat scoring path is O(N·D) per query batch; the IVF index
(src/repro/index/) scores √N centroids and gathers only the probed
clusters' rows, trading recall for scan cost.  This bench quantifies
that trade as QPS-vs-Recall@k against the flat **gemm** path (the
throughput-first flat baseline) swept over corpus size × nprobe:

- ``index_flat_gemm_*``     — the baseline batched QPS;
- ``index_ivf_*_p{nprobe}`` — IVF QPS, Recall@10 vs the flat top-10,
  probed row fraction, and the speedup multiple;
- ``index_train_*``         — one-off spherical k-means fit cost;
- ``index_exact_parity_*``  — asserts ``guarantee="exact"`` returns
  bit-identical (ids, scores, tie order) results to the flat scan.

Acceptance bar (full run): ≥ 3x QPS over flat gemm at N = 50k with
Recall@10 ≥ 0.95 at some swept nprobe.  The ``--smoke`` run (CI) uses
a tiny corpus and asserts the exactness parity plus Recall@1 ≥ 0.9 on
the entity workload at nprobe=1.

    PYTHONPATH=src python -m benchmarks.bench_index [--smoke]
"""
from __future__ import annotations

import argparse
import time

from repro.core.engine import QueryEngine
from repro.core.ingest import KnowledgeBase
from repro.data.corpus import make_topical_corpus

FULL_SIZES = (1_000, 10_000, 50_000)
FULL_DIM = 1024
SMOKE_SIZES = (400,)
SMOKE_DIM = 512

NPROBES = (1, 2, 4, 8, 16)
BATCH = 8
K = 10


def _build_kb(n_docs: int, dim: int):
    """Topical corpus (data/corpus.py): real collections cluster by
    topic, and cluster pruning is measured where cosine neighborhoods
    actually concentrate — the uniform ``make_corpus`` is intentionally
    structure-free (every doc a random bag over one flat vocab), the
    worst case for *any* clustered index."""
    docs, entities, topics = make_topical_corpus(
        n_docs=n_docs, n_topics=max(8, n_docs // 300), n_entities=16, seed=0,
    )
    kb = KnowledgeBase(dim=dim)
    for i, d in enumerate(docs):
        kb.add_text(f"doc_{i:06d}.txt", d)
    return kb, entities, topics


def _workload(entities, topics) -> tuple[list[str], slice]:
    """Entity lookups + topical phrase queries, and the slice of the
    topical subset.  QPS is measured over the whole mix; Recall@10 is
    scored on the topical queries (semantic ranking recall — their flat
    top-10 is a cosine neighborhood an index must preserve).  Entity
    lookups are scored as Recall@1 against the injected ground truth:
    their flat ranks 2..10 are uniform common-word noise ("invoice",
    "code", …) that no clustered index — and no user — cares about."""
    codes = list(entities)
    queries = (codes
               + [f"lookup {c} status report" for c in codes[:8]]
               + [" ".join(t[:6]) for t in topics[:16]])
    return queries, slice(len(codes) + 8, None)


def _qps(engine: QueryEngine, queries: list[str], reps: int) -> float:
    for start in range(0, len(queries), BATCH):  # warm the jit buckets
        engine.query_batch(queries[start: start + BATCH], k=K)
    t0 = time.perf_counter()
    for _ in range(reps):
        for start in range(0, len(queries), BATCH):
            engine.query_batch(queries[start: start + BATCH], k=K)
    dt = time.perf_counter() - t0
    return reps * len(queries) / dt


def _recall(got, want, k: int) -> float:
    """Mean |ivf top-k ∩ flat top-k| / k over the query set."""
    total = 0.0
    for g, w in zip(got, want):
        truth = {r.doc_id for r in w[:k]}
        total += len({r.doc_id for r in g[:k]} & truth) / max(len(truth), 1)
    return total / max(len(got), 1)


def bench_index(smoke: bool = False):
    sizes, dim = (SMOKE_SIZES, SMOKE_DIM) if smoke else (FULL_SIZES, FULL_DIM)
    reps = 2 if smoke else 3
    rows = []
    for n_docs in sizes:
        kb, entities, topics = _build_kb(n_docs, dim)
        queries, topical = _workload(entities, topics)

        # ---- exactness parity: ivf@exact ≡ flat, bit for bit ------------
        flat_map = QueryEngine(kb, scoring_path="map")
        exact = QueryEngine(kb, scoring_path="map", index="ivf",
                            guarantee="exact", nprobe=1)
        a = flat_map.query_batch(queries, k=K)
        b = exact.query_batch(queries, k=K)
        mism = sum(
            [(r.doc_id, r.score, r.cosine, r.boosted) for r in x]
            != [(r.doc_id, r.score, r.cosine, r.boosted) for r in y]
            for x, y in zip(a, b)
        )
        assert mism == 0, (
            f"ivf@exact diverged from the flat scan on {mism} queries"
        )
        rows.append((f"index_exact_parity_{n_docs}docs", 0.0,
                     f"queries={len(queries)}_mismatches=0"))

        # ---- entity Recall@1 at nprobe=1 (the smoke recall bar) ---------
        probe1 = QueryEngine(kb, scoring_path="map", index="ivf", nprobe=1)
        hits = sum(
            res[0].doc_id == f"doc_{target:06d}.txt"
            for res, target in zip(
                probe1.query_batch(list(entities), k=1), entities.values()
            )
        )
        recall1 = hits / len(entities)
        rows.append((f"index_ivf_entity_recall1_{n_docs}docs_p1", 0.0,
                     f"recall1={recall1:.3f}"))
        if smoke:
            assert recall1 >= 0.9, (
                f"entity Recall@1 at nprobe=1 was {recall1:.2f} (need ≥0.9)"
            )

        # ---- QPS-vs-Recall sweep vs the flat gemm baseline --------------
        flat = QueryEngine(kb, gemm_batch=True)
        truth = flat.query_batch(queries, k=K)
        flat_qps = _qps(flat, queries, reps)
        rows.append((f"index_flat_gemm_{n_docs}docs",
                     1e6 / flat_qps, f"qps={flat_qps:.0f}"))

        t0 = time.perf_counter()
        ivf0 = QueryEngine(kb, gemm_batch=True, index="ivf", nprobe=1)
        rows.append((f"index_train_{n_docs}docs",
                     (time.perf_counter() - t0) * 1e6,
                     f"clusters={ivf0.ivf.n_clusters}"))

        best = (0.0, 0.0, None)  # (speedup, recall, nprobe)
        for nprobe in NPROBES:
            if nprobe > ivf0.ivf.n_clusters:
                continue
            ivf = QueryEngine(kb, gemm_batch=True, index="ivf",
                              nprobe=nprobe)
            got = ivf.query_batch(queries, k=K)
            rec = _recall(got[topical], truth[topical], K)
            qps = _qps(ivf, queries, reps)
            frac = ivf.index_stats()["probed_fraction"]
            speedup = qps / flat_qps
            if rec >= 0.95 and speedup > best[0]:
                best = (speedup, rec, nprobe)
            rows.append((
                f"index_ivf_{n_docs}docs_p{nprobe}",
                1e6 / qps,
                f"qps={qps:.0f}_recall{K}={rec:.3f}"
                f"_speedup={speedup:.2f}x_probed={frac:.3f}",
            ))
        if not smoke and n_docs >= 50_000:
            # the tentpole acceptance: ≥3x over flat gemm at recall ≥0.95
            assert best[2] is not None and best[0] >= 3.0, (
                f"no swept nprobe reached 3x at Recall@{K} ≥ 0.95 "
                f"(best {best[0]:.2f}x at nprobe={best[2]})"
            )

        # ---- index health counters (engine.index_stats) -----------------
        # probe1 served the recall workload above, so its probe
        # accounting is populated
        s = probe1.index_stats()
        rows.append((
            f"index_stats_{n_docs}docs", 0.0,
            f"clusters={s['n_clusters']}_probed={s['probed_fraction']:.3f}"
            f"_rounds={s['rounds']}_drift={s['drift']}"
            f"_retrains={s['retrains']}",
        ))
    return rows


def bench_sharded(smoke: bool = False, max_shards: int = 8):
    """Shard-count sweep over the mesh-partitioned IVF plane.

    For each shard count S ∈ {1, 2, 4, 8} (capped at ``max_shards``):
    asserts ``index="ivf-sharded", guarantee="exact"`` is bit-identical
    to the flat map scan, then reports exact-mode QPS with the
    host-side stable-merge overhead (``merge_seconds`` as a fraction of
    wall time) and probe-mode QPS with topical Recall@10.  On a
    single-device host every S runs the logical per-shard fallback —
    identical numerics to the mesh placement (tests prove it), so the
    parity sweep is meaningful anywhere; run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to measure
    the real ``shard_map`` dispatch (the CI multi-device leg does).
    """
    sizes, dim = (SMOKE_SIZES, SMOKE_DIM) if smoke else ((50_000,), FULL_DIM)
    reps = 2 if smoke else 3
    shard_counts = [s for s in (1, 2, 4, 8) if s <= max_shards]
    rows = []
    for n_docs in sizes:
        kb, entities, topics = _build_kb(n_docs, dim)
        queries, topical = _workload(entities, topics)
        flat = QueryEngine(kb, scoring_path="map")
        truth = flat.query_batch(queries, k=K)
        flat_qps = _qps(flat, queries, reps)
        rows.append((f"index_flat_map_{n_docs}docs",
                     1e6 / flat_qps, f"qps={flat_qps:.0f}"))
        for n_shards in shard_counts:
            exact = QueryEngine(kb, scoring_path="map",
                                index="ivf-sharded", guarantee="exact",
                                nprobe=8, n_shards=n_shards)
            got = exact.query_batch(queries, k=K)
            mism = sum(
                [(r.doc_id, r.score, r.cosine, r.boosted) for r in x]
                != [(r.doc_id, r.score, r.cosine, r.boosted) for r in y]
                for x, y in zip(truth, got)
            )
            assert mism == 0, (
                f"sharded@exact (S={n_shards}) diverged from the flat "
                f"scan on {mism} queries"
            )
            placement = "mesh" if exact.ivf.mesh is not None else "logical"
            rows.append((f"index_sharded_parity_{n_docs}docs_s{n_shards}",
                         0.0,
                         f"queries={len(queries)}_mismatches=0"
                         f"_{placement}"))

            qps = _qps(exact, queries, reps)
            # merge overhead: host stable-merge seconds of one warmed
            # dispatch as a fraction of that dispatch's wall time
            t0 = time.perf_counter()
            exact.query_batch(queries[:BATCH], k=K)
            batch_wall = time.perf_counter() - t0
            merge = exact.index_stats()["merge_seconds"]
            rows.append((
                f"index_sharded_exact_{n_docs}docs_s{n_shards}",
                1e6 / qps,
                f"qps={qps:.0f}_speedup={qps / flat_qps:.2f}x"
                f"_merge_frac={min(1.0, merge / max(batch_wall, 1e-9)):.3f}"
                f"_{placement}",
            ))

            probe = QueryEngine(kb, scoring_path="map",
                                index="ivf-sharded", nprobe=8,
                                n_shards=n_shards)
            got = probe.query_batch(queries, k=K)
            rec = _recall(got[topical], truth[topical], K)
            pqps = _qps(probe, queries, reps)
            rows.append((
                f"index_sharded_probe_{n_docs}docs_s{n_shards}_p8",
                1e6 / pqps,
                f"qps={pqps:.0f}_recall{K}={rec:.3f}"
                f"_speedup={pqps / flat_qps:.2f}x_{placement}",
            ))
        # entity Recall@1 bar on the sharded probe plane (smoke gate)
        probe1 = QueryEngine(kb, scoring_path="map", index="ivf-sharded",
                             nprobe=1, n_shards=shard_counts[-1])
        hits = sum(
            res[0].doc_id == f"doc_{target:06d}.txt"
            for res, target in zip(
                probe1.query_batch(list(entities), k=1), entities.values()
            )
        )
        recall1 = hits / len(entities)
        rows.append((f"index_sharded_entity_recall1_{n_docs}docs"
                     f"_s{shard_counts[-1]}_p1", 0.0,
                     f"recall1={recall1:.3f}"))
        if smoke:
            assert recall1 >= 0.9, (
                f"sharded entity Recall@1 at nprobe=1 was {recall1:.2f} "
                "(need ≥0.9)"
            )
    return rows


ALL = [bench_index]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus (CI): asserts ivf@exact is "
                    "bit-identical to flat and entity Recall@1 ≥ 0.9 "
                    "at nprobe=1")
    ap.add_argument("--shards", type=int, default=None,
                    help="also sweep the sharded plane over shard counts "
                    "1/2/4/8 capped at this value (asserts sharded@exact "
                    "bit-parity with the flat scan at every count)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for fn in ALL:
        for name, us, derived in fn(smoke=args.smoke):
            print(f"{name},{us:.1f},{derived}", flush=True)
    if args.shards:
        for name, us, derived in bench_sharded(smoke=args.smoke,
                                               max_shards=args.shards):
            print(f"{name},{us:.1f},{derived}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
