# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
#
#   RQ1 (paper §5.2)  cold vs incremental ingestion
#   RQ2 (paper §5.3)  hybrid vs pure-cosine entity Recall@1
#   RQ3 (paper §5.4)  container footprint + query latency
#   kernels           HSF / top-k micro-benchmarks
#   scale             sharded-retrieval payload accounting
#   serving           micro-batching scheduler load tests (open/closed loop)
#   persistence       journaled delta saves vs full container rewrites
#   index             IVF clustered retrieval: QPS-vs-Recall vs flat scan
#
# Roofline tables are a separate heavier entry point
# (``python -m benchmarks.roofline``) because they compile dry-run
# variants under the 512-device XLA flag.
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_index,
        bench_paper,
        bench_persistence,
        bench_scale,
        bench_serving,
    )

    print("name,us_per_call,derived")
    failures = 0
    for fn in (bench_paper.ALL + bench_scale.ALL + bench_serving.ALL
               + bench_persistence.ALL + bench_index.ALL):
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__},NaN,FAILED", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
