"""Serving-runtime load benchmarks: throughput vs. tail latency through
the micro-batching scheduler (serving/scheduler.py).

Two standard load-generator shapes, swept over flush deadlines:

- **closed-loop**: N worker threads, each submitting its next request
  the moment the previous one resolves.  Measures peak sustainable
  throughput (and proves the scheduler beats per-request batch-size-1
  dispatch — the whole reason the subsystem exists).
- **open-loop**: a fixed arrival rate, requests submitted on a clock
  regardless of completions (the honest tail-latency methodology:
  closed loops self-throttle and hide queueing delay).  Measures
  p50/p99 under a load the server does not control.

The serving result cache is disabled for all runs so every request
pays a real scoring dispatch (the cache's win is measured separately
by its hit-rate counters in the drivers).  All runtimes — including
the batch-1 baseline — score through the throughput-first ``gemm``
path (docs/ARCHITECTURE.md §5): the bit-stable ``lax.map`` default
serializes per-query compute, so it amortizes only dispatch overhead
under batching; the GEMM genuinely scales sublinearly in batch size,
which is the configuration a throughput benchmark should measure.

CSV rows follow the suite convention (``name,us_per_call,derived``).

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke]
"""
from __future__ import annotations

import argparse
import threading
import time

from repro.analysis import sanitizers
from repro.core.ingest import KnowledgeBase
from repro.data.corpus import make_corpus
from repro.obs import (
    format_breakdown,
    request_decomposition,
    trace as obs_trace,
    write_chrome_trace,
)
from repro.serving import RequestRejected, ServingRuntime

# (n_docs, dim, n_requests, n_workers, open-loop arrival rate qps)
# closed-loop saturation wants workers ≥ max_batch: while one flush
# computes, every worker resubmits, so the next flush fills to the cap
# without ever waiting out the deadline
FULL = (2000, 2048, 384, 16, 200.0)
SMOKE = (200, 512, 160, 16, 150.0)

DEADLINES_MS = (0.0, 2.0, 8.0)  # acceptance: ≥ 3 flush-deadline settings
K = 5


def _build_kb(n_docs: int, dim: int):
    docs, entities = make_corpus(n_docs=n_docs, n_entities=16, seed=0)
    kb = KnowledgeBase(dim=dim)
    for i, d in enumerate(docs):
        kb.add_text(f"doc_{i:05d}.txt", d)
    queries = [f"lookup {code} status report" for code in entities]
    return kb, queries


def _runtime(kb, *, max_batch: int, deadline_s: float) -> ServingRuntime:
    # result cache off: measure scoring dispatches, not dict lookups
    return ServingRuntime(kb, max_batch=max_batch,
                          flush_deadline=deadline_s,
                          max_queue=4096, result_cache_size=0,
                          scoring_path="gemm")


def _warm(runtime: ServingRuntime, queries: list[str]) -> None:
    """Pre-compile every power-of-two bucket the run can hit."""
    with runtime:
        b = 1
        while b <= runtime.scheduler.max_batch:
            runtime.query_batch(queries[:b], k=K)
            b *= 2
        if sanitizers.enabled():
            # RAGDB_SANITIZERS=1: baseline the jit caches — any
            # steady-state recompile now fails the run loudly
            runtime.arm_sanitizers(k=K)
        runtime.metrics.reset()


def closed_loop(runtime: ServingRuntime, queries: list[str],
                n_requests: int, n_workers: int,
                explain: bool = False) -> dict:
    """N workers, each fires its next request on completion."""
    counter = {"i": 0}
    lock = threading.Lock()

    def worker(wid: int):
        while True:
            with lock:
                i = counter["i"]
                if i >= n_requests:
                    return
                counter["i"] = i + 1
            q = queries[(i * 7 + wid) % len(queries)]
            runtime.submit(q, k=K, explain=explain).result(timeout=120)

    with runtime:
        t0 = time.perf_counter()
        threads = [threading.Thread(target=worker, args=(w,))
                   for w in range(n_workers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
    m = runtime.metrics.snapshot()
    return {"throughput_qps": n_requests / dt, "wall_s": dt, **m}


def open_loop(runtime: ServingRuntime, queries: list[str],
              n_requests: int, rate_qps: float) -> dict:
    """Fixed arrival rate; rejected submissions count, never block."""
    futures = []
    rejected = 0
    with runtime:
        period = 1.0 / rate_qps
        t0 = time.perf_counter()
        for i in range(n_requests):
            target = t0 + i * period
            delay = target - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            try:
                futures.append(
                    runtime.submit(queries[(i * 7) % len(queries)], k=K)
                )
            except RequestRejected:
                rejected += 1
        for f in futures:
            f.result(timeout=120)
        dt = time.perf_counter() - t0
    m = runtime.metrics.snapshot()
    return {"offered_qps": rate_qps, "achieved_qps": len(futures) / dt,
            "open_rejected": rejected, **m}


def bench_serving_closed(smoke: bool = False):
    """Closed-loop sweep + the batch-1 per-request baseline."""
    n_docs, dim, n_requests, n_workers, _ = SMOKE if smoke else FULL
    kb, queries = _build_kb(n_docs, dim)
    rows = []

    # per-request dispatch baseline: max_batch=1 forces one scoring
    # dispatch per request through the same machinery
    rt = _runtime(kb, max_batch=1, deadline_s=0.0)
    _warm(rt, queries)
    base = closed_loop(rt, queries, n_requests, n_workers)
    rows.append((
        f"serving_closed_batch1_{n_docs}docs",
        base["wall_s"] / n_requests * 1e6,
        f"qps={base['throughput_qps']:.0f}_p50ms={base['latency_p50_ms']:.2f}"
        f"_p99ms={base['latency_p99_ms']:.2f}_occ={base['batch_occupancy_mean']:.1f}",
    ))

    best = 0.0
    for dl_ms in DEADLINES_MS:
        rt = _runtime(kb, max_batch=16, deadline_s=dl_ms / 1e3)
        _warm(rt, queries)
        r = closed_loop(rt, queries, n_requests, n_workers)
        best = max(best, r["throughput_qps"])
        rows.append((
            f"serving_closed_flush{dl_ms:g}ms_{n_docs}docs",
            r["wall_s"] / n_requests * 1e6,
            f"qps={r['throughput_qps']:.0f}_p50ms={r['latency_p50_ms']:.2f}"
            f"_p99ms={r['latency_p99_ms']:.2f}_occ={r['batch_occupancy_mean']:.1f}",
        ))

    # acceptance: micro-batching must beat per-request dispatch
    assert best > base["throughput_qps"], (
        f"micro-batched scheduler ({best:.0f} qps) did not beat "
        f"per-request dispatch ({base['throughput_qps']:.0f} qps)"
    )
    rows.append(("serving_closed_speedup", 0.0,
                 f"microbatch_vs_batch1={best / base['throughput_qps']:.2f}x"))
    return rows


def bench_serving_open(smoke: bool = False):
    """Open-loop tail latency across flush deadlines at fixed offered
    load."""
    n_docs, dim, n_requests, _, rate = SMOKE if smoke else FULL
    kb, queries = _build_kb(n_docs, dim)
    rows = []
    for dl_ms in DEADLINES_MS:
        rt = _runtime(kb, max_batch=16, deadline_s=dl_ms / 1e3)
        _warm(rt, queries)
        r = open_loop(rt, queries, n_requests, rate)
        rows.append((
            f"serving_open_flush{dl_ms:g}ms_{n_docs}docs",
            1e6 / rate,
            f"offered={rate:.0f}qps_achieved={r['achieved_qps']:.0f}qps"
            f"_p50ms={r['latency_p50_ms']:.2f}_p99ms={r['latency_p99_ms']:.2f}"
            f"_occ={r['batch_occupancy_mean']:.1f}_rej={r['open_rejected']}",
        ))
    return rows


TRACE_SAMPLE = 0.25  # the documented production sampling default


def bench_serving_traced(smoke: bool = False, trace_path: str | None = None,
                         sample: float = TRACE_SAMPLE,
                         explain_out: str | None = None,
                         health_out: str | None = None):
    """The observability overhead + correctness contract, measured:

    1. closed loop untraced vs traced+EXPLAIN (1-in-4 request span
       sampling, the production default; every traced-arm request also
       carries ``explain=True``, so the gate covers plan capture too) —
       the traced arm must keep ≥ 95% of untraced throughput;
    2. every sampled request's stage spans (queue_wait + flush_wait +
       score + merge) must tile the request span exactly — the sum is
       asserted against the end-to-end duration per request;
    3. optionally exports the Chrome trace-event JSON (``--trace``)
       and prints the per-stage breakdown table.

    Methodology: one runtime, warmed once, then tightly interleaved
    off/on run pairs (the arms are seconds apart, so slow host drift
    cancels) aggregated by the *median* per-pair QPS ratio — host
    noise on short closed loops is heavy-tailed (transient ±20%
    stalls), so best-of or mean aggregation would gate on the noise
    floor, not the overhead.  Workers run at 2x ``max_batch`` so every
    flush fills without waiting out the deadline (batch-phase jitter
    is the other big variance source).  More pairs are added (up to
    15) until the median stabilizes past the gate.
    """
    n_docs, dim, n_requests, _, _ = SMOKE if smoke else FULL
    kb, queries = _build_kb(n_docs, dim)
    n_requests = max(n_requests, 1000)  # short runs measure only noise
    max_batch = 16

    rt = _runtime(kb, max_batch=max_batch, deadline_s=0.002)
    _warm(rt, queries)

    def run_qps(explain: bool = False) -> float:
        r = closed_loop(rt, queries, n_requests, 2 * max_batch,
                        explain=explain)
        return r["throughput_qps"]

    tracer = obs_trace.get()
    ratios: list[float] = []
    spans = []
    median = 0.0
    try:
        for round_ in range(3):
            for _ in range(5):
                tracer.disable()
                off = run_qps()
                tracer.enable(sample=sample)
                on = run_qps(explain=True)
                got = tracer.drain()
                spans = got or spans
                tracer.disable()
                ratios.append(on / off)
            srt = sorted(ratios)
            median = srt[len(srt) // 2]
            if median >= 0.95:
                break
    finally:
        tracer.disable()

    reqs = request_decomposition(spans)
    assert reqs, "traced run produced no request spans"
    worst = max(abs(r["request_s"] - r["stage_sum_s"]) for r in reqs)
    # the four stages share perf_counter timestamps, so they tile the
    # request exactly up to the ~1 ns span-record quantization
    assert worst < 1e-6, (
        f"stage decomposition does not tile request latency: worst "
        f"residual {worst * 1e6:.3f} us across {len(reqs)} requests"
    )
    assert median >= 0.95, (
        f"tracing overhead exceeds the 5% budget: median traced/untraced "
        f"qps ratio {median:.3f} over {len(ratios)} interleaved pairs"
    )

    if trace_path:
        n = write_chrome_trace(trace_path, spans)
        print(f"# trace: {n} events -> {trace_path}")
        print("\n".join("# " + ln
                        for ln in format_breakdown(spans).splitlines()))
    if explain_out or health_out:
        # one dedicated explain'd request for the sample-plan artifact,
        # plus a health verdict over the run the gate just measured
        import json

        from repro.obs.explain import write_plans

        with rt:
            rt.health()  # first sample anchors the fast window
            served = rt.submit(queries[0], k=K, explain=True).result(
                timeout=120)
            health = rt.health()
        if explain_out and served.plan is not None:
            write_plans(explain_out, [served.plan],
                        extra={"rendered": served.plan.render()})
            print(f"# explain plan -> {explain_out}")
        if health_out:
            with open(health_out, "w", encoding="utf-8") as f:
                json.dump(health, f, indent=2, sort_keys=True, default=str)
            print(f"# health ({health['status']}) -> {health_out}")
    return [
        (f"serving_traced_overhead_{n_docs}docs", 0.0,
         f"median_qps_ratio={median:.3f}_pairs={len(ratios)}"
         f"_sample={sample:g}"),
        (f"serving_trace_decomposition_{n_docs}docs", 0.0,
         f"requests={len(reqs)}_worst_residual_us={worst * 1e6:.3f}"),
    ]


# --------------------------------------------------------------------------
# multi-tenant sweep (tenancy plane, docs/ARCHITECTURE.md §13)
# --------------------------------------------------------------------------

# (tenant counts, resident budget, docs per tenant, requests per leg)
MT_FULL = ((1, 8, 64), 8, 200, 384)
MT_SMOKE = ((1, 4, 16), 4, 40, 128)
MT_ZIPF_SKEW = 1.1


def _zipf_picks(rng, n_tenants: int, n: int) -> list[int]:
    """Zipf-skewed tenant choices: rank r drawn ∝ 1/(r+1)^skew — a few
    hot tenants plus a long cold tail, the shape that actually stresses
    an LRU resident set."""
    weights = [1.0 / (r + 1) ** MT_ZIPF_SKEW for r in range(n_tenants)]
    return rng.choices(range(n_tenants), weights=weights, k=n)


def _tenant_name(i: int) -> str:
    return f"t{i:03d}"


def _seed_tenant_fleet(root: str, n_tenants: int, n_docs: int, dim: int):
    """Write one durable container per tenant (equal corpus sizes, so
    every tenant traces the same jit bucket set — remounts are
    recompile-free by construction); returns the query texts."""
    from repro.obs.metrics import MetricsRegistry
    from repro.tenancy import ContainerPool

    docs, entities = make_corpus(n_docs=n_docs, n_entities=8, seed=0)
    queries = [f"lookup {code} status report" for code in entities]
    pool = ContainerPool(root, kb_kwargs={"dim": dim},
                         registry=MetricsRegistry(),
                         max_resident=n_tenants + 1, scoring_path="gemm")
    for t in range(n_tenants):
        name = _tenant_name(t)
        with pool.pinned(name) as mt:
            for i, d in enumerate(docs):
                mt.kb.add_text(f"doc_{i:05d}.txt", f"{d} tenant {name}")
            mt.snapshots.publish(durable=True)
    pool.drain()
    return queries


def _mt_runtime(root: str, dim: int, budget: int, deadline_s: float):
    """A fresh pool (isolated metrics registry per leg) + runtime."""
    from repro.obs.metrics import MetricsRegistry
    from repro.tenancy import ContainerPool

    reg = MetricsRegistry()
    pool = ContainerPool(root, kb_kwargs={"dim": dim}, registry=reg,
                         max_resident=budget, scoring_path="gemm")
    rt = ServingRuntime(pool=pool, max_batch=16, flush_deadline=deadline_s,
                        max_queue=4096, result_cache_size=0)
    return rt, pool, reg


def _mt_warm(rt, queries, tenant: str) -> None:
    """Warm the shared bucket set through one tenant (all tenants have
    equal corpus shapes) and arm the recompile guard when sanitizers
    are on — steady-state mounts/evictions must then stay trace-free."""
    b = 1
    while b <= rt.scheduler.max_batch:
        rt.query_batch([queries[i % len(queries)] for i in range(b)],
                       k=K, tenant=tenant)
        b *= 2
    if sanitizers.enabled():
        rt.arm_sanitizers(k=K, tenants=[tenant])
    rt.metrics.reset()


def _mt_closed_loop(rt, queries, picks: list[int], n_workers: int) -> float:
    """Closed loop with a pre-drawn zipf tenant schedule; returns
    wall-clock seconds."""
    counter = {"i": 0}
    lock = threading.Lock()

    def worker(wid: int):
        while True:
            with lock:
                i = counter["i"]
                if i >= len(picks):
                    return
                counter["i"] = i + 1
            rt.submit(queries[(i * 7 + wid) % len(queries)], k=K,
                      tenant=_tenant_name(picks[i])).result(timeout=120)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(n_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0


def bench_serving_multitenant(smoke: bool = False):
    """N tenants through one runtime: zipf-skewed traffic over a
    bounded resident set.  Reports per-leg throughput, worst per-tenant
    p99, mount (cold-start/remount) and evict latency percentiles, and
    an isolation gate: a hot tenant hammering the scheduler must leave
    an unrelated tenant's p99 within 2x of that tenant's solo run.
    """
    import random
    import tempfile

    tenant_counts, budget, n_docs, n_requests = MT_SMOKE if smoke else MT_FULL
    _, dim, _, n_workers, _ = SMOKE if smoke else FULL
    deadline_s = 0.002
    rows = []
    with tempfile.TemporaryDirectory(prefix="ragdb_mt_bench_") as root:
        queries = _seed_tenant_fleet(root, max(tenant_counts), n_docs, dim)

        for n_tenants in tenant_counts:
            rt, pool, reg = _mt_runtime(root, dim, budget, deadline_s)
            picks = _zipf_picks(random.Random(1234), n_tenants, n_requests)
            with rt:
                _mt_warm(rt, queries, _tenant_name(0))
                dt = _mt_closed_loop(rt, queries, picks, n_workers)
                per_tenant = rt.tenant_metrics()
            pool.drain()
            worst_p99 = max(s["latency_p99_ms"] for s in per_tenant.values())
            # mount/evict latency straight off the pool's histograms
            # (the leg's private registry, so legs never cross-talk);
            # mount covers both cold starts and post-evict remounts
            mount_h = reg.histogram("ragdb_tenant_mount_seconds")
            evict_h = reg.histogram("ragdb_tenant_evict_seconds")
            rows.append((
                f"serving_mt_{n_tenants}t_budget{budget}_{n_docs}docs",
                dt / n_requests * 1e6,
                f"qps={n_requests / dt:.0f}"
                f"_tenants_hit={len(per_tenant)}"
                f"_worst_p99ms={worst_p99:.2f}"
                f"_mounts={mount_h.n}"
                f"_mount_p99ms={mount_h.percentile(99) * 1e3:.2f}"
                f"_evictions={evict_h.n}"
                f"_evict_p99ms={evict_h.percentile(99) * 1e3:.2f}",
            ))

        # isolation: solo baseline for the observed tenant, then the
        # same paced load while a hot tenant saturates the scheduler
        rate = 50.0
        n_cold = max(n_requests // 2, 64)
        cold, hot = _tenant_name(0), _tenant_name(1)

        def paced_cold(rt) -> None:
            period = 1.0 / rate
            futures = []
            t0 = time.perf_counter()
            for i in range(n_cold):
                delay = t0 + i * period - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
                futures.append(rt.submit(queries[i % len(queries)], k=K,
                                         tenant=cold))
            for f in futures:
                f.result(timeout=120)

        rt, pool, _ = _mt_runtime(root, dim, budget, deadline_s)
        with rt:
            _mt_warm(rt, queries, cold)
            paced_cold(rt)
            solo_p99 = rt.tenant_metrics()[cold]["latency_p99_ms"]
        pool.drain()

        rt, pool, _ = _mt_runtime(root, dim, budget, deadline_s)
        with rt:
            _mt_warm(rt, queries, cold)
            rt.query_batch(queries[:1], k=K, tenant=hot)  # mount hot
            rt.metrics.reset()
            hot_picks = [1] * (n_requests * 2)
            hot_thread = threading.Thread(
                target=_mt_closed_loop,
                args=(rt, queries, hot_picks, n_workers))
            hot_thread.start()
            paced_cold(rt)
            hot_thread.join()
            m = rt.tenant_metrics()
            cold_p99 = m[cold]["latency_p99_ms"]
            hot_qps = m[hot]["qps"]
        pool.drain()

        # the gate: overload on one tenant must not starve another.
        # Floor the baseline at 1 ms so a near-zero solo p99 (tiny
        # smoke corpora) cannot turn measurement noise into a failure.
        limit = 2.0 * max(solo_p99, 1.0)
        assert cold_p99 <= limit, (
            f"tenant isolation violated: cold-tenant p99 {cold_p99:.2f} ms "
            f"under hot-tenant overload vs {solo_p99:.2f} ms solo "
            f"(limit {limit:.2f} ms)"
        )
        rows.append((
            "serving_mt_isolation",
            0.0,
            f"solo_p99ms={solo_p99:.2f}_overload_p99ms={cold_p99:.2f}"
            f"_ratio={cold_p99 / max(solo_p99, 1e-9):.2f}"
            f"_hot_qps={hot_qps:.0f}",
        ))
    return rows


ALL = [bench_serving_closed, bench_serving_open, bench_serving_traced,
       bench_serving_multitenant]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus, ~100 requests (CI concurrency "
                    "smoke for the scheduler/snapshot machinery)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="write the traced run's Chrome trace-event "
                    "JSON here (Perfetto-loadable; inspect with "
                    "`python -m repro.obs FILE`)")
    ap.add_argument("--trace-sample", type=float, default=TRACE_SAMPLE,
                    help="request sampling rate for the traced arm "
                    f"(default {TRACE_SAMPLE:g})")
    ap.add_argument("--explain-out", default=None, metavar="FILE",
                    help="write a sample EXPLAIN plan (JSON, rendered "
                    "tree included) from the traced leg here; inspect "
                    "with `python -m repro.obs explain FILE`")
    ap.add_argument("--health-out", default=None, metavar="FILE",
                    help="write the traced leg's SLO health verdict "
                    "(runtime.health() JSON) here")
    ap.add_argument("--only", default=None, metavar="SUFFIX",
                    help="run just the bench_serving_<SUFFIX> bench "
                    "(closed | open | traced | multitenant)")
    args = ap.parse_args(argv)
    benches = ALL if args.only is None else [
        fn for fn in ALL if fn.__name__ == f"bench_serving_{args.only}"]
    if not benches:
        ap.error(f"unknown bench suffix {args.only!r}")
    print("name,us_per_call,derived")
    for fn in benches:
        kwargs = {"smoke": args.smoke}
        if fn is bench_serving_traced:
            kwargs["trace_path"] = args.trace
            kwargs["sample"] = args.trace_sample
            kwargs["explain_out"] = args.explain_out
            kwargs["health_out"] = args.health_out
        for name, us, derived in fn(**kwargs):
            print(f"{name},{us:.1f},{derived}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
