"""Serving-runtime load benchmarks: throughput vs. tail latency through
the micro-batching scheduler (serving/scheduler.py).

Two standard load-generator shapes, swept over flush deadlines:

- **closed-loop**: N worker threads, each submitting its next request
  the moment the previous one resolves.  Measures peak sustainable
  throughput (and proves the scheduler beats per-request batch-size-1
  dispatch — the whole reason the subsystem exists).
- **open-loop**: a fixed arrival rate, requests submitted on a clock
  regardless of completions (the honest tail-latency methodology:
  closed loops self-throttle and hide queueing delay).  Measures
  p50/p99 under a load the server does not control.

The serving result cache is disabled for all runs so every request
pays a real scoring dispatch (the cache's win is measured separately
by its hit-rate counters in the drivers).  All runtimes — including
the batch-1 baseline — score through the throughput-first ``gemm``
path (docs/ARCHITECTURE.md §5): the bit-stable ``lax.map`` default
serializes per-query compute, so it amortizes only dispatch overhead
under batching; the GEMM genuinely scales sublinearly in batch size,
which is the configuration a throughput benchmark should measure.

CSV rows follow the suite convention (``name,us_per_call,derived``).

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke]
"""
from __future__ import annotations

import argparse
import threading
import time

from repro.analysis import sanitizers
from repro.core.ingest import KnowledgeBase
from repro.data.corpus import make_corpus
from repro.serving import RequestRejected, ServingRuntime

# (n_docs, dim, n_requests, n_workers, open-loop arrival rate qps)
# closed-loop saturation wants workers ≥ max_batch: while one flush
# computes, every worker resubmits, so the next flush fills to the cap
# without ever waiting out the deadline
FULL = (2000, 2048, 384, 16, 200.0)
SMOKE = (200, 512, 160, 16, 150.0)

DEADLINES_MS = (0.0, 2.0, 8.0)  # acceptance: ≥ 3 flush-deadline settings
K = 5


def _build_kb(n_docs: int, dim: int):
    docs, entities = make_corpus(n_docs=n_docs, n_entities=16, seed=0)
    kb = KnowledgeBase(dim=dim)
    for i, d in enumerate(docs):
        kb.add_text(f"doc_{i:05d}.txt", d)
    queries = [f"lookup {code} status report" for code in entities]
    return kb, queries


def _runtime(kb, *, max_batch: int, deadline_s: float) -> ServingRuntime:
    # result cache off: measure scoring dispatches, not dict lookups
    return ServingRuntime(kb, max_batch=max_batch,
                          flush_deadline=deadline_s,
                          max_queue=4096, result_cache_size=0,
                          scoring_path="gemm")


def _warm(runtime: ServingRuntime, queries: list[str]) -> None:
    """Pre-compile every power-of-two bucket the run can hit."""
    with runtime:
        b = 1
        while b <= runtime.scheduler.max_batch:
            runtime.query_batch(queries[:b], k=K)
            b *= 2
        if sanitizers.enabled():
            # RAGDB_SANITIZERS=1: baseline the jit caches — any
            # steady-state recompile now fails the run loudly
            runtime.arm_sanitizers(k=K)
        runtime.metrics.reset()


def closed_loop(runtime: ServingRuntime, queries: list[str],
                n_requests: int, n_workers: int) -> dict:
    """N workers, each fires its next request on completion."""
    counter = {"i": 0}
    lock = threading.Lock()

    def worker(wid: int):
        while True:
            with lock:
                i = counter["i"]
                if i >= n_requests:
                    return
                counter["i"] = i + 1
            q = queries[(i * 7 + wid) % len(queries)]
            runtime.submit(q, k=K).result(timeout=120)

    with runtime:
        t0 = time.perf_counter()
        threads = [threading.Thread(target=worker, args=(w,))
                   for w in range(n_workers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
    m = runtime.metrics.snapshot()
    return {"throughput_qps": n_requests / dt, "wall_s": dt, **m}


def open_loop(runtime: ServingRuntime, queries: list[str],
              n_requests: int, rate_qps: float) -> dict:
    """Fixed arrival rate; rejected submissions count, never block."""
    futures = []
    rejected = 0
    with runtime:
        period = 1.0 / rate_qps
        t0 = time.perf_counter()
        for i in range(n_requests):
            target = t0 + i * period
            delay = target - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            try:
                futures.append(
                    runtime.submit(queries[(i * 7) % len(queries)], k=K)
                )
            except RequestRejected:
                rejected += 1
        for f in futures:
            f.result(timeout=120)
        dt = time.perf_counter() - t0
    m = runtime.metrics.snapshot()
    return {"offered_qps": rate_qps, "achieved_qps": len(futures) / dt,
            "open_rejected": rejected, **m}


def bench_serving_closed(smoke: bool = False):
    """Closed-loop sweep + the batch-1 per-request baseline."""
    n_docs, dim, n_requests, n_workers, _ = SMOKE if smoke else FULL
    kb, queries = _build_kb(n_docs, dim)
    rows = []

    # per-request dispatch baseline: max_batch=1 forces one scoring
    # dispatch per request through the same machinery
    rt = _runtime(kb, max_batch=1, deadline_s=0.0)
    _warm(rt, queries)
    base = closed_loop(rt, queries, n_requests, n_workers)
    rows.append((
        f"serving_closed_batch1_{n_docs}docs",
        base["wall_s"] / n_requests * 1e6,
        f"qps={base['throughput_qps']:.0f}_p50ms={base['latency_p50_ms']:.2f}"
        f"_p99ms={base['latency_p99_ms']:.2f}_occ={base['batch_occupancy_mean']:.1f}",
    ))

    best = 0.0
    for dl_ms in DEADLINES_MS:
        rt = _runtime(kb, max_batch=16, deadline_s=dl_ms / 1e3)
        _warm(rt, queries)
        r = closed_loop(rt, queries, n_requests, n_workers)
        best = max(best, r["throughput_qps"])
        rows.append((
            f"serving_closed_flush{dl_ms:g}ms_{n_docs}docs",
            r["wall_s"] / n_requests * 1e6,
            f"qps={r['throughput_qps']:.0f}_p50ms={r['latency_p50_ms']:.2f}"
            f"_p99ms={r['latency_p99_ms']:.2f}_occ={r['batch_occupancy_mean']:.1f}",
        ))

    # acceptance: micro-batching must beat per-request dispatch
    assert best > base["throughput_qps"], (
        f"micro-batched scheduler ({best:.0f} qps) did not beat "
        f"per-request dispatch ({base['throughput_qps']:.0f} qps)"
    )
    rows.append(("serving_closed_speedup", 0.0,
                 f"microbatch_vs_batch1={best / base['throughput_qps']:.2f}x"))
    return rows


def bench_serving_open(smoke: bool = False):
    """Open-loop tail latency across flush deadlines at fixed offered
    load."""
    n_docs, dim, n_requests, _, rate = SMOKE if smoke else FULL
    kb, queries = _build_kb(n_docs, dim)
    rows = []
    for dl_ms in DEADLINES_MS:
        rt = _runtime(kb, max_batch=16, deadline_s=dl_ms / 1e3)
        _warm(rt, queries)
        r = open_loop(rt, queries, n_requests, rate)
        rows.append((
            f"serving_open_flush{dl_ms:g}ms_{n_docs}docs",
            1e6 / rate,
            f"offered={rate:.0f}qps_achieved={r['achieved_qps']:.0f}qps"
            f"_p50ms={r['latency_p50_ms']:.2f}_p99ms={r['latency_p99_ms']:.2f}"
            f"_occ={r['batch_occupancy_mean']:.1f}_rej={r['open_rejected']}",
        ))
    return rows


ALL = [bench_serving_closed, bench_serving_open]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus, ~100 requests (CI concurrency "
                    "smoke for the scheduler/snapshot machinery)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for fn in ALL:
        for name, us, derived in fn(smoke=args.smoke):
            print(f"{name},{us:.1f},{derived}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
