"""Serving-runtime load benchmarks: throughput vs. tail latency through
the micro-batching scheduler (serving/scheduler.py).

Two standard load-generator shapes, swept over flush deadlines:

- **closed-loop**: N worker threads, each submitting its next request
  the moment the previous one resolves.  Measures peak sustainable
  throughput (and proves the scheduler beats per-request batch-size-1
  dispatch — the whole reason the subsystem exists).
- **open-loop**: a fixed arrival rate, requests submitted on a clock
  regardless of completions (the honest tail-latency methodology:
  closed loops self-throttle and hide queueing delay).  Measures
  p50/p99 under a load the server does not control.

The serving result cache is disabled for all runs so every request
pays a real scoring dispatch (the cache's win is measured separately
by its hit-rate counters in the drivers).  All runtimes — including
the batch-1 baseline — score through the throughput-first ``gemm``
path (docs/ARCHITECTURE.md §5): the bit-stable ``lax.map`` default
serializes per-query compute, so it amortizes only dispatch overhead
under batching; the GEMM genuinely scales sublinearly in batch size,
which is the configuration a throughput benchmark should measure.

CSV rows follow the suite convention (``name,us_per_call,derived``).

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke]
"""
from __future__ import annotations

import argparse
import threading
import time

from repro.analysis import sanitizers
from repro.core.ingest import KnowledgeBase
from repro.data.corpus import make_corpus
from repro.obs import (
    format_breakdown,
    request_decomposition,
    trace as obs_trace,
    write_chrome_trace,
)
from repro.serving import RequestRejected, ServingRuntime

# (n_docs, dim, n_requests, n_workers, open-loop arrival rate qps)
# closed-loop saturation wants workers ≥ max_batch: while one flush
# computes, every worker resubmits, so the next flush fills to the cap
# without ever waiting out the deadline
FULL = (2000, 2048, 384, 16, 200.0)
SMOKE = (200, 512, 160, 16, 150.0)

DEADLINES_MS = (0.0, 2.0, 8.0)  # acceptance: ≥ 3 flush-deadline settings
K = 5


def _build_kb(n_docs: int, dim: int):
    docs, entities = make_corpus(n_docs=n_docs, n_entities=16, seed=0)
    kb = KnowledgeBase(dim=dim)
    for i, d in enumerate(docs):
        kb.add_text(f"doc_{i:05d}.txt", d)
    queries = [f"lookup {code} status report" for code in entities]
    return kb, queries


def _runtime(kb, *, max_batch: int, deadline_s: float) -> ServingRuntime:
    # result cache off: measure scoring dispatches, not dict lookups
    return ServingRuntime(kb, max_batch=max_batch,
                          flush_deadline=deadline_s,
                          max_queue=4096, result_cache_size=0,
                          scoring_path="gemm")


def _warm(runtime: ServingRuntime, queries: list[str]) -> None:
    """Pre-compile every power-of-two bucket the run can hit."""
    with runtime:
        b = 1
        while b <= runtime.scheduler.max_batch:
            runtime.query_batch(queries[:b], k=K)
            b *= 2
        if sanitizers.enabled():
            # RAGDB_SANITIZERS=1: baseline the jit caches — any
            # steady-state recompile now fails the run loudly
            runtime.arm_sanitizers(k=K)
        runtime.metrics.reset()


def closed_loop(runtime: ServingRuntime, queries: list[str],
                n_requests: int, n_workers: int) -> dict:
    """N workers, each fires its next request on completion."""
    counter = {"i": 0}
    lock = threading.Lock()

    def worker(wid: int):
        while True:
            with lock:
                i = counter["i"]
                if i >= n_requests:
                    return
                counter["i"] = i + 1
            q = queries[(i * 7 + wid) % len(queries)]
            runtime.submit(q, k=K).result(timeout=120)

    with runtime:
        t0 = time.perf_counter()
        threads = [threading.Thread(target=worker, args=(w,))
                   for w in range(n_workers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
    m = runtime.metrics.snapshot()
    return {"throughput_qps": n_requests / dt, "wall_s": dt, **m}


def open_loop(runtime: ServingRuntime, queries: list[str],
              n_requests: int, rate_qps: float) -> dict:
    """Fixed arrival rate; rejected submissions count, never block."""
    futures = []
    rejected = 0
    with runtime:
        period = 1.0 / rate_qps
        t0 = time.perf_counter()
        for i in range(n_requests):
            target = t0 + i * period
            delay = target - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            try:
                futures.append(
                    runtime.submit(queries[(i * 7) % len(queries)], k=K)
                )
            except RequestRejected:
                rejected += 1
        for f in futures:
            f.result(timeout=120)
        dt = time.perf_counter() - t0
    m = runtime.metrics.snapshot()
    return {"offered_qps": rate_qps, "achieved_qps": len(futures) / dt,
            "open_rejected": rejected, **m}


def bench_serving_closed(smoke: bool = False):
    """Closed-loop sweep + the batch-1 per-request baseline."""
    n_docs, dim, n_requests, n_workers, _ = SMOKE if smoke else FULL
    kb, queries = _build_kb(n_docs, dim)
    rows = []

    # per-request dispatch baseline: max_batch=1 forces one scoring
    # dispatch per request through the same machinery
    rt = _runtime(kb, max_batch=1, deadline_s=0.0)
    _warm(rt, queries)
    base = closed_loop(rt, queries, n_requests, n_workers)
    rows.append((
        f"serving_closed_batch1_{n_docs}docs",
        base["wall_s"] / n_requests * 1e6,
        f"qps={base['throughput_qps']:.0f}_p50ms={base['latency_p50_ms']:.2f}"
        f"_p99ms={base['latency_p99_ms']:.2f}_occ={base['batch_occupancy_mean']:.1f}",
    ))

    best = 0.0
    for dl_ms in DEADLINES_MS:
        rt = _runtime(kb, max_batch=16, deadline_s=dl_ms / 1e3)
        _warm(rt, queries)
        r = closed_loop(rt, queries, n_requests, n_workers)
        best = max(best, r["throughput_qps"])
        rows.append((
            f"serving_closed_flush{dl_ms:g}ms_{n_docs}docs",
            r["wall_s"] / n_requests * 1e6,
            f"qps={r['throughput_qps']:.0f}_p50ms={r['latency_p50_ms']:.2f}"
            f"_p99ms={r['latency_p99_ms']:.2f}_occ={r['batch_occupancy_mean']:.1f}",
        ))

    # acceptance: micro-batching must beat per-request dispatch
    assert best > base["throughput_qps"], (
        f"micro-batched scheduler ({best:.0f} qps) did not beat "
        f"per-request dispatch ({base['throughput_qps']:.0f} qps)"
    )
    rows.append(("serving_closed_speedup", 0.0,
                 f"microbatch_vs_batch1={best / base['throughput_qps']:.2f}x"))
    return rows


def bench_serving_open(smoke: bool = False):
    """Open-loop tail latency across flush deadlines at fixed offered
    load."""
    n_docs, dim, n_requests, _, rate = SMOKE if smoke else FULL
    kb, queries = _build_kb(n_docs, dim)
    rows = []
    for dl_ms in DEADLINES_MS:
        rt = _runtime(kb, max_batch=16, deadline_s=dl_ms / 1e3)
        _warm(rt, queries)
        r = open_loop(rt, queries, n_requests, rate)
        rows.append((
            f"serving_open_flush{dl_ms:g}ms_{n_docs}docs",
            1e6 / rate,
            f"offered={rate:.0f}qps_achieved={r['achieved_qps']:.0f}qps"
            f"_p50ms={r['latency_p50_ms']:.2f}_p99ms={r['latency_p99_ms']:.2f}"
            f"_occ={r['batch_occupancy_mean']:.1f}_rej={r['open_rejected']}",
        ))
    return rows


TRACE_SAMPLE = 0.25  # the documented production sampling default


def bench_serving_traced(smoke: bool = False, trace_path: str | None = None,
                         sample: float = TRACE_SAMPLE):
    """The observability overhead + correctness contract, measured:

    1. closed loop untraced vs traced (1-in-4 request sampling, the
       production default) — the traced arm must keep ≥ 95% of
       untraced throughput;
    2. every sampled request's stage spans (queue_wait + flush_wait +
       score + merge) must tile the request span exactly — the sum is
       asserted against the end-to-end duration per request;
    3. optionally exports the Chrome trace-event JSON (``--trace``)
       and prints the per-stage breakdown table.

    Methodology: one runtime, warmed once, then tightly interleaved
    off/on run pairs (the arms are seconds apart, so slow host drift
    cancels) aggregated by the *median* per-pair QPS ratio — host
    noise on short closed loops is heavy-tailed (transient ±20%
    stalls), so best-of or mean aggregation would gate on the noise
    floor, not the overhead.  Workers run at 2x ``max_batch`` so every
    flush fills without waiting out the deadline (batch-phase jitter
    is the other big variance source).  More pairs are added (up to
    15) until the median stabilizes past the gate.
    """
    n_docs, dim, n_requests, _, _ = SMOKE if smoke else FULL
    kb, queries = _build_kb(n_docs, dim)
    n_requests = max(n_requests, 1000)  # short runs measure only noise
    max_batch = 16

    rt = _runtime(kb, max_batch=max_batch, deadline_s=0.002)
    _warm(rt, queries)

    def run_qps() -> float:
        r = closed_loop(rt, queries, n_requests, 2 * max_batch)
        return r["throughput_qps"]

    tracer = obs_trace.get()
    ratios: list[float] = []
    spans = []
    median = 0.0
    try:
        for round_ in range(3):
            for _ in range(5):
                tracer.disable()
                off = run_qps()
                tracer.enable(sample=sample)
                on = run_qps()
                got = tracer.drain()
                spans = got or spans
                tracer.disable()
                ratios.append(on / off)
            srt = sorted(ratios)
            median = srt[len(srt) // 2]
            if median >= 0.95:
                break
    finally:
        tracer.disable()

    reqs = request_decomposition(spans)
    assert reqs, "traced run produced no request spans"
    worst = max(abs(r["request_s"] - r["stage_sum_s"]) for r in reqs)
    # the four stages share perf_counter timestamps, so they tile the
    # request exactly up to the ~1 ns span-record quantization
    assert worst < 1e-6, (
        f"stage decomposition does not tile request latency: worst "
        f"residual {worst * 1e6:.3f} us across {len(reqs)} requests"
    )
    assert median >= 0.95, (
        f"tracing overhead exceeds the 5% budget: median traced/untraced "
        f"qps ratio {median:.3f} over {len(ratios)} interleaved pairs"
    )

    if trace_path:
        n = write_chrome_trace(trace_path, spans)
        print(f"# trace: {n} events -> {trace_path}")
        print("\n".join("# " + ln
                        for ln in format_breakdown(spans).splitlines()))
    return [
        (f"serving_traced_overhead_{n_docs}docs", 0.0,
         f"median_qps_ratio={median:.3f}_pairs={len(ratios)}"
         f"_sample={sample:g}"),
        (f"serving_trace_decomposition_{n_docs}docs", 0.0,
         f"requests={len(reqs)}_worst_residual_us={worst * 1e6:.3f}"),
    ]


ALL = [bench_serving_closed, bench_serving_open, bench_serving_traced]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus, ~100 requests (CI concurrency "
                    "smoke for the scheduler/snapshot machinery)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="write the traced run's Chrome trace-event "
                    "JSON here (Perfetto-loadable; inspect with "
                    "`python -m repro.obs FILE`)")
    ap.add_argument("--trace-sample", type=float, default=TRACE_SAMPLE,
                    help="request sampling rate for the traced arm "
                    f"(default {TRACE_SAMPLE:g})")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for fn in ALL:
        kwargs = {"smoke": args.smoke}
        if fn is bench_serving_traced:
            kwargs["trace_path"] = args.trace
            kwargs["sample"] = args.trace_sample
        for name, us, derived in fn(**kwargs):
            print(f"{name},{us:.1f},{derived}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
