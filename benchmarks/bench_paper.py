"""Per-paper-table benchmarks (§5.2 RQ1, §5.3 RQ2, §5.4 RQ3) plus
kernel micro-benchmarks.  Each function returns a list of
(name, us_per_call, derived) rows for the CSV printer in run.py."""
from __future__ import annotations

import os
import pickle
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hsf, signature as sigmod
from repro.core.ingest import KnowledgeBase
from repro.core.retrieval import Retriever
from repro.data.corpus import make_corpus, write_corpus_dir


def _timeit(fn, n=5, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # µs


# --------------------------------------------------------------------------
# RQ1 — ingestion efficiency (paper table: cold 14.59 s vs incr 0.46 s,
# 31.6×, on 1000 docs).
# --------------------------------------------------------------------------

def bench_rq1_ingestion():
    rows = []
    docs, _ = make_corpus(n_docs=1000, doc_len=120, seed=0)
    with tempfile.TemporaryDirectory() as d:
        src = os.path.join(d, "corpus")
        write_corpus_dir(src, docs)
        kb = KnowledgeBase(dim=4096)
        t0 = time.perf_counter()
        cold = kb.sync(src)
        t_cold = time.perf_counter() - t0
        kb.materialize()
        t0 = time.perf_counter()
        warm = kb.sync(src)
        t_warm = time.perf_counter() - t0
        # delta: touch 10 files
        for i in range(10):
            with open(os.path.join(src, f"doc_{i:05d}.txt"), "a") as f:
                f.write(" updated content")
        t0 = time.perf_counter()
        delta = kb.sync(src)
        t_delta = time.perf_counter() - t0
    assert cold.added == 1000 and warm.skipped == 1000
    assert delta.updated == 10
    rows.append(("rq1_cold_ingest_1000docs", t_cold * 1e6,
                 f"docs_per_s={1000 / t_cold:.1f}"))
    rows.append(("rq1_incremental_unchanged", t_warm * 1e6,
                 f"speedup_vs_cold={t_cold / t_warm:.1f}x"))
    rows.append(("rq1_incremental_10_updated", t_delta * 1e6,
                 f"speedup_vs_cold={t_cold / t_delta:.1f}x"))
    return rows


# --------------------------------------------------------------------------
# RQ2 — hybrid vs pure-cosine entity retrieval (paper: 100 % Recall@1,
# top score 1.5753).
# --------------------------------------------------------------------------

def bench_rq2_recall():
    rows = []
    docs, entities = make_corpus(n_docs=1000, n_entities=20, seed=0)
    kb = KnowledgeBase(dim=4096)
    for i, d in enumerate(docs):
        kb.add_text(f"doc_{i:05d}.txt", d)
    hybrid = Retriever(kb, alpha=1.0, beta=1.0)
    cosine = Retriever(kb, alpha=1.0, beta=0.0)

    def recall_at_1(r):
        hits = 0
        for code, idx in entities.items():
            if r.query(code, k=1)[0].doc_id == f"doc_{idx:05d}.txt":
                hits += 1
        return hits / len(entities)

    rec_h = recall_at_1(hybrid)
    rec_c = recall_at_1(cosine)
    code = next(iter(entities))
    top = hybrid.query(code, k=1)[0]
    t = _timeit(lambda: hybrid.query(code, k=5))
    rows.append(("rq2_hybrid_recall_at_1", t, f"recall={rec_h:.3f}"))
    rows.append(("rq2_cosine_recall_at_1", t, f"recall={rec_c:.3f}"))
    rows.append(("rq2_hybrid_top_score", t,
                 f"score={top.score:.4f}_boosted={top.boosted}"))
    assert rec_h == 1.0, "hybrid Recall@1 must be 100% (paper claim)"
    return rows


# --------------------------------------------------------------------------
# RQ3 — footprint + query latency.  The paper's 99.5 % figure compares
# the full STACK (Docker + ChromaDB + torch + embedding model ≈ 1.2 GB)
# against its single file.  We reproduce that with published component
# sizes (constants below — they cannot be downloaded offline) plus our
# measured artifacts, and additionally report the data-file comparison:
# our container (with and without the rematerializable dense ⟨V⟩ region)
# vs a 384-dim dense-embedding vector store for the same corpus.
# --------------------------------------------------------------------------

# Published wheel/model sizes (PyPI / HF, 2024-2025): torch ≈ 750 MB,
# chromadb+deps ≈ 150 MB, sentence-transformers MiniLM ≈ 90 MB,
# onnxruntime ≈ 60 MB ⇒ "standard stack" ≈ 1.05 GB before any data.
STANDARD_STACK_BYTES = int(1.05e9)
DENSE_EMBED_DIM = 384  # MiniLM-class


def bench_rq3_footprint():
    rows = []
    docs, entities = make_corpus(n_docs=1000, seed=0)
    kb = KnowledgeBase(dim=4096)
    for i, d in enumerate(docs):
        kb.add_text(f"doc_{i:05d}.txt", d)
    with tempfile.TemporaryDirectory() as d:
        p_full = os.path.join(d, "kb.ragdb")
        p_slim = os.path.join(d, "kb_slim.ragdb")
        kb.save(p_full, include_matrix=True)
        kb.save(p_slim, include_matrix=False)
        full_bytes = os.path.getsize(p_full)
        slim_bytes = os.path.getsize(p_slim)
        # our deployable unit = container + this library (no torch/CUDA)
        import repro

        src_root = os.path.dirname(os.path.dirname(repro.__file__))
        lib_bytes = sum(
            os.path.getsize(os.path.join(r, f))
            for r, _, fs in os.walk(src_root) for f in fs
            if f.endswith(".py")
        )
        # slim container restores + retrieves identically
        kb2 = KnowledgeBase.load(p_slim)
        code = next(iter(entities))
        assert Retriever(kb2).query(code, k=1)[0].doc_id == \
            Retriever(kb).query(code, k=1)[0].doc_id

    dense_store = 1000 * DENSE_EMBED_DIM * 4  # vectors only, no index
    ours_total = slim_bytes + lib_bytes
    theirs_total = STANDARD_STACK_BYTES + dense_store
    r = Retriever(kb)
    t_query = _timeit(lambda: r.query(code, k=5), n=20)
    rows.append(("rq3_container_bytes_full", 0.0, f"bytes={full_bytes}"))
    rows.append(("rq3_container_bytes_slim", 0.0,
                 f"bytes={slim_bytes}_matrix_rematerialized"))
    rows.append(("rq3_stack_footprint_ours", 0.0,
                 f"bytes={ours_total}_incl_library"))
    rows.append(("rq3_stack_footprint_standard", 0.0,
                 f"bytes={theirs_total}_reduction="
                 f"{(1 - ours_total / theirs_total) * 100:.2f}%"))
    rows.append(("rq3_query_latency", t_query, "corpus=1000"))
    return rows


# --------------------------------------------------------------------------
# kernel micro-benchmarks (CPU interpret-mode timings are NOT TPU perf;
# they validate plumbing and give relative jnp-vs-kernel structure)
# --------------------------------------------------------------------------

def bench_kernels():
    rows = []
    rng = np.random.default_rng(0)
    n, d, w = 4096, 4096, 128
    dv = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    ds = jnp.asarray(rng.integers(0, 2**31, size=(n, w)).astype(np.int32))
    qv = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    qs = jnp.asarray(rng.integers(0, 2**31, size=(w,)).astype(np.int32))

    f_ref = jax.jit(lambda: hsf.hsf_scores(dv, ds, qv, qs))
    t_ref = _timeit(lambda: jax.block_until_ready(f_ref()), n=10)
    rows.append(("hsf_scores_jnp_4096x4096", t_ref,
                 f"gflops={2 * n * d / t_ref / 1e3:.2f}"))

    scores = f_ref()
    f_topk = jax.jit(lambda: jax.lax.top_k(scores, 16))
    t_topk = _timeit(lambda: jax.block_until_ready(f_topk()[0]), n=10)
    rows.append(("topk_lax_4096_k16", t_topk, ""))
    return rows


ALL = [bench_rq1_ingestion, bench_rq2_recall, bench_rq3_footprint,
       bench_kernels]
