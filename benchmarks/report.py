"""Render EXPERIMENTS.md from archived results (dry-run JSONs, roofline
JSONs, benchmark CSV).  Re-runnable: ``python -m benchmarks.report``."""
from __future__ import annotations

import json
import os

RESULTS = "results"
CHIPS = 256


def _load(path):
    with open(path) as f:
        return json.load(f)


def _fmt(x, nd=3):
    if x == 0:
        return "0"
    if abs(x) >= 1e4 or abs(x) < 1e-3:
        return f"{x:.2e}"
    return f"{x:.{nd}f}"


def dryrun_table(dirname: str) -> str:
    rows = []
    for fn in sorted(os.listdir(dirname)):
        if not fn.endswith(".json"):
            continue
        d = _load(os.path.join(dirname, fn))
        rows.append(d)
    out = ["| arch | shape | mesh | compile s | HLO GFLOPs/dev | coll MB/dev | args GB/dev | temp GB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for d in rows:
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {d['compile_s']:.1f} "
            f"| {d['flops'] / 1e9:.1f} "
            f"| {d['collectives']['total_bytes'] / 1e6:.1f} "
            f"| {d['memory']['argument_bytes'] / 1e9:.2f} "
            f"| {d['memory']['temp_bytes'] / 1e9:.2f} |"
        )
    return "\n".join(out)


def roofline_table(path: str) -> str:
    rows = _load(path)
    out = ["| arch | shape | compute s | memory s | collective s | dominant | useful ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt(r['compute_s'])} "
            f"| {_fmt(r['memory_s'])} | {_fmt(r['collective_s'])} "
            f"| {r['dominant']} | {r['useful_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def compare_table(base_path: str, opt_path: str, cells) -> str:
    base = {(r["arch"], r["shape"]): r for r in _load(base_path)}
    opt = {(r["arch"], r["shape"]): r for r in _load(opt_path)}
    out = ["| cell | term | baseline | optimized | Δ |", "|---|---|---|---|---|"]
    for cell in cells:
        b, o = base.get(cell), opt.get(cell)
        if not b or not o:
            continue
        for term in ("compute_s", "memory_s", "collective_s"):
            delta = (o[term] - b[term]) / b[term] * 100 if b[term] else 0
            out.append(
                f"| {cell[0]} × {cell[1]} | {term} | {_fmt(b[term])} "
                f"| {_fmt(o[term])} | {delta:+.1f}% |"
            )
    return "\n".join(out)


def _read_fragment(path):
    """Optional prose fragment — reports render without it."""
    if os.path.exists(path):
        with open(path) as f:
            return f.read()
    return f"<!-- {path} not present -->\n"


def main():
    parts = []
    parts.append(_read_fragment("EXPERIMENTS.header.md"))

    parts.append("\n## §Dry-run — per-cell compiled artifacts\n")
    parts.append(
        "All 40 assigned (arch × shape) cells + 2 RAGdb corpus cells, on "
        "BOTH the 16×16 single-pod and 2×16×16 multi-pod meshes "
        "(84 lower+compile passes, zero failures).  Values from "
        "`compiled.memory_analysis()` / `cost_analysis()` / HLO parsing; "
        "loop bodies counted once (see §Roofline methodology).\n")
    parts.append(dryrun_table(os.path.join(RESULTS, "dryrun")))

    parts.append("\n\n## §Roofline — optimized (current) build\n")
    parts.append(roofline_table(os.path.join(RESULTS, "roofline.json")))
    parts.append("\n\n### Baseline (paper-faithful, pre-optimization) build\n")
    parts.append(roofline_table(os.path.join(RESULTS,
                                             "roofline_baseline.json")))

    parts.append("\n\n### Hillclimbed cells, before → after\n")
    parts.append(compare_table(
        os.path.join(RESULTS, "roofline_baseline.json"),
        os.path.join(RESULTS, "roofline.json"),
        [("gemma2-9b", "decode_32k"), ("gemma3-27b", "train_4k"),
         ("dlrm-mlperf", "retrieval_cand")],
    ))

    parts.append("\n" + _read_fragment("EXPERIMENTS.perf.md"))

    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(parts))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
