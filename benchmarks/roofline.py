import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# Roofline analysis (feeds the EXPERIMENTS.md report rendered by
# benchmarks/report.py from archived results).
#
# Terms per (arch × shape) on the single-pod 16×16 mesh, v5e constants:
#     compute    = FLOPs/device            / 197e12  (bf16 peak)
#     memory     = HBM bytes/device        / 819e9
#     collective = collective bytes/device / 50e9    (per-link ICI)
#
# Accounting subtlety this module owns: XLA's cost_analysis counts each
# while-loop body ONCE, so the production artifact under-reports
# anything inside the microbatch scan / layer scan / kv-block scan.
# For LM cells we therefore compile *cost-exact variants* — identical
# layer dimensions, 1-or-2 scan trips, with every scan unrolled
# (COST_EXACT_UNROLL) — fit the exact linear model
#     F(m, u) = α + m·β + m·u·γ
# (m = microbatches, u = scan units), and extrapolate to the production
# trip counts.  Non-LM cells have no scans: their production numbers are
# already exact.
#
# Collective bytes come from the post-SPMD HLO text (per-partition
# shapes), same extrapolation.

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
from dataclasses import replace  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ARCHS, get as get_arch  # noqa: E402
from repro.configs import shapes as shp  # noqa: E402
from repro.launch import mesh as meshlib  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.launch.dryrun import collective_bytes  # noqa: E402
from repro.models import attention as attn_mod  # noqa: E402
from repro.models import moe as moe_mod  # noqa: E402
from repro.models import transformer as T  # noqa: E402

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link
CHIPS = 256


def _measure(cell) -> dict:
    compiled = cell.fn.lower(*cell.args).compile()
    cost = compiled.cost_analysis()
    colls = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(colls["total_bytes"]),
        "coll_counts": colls["counts"],
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "arg_bytes": getattr(mem, "argument_size_in_bytes", 0),
    }
    jax.clear_caches()
    return out


def _variant_cfg(cfg: T.LMConfig, n_units: int) -> T.LMConfig:
    tail = len(cfg.tail_kinds)
    n_layers = cfg.n_dense_head_layers + n_units * len(cfg.pattern) + tail
    return replace(cfg, n_layers=n_layers)


def _build_variant(arch_id, cfg, spec, mesh, n_units, n_micro):
    """Cost-exact cell: reduced trips, all scans unrolled."""
    attn_mod.COST_EXACT_UNROLL = True
    T.COST_EXACT_UNROLL = True
    moe_mod.COST_EXACT_SURROGATE = True
    try:
        vcfg = _variant_cfg(cfg, n_units)
        if spec.kind == "lm_train":
            dpn = meshlib.dp_size(mesh)
            vmeta = dict(spec.meta)
            vmeta["batch"] = dpn * n_micro
            vspec = dataclasses.replace(spec, meta=vmeta)
            cell = steps.build_lm_train_cell(arch_id, vcfg, vspec, mesh)
            assert cell.meta["n_micro"] == n_micro, cell.meta
        elif spec.kind == "lm_prefill":
            cell = steps.build_lm_prefill_cell(arch_id, vcfg, spec, mesh)
        else:
            cell = steps.build_lm_decode_cell(arch_id, vcfg, spec, mesh)
        return _measure(cell)
    finally:
        attn_mod.COST_EXACT_UNROLL = False
        T.COST_EXACT_UNROLL = False
        moe_mod.COST_EXACT_SURROGATE = False


def lm_exact_totals(arch_id: str, shape_id: str, mesh, cache_dir: str) -> dict:
    """Fit F(m, u) = α + m·β + m·u·γ from unrolled variants and
    extrapolate to production trip counts."""
    os.makedirs(cache_dir, exist_ok=True)
    cpath = os.path.join(cache_dir, f"{arch_id}__{shape_id}__exact.json")
    if os.path.exists(cpath):
        with open(cpath) as f:
            return json.load(f)

    arch = get_arch(arch_id)
    cfg = arch.config
    spec = shp.shapes_for_family("lm")[shape_id]
    u_real = cfg.n_units
    keys = ("flops", "bytes", "coll")

    if spec.kind == "lm_train":
        dpn = meshlib.dp_size(mesh)
        m_real = spec.meta["batch"] // dpn
        f11 = _build_variant(arch_id, cfg, spec, mesh, 1, 1)
        f21 = _build_variant(arch_id, cfg, spec, mesh, 2, 1)
        f12 = _build_variant(arch_id, cfg, spec, mesh, 1, 2)
        total = {}
        for k in keys:
            gamma = f21[k] - f11[k]
            beta = f12[k] - f11[k] - gamma
            alpha = f11[k] - beta - gamma
            total[k] = alpha + m_real * beta + m_real * u_real * gamma
            total[k + "_parts"] = {"alpha": alpha, "beta": beta,
                                   "gamma": gamma, "m": m_real, "u": u_real}
    else:
        f1 = _build_variant(arch_id, cfg, spec, mesh, 1, 1)
        f2 = _build_variant(arch_id, cfg, spec, mesh, 2, 1)
        total = {}
        for k in keys:
            gamma = f2[k] - f1[k]
            alpha = f1[k] - gamma
            total[k] = alpha + u_real * gamma
            total[k + "_parts"] = {"alpha": alpha, "gamma": gamma,
                                   "u": u_real}
    with open(cpath, "w") as f:
        json.dump(total, f, indent=1)
    return total


def model_flops(arch_id: str, shape_id: str) -> float:
    """Analytic MODEL_FLOPS (global): 6·N_active·tokens for training,
    2·N_active·tokens for fwd-only serving."""
    arch = get_arch(arch_id)
    cfg = arch.config
    spec = shp.shapes_for_family(arch.family)[shape_id]
    m = spec.meta
    if arch.family == "lm":
        n = cfg.active_param_count()
        if spec.kind == "lm_train":
            return 6.0 * n * m["batch"] * m["seq"]
        if spec.kind == "lm_prefill":
            return 2.0 * n * m["batch"] * m["seq"]
        return 2.0 * n * m["batch"]  # decode: one token per sequence
    if arch.family == "gnn":
        # per-edge message (C·n_rbf + C + C·n_sh mults) + per-node
        # products/update (≈ 8·C² per layer)
        c = cfg.d_hidden
        per_edge = 2 * c * (cfg.n_rbf + cfg.n_sh + 1)
        per_node = 2 * (8 * c * c + c * cfg.d_feat / cfg.n_layers)
        fwd = cfg.n_layers * (m["n_edges"] * per_edge + m["n_nodes"] * per_node)
        return 3.0 * fwd  # train: fwd + bwd ≈ 3×
    if arch.family == "recsys":
        cfg_ = cfg
        dense_mults = 0
        dims_chains = []
        if cfg_.bot_mlp:
            dims_chains.append((cfg_.n_dense,) + cfg_.bot_mlp)
            n_inter = cfg_.n_sparse + 1
            d_top = n_inter * (n_inter - 1) // 2 + cfg_.bot_mlp[-1]
            dims_chains.append((d_top,) + cfg_.top_mlp)
        if cfg_.mlp_dims:
            dims_chains.append(
                (cfg_.n_sparse * cfg_.embed_dim,) + cfg_.mlp_dims + (1,))
        for dims in dims_chains:
            for i in range(len(dims) - 1):
                dense_mults += dims[i] * dims[i + 1]
        inter = cfg_.n_sparse ** 2 * cfg_.embed_dim  # dot/FM/attn order
        per_ex = 2 * (dense_mults + inter)
        batch = m.get("batch", 1) if shape_id != "retrieval_cand" \
            else m["n_candidates"]
        mult = 3.0 if shape_id == "train_batch" else 1.0
        if shape_id == "retrieval_cand":
            per_ex = 2 * cfg_.embed_dim
        return mult * per_ex * batch
    if arch.family == "ragdb":
        n_docs = m["docs_per_device"] * CHIPS
        return 2.0 * n_docs * cfg.dim * m["query_batch"]
    return 0.0


def analyze(arch_id: str, shape_id: str, dryrun_dir: str, cache_dir: str,
            mesh=None) -> dict:
    tag = f"{arch_id}__{shape_id}__16x16.json"
    with open(os.path.join(dryrun_dir, tag)) as f:
        prod = json.load(f)
    arch = get_arch(arch_id)
    mesh = mesh or meshlib.make_production_mesh()

    if arch.family == "lm":
        totals = lm_exact_totals(arch_id, shape_id, mesh, cache_dir)
        flops, bts, coll = totals["flops"], totals["bytes"], totals["coll"]
    else:
        flops, bts, coll = (prod["flops"], prod["bytes_accessed"],
                            prod["collectives"]["total_bytes"])

    t_compute = flops / PEAK_FLOPS
    t_memory = bts / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(arch_id, shape_id)
    hlo_total_flops = flops * CHIPS
    return {
        "arch": arch_id, "shape": shape_id,
        **{k: float(v) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_global": hlo_total_flops,
        "useful_ratio": mf / hlo_total_flops if hlo_total_flops else 0.0,
        "roofline_fraction": (
            (mf / CHIPS / PEAK_FLOPS) / bound if bound else 0.0
        ),
        "mem_temp_bytes": prod["memory"]["temp_bytes"],
        "mem_args_bytes": prod["memory"]["argument_bytes"],
        "coll_counts": prod["collectives"]["counts"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--cache-dir", default="results/roofline_exact")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--arch", default=None)
    args = ap.parse_args()

    mesh = meshlib.make_production_mesh()
    rows = []
    for arch_id, spec in ARCHS.items():
        if args.arch and arch_id != args.arch:
            continue
        for shape_id in shp.shapes_for_family(spec.family):
            try:
                r = analyze(arch_id, shape_id, args.dryrun_dir,
                            args.cache_dir, mesh)
                rows.append(r)
                print(f"{arch_id:22s} {shape_id:14s} "
                      f"C={r['compute_s']:9.3e}s M={r['memory_s']:9.3e}s "
                      f"N={r['collective_s']:9.3e}s dom={r['dominant']:10s} "
                      f"useful={r['useful_ratio']:6.3f} "
                      f"roofline={r['roofline_fraction']:6.3f}", flush=True)
            except FileNotFoundError as e:
                print(f"skip {arch_id} {shape_id}: {e}", flush=True)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {len(rows)} rows to {args.out}")


if __name__ == "__main__":
    main()
