"""Scale-out benchmarks (ours, beyond the paper's tables):
sharded-retrieval equivalence + collective payload accounting, one real
multi-(fake-)device retrieval timing, batched-QPS through the
QueryEngine serving plane, incremental query-plane refresh latency, and
a map-vs-gemm-vs-fused-kernel batched scoring-path shoot-out."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import retrieval
from repro.core.engine import QueryEngine
from repro.core.ingest import KnowledgeBase
from repro.data.corpus import make_corpus


def bench_retrieval_scale():
    rows = []
    n_dev = jax.device_count()
    if n_dev == 1:
        # single-device container: report the logical payload model only
        k, shards = 16, 256
        payload = shards * k * (4 + 4) * 64  # (score, id) × qbatch 64
        rows.append(("retrieval_merge_payload_model", 0.0,
                     f"bytes_at_256dev_k16_q64={payload}"))
        return rows

    mesh = jax.make_mesh(
        (n_dev, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
    rng = np.random.default_rng(0)
    n, d, w = 8192, 1024, 128
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    sigs = rng.integers(0, 2**31, size=(n, w)).astype(np.int32)
    pv, ps, nd = retrieval.pad_corpus(vecs, sigs, n_dev)
    qv = rng.normal(size=(8, d)).astype(np.float32)
    qs = sigs[:8].copy()
    ret = jax.jit(retrieval.build_sharded_retrieve(
        mesh, ("data",), nd, k=16))
    pv_d = jax.device_put(pv, NamedSharding(mesh, P("data", None)))
    ps_d = jax.device_put(ps, NamedSharding(mesh, P("data", None)))
    out = ret(pv_d, ps_d, jnp.asarray(qv), jnp.asarray(qs))
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(10):
        jax.block_until_ready(ret(pv_d, ps_d, jnp.asarray(qv),
                                  jnp.asarray(qs)))
    t = (time.perf_counter() - t0) / 10 * 1e6
    rows.append((f"sharded_retrieval_{n_dev}dev_8192docs", t, "q=8 k=16"))
    return rows


# --------------------------------------------------------------------------
# batched serving QPS (the engine's reason to exist): one query_batch
# dispatch vs the same queries looped one-by-one
# --------------------------------------------------------------------------

def _build_kb(n_docs: int, dim: int = 2048) -> tuple[KnowledgeBase, dict]:
    docs, entities = make_corpus(n_docs=n_docs, n_entities=16, seed=0)
    kb = KnowledgeBase(dim=dim)
    for i, d in enumerate(docs):
        kb.add_text(f"doc_{i:05d}.txt", d)
    return kb, entities


def _qps(fn, n_queries, reps=5):
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    dt = (time.perf_counter() - t0) / reps
    return n_queries / dt, dt


def bench_batched_qps():
    rows = []
    kb, entities = _build_kb(2000)
    engine = QueryEngine(kb)
    queries = [f"lookup {code} status report" for code in entities]

    for b in (1, 4, 16):
        batch = queries[:b]
        engine.query_batch(batch, k=5)  # warm this bucket's jit cache
        rate, dt = _qps(lambda: engine.query_batch(batch, k=5), b)
        rows.append((f"engine_query_batch_b{b}_2000docs", dt / b * 1e6,
                     f"qps={rate:.0f}"))
    rate, dt = _qps(
        lambda: [engine.query_batch([q], k=5) for q in queries[:16]], 16
    )
    rows.append(("engine_query_looped_16_2000docs", dt / 16 * 1e6,
                 f"qps={rate:.0f}"))
    hits = engine.cache_stats()
    rows.append(("engine_query_cache", 0.0,
                 f"hits={hits['hits']}_misses={hits['misses']}"))
    return rows


# --------------------------------------------------------------------------
# incremental query-plane refresh: patch dirty rows vs cold rebuild —
# the paper's O(U) ingest win (§3.3, 31.6×) applied at serving time
# --------------------------------------------------------------------------

def bench_refresh_latency():
    rows = []
    kb, _ = _build_kb(2000)
    engine = QueryEngine(kb)

    def touch(n, salt):
        for i in range(n):
            kb.add_text(f"doc_{i:05d}.txt",
                        f"rewritten document {i} salt {salt} "
                        f"with fresh INV-{9000 + i}")

    for n_touch in (1, 10, 100):
        touch(n_touch, "warmup")
        engine.refresh()  # steady state: row-bucket jit caches warm
        touch(n_touch, "timed")
        t0 = time.perf_counter()
        stats = engine.refresh()
        t_incr = time.perf_counter() - t0
        assert stats.changed == n_touch
        t0 = time.perf_counter()
        QueryEngine(kb)  # cold build: re-vectorizes all 2000 docs
        t_cold = time.perf_counter() - t0
        rows.append((f"engine_refresh_{n_touch}of2000", t_incr * 1e6,
                     f"cold_rebuild_speedup={t_cold / t_incr:.1f}x"))
    return rows


# --------------------------------------------------------------------------
# batched scoring-path shoot-out: lax.map of the single-query matvec
# (bit-stable default) vs the [B,D]×[D,N] GEMM vs the fused batched
# Pallas kernel with in-kernel top-k — same corpus, same queries, one run
# --------------------------------------------------------------------------

def bench_batched_paths():
    rows = []
    kb, entities = _build_kb(2000)
    queries = [f"lookup {code} status report" for code in entities]
    engines = [
        ("map", QueryEngine(kb)),
        ("gemm", QueryEngine(kb, gemm_batch=True)),
        ("kernel", QueryEngine(kb, use_kernel=True)),
    ]
    for name, eng in engines:
        for b in (1, 8, 16):
            batch = queries[:b]
            eng.query_batch(batch, k=5)  # warm this bucket's jit cache
            rate, dt = _qps(lambda: eng.query_batch(batch, k=5), b)
            rows.append((f"engine_path_{name}_b{b}_2000docs", dt / b * 1e6,
                         f"qps={rate:.0f}"))
    # sanity: all paths surface the same top-1 entity doc.  Top-1 on
    # entity queries wins by the β boost margin, so this is immune to
    # the sub-ulp reduction-order noise the gemm/kernel paths are
    # documented to carry (a full-ranking equality assert would abort
    # the suite on near-tie filler docs on real hardware).
    b16 = [e.query_batch(queries[:16], k=5) for _, e in engines]
    top1 = [[q[0].doc_id for q in path] for path in b16]
    assert top1[0] == top1[1] == top1[2], "scoring paths disagree on top-1"
    return rows


ALL = [bench_retrieval_scale, bench_batched_qps, bench_refresh_latency,
       bench_batched_paths]
