"""Scale-out benchmarks (ours, beyond the paper's tables):
sharded-retrieval equivalence + collective payload accounting, and
one real multi-(fake-)device retrieval timing."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import retrieval


def bench_retrieval_scale():
    rows = []
    n_dev = jax.device_count()
    if n_dev == 1:
        # single-device container: report the logical payload model only
        k, shards = 16, 256
        payload = shards * k * (4 + 4) * 64  # (score, id) × qbatch 64
        rows.append(("retrieval_merge_payload_model", 0.0,
                     f"bytes_at_256dev_k16_q64={payload}"))
        return rows

    mesh = jax.make_mesh(
        (n_dev, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
    rng = np.random.default_rng(0)
    n, d, w = 8192, 1024, 128
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    sigs = rng.integers(0, 2**31, size=(n, w)).astype(np.int32)
    pv, ps, nd = retrieval.pad_corpus(vecs, sigs, n_dev)
    qv = rng.normal(size=(8, d)).astype(np.float32)
    qs = sigs[:8].copy()
    ret = jax.jit(retrieval.build_sharded_retrieve(
        mesh, ("data",), nd, k=16))
    pv_d = jax.device_put(pv, NamedSharding(mesh, P("data", None)))
    ps_d = jax.device_put(ps, NamedSharding(mesh, P("data", None)))
    out = ret(pv_d, ps_d, jnp.asarray(qv), jnp.asarray(qs))
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(10):
        jax.block_until_ready(ret(pv_d, ps_d, jnp.asarray(qv),
                                  jnp.asarray(qs)))
    t = (time.perf_counter() - t0) / 10 * 1e6
    rows.append((f"sharded_retrieval_{n_dev}dev_8192docs", t, "q=8 k=16"))
    return rows


ALL = [bench_retrieval_scale]
