"""Persistence-plane benchmarks: journaled delta saves vs full rewrites.

The paper's 31.6x incremental-ingest win (§3.3) used to stop at the
persistence boundary: every ``save()`` re-serialized all N docs.  This
bench measures the layer that carries O(U) through to disk
(docs/ARCHITECTURE.md §8):

- **bytes written**: one full ``save()`` vs ``save_delta()`` appends
  swept over delta sizes U ∈ {1, 10, 100} — the acceptance bar is a
  1-doc delta into a ≥1k-doc container writing ≥10x fewer bytes than
  the full save (it is typically 2-3 orders of magnitude);
- **publish latency**: wall time of full save vs delta append (the
  fsync-bound floor of a durable publish) and of ``load()`` replaying
  base + journal;
- **compaction**: folding the journal back into a fresh base.

CSV rows follow the suite convention (``name,us_per_call,derived``).

    PYTHONPATH=src python -m benchmarks.bench_persistence [--smoke]
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

from repro.core.container import journal_size
from repro.core.ingest import KnowledgeBase
from repro.data.corpus import make_corpus

FULL = (10_000, 1024)   # (n_docs, dim)
SMOKE = (1_000, 256)    # CI: still ≥1k docs so the 10x bar is honest

DELTA_SIZES = (1, 10, 100)


def _build_kb(n_docs: int, dim: int) -> tuple[KnowledgeBase, list[str]]:
    docs, _ = make_corpus(n_docs=n_docs, n_entities=16, seed=0)
    kb = KnowledgeBase(dim=dim)
    for i, d in enumerate(docs):
        kb.add_text(f"doc_{i:06d}.txt", d)
    return kb, docs


def bench_persistence(smoke: bool = False):
    n_docs, dim = SMOKE if smoke else FULL
    kb, docs = _build_kb(n_docs, dim)
    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "kb.ragdb")

        t0 = time.perf_counter()
        kb.save(path)
        full_s = time.perf_counter() - t0
        full_bytes = os.path.getsize(path)
        rows.append((
            f"persist_full_save_{n_docs}docs",
            full_s * 1e6,
            f"bytes={full_bytes}",
        ))

        ratio_u1 = None
        for u in DELTA_SIZES:
            if u > n_docs:
                continue
            for j in range(u):
                kb.add_text(f"doc_{j:06d}.txt",
                            docs[j] + f" updated UPD-{u}-{j}")
            before = journal_size(path)
            t0 = time.perf_counter()
            gen = kb.save_delta(path, compact_ratio=None)
            delta_s = time.perf_counter() - t0
            delta_bytes = journal_size(path) - before
            ratio = full_bytes / max(delta_bytes, 1)
            if u == 1:
                ratio_u1 = ratio
            rows.append((
                f"persist_delta_u{u}_{n_docs}docs",
                delta_s * 1e6,
                f"bytes={delta_bytes}_full={full_bytes}"
                f"_ratio={ratio:.0f}x_gen={gen}",
            ))

        # acceptance: a 1-doc delta publish into a ≥1k-doc container
        # writes ≥10x fewer bytes than a full save
        assert ratio_u1 is not None and ratio_u1 >= 10, (
            f"1-doc delta wrote only {ratio_u1:.1f}x fewer bytes than a "
            f"full save (need ≥10x)"
        )

        # replay: load() = base + journal, and it must see the deltas
        t0 = time.perf_counter()
        out = KnowledgeBase.load(path)
        load_s = time.perf_counter() - t0
        assert out.n_docs == kb.n_docs
        last_u = max(u for u in DELTA_SIZES if u <= n_docs)
        assert f"UPD-{last_u}-0" in out.texts["doc_000000.txt"]
        assert out.loaded_generation == kb.loaded_generation
        rows.append((
            f"persist_load_replay_{n_docs}docs",
            load_s * 1e6,
            f"journal_bytes={journal_size(path)}"
            f"_generation={out.loaded_generation}",
        ))

        # compaction: fold the journal into a fresh base
        t0 = time.perf_counter()
        kb.compact(path)
        compact_s = time.perf_counter() - t0
        assert journal_size(path) == 0
        rows.append((
            f"persist_compact_{n_docs}docs",
            compact_s * 1e6,
            f"base_bytes={os.path.getsize(path)}_journal_bytes=0",
        ))
    return rows


ALL = [bench_persistence]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="1k-doc corpus (CI smoke: still large enough "
                    "to hold the ≥10x delta-vs-full bytes bar)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for fn in ALL:
        for name, us, derived in fn(smoke=args.smoke):
            print(f"{name},{us:.1f},{derived}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
