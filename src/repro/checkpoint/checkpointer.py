"""Sharded, content-hashed checkpointing on the knowledge-container
format (paper C4 reused as the training-state store).

- Atomic publish: data files land first, then the generation manifest is
  os.replace'd — a crash mid-save can never corrupt the latest restore
  point (the previous generation's manifest still names only complete,
  hash-verified files).
- Content addressing: shard files are named by their data hash, so
  elastic re-sharding / replication is a manifest edit, and unchanged
  leaves between checkpoints dedupe to the same file name.
- Async save: `save_async` snapshots to host (device_get) on the caller
  thread, then writes on a background thread — the train step resumes
  as soon as the device→host copy completes.
- Exact resume: restore returns bit-identical leaves (tested), plus the
  DataCursor step for deterministic pipeline replay.

Multi-host note: each host saves the shards it owns (addressable
devices) into its own shard file; the manifest merge is a trivial
concat because files are content-addressed.  This container runs
single-host, so n_hosts=1 paths are what execute here.
"""
from __future__ import annotations

import json
import os
import threading
from dataclasses import dataclass

import jax
import numpy as np

from repro.core.container import Container, publish_sharded, ShardedContainer


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten(template, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclass
class Checkpointer:
    root: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.root, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ---- save -----------------------------------------------------------

    def save(self, step: int, state, extra_meta: dict | None = None) -> int:
        flat = _flatten(state)
        return self._write(step, flat, extra_meta or {})

    def save_async(self, step: int, state, extra_meta: dict | None = None):
        """Device→host copy now; file I/O on a background thread."""
        self.wait()
        flat = _flatten(state)  # blocking device_get = the sync point

        def work():
            self._write(step, flat, extra_meta or {})

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, extra_meta: dict) -> int:
        # the manifest's generation-history GC enforces the keep window
        # exactly (files referenced by the last ``keep`` generations
        # survive; older unreferenced shards are collected) — no more
        # mtime heuristics
        return publish_sharded(
            self.root,
            shard_segments=[flat],
            shard_metas=[{"step": step}],
            meta={"step": step, **extra_meta},
            gc=True,
            gc_grace=self.keep,
        )

    # ---- restore --------------------------------------------------------

    def latest_step(self) -> int | None:
        mpath = os.path.join(self.root, "manifest.json")
        if not os.path.exists(mpath):
            return None
        with open(mpath) as f:
            return int(json.load(f)["meta"]["step"])

    def restore(self, template):
        """Restore into the structure of ``template`` (e.g. the abstract
        state from init).  Returns (state, step)."""
        self.wait()
        sc = ShardedContainer.open(self.root)
        flat: dict[str, np.ndarray] = {}
        for i in range(sc.n_shards):
            flat.update(sc.open_shard(i).read_all())
        return _unflatten(template, flat), int(sc.meta["step"])
