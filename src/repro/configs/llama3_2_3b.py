"""llama3.2-3b [hf:meta-llama/Llama-3.2-3B family; assignment spec].

28L, d_model 3072, 24 q heads (GQA kv=8), head_dim 128, d_ff 8192,
vocab 128256.  Full causal attention, RoPE base 500k, SwiGLU, tied.
"""
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="llama3.2-3b",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=128256,
    rope_base=500_000.0,
    activation="silu",
    tie_embeddings=True,
)

SMOKE = LMConfig(
    name="llama3.2-smoke",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    rope_base=500_000.0,
    activation="silu",
    tie_embeddings=True,
    dtype="float32",
)
