"""gemma3-27b [hf:google/gemma-3-27b-it family; assignment spec].

62L, d_model 5376, 32 q heads (GQA kv=16), head_dim 128, d_ff 21504,
vocab 262144.  5 local (sliding window 1024) : 1 global interleave;
RoPE base 1M global / 10k local; qk-norm; sandwich norms; tied embeds;
query scale (d_model/n_heads)^-1/2 = 168^-1/2.
"""
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="gemma3-27b",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab=262144,
    pattern=("local",) * 5 + ("global",),
    window=1024,
    qk_norm=True,
    post_norms=True,
    rope_base=1_000_000.0,
    rope_base_local=10_000.0,
    activation="gelu",
    embed_scale=True,
    tie_embeddings=True,
    query_scale=(5376 / 32) ** -0.5,
)

SMOKE = LMConfig(
    name="gemma3-smoke",
    n_layers=8,  # 1 full pattern unit + 2 tail layers
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    pattern=("local",) * 5 + ("global",),
    window=16,
    qk_norm=True,
    post_norms=True,
    rope_base=1_000_000.0,
    rope_base_local=10_000.0,
    activation="gelu",
    embed_scale=True,
    tie_embeddings=True,
    query_scale=(64 / 4) ** -0.5,
    dtype="float32",
)
