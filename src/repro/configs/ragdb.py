"""The paper's own architecture: the RAGdb retrieval plane, scaled.

Shapes (ours — the paper runs 1k docs on one laptop; the production
configs shard the corpus over the mesh):

    edge_1k      1,024 docs × 1 device      (the paper's regime)
    pod_16m      16.7M docs × 256 devices   (65,536 docs/device)
    multipod_33m 33.5M docs × 512 devices
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class RAGdbConfig:
    name: str = "ragdb"
    dim: int = 4096  # hashed TF-IDF dims
    sig_words: int = 128  # bloom signature int32 words
    alpha: float = 1.0
    beta: float = 1.0
    top_k: int = 16
    query_batch: int = 64
    docs_per_device: int = 65536


FULL = RAGdbConfig()
SMOKE = RAGdbConfig(name="ragdb-smoke", dim=512, sig_words=128, top_k=4,
                    query_batch=4, docs_per_device=256)
