"""dlrm-rm2 [arXiv:1906.00091; Park et al. RM2 class]:
13 dense, 26 sparse (Criteo vocabs), embed 64,
bottom 13-512-256-64, top 512-512-256-1, dot interaction."""
from repro.models.recsys.base import CRITEO_VOCABS, RecsysConfig

FULL = RecsysConfig(
    name="dlrm-rm2",
    vocab_sizes=CRITEO_VOCABS,
    embed_dim=64,
    n_dense=13,
    bot_mlp=(512, 256, 64),
    top_mlp=(512, 512, 256, 1),
    interaction="dot",
)

SMOKE = RecsysConfig(
    name="dlrm-rm2-smoke",
    vocab_sizes=(97, 41, 13, 7, 29, 3) * 2,  # 12 tiny tables
    embed_dim=16,
    n_dense=13,
    bot_mlp=(32, 16),
    top_mlp=(32, 16, 1),
    interaction="dot",
)
