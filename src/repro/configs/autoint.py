"""autoint [arXiv:1810.11921]: 39 sparse fields, embed 16,
3 self-attention layers, 2 heads, d_attn 32."""
from repro.models.recsys.base import DEEPFM_VOCABS, RecsysConfig

FULL = RecsysConfig(
    name="autoint",
    vocab_sizes=DEEPFM_VOCABS,
    embed_dim=16,
    n_attn_layers=3,
    n_attn_heads=2,
    d_attn=32,
    interaction="self-attn",
)

SMOKE = RecsysConfig(
    name="autoint-smoke",
    vocab_sizes=(53, 11, 7, 31, 17, 23, 5, 13),
    embed_dim=8,
    n_attn_layers=2,
    n_attn_heads=2,
    d_attn=16,
    interaction="self-attn",
)
