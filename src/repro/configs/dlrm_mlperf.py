"""dlrm-mlperf [arXiv:1906.00091; MLPerf DLRM benchmark, Criteo 1TB]:
13 dense, 26 sparse, embed 128, bottom 13-512-256-128,
top 1024-1024-512-256-1, dot interaction."""
from repro.models.recsys.base import CRITEO_VOCABS, RecsysConfig

FULL = RecsysConfig(
    name="dlrm-mlperf",
    vocab_sizes=CRITEO_VOCABS,
    embed_dim=128,
    n_dense=13,
    bot_mlp=(512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
    interaction="dot",
)

SMOKE = RecsysConfig(
    name="dlrm-mlperf-smoke",
    vocab_sizes=(97, 41, 13, 7, 29, 3) * 2,
    embed_dim=32,
    n_dense=13,
    bot_mlp=(64, 32),
    top_mlp=(64, 32, 1),
    interaction="dot",
)
