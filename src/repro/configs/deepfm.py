"""deepfm [arXiv:1703.04247]: 39 sparse fields, embed 10,
deep MLP 400-400-400, FM interaction."""
from repro.models.recsys.base import DEEPFM_VOCABS, RecsysConfig

FULL = RecsysConfig(
    name="deepfm",
    vocab_sizes=DEEPFM_VOCABS,
    embed_dim=10,
    mlp_dims=(400, 400, 400),
    interaction="fm",
)

SMOKE = RecsysConfig(
    name="deepfm-smoke",
    vocab_sizes=(53, 11, 7, 31, 17, 23, 5, 13),
    embed_dim=8,
    mlp_dims=(32, 32),
    interaction="fm",
)
