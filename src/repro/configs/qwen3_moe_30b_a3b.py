"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B].

48L, d_model 2048, 32 q heads (GQA kv=4), head_dim 128, vocab 151936.
MoE: 128 routed experts, top-8, d_ff(expert)=768, gate renormalized
(norm_topk_prob), no shared experts.  qk-norm; untied embeddings.
~30.5 B total / ~3.3 B active.
"""
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab=151936,
    qk_norm=True,
    rope_base=1_000_000.0,
    activation="silu",
    tie_embeddings=False,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768, norm_topk=True),
)

SMOKE = LMConfig(
    name="qwen3-moe-smoke",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab=512,
    qk_norm=True,
    rope_base=1_000_000.0,
    activation="silu",
    tie_embeddings=False,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, norm_topk=True),
    dtype="float32",
)
