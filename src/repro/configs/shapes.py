"""Per-family input-shape sets: the 40 (arch × shape) dry-run cells.

``input_specs(arch_id, shape_id)`` returns weak-type-correct
``jax.ShapeDtypeStruct`` stand-ins for every *data* input of the step the
shape exercises (parameters and KV caches are shape-evaluated separately
by the dry-run via ``jax.eval_shape``) — no device allocation ever
happens for the full configs.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

S = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class ShapeSpec:
    shape_id: str
    kind: str  # which step function this lowers
    meta: dict


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "lm_train",
                          {"seq": 4096, "batch": 256}),
    "prefill_32k": ShapeSpec("prefill_32k", "lm_prefill",
                             {"seq": 32768, "batch": 32}),
    "decode_32k": ShapeSpec("decode_32k", "lm_decode",
                            {"seq": 32768, "batch": 128}),
    "long_500k": ShapeSpec("long_500k", "lm_decode",
                           {"seq": 524288, "batch": 1}),
}

# minibatch_lg slot geometry: 1024 seeds, fanout 15 then 10
#   nodes 1024·(1 + 15 + 150) = 169,984;  edges 1024·(15 + 150) = 168,960
_MB_NODES = 1024 * (1 + 15 + 150)
_MB_EDGES = 1024 * (15 + 150)


def _pad512(n: int) -> int:
    """Graph slots are padded to a multiple of 512 so node/edge arrays
    shard evenly on every production mesh (masks carry validity — the
    data pipeline owns the padding, logical sizes stay exact)."""
    return n + (-n) % 512


GNN_SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "gnn_train",
        {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_graphs": 1,
         "pad_nodes": _pad512(2708), "pad_edges": _pad512(10556)},
    ),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "gnn_train_sampled",
        {"n_nodes": _MB_NODES, "n_edges": _MB_EDGES, "d_feat": 602,
         "batch_nodes": 1024, "fanout": (15, 10), "n_graphs": 1,
         "pad_nodes": _pad512(_MB_NODES), "pad_edges": _pad512(_MB_EDGES)},
    ),
    "ogb_products": ShapeSpec(
        "ogb_products", "gnn_train",
        {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100,
         "n_graphs": 1,
         "pad_nodes": _pad512(2449029), "pad_edges": _pad512(61859140)},
    ),
    "molecule": ShapeSpec(
        "molecule", "gnn_train_batched",
        {"n_nodes": 30 * 128, "n_edges": 64 * 128, "d_feat": 32,
         "batch": 128, "n_graphs": 128,
         "pad_nodes": _pad512(30 * 128), "pad_edges": _pad512(64 * 128)},
    ),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "recsys_train", {"batch": 65536}),
    "serve_p99": ShapeSpec("serve_p99", "recsys_serve", {"batch": 512}),
    "serve_bulk": ShapeSpec("serve_bulk", "recsys_serve", {"batch": 262144}),
    "retrieval_cand": ShapeSpec(
        "retrieval_cand", "recsys_retrieval",
        {"batch": 1, "n_candidates": 1_000_000, "top_k": 16,
         # candidate array padded to shard evenly on any mesh; padding
         # scores are masked to -inf before the top-k merge
         "pad_candidates": 1_000_000 + (-1_000_000) % 512},
    ),
}

# The paper's own plane: sharded corpus retrieval (extra cells beyond 40).
RAGDB_SHAPES = {
    "edge_1k": ShapeSpec("edge_1k", "ragdb_retrieve",
                         {"docs_per_device": 1024, "query_batch": 4}),
    "pod_16m": ShapeSpec("pod_16m", "ragdb_retrieve",
                         {"docs_per_device": 65536, "query_batch": 64}),
}


def shapes_for_family(family: str) -> dict[str, ShapeSpec]:
    return {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES,
            "ragdb": RAGDB_SHAPES}[family]


def input_specs(arch, spec: ShapeSpec) -> dict:
    """Data-input ShapeDtypeStructs for (arch config, shape)."""
    m = spec.meta
    if spec.kind == "lm_train":
        return {
            "tokens": S((m["batch"], m["seq"]), jnp.int32),
            "targets": S((m["batch"], m["seq"]), jnp.int32),
        }
    if spec.kind == "lm_prefill":
        return {"tokens": S((m["batch"], m["seq"]), jnp.int32)}
    if spec.kind == "lm_decode":
        return {
            "tokens": S((m["batch"], 1), jnp.int32),
            "lengths": S((m["batch"],), jnp.int32),
        }
    if spec.kind in ("gnn_train", "gnn_train_sampled", "gnn_train_batched"):
        nn, ne = m["pad_nodes"], m["pad_edges"]
        specs = {
            "node_feats": S((nn, m["d_feat"]), jnp.float32),
            "positions": S((nn, 3), jnp.float32),
            "senders": S((ne,), jnp.int32),
            "receivers": S((ne,), jnp.int32),
            "labels": S((nn,), jnp.int32),
            "edge_mask": S((ne,), jnp.float32),
            "node_mask": S((nn,), jnp.float32),
        }
        if spec.kind == "gnn_train_sampled":
            specs["seed_mask"] = S((nn,), jnp.float32)
        if spec.kind == "gnn_train_batched":
            specs["graph_ids"] = S((nn,), jnp.int32)
            specs["energy_targets"] = S((m["n_graphs"],), jnp.float32)
        return specs
    if spec.kind in ("recsys_train", "recsys_serve"):
        specs = {"sparse_idx": S((m["batch"], arch.n_sparse), jnp.int32)}
        if arch.n_dense:
            specs["dense"] = S((m["batch"], arch.n_dense), jnp.float32)
        if spec.kind == "recsys_train":
            specs["labels"] = S((m["batch"],), jnp.float32)
        return specs
    if spec.kind == "recsys_retrieval":
        specs = {"candidate_ids": S((m["pad_candidates"],), jnp.int32)}
        if arch.n_dense:
            specs["query"] = S((m["batch"], arch.n_dense), jnp.float32)
        else:
            specs["query"] = S((m["batch"], arch.n_sparse), jnp.int32)
        return specs
    if spec.kind == "ragdb_retrieve":
        # per-device doc shard sizes are multiplied by mesh size at
        # lowering time (launch/steps.py)
        return {
            "query_vecs": S((m["query_batch"], arch.dim), jnp.float32),
            "query_sigs": S((m["query_batch"], arch.sig_words), jnp.int32),
        }
    raise ValueError(spec.kind)
