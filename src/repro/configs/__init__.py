"""Architecture registry: ``--arch <id>`` resolution for every assigned
architecture plus the paper's own retrieval plane."""
from __future__ import annotations

import importlib
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys | ragdb
    module: str

    @property
    def config(self):
        return importlib.import_module(self.module).FULL

    @property
    def smoke_config(self):
        return importlib.import_module(self.module).SMOKE


ARCHS: dict[str, ArchSpec] = {
    "gemma3-27b": ArchSpec("gemma3-27b", "lm", "repro.configs.gemma3_27b"),
    "gemma2-9b": ArchSpec("gemma2-9b", "lm", "repro.configs.gemma2_9b"),
    "llama3.2-3b": ArchSpec("llama3.2-3b", "lm", "repro.configs.llama3_2_3b"),
    "qwen3-moe-30b-a3b": ArchSpec(
        "qwen3-moe-30b-a3b", "lm", "repro.configs.qwen3_moe_30b_a3b"
    ),
    "deepseek-v2-lite-16b": ArchSpec(
        "deepseek-v2-lite-16b", "lm", "repro.configs.deepseek_v2_lite_16b"
    ),
    "mace": ArchSpec("mace", "gnn", "repro.configs.mace"),
    "dlrm-rm2": ArchSpec("dlrm-rm2", "recsys", "repro.configs.dlrm_rm2"),
    "deepfm": ArchSpec("deepfm", "recsys", "repro.configs.deepfm"),
    "dlrm-mlperf": ArchSpec("dlrm-mlperf", "recsys",
                            "repro.configs.dlrm_mlperf"),
    "autoint": ArchSpec("autoint", "recsys", "repro.configs.autoint"),
    "ragdb": ArchSpec("ragdb", "ragdb", "repro.configs.ragdb"),
}

ASSIGNED = [a for a in ARCHS if a != "ragdb"]  # the 10 graded archs


def get(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}"
        )
    return ARCHS[arch_id]


def cells():
    """All (arch_id, shape_id) dry-run cells (40 assigned + ragdb extras)."""
    from repro.configs import shapes as shp

    out = []
    for arch_id, spec in ARCHS.items():
        for shape_id in shp.shapes_for_family(spec.family):
            out.append((arch_id, shape_id))
    return out
