"""deepseek-v2-lite-16b [arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2-Lite].

27L, d_model 2048, 16 heads, vocab 102400.  MLA: kv_lora_rank 512,
decoupled RoPE key dim 64, nope 128, v 128 (queries uncompressed in
Lite).  MoE: 64 routed + 2 shared experts, top-6, d_ff(expert) 1408;
layer 0 is a dense MLP with d_ff 10944.  ~15.7 B total / ~2.4 B active.

Assignment-line note (recorded here for traceability): the line says both
"64e top-6" and "160 routed" — 160 routed belongs to full V2; the Lite
model named here has 64 routed + 2 shared, which we use.
"""
from repro.models.mla import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=102400,
    rope_base=10_000.0,
    activation="silu",
    tie_embeddings=False,
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128,
                  v_head_dim=128, q_lora_rank=None),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                  norm_topk=False),
    n_dense_head_layers=1,
    dense_d_ff=10944,
)

SMOKE = LMConfig(
    name="deepseek-v2-smoke",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=32,
    vocab=512,
    rope_base=10_000.0,
    activation="silu",
    tie_embeddings=False,
    mla=MLAConfig(kv_lora_rank=32, rope_head_dim=8, nope_head_dim=16,
                  v_head_dim=16, q_lora_rank=None),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=2,
                  norm_topk=False),
    n_dense_head_layers=1,
    dense_d_ff=128,
    dtype="float32",
)
