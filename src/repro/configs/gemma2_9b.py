"""gemma2-9b [arXiv:2408.00118].

42L, d_model 3584, 16 q heads (GQA kv=8), head_dim 256, d_ff 14336,
vocab 256000.  Local (window 4096) / global alternating; attention
logit softcap 50, final logit softcap 30; sandwich norms; tied embeds.
"""
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="gemma2-9b",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    rope_base=10_000.0,
    activation="gelu",
    embed_scale=True,
    tie_embeddings=True,
    query_scale=256 ** -0.5,
)

SMOKE = LMConfig(
    name="gemma2-smoke",
    n_layers=5,  # 2 units + 1 tail layer
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    pattern=("local", "global"),
    window=16,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    rope_base=10_000.0,
    activation="gelu",
    embed_scale=True,
    tie_embeddings=True,
    query_scale=16 ** -0.5,
    dtype="float32",
)
