"""mace [arXiv:2206.07697]: 2 layers, d_hidden 128, l_max 2,
correlation order 3, 8 radial basis functions, E(3)-equivariant.

d_feat varies per shape (the graph shapes carry their own feature
widths); the config pins the architecture, input_specs pins d_feat.
"""
from repro.models.gnn.mace import MACEConfig

FULL = MACEConfig(
    name="mace",
    n_layers=2,
    d_hidden=128,
    l_max=2,
    correlation_order=3,
    n_rbf=8,
    d_feat=128,  # overridden per shape via dataclasses.replace
    n_classes=64,
)

SMOKE = MACEConfig(
    name="mace-smoke",
    n_layers=2,
    d_hidden=32,
    l_max=2,
    correlation_order=3,
    n_rbf=8,
    d_feat=16,
    n_classes=8,
)
