"""repro — RAGdb reproduction grown into a jax_pallas serving system.

Importing the package installs JAX compatibility shims (see compat.py)
so every module can target one JAX API surface regardless of the
pinned release.
"""
from repro import compat as _compat

_compat.install()
