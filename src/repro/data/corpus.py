"""Synthetic corpus generator — reproduces the paper's §5.1 setup:
mixed business/technical English documents with unique entity codes
injected into known documents, so Recall@1 for entity queries is
ground-truthable.

Fully deterministic from the seed (the benchmark and the tests replay
identical corpora).
"""
from __future__ import annotations

import numpy as np

_BUSINESS = (
    "invoice payment quarterly revenue forecast client contract renewal "
    "procurement supplier ledger audit compliance budget expense margin "
    "stakeholder projection fiscal onboarding churn retention pipeline"
).split()
_TECH = (
    "server deployment kubernetes container latency throughput database "
    "index replication shard failover cache queue endpoint token schema "
    "migration rollback observability metric tracing alert incident"
).split()
_GLUE = "the of for with and to in on a is was were has have".split()


def make_corpus(
    n_docs: int = 1000,
    doc_len: int = 120,
    n_entities: int = 10,
    seed: int = 0,
) -> tuple[list[str], dict[str, int]]:
    """Returns (documents, {entity_code: doc_index}).

    Entity codes follow the paper's pattern (UNIQUE_INVOICE_CODE_XYZ_999)
    and each appears in exactly one document.
    """
    rng = np.random.default_rng(seed)
    vocab = _BUSINESS + _TECH + _GLUE
    docs = []
    for i in range(n_docs):
        words = rng.choice(vocab, size=doc_len)
        docs.append(" ".join(words))

    entities: dict[str, int] = {}
    targets = rng.choice(n_docs, size=n_entities, replace=False)
    for j, doc_idx in enumerate(targets):
        code = f"UNIQUE_INVOICE_CODE_{chr(65 + j % 26)}{chr(88 + j % 3)}_{900 + j}"
        words = docs[doc_idx].split()
        pos = int(rng.integers(0, len(words)))
        words.insert(pos, code)
        docs[doc_idx] = " ".join(words)
        entities[code] = int(doc_idx)
    return docs, entities


def make_topical_corpus(
    n_docs: int = 1000,
    doc_len: int = 120,
    n_topics: int = 32,
    n_entities: int = 10,
    seed: int = 0,
    sharpness: float = 0.85,
) -> tuple[list[str], dict[str, int], list[list[str]]]:
    """Returns (documents, {entity_code: doc_index}, topic_core_words).

    Like ``make_corpus`` but with *topical structure*: each document
    draws ``sharpness`` of its words from one topic's core vocabulary
    (16 words over an extended 512-term vocab) and the rest globally.
    Real document collections cluster by topic; the uniform
    ``make_corpus`` is intentionally structure-free (worst case for any
    cluster-pruned index), so the index-plane benchmarks measure
    QPS-vs-Recall on this generator (benchmarks/bench_index.py) where
    cosine neighborhoods actually concentrate.  Entity codes are
    injected exactly as in ``make_corpus``.  Deterministic from seed.
    """
    rng = np.random.default_rng(seed)
    base = _BUSINESS + _TECH + _GLUE
    vocab = np.array(base + [f"term{i:04d}" for i in range(512 - len(base))])
    cores = [rng.choice(len(vocab), size=16, replace=False)
             for _ in range(n_topics)]
    docs = []
    for i in range(n_docs):
        core = cores[int(rng.integers(n_topics))]
        from_core = rng.random(doc_len) < sharpness
        idx = np.where(
            from_core,
            core[rng.integers(0, len(core), size=doc_len)],
            rng.integers(0, len(vocab), size=doc_len),
        )
        docs.append(" ".join(vocab[idx]))

    entities: dict[str, int] = {}
    targets = rng.choice(n_docs, size=n_entities, replace=False)
    for j, doc_idx in enumerate(targets):
        code = f"UNIQUE_INVOICE_CODE_{chr(65 + j % 26)}{chr(88 + j % 3)}_{900 + j}"
        words = docs[doc_idx].split()
        words.insert(int(rng.integers(0, len(words))), code)
        docs[doc_idx] = " ".join(words)
        entities[code] = int(doc_idx)
    return docs, entities, [list(vocab[c]) for c in cores]


def write_corpus_dir(path: str, docs: list[str]) -> None:
    import os

    os.makedirs(path, exist_ok=True)
    for i, d in enumerate(docs):
        with open(os.path.join(path, f"doc_{i:05d}.txt"), "w") as f:
            f.write(d)
