"""Deterministic sharded data pipeline.

Restart invariant: every batch is a pure function of (seed, step,
shard), so after a failure the survivor set re-derives the exact token
stream from the checkpointed step counter — no data loss, no
duplication, no pipeline state to checkpoint beyond one integer
(the DataCursor).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np


@dataclass
class DataCursor:
    """The only mutable pipeline state; checkpointed as one int."""

    seed: int
    step: int = 0

    def advance(self) -> int:
        s = self.step
        self.step += 1
        return s


def _rng(seed: int, step: int, stream: str) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, hash(stream) & 0x7FFFFFFF])
    )


def lm_batch(cursor: DataCursor, batch: int, seq: int, vocab: int):
    """Synthetic LM tokens with local n-gram structure (so loss can
    actually decrease in the example trainers)."""
    step = cursor.advance()
    rng = _rng(cursor.seed, step, "lm")
    # Markov-ish stream: next token = (prev * 31 + noise) % vocab
    start = rng.integers(0, vocab, size=(batch, 1))
    noise = rng.integers(0, 17, size=(batch, seq))
    toks = np.zeros((batch, seq + 1), np.int64)
    toks[:, 0] = start[:, 0]
    for t in range(1, seq + 1):
        toks[:, t] = (toks[:, t - 1] * 31 + noise[:, min(t - 1, seq - 1)]) % vocab
    return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)


def recsys_batch(cursor: DataCursor, batch: int, vocab_sizes, n_dense: int):
    step = cursor.advance()
    rng = _rng(cursor.seed, step, "recsys")
    sparse = np.stack(
        [rng.integers(0, v, size=batch) for v in vocab_sizes], axis=1
    ).astype(np.int32)
    dense = rng.normal(size=(batch, n_dense)).astype(np.float32) \
        if n_dense else None
    # click label correlated with field 0 parity (learnable signal)
    logit = (sparse[:, 0] % 2) * 2.0 - 1.0 + rng.normal(size=batch)
    labels = (logit > 0).astype(np.float32)
    return dense, sparse, labels


def gnn_graph(cursor: DataCursor, n_nodes: int, n_edges: int, d_feat: int,
              n_graphs: int = 1):
    step = cursor.advance()
    rng = _rng(cursor.seed, step, "gnn")
    feats = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    pos = rng.normal(size=(n_nodes, 3)).astype(np.float32) * 3.0
    if n_graphs > 1:
        per = n_nodes // n_graphs
        graph_ids = (np.arange(n_nodes) // per).clip(0, n_graphs - 1)
        # edges stay within a graph
        eper = n_edges // n_graphs
        snd, rcv = [], []
        for g in range(n_graphs):
            snd.append(rng.integers(g * per, (g + 1) * per, size=eper))
            rcv.append(rng.integers(g * per, (g + 1) * per, size=eper))
        senders = np.concatenate(snd)
        receivers = np.concatenate(rcv)
        pad = n_edges - len(senders)
        senders = np.concatenate([senders, np.zeros(pad, np.int64)])
        receivers = np.concatenate([receivers, np.zeros(pad, np.int64)])
    else:
        graph_ids = np.zeros(n_nodes, np.int64)
        senders = rng.integers(0, n_nodes, size=n_edges)
        receivers = rng.integers(0, n_nodes, size=n_edges)
    labels = rng.integers(0, 8, size=n_nodes)
    energy = rng.normal(size=n_graphs).astype(np.float32)
    return {
        "node_feats": feats, "positions": pos,
        "senders": senders.astype(np.int32),
        "receivers": receivers.astype(np.int32),
        "graph_ids": graph_ids.astype(np.int32),
        "labels": labels.astype(np.int32),
        "energy_targets": energy,
    }
