"""Compatibility shims for older JAX releases.

The codebase targets the current JAX API surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``,
``check_vma=``).  Some deployment images pin older releases (0.4.x)
where those still live under ``jax.experimental.shard_map`` /
``check_rep=`` or do not exist at all.  ``install()`` patches the
missing names onto the ``jax`` namespace so the rest of the code (and
the tests) can be written against one API.

Every shim is gated on a feature probe — on a current JAX this module
is a no-op, and it never *changes* existing behaviour, it only fills
holes.  Called once from ``repro/__init__.py``.
"""
from __future__ import annotations

import enum
import functools
import inspect


def _install_shard_map(jax) -> None:
    try:
        jax.shard_map  # noqa: B018 — probe (old releases raise here)
        return
    except AttributeError:
        pass
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
                  axis_names=None, **kwargs):
        # check_vma was named check_rep before the varying-manual-axes
        # rework; semantics are close enough for "turn the check off".
        #
        # axis_names (the set of MANUAL axes) has no reliable old-API
        # equivalent: `auto=` partial mode lowers axis_index to a
        # PartitionId the 0.4.x SPMD partitioner rejects.  We run FULL
        # manual instead, which is equivalent as long as the in/out
        # specs never mention a non-manual axis (inputs are then simply
        # replicated over those axes — true for every call site here).
        if axis_names is not None:
            for spec in jax.tree_util.tree_leaves(
                (in_specs, out_specs),
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
            ):
                for entry in spec:
                    names = entry if isinstance(entry, tuple) else (entry,)
                    assert all(n is None or n in axis_names for n in names), (
                        f"compat shard_map: spec {spec} mentions an axis "
                        f"outside axis_names={axis_names}; full-manual "
                        "fallback would change semantics"
                    )
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=bool(check_vma), **kwargs,
        )

    jax.shard_map = shard_map


def _install_axis_type(jax) -> None:
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if not hasattr(jax, "make_mesh"):
        from jax.experimental import mesh_utils

        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            devs = mesh_utils.create_device_mesh(
                tuple(axis_shapes), devices=devices
            )
            return jax.sharding.Mesh(devs, tuple(axis_names))

        jax.make_mesh = make_mesh
        return

    params = inspect.signature(jax.make_mesh).parameters
    if "axis_types" not in params:
        orig = jax.make_mesh

        @functools.wraps(orig)
        def make_mesh(axis_shapes, axis_names, *args, axis_types=None, **kw):
            # Old releases have no axis-type concept: every axis behaves
            # as Auto, which is the only type this repo requests.
            return orig(axis_shapes, axis_names, *args, **kw)

        jax.make_mesh = make_mesh


def _install_pallas_names() -> None:
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:  # pallas not built for this backend
        return
    if not hasattr(pltpu, "CompilerParams") and hasattr(
        pltpu, "TPUCompilerParams"
    ):
        pltpu.CompilerParams = pltpu.TPUCompilerParams


def install() -> None:
    import jax

    _install_shard_map(jax)
    _install_axis_type(jax)
    _install_pallas_names()
