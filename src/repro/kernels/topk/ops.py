"""Public top-k wrapper: padding + backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.topk.topk import top_k_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def top_k(
    scores: jnp.ndarray,  # [N]
    k: int,
    block: int = 1024,
    interpret: bool | None = None,
):
    """Streaming top-k; (values [k], indices [k] int32).

    Requires k <= min(N, 128).  Padding scores are -inf and can never
    displace real candidates (ids of padding are >= N and only appear
    if k > N, which is rejected).
    """
    if interpret is None:
        interpret = _default_interpret()
    n = scores.shape[0]
    assert k <= n, (k, n)
    block = min(block, max(128, 1 << (n - 1).bit_length()))
    pad = (-n) % block
    if pad:
        scores = jnp.concatenate(
            [scores, jnp.full((pad,), -jnp.inf, scores.dtype)]
        )
    return top_k_pallas(scores, k=k, block=block, interpret=interpret)
