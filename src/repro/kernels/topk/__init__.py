from repro.kernels.topk.ops import top_k  # noqa: F401
