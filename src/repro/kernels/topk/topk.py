"""Blockwise streaming top-k kernel (Pallas TPU).

Retrieval's merge step: a score stream of length N (N up to millions of
candidates for `retrieval_cand`) reduced to the k best (k ≤ 128).  One
grid step consumes a (1 × block) score tile and folds it into a running
top-k held in VMEM scratch:

    cand = concat(running_topk, block_scores)      # 1 × (128 + block)
    k × (max, argmax, knock-out)                   # VPU reductions

k passes of argmax over a VMEM-resident tile beat a full sort on TPU for
small k (no cross-lane shuffle network needed), and the scratch carry
makes the kernel single-pass over HBM — the score stream is read exactly
once, which is the memory-roofline optimum for this op.

Tie-breaking is (score desc, id asc): candidates are ordered running-
first and ids ascend within a block, so argmax's first-match semantics
give the stable order for free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -jnp.inf
KPAD = 128  # scratch lane width; supports k <= 128


def _topk_kernel(scores_ref, vals_ref, ids_ref, vscr, iscr, *, k, block, nblocks):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        vscr[...] = jnp.full_like(vscr, NEG_INF)
        iscr[...] = jnp.full_like(iscr, jnp.int32(2**31 - 1))

    s = scores_ref[...]  # [1, block]
    gids = i * block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)

    cand_v = jnp.concatenate([vscr[...], s], axis=1)  # [1, KPAD + block]
    cand_i = jnp.concatenate([iscr[...], gids], axis=1)

    new_v, new_i = [], []
    for _ in range(k):  # k static — unrolled VPU reduction chain
        a = jnp.argmax(cand_v, axis=1)[0]
        new_v.append(cand_v[0, a])
        new_i.append(cand_i[0, a])
        cand_v = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, 1) == a,
            NEG_INF,
            cand_v,
        )
    pad = KPAD - k
    vrow = jnp.concatenate(
        [jnp.stack(new_v), jnp.full((pad,), NEG_INF, vscr.dtype)]
    ).reshape(1, KPAD)
    irow = jnp.concatenate(
        [jnp.stack(new_i), jnp.full((pad,), 2**31 - 1, jnp.int32)]
    ).reshape(1, KPAD)
    vscr[...] = vrow
    iscr[...] = irow

    @pl.when(i == nblocks - 1)
    def _final():
        vals_ref[...] = vscr[...]
        ids_ref[...] = iscr[...]


@functools.partial(jax.jit, static_argnames=("k", "block", "interpret"))
def top_k_pallas(
    scores: jnp.ndarray,  # [N] f32, N % block == 0
    *,
    k: int,
    block: int = 1024,
    interpret: bool = False,
):
    n = scores.shape[0]
    assert n % block == 0 and k <= KPAD
    nblocks = n // block
    kernel = functools.partial(
        _topk_kernel, k=k, block=block, nblocks=nblocks
    )
    vals, ids = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((1, KPAD), lambda i: (0, 0)),
            pl.BlockSpec((1, KPAD), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, KPAD), scores.dtype),
            jax.ShapeDtypeStruct((1, KPAD), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, KPAD), scores.dtype),
            pltpu.VMEM((1, KPAD), jnp.int32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="topk_stream",
    )(scores.reshape(1, n))
    return vals[0, :k], ids[0, :k]
