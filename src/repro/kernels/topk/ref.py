"""Oracle: jax.lax.top_k with (score desc, id asc) tie-breaking."""
from __future__ import annotations

import jax.numpy as jnp


def top_k_ref(scores: jnp.ndarray, k: int):
    """(values [k], indices [k] int32), ties broken toward lower index."""
    ids = jnp.arange(scores.shape[0], dtype=jnp.int32)
    order = jnp.lexsort((ids, -scores))[:k]
    return scores[order], order.astype(jnp.int32)
