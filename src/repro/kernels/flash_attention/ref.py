"""Dense pure-jnp oracle for the flash attention kernel.

Semantics (shared with the kernel):
- GQA: q heads grouped onto kv heads (Hq % Hkv == 0).
- causal mask; optional sliding window (attend iff 0 <= q-k < window,
  i.e. gemma-style backward window including self).
- optional logit softcap: s <- cap * tanh(s / cap), applied after scale,
  before masking (gemma2 convention).
- rows with no attendable key return zeros.
"""
from __future__ import annotations

import jax.numpy as jnp

MASK_VALUE = -1e30


def attention_ref(
    q: jnp.ndarray,  # [B, Hq, Lq, Dh]
    k: jnp.ndarray,  # [B, Hkv, Lk, Dh]
    v: jnp.ndarray,  # [B, Hkv, Lk, Dh]
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    b, hq, lq, dh = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)

    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = q_offset + jnp.arange(lq)[:, None]
    k_pos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, MASK_VALUE)

    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * mask[None, None]
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    o = o / jnp.where(l == 0.0, 1.0, l)
    return o.astype(q.dtype)
