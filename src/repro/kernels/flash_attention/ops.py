"""Jit'd public wrapper: padding, backend dispatch, block-size selection."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_seq(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(
    q: jnp.ndarray,  # [B, Hq, Lq, Dh]
    k: jnp.ndarray,  # [B, Hkv, Lk, Dh]
    v: jnp.ndarray,
    *,
    scale: float | None = None,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    q_offset: int = 0,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Blockwise attention; pads seq dims to block multiples internally.

    ``q_offset``: absolute position of q[..., 0, :] — used when the query
    chunk is a suffix of the kv sequence (chunked prefill).
    """
    if interpret is None:
        interpret = _default_interpret()
    if scale is None:
        scale = q.shape[-1] ** -0.5
    lq, lk = q.shape[2], k.shape[2]
    block_q = min(block_q, max(8, lq))
    block_k = min(block_k, max(8, lk))
    qp = _pad_seq(q, 2, block_q)
    kp = _pad_seq(k, 2, block_k)
    vp = _pad_seq(v, 2, block_k)
    out = flash_attention_pallas(
        qp, kp, vp,
        scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k,
        q_offset=q_offset, kv_len=lk,
        interpret=interpret,
    )
    return out[:, :, :lq]
