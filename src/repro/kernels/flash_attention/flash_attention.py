"""Blockwise IO-aware attention kernel (Pallas TPU) — FlashAttention
adapted to the TPU memory hierarchy, with the mask family the assigned
LM architectures need: causal, sliding window (gemma2/3 local layers),
logit softcap (gemma2), GQA head grouping.

Grid: (batch, q_heads, q_blocks, k_blocks); the k_blocks axis is the
innermost ("arbitrary") dimension and carries the online-softmax state in
VMEM scratch:

    m   (block_q, 128) f32   running row max (lane-broadcast)
    l   (block_q, 128) f32   running row sum
    acc (block_q, d)   f32   running weighted value sum

Per step the working set is q(block_q×d) + k,v(block_k×d) + scores
(block_q×block_k) — with the default 512×512 blocks at d=128 this is
~1.4 MB bf16, leaving VMEM room for double buffering.

Irrelevant (q_block, k_block) pairs under causal/window masking are
skipped via @pl.when on the block-level relevance test — for a window of
w the per-row work drops from O(L) to O(w + block), which is the
structural win for gemma3's 5:1 local:global stack.

KV padding is masked with k_pos < kv_len so callers may pad freely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MASK_VALUE = -1e30
LANES = 128


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, causal, window, softcap, block_q, block_k, nk,
    q_offset, kv_len,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, MASK_VALUE)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_offset + qi * block_q
    k_start = ki * block_k

    # Block-level relevance: skip blocks fully outside the mask.
    relevant = k_start < kv_len
    if causal:
        relevant &= k_start <= q_start + block_q - 1
    if window is not None:
        relevant &= (k_start + block_k - 1) > (q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0]  # [bq, d]
        k = k_ref[0, 0]  # [bk, d]
        v = v_ref[0, 0]  # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [bq, bk]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < kv_len
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, MASK_VALUE)

        m_prev = m_scr[:, 0:1]  # [bq, 1]
        m_curr = jnp.max(s, axis=-1, keepdims=True)
        m_next = jnp.maximum(m_prev, m_curr)
        # Explicit mask multiply: correct even for fully-masked rows
        # (where exp(s - m_next) == exp(0) == 1).
        p = jnp.exp(s - m_next) * mask.astype(jnp.float32)
        alpha = jnp.exp(m_prev - m_next)  # [bq, 1]
        l_next = alpha * l_scr[:, 0:1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = jnp.broadcast_to(m_next, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_next, l_scr.shape)

    @pl.when(ki == nk - 1)
    def _final():
        l = l_scr[:, 0:1]
        o_ref[0, 0] = (
            acc_scr[...] / jnp.where(l == 0.0, 1.0, l)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "causal", "window", "softcap", "block_q", "block_k",
        "q_offset", "kv_len", "interpret",
    ),
)
def flash_attention_pallas(
    q: jnp.ndarray,  # [B, Hq, Lq, Dh]   Lq % block_q == 0
    k: jnp.ndarray,  # [B, Hkv, Lk, Dh]  Lk % block_k == 0
    v: jnp.ndarray,
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    q_offset: int = 0,
    kv_len: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, lq, dh = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    assert lq % block_q == 0 and lk % block_k == 0, (lq, lk, block_q, block_k)
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    nq, nk = lq // block_q, lk // block_k
    kv_len = lk if kv_len is None else kv_len

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, nk=nk,
        q_offset=q_offset, kv_len=kv_len,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, dh),
                lambda b, h, qi, ki: (b, h // group, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, dh),
                lambda b, h, qi, ki: (b, h // group, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, dh), lambda b, h, qi, ki: (b, h, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
