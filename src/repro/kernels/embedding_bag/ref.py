"""Pure-jnp oracle: EmbeddingBag = gather + segment-reduce.

JAX has no native EmbeddingBag (kernel_taxonomy §RecSys) — this
take+segment_sum composition IS the production jnp path; the Pallas
kernel fuses the gather and the reduce so rows stream HBM→VMEM once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(
    table: jnp.ndarray,  # [V, E]
    indices: jnp.ndarray,  # [n] int32
    segment_ids: jnp.ndarray,  # [n] int32, values in [0, n_bags)
    n_bags: int,
    weights: jnp.ndarray | None = None,  # [n] f32
    mode: str = "sum",
) -> jnp.ndarray:
    rows = jnp.take(table, indices, axis=0).astype(jnp.float32)
    if weights is not None:
        rows = rows * weights[:, None].astype(jnp.float32)
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
    if mode == "mean":
        counts = jax.ops.segment_sum(
            jnp.ones_like(segment_ids, jnp.float32), segment_ids,
            num_segments=n_bags,
        )
        out = out / jnp.maximum(counts, 1.0)[:, None]
    return out.astype(table.dtype)
