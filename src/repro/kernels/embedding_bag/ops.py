"""Public EmbeddingBag wrapper: sorting, empty-bag zeroing, mean mode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.embedding_bag import embedding_bag_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def embedding_bag(
    table: jnp.ndarray,  # [V, E]
    indices: jnp.ndarray,  # [n] int
    segment_ids: jnp.ndarray,  # [n] int, values in [0, n_bags)
    n_bags: int,
    weights: jnp.ndarray | None = None,
    mode: str = "sum",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused bag reduce: out[b] = Σ_{i: seg[i]==b} w[i] · table[idx[i]].

    Bags with no indices are zero.  Input order is free — a stable sort
    by segment id happens here (the kernel requires grouped segments).
    """
    if interpret is None:
        interpret = _default_interpret()
    n = indices.shape[0]
    indices = indices.astype(jnp.int32)
    segment_ids = segment_ids.astype(jnp.int32)
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    order = jnp.argsort(segment_ids, stable=True)
    indices = indices[order]
    segment_ids = segment_ids[order]
    weights = weights[order].astype(jnp.float32)

    out = embedding_bag_pallas(
        table, indices, segment_ids, weights,
        n_bags=n_bags, interpret=interpret,
    )
    counts = jax.ops.segment_sum(
        jnp.ones((n,), jnp.float32), segment_ids, num_segments=n_bags
    )
    out = jnp.where(counts[:, None] > 0, out, 0.0)
    if mode == "mean":
        out = out / jnp.maximum(counts, 1.0)[:, None]
    return out.astype(table.dtype)
