"""Fused EmbeddingBag kernel (Pallas TPU, scalar-prefetch indexed).

The recsys hot path (kernel_taxonomy §RecSys): ragged gather over a huge
table followed by a per-bag segment reduce.  The TPU-native formulation
uses ``PrefetchScalarGridSpec``: the index and segment arrays live in
SMEM ahead of the grid, and *drive the BlockSpec index maps*:

    grid step i:
        in  block = table[indices[i]]      (1 × E row, HBM→VMEM DMA)
        out block = out[segment_ids[i]]    (1 × E row, revisited)

Consecutive steps that map to the same output row accumulate in-place —
the canonical TPU "revisited output block" pattern, which is why the
wrapper sorts by segment id.  First-visit detection zero-initializes the
accumulator, so the kernel needs no separate init pass over the output.

Weights ride along in SMEM (scalar prefetch) — this is exactly the
tf·idf·sign accumulation of the paper's vectorizer (core/vectorizer.py),
so the retrieval plane and the recsys plane share this kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, seg_ref, w_ref, row_ref, out_ref):
    i = pl.program_id(0)
    prev = seg_ref[jnp.maximum(i - 1, 0)]
    first = jnp.logical_or(i == 0, seg_ref[i] != prev)

    @pl.when(first)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += row_ref[...].astype(out_ref.dtype) * w_ref[i]


@functools.partial(jax.jit, static_argnames=("n_bags", "interpret"))
def embedding_bag_pallas(
    table: jnp.ndarray,  # [V, E]
    indices: jnp.ndarray,  # [n] int32 (any order)
    segment_ids: jnp.ndarray,  # [n] int32 sorted ascending
    weights: jnp.ndarray,  # [n] f32
    *,
    n_bags: int,
    interpret: bool = False,
) -> jnp.ndarray:
    n = indices.shape[0]
    e = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, e), lambda i, idx, seg, w: (idx[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, e), lambda i, idx, seg, w: (seg[i], 0)),
    )
    return pl.pallas_call(
        _bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, e), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="embedding_bag",
    )(indices, segment_ids, weights, table)
