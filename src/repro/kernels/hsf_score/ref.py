"""Pure-jnp oracle for the fused HSF kernel (paper §4)."""
from __future__ import annotations

import jax.numpy as jnp


def hsf_score_ref(
    doc_vecs: jnp.ndarray,  # [N, D] float
    doc_sigs: jnp.ndarray,  # [N, W] int32
    query_vec: jnp.ndarray,  # [D] float
    query_sig: jnp.ndarray,  # [W] int32
    alpha: float,
    beta: float,
) -> jnp.ndarray:
    """α·(docs @ q) + β·bloom_containment — float32 [N]."""
    cos = doc_vecs.astype(jnp.float32) @ query_vec.astype(jnp.float32)
    hits = (doc_sigs & query_sig) == query_sig
    ind = jnp.all(hits, axis=-1).astype(jnp.float32)
    return alpha * cos + beta * ind
