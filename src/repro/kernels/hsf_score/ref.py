"""Pure-jnp oracle for the fused HSF kernel (paper §4)."""
from __future__ import annotations

import jax.numpy as jnp


def hsf_score_ref(
    doc_vecs: jnp.ndarray,  # [N, D] float
    doc_sigs: jnp.ndarray,  # [N, W] int32
    query_vec: jnp.ndarray,  # [D] float
    query_sig: jnp.ndarray,  # [W] int32
    alpha: float,
    beta: float,
) -> jnp.ndarray:
    """α·(docs @ q) + β·bloom_containment — float32 [N]."""
    cos = doc_vecs.astype(jnp.float32) @ query_vec.astype(jnp.float32)
    hits = (doc_sigs & query_sig) == query_sig
    ind = jnp.all(hits, axis=-1).astype(jnp.float32)
    return alpha * cos + beta * ind


def hsf_score_topk_ref(
    doc_vecs: jnp.ndarray,   # [N, D] float
    doc_sigs: jnp.ndarray,   # [N, W] int32
    query_vecs: jnp.ndarray,  # [B, D] float
    query_sigs: jnp.ndarray,  # [B, W] int32
    alpha: float,
    beta: float,
    k: int,
    n_valid=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Unfused oracle for the batched kernel: full [B, N] scores, then
    a (score desc, id asc) lexicographic top-k — retrieval._stable_top_k
    semantics, materialized the expensive way the kernel avoids.
    ``n_valid`` masks the corpus suffix to -inf like the kernel's SMEM
    scalar (also the delegate for the ops-level k > KPAD fallback)."""
    cos = query_vecs.astype(jnp.float32) @ doc_vecs.astype(jnp.float32).T
    hits = (doc_sigs[None, :, :] & query_sigs[:, None, :]) \
        == query_sigs[:, None, :]
    ind = jnp.all(hits, axis=-1).astype(jnp.float32)
    scores = alpha * cos + beta * ind  # [B, N]
    ids = jnp.broadcast_to(
        jnp.arange(scores.shape[1], dtype=jnp.int32), scores.shape
    )
    if n_valid is not None:
        scores = jnp.where(ids < n_valid, scores, -jnp.inf)
    order = jnp.lexsort((ids, -scores), axis=-1)[:, :k]
    return jnp.take_along_axis(scores, order, axis=-1), order
