"""Jit'd public wrapper for the fused HSF kernel.

Handles padding to the block size, backend dispatch (interpret mode on
CPU hosts — the kernel body itself is what we validate), and restoring
the caller's document count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hsf_score.hsf_score import hsf_score_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def hsf_score(
    doc_vecs,
    doc_sigs,
    query_vec,
    query_sig,
    *,
    alpha: float = 1.0,
    beta: float = 1.0,
    block_docs: int = 512,
    interpret: bool | None = None,
):
    """Fused HSF scores, float32 [N].

    Padding docs score α·0 + β·(empty-sig containment); they are sliced
    off before returning so callers never see them.
    """
    if interpret is None:
        interpret = _default_interpret()
    n = doc_vecs.shape[0]
    block = min(block_docs, max(8, 1 << (n - 1).bit_length())) if n else block_docs
    pad = (-n) % block
    if pad:
        doc_vecs = jnp.concatenate(
            [doc_vecs, jnp.zeros((pad, doc_vecs.shape[1]), doc_vecs.dtype)]
        )
        doc_sigs = jnp.concatenate(
            [doc_sigs, jnp.zeros((pad, doc_sigs.shape[1]), doc_sigs.dtype)]
        )
    scores = hsf_score_pallas(
        doc_vecs,
        doc_sigs,
        query_vec,
        query_sig,
        alpha=alpha,
        beta=beta,
        block_docs=block,
        interpret=interpret,
    )
    return scores[:n]
