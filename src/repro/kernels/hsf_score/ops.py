"""Jit'd public wrappers for the fused HSF kernels.

Handle padding to the block size (and, for the batched kernel, to the
sublane-aligned query-batch size), backend dispatch (interpret mode on
CPU hosts — the kernel body itself is what we validate), and restoring
the caller's document/query counts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hsf_score.hsf_score import (
    ID_SENTINEL,
    KPAD,
    hsf_score_pallas,
    hsf_score_topk_pallas,
)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_block(n: int, block_docs: int) -> int:
    """Shrink the doc block for small corpora (min sublane tile is 8)."""
    return min(block_docs, max(8, 1 << (n - 1).bit_length()))


def _pad_rows(arr, pad: int):
    """Append ``pad`` zero rows (no-op for pad == 0)."""
    if not pad:
        return arr
    return jnp.concatenate(
        [arr, jnp.zeros((pad, arr.shape[1]), arr.dtype)]
    )


def pad_docs_for_kernel(doc_vecs, doc_sigs, block_docs: int = 512):
    """Block-align doc operands ahead of time (zero rows appended).

    `hsf_score_batched` pads per call when N is ragged — inside a jitted
    serving loop that is an O(N·D) copy per dispatch.  Callers that own
    the doc arrays (the QueryEngine) align them once per refresh with
    this helper, making the wrapper's pad a no-op; the appended rows
    must then be masked by passing the true doc count as ``n_valid``.
    Returns the inputs unchanged when already aligned.
    """
    n = doc_vecs.shape[0]
    if n == 0:
        return doc_vecs, doc_sigs
    pad = (-n) % _pick_block(n, block_docs)
    return _pad_rows(doc_vecs, pad), _pad_rows(doc_sigs, pad)


def hsf_score(
    doc_vecs,
    doc_sigs,
    query_vec,
    query_sig,
    *,
    alpha: float = 1.0,
    beta: float = 1.0,
    block_docs: int = 512,
    interpret: bool | None = None,
):
    """Fused HSF scores, float32 [N].

    Padding docs score α·0 + β·(empty-sig containment); they are sliced
    off before returning so callers never see them.  An empty corpus
    returns an empty [0] vector without launching a kernel (a zero-size
    grid is not a valid pallas_call).
    """
    if interpret is None:
        interpret = _default_interpret()
    n = doc_vecs.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.float32)
    block = _pick_block(n, block_docs)
    pad = (-n) % block
    doc_vecs = _pad_rows(doc_vecs, pad)
    doc_sigs = _pad_rows(doc_sigs, pad)
    scores = hsf_score_pallas(
        doc_vecs,
        doc_sigs,
        query_vec,
        query_sig,
        alpha=alpha,
        beta=beta,
        block_docs=block,
        interpret=interpret,
    )
    return scores[:n]


def hsf_score_batched(
    doc_vecs,   # [N, D]
    doc_sigs,   # [N, W] int32
    query_vecs,  # [B, D]
    query_sigs,  # [B, W] int32
    *,
    k: int,
    alpha: float = 1.0,
    beta: float = 1.0,
    n_valid=None,
    block_docs: int = 512,
    interpret: bool | None = None,
):
    """Fused batched HSF + in-kernel top-k: (vals [B, k'], ids [B, k']),
    k' = min(k, N), ordered by (score desc, doc-id asc) exactly as
    `retrieval._stable_top_k`.

    The [B, N] score matrix never exists — each grid step folds one doc
    block into a [B, k] VMEM carry.  ``n_valid`` (default N) masks a
    suffix of the corpus to -inf; mesh-sharded callers pass their
    per-shard valid count (a traced scalar is fine — it rides in SMEM).
    Rows that cannot fill (k' > n_valid) carry -inf scores with sentinel
    ids (2³¹−1).
    """
    if interpret is None:
        interpret = _default_interpret()
    n = doc_vecs.shape[0]
    b = query_vecs.shape[0]
    k_eff = min(k, n)
    if n == 0 or b == 0 or k_eff <= 0:
        return (jnp.zeros((b, max(k_eff, 0)), jnp.float32),
                jnp.zeros((b, max(k_eff, 0)), jnp.int32))

    if n_valid is None:
        n_valid = n
    n_valid = jnp.asarray(n_valid, jnp.int32).reshape(1)

    if k_eff > KPAD:
        # beyond the kernel's VMEM carry width: delegate to the unfused
        # oracle (same (score desc, id asc) contract) so callers never
        # have to special-case large k; unfillable rows get the same
        # sentinel ids the kernel emits
        from repro.kernels.hsf_score.ref import hsf_score_topk_ref

        vals, ids = hsf_score_topk_ref(
            doc_vecs, doc_sigs, query_vecs, query_sigs, alpha, beta,
            k_eff, n_valid=n_valid[0],
        )
        return vals, jnp.where(jnp.isneginf(vals),
                               jnp.int32(ID_SENTINEL), ids)

    block = _pick_block(n, block_docs)
    pad_n = (-n) % block
    doc_vecs = _pad_rows(doc_vecs, pad_n)
    doc_sigs = _pad_rows(doc_sigs, pad_n)
    pad_b = (-b) % 8  # f32 sublane tile
    query_vecs = _pad_rows(query_vecs, pad_b)
    query_sigs = _pad_rows(query_sigs, pad_b)
    vals, ids = hsf_score_topk_pallas(
        doc_vecs,
        doc_sigs,
        query_vecs,
        query_sigs,
        n_valid,
        k=k_eff,
        alpha=alpha,
        beta=beta,
        block_docs=block,
        interpret=interpret,
    )
    return vals[:b], ids[:b]
