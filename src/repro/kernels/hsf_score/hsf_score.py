"""Fused HSF scoring kernels (Pallas TPU): single-query scoring and the
batched multi-query variant with in-kernel top-k.

Single-query (`hsf_score_pallas`) — one grid step scores a
(block_docs × D) tile of the document matrix against a resident query:

    VMEM working set per step:
        docs tile   block_docs × D      (bf16/f32)   — MXU operand
        sigs tile   block_docs × W      (int32)      — VPU operand
        query       1 × D               (f32)
        query sig   1 × W               (int32)
        out tile    block_docs          (f32)

    compute: cos  = docs @ qᵀ                     (MXU, D-contraction)
             ind  = all((sigs & qsig) == qsig)    (VPU, bitwise+reduce)
             out  = α·cos + β·ind                 (fused epilogue)

Tiling constraints: D and W are multiples of 128 (lane alignment);
block_docs a multiple of 8 (sublane).  Default block_docs=512, D=4096,
W=128 → docs tile 4 MB (bf16) / 8 MB (f32), well inside a 16 MB VMEM
with double buffering headroom at bf16.

The fusion is the point: the unfused path reads the doc matrix for the
matmul and the signature matrix for the boost in two HBM passes and
materializes an [N] cosine intermediate; fused, every byte of ⟨V⟩ and ⟨I⟩
regions is read exactly once and the boost costs zero extra bandwidth.

Batched multi-query (`hsf_score_topk_pallas`) — the serving-plane hot
loop.  The whole query batch is VMEM-resident; one grid step consumes a
(block_docs × D) doc tile and a (block_docs × W) signature tile:

    cos    = q_batch @ docsᵀ                      (MXU, [B,D]×[block,D])
    ind    = containment, streamed word-by-word   (VPU, no [B,block,W]
             over the W signature words            intermediate)
    scores = α·cos + β·ind, padding masked to -inf
    top-k  = k-pass argmax merge of (carry ‖ block scores) into a
             [B, KPAD] running candidate set in VMEM scratch

The carry makes the kernel single-pass over HBM *and* keeps the full
[B, N] score matrix from ever existing: only [B, k] survives each step.
Tie-breaking is (score desc, doc-id asc), bit-identical to
`retrieval._stable_top_k`: carried ids are always smaller than the
current block's ids and both candidate lists are kept sorted, so
argmax's first-match semantics implement the lexicographic rule for
free.  Rows that never fill (k > n_valid) surface ID_SENTINEL with a
-inf score.  A scalar ``n_valid`` rides in SMEM so mesh-sharded callers
can mask their local padding range without a second kernel variant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _hsf_kernel(q_ref, qsig_ref, docs_ref, sigs_ref, out_ref, *, alpha, beta):
    docs = docs_ref[...]
    q = q_ref[...]  # [1, D]
    # MXU: [B, D] x [D, 1] -> [B, 1]; accumulate in f32 regardless of
    # operand dtype.
    cos = jax.lax.dot_general(
        docs,
        q,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [B, 1]
    qs = qsig_ref[...]  # [1, W] int32
    hits = (sigs_ref[...] & qs) == qs  # [B, W] bool
    ind = jnp.all(hits, axis=-1, keepdims=True).astype(jnp.float32)  # [B, 1]
    out_ref[...] = alpha * cos + beta * ind


@functools.partial(
    jax.jit, static_argnames=("alpha", "beta", "block_docs", "interpret")
)
def hsf_score_pallas(
    doc_vecs: jnp.ndarray,  # [N, D], N % block_docs == 0
    doc_sigs: jnp.ndarray,  # [N, W] int32
    query_vec: jnp.ndarray,  # [D]
    query_sig: jnp.ndarray,  # [W] int32
    *,
    alpha: float,
    beta: float,
    block_docs: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    n, d = doc_vecs.shape
    w = doc_sigs.shape[1]
    assert n % block_docs == 0, (n, block_docs)
    grid = (n // block_docs,)

    kernel = functools.partial(_hsf_kernel, alpha=alpha, beta=beta)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),  # query resident
            pl.BlockSpec((1, w), lambda i: (0, 0)),  # query sig resident
            pl.BlockSpec((block_docs, d), lambda i: (i, 0)),
            pl.BlockSpec((block_docs, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_docs, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="hsf_score",
    )(
        query_vec.reshape(1, d),
        query_sig.reshape(1, w),
        doc_vecs,
        doc_sigs,
    )[:, 0]


# ---------------------------------------------------------------------------
# batched multi-query HSF + in-kernel top-k
# ---------------------------------------------------------------------------

NEG_INF = -jnp.inf
KPAD = 128  # scratch lane width (same carry layout as kernels/topk)
ID_SENTINEL = 2**31 - 1  # id of never-filled carry slots


def _hsf_topk_kernel(nvalid_ref, q_ref, qsig_ref, docs_ref, sigs_ref,
                     vals_ref, ids_ref, vscr, iscr,
                     *, k, alpha, beta, block, nblocks):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        vscr[...] = jnp.full_like(vscr, NEG_INF)
        iscr[...] = jnp.full_like(iscr, jnp.int32(ID_SENTINEL))

    docs = docs_ref[...]  # [block, D]
    q = q_ref[...]        # [B, D]
    # MXU: [B, D] × [block, D] with D-contraction → [B, block], f32 acc.
    cos = jax.lax.dot_general(
        q, docs, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # VPU: containment streamed over signature words.  The naive
    # broadcast materializes [B, block, W] (int32 — megabytes of VMEM at
    # serving batch sizes); folding word-by-word keeps the working set
    # at one [B, block] boolean.
    qs = qsig_ref[...]    # [B, W] int32
    sg = sigs_ref[...]    # [block, W] int32
    b = q.shape[0]

    def w_body(wi, ok):
        qw = jax.lax.dynamic_slice(qs, (0, wi), (b, 1))      # [B, 1]
        sw = jax.lax.dynamic_slice(sg, (0, wi), (block, 1))  # [block, 1]
        return ok & ((sw.reshape(1, block) & qw) == qw)

    ok = jax.lax.fori_loop(0, qs.shape[1], w_body,
                           jnp.full((b, block), True))
    scores = alpha * cos + beta * ok.astype(jnp.float32)

    # mask docs past n_valid (ragged-N padding, sharded-suffix padding)
    lids = i * block + jax.lax.broadcasted_iota(jnp.int32, (b, block), 1)
    scores = jnp.where(lids < nvalid_ref[0], scores, NEG_INF)

    # merge carry ‖ block with k argmax passes.  First-match argmax is
    # the (score desc, id asc) rule: the carry is sorted and holds only
    # ids from earlier blocks (strictly smaller than any lid here), and
    # within the block ids ascend with lane position.
    cand_v = jnp.concatenate([vscr[...], scores], axis=1)  # [B, KPAD+block]
    cand_i = jnp.concatenate([iscr[...], lids], axis=1)
    new_v, new_i = [], []
    for _ in range(k):  # k static — unrolled VPU reduction chain
        a = jnp.argmax(cand_v, axis=1)  # [B]
        new_v.append(jnp.take_along_axis(cand_v, a[:, None], axis=1))
        new_i.append(jnp.take_along_axis(cand_i, a[:, None], axis=1))
        knocked = (
            jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, 1)
            == a[:, None]
        )
        cand_v = jnp.where(knocked, NEG_INF, cand_v)
        # clear the id too: once every candidate is -inf (k > n_valid),
        # argmax re-picks slot 0 — without this, that slot still holds
        # an already-emitted doc id and unfillable rows would surface
        # duplicate real ids instead of the documented sentinel
        cand_i = jnp.where(knocked, jnp.int32(ID_SENTINEL), cand_i)
    pad = KPAD - k
    vscr[...] = jnp.concatenate(
        new_v + [jnp.full((b, pad), NEG_INF, vscr.dtype)], axis=1)
    iscr[...] = jnp.concatenate(
        new_i + [jnp.full((b, pad), jnp.int32(ID_SENTINEL))], axis=1)

    @pl.when(i == nblocks - 1)
    def _final():
        vals_ref[...] = vscr[...]
        ids_ref[...] = iscr[...]


@functools.partial(
    jax.jit,
    static_argnames=("k", "alpha", "beta", "block_docs", "interpret"),
)
def hsf_score_topk_pallas(
    doc_vecs: jnp.ndarray,   # [N, D], N % block_docs == 0
    doc_sigs: jnp.ndarray,   # [N, W] int32
    query_vecs: jnp.ndarray,  # [B, D], B % 8 == 0
    query_sigs: jnp.ndarray,  # [B, W] int32
    n_valid: jnp.ndarray,    # [1] int32 — docs beyond score -inf
    *,
    k: int,
    alpha: float,
    beta: float,
    block_docs: int = 512,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused batched HSF + top-k: (vals [B, k] f32, ids [B, k] i32)."""
    n, d = doc_vecs.shape
    b, w = query_sigs.shape
    assert n % block_docs == 0, (n, block_docs)
    assert 0 < k <= KPAD, k
    nblocks = n // block_docs

    kernel = functools.partial(
        _hsf_topk_kernel, k=k, alpha=alpha, beta=beta,
        block=block_docs, nblocks=nblocks,
    )
    vals, ids = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # n_valid scalar
            pl.BlockSpec((b, d), lambda i: (0, 0)),      # queries resident
            pl.BlockSpec((b, w), lambda i: (0, 0)),      # query sigs
            pl.BlockSpec((block_docs, d), lambda i: (i, 0)),
            pl.BlockSpec((block_docs, w), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, KPAD), lambda i: (0, 0)),
            pl.BlockSpec((b, KPAD), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, KPAD), jnp.float32),
            jax.ShapeDtypeStruct((b, KPAD), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((b, KPAD), jnp.float32),
            pltpu.VMEM((b, KPAD), jnp.int32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="hsf_topk_batched",
    )(n_valid, query_vecs, query_sigs, doc_vecs, doc_sigs)
    return vals[:, :k], ids[:, :k]
