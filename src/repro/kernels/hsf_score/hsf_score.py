"""Fused HSF scoring kernel (Pallas TPU).

One grid step scores a (block_docs × D) tile of the document matrix
against a resident query:

    VMEM working set per step:
        docs tile   block_docs × D      (bf16/f32)   — MXU operand
        sigs tile   block_docs × W      (int32)      — VPU operand
        query       1 × D               (f32)
        query sig   1 × W               (int32)
        out tile    block_docs          (f32)

    compute: cos  = docs @ qᵀ                     (MXU, D-contraction)
             ind  = all((sigs & qsig) == qsig)    (VPU, bitwise+reduce)
             out  = α·cos + β·ind                 (fused epilogue)

Tiling constraints: D and W are multiples of 128 (lane alignment);
block_docs a multiple of 8 (sublane).  Default block_docs=512, D=4096,
W=128 → docs tile 4 MB (bf16) / 8 MB (f32), well inside a 16 MB VMEM
with double buffering headroom at bf16.

The fusion is the point: the unfused path reads the doc matrix for the
matmul and the signature matrix for the boost in two HBM passes and
materializes an [N] cosine intermediate; fused, every byte of ⟨V⟩ and ⟨I⟩
regions is read exactly once and the boost costs zero extra bandwidth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _hsf_kernel(q_ref, qsig_ref, docs_ref, sigs_ref, out_ref, *, alpha, beta):
    docs = docs_ref[...]
    q = q_ref[...]  # [1, D]
    # MXU: [B, D] x [D, 1] -> [B, 1]; accumulate in f32 regardless of
    # operand dtype.
    cos = jax.lax.dot_general(
        docs,
        q,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [B, 1]
    qs = qsig_ref[...]  # [1, W] int32
    hits = (sigs_ref[...] & qs) == qs  # [B, W] bool
    ind = jnp.all(hits, axis=-1, keepdims=True).astype(jnp.float32)  # [B, 1]
    out_ref[...] = alpha * cos + beta * ind


@functools.partial(
    jax.jit, static_argnames=("alpha", "beta", "block_docs", "interpret")
)
def hsf_score_pallas(
    doc_vecs: jnp.ndarray,  # [N, D], N % block_docs == 0
    doc_sigs: jnp.ndarray,  # [N, W] int32
    query_vec: jnp.ndarray,  # [D]
    query_sig: jnp.ndarray,  # [W] int32
    *,
    alpha: float,
    beta: float,
    block_docs: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    n, d = doc_vecs.shape
    w = doc_sigs.shape[1]
    assert n % block_docs == 0, (n, block_docs)
    grid = (n // block_docs,)

    kernel = functools.partial(_hsf_kernel, alpha=alpha, beta=beta)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),  # query resident
            pl.BlockSpec((1, w), lambda i: (0, 0)),  # query sig resident
            pl.BlockSpec((block_docs, d), lambda i: (i, 0)),
            pl.BlockSpec((block_docs, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_docs, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="hsf_score",
    )(
        query_vec.reshape(1, d),
        query_sig.reshape(1, w),
        doc_vecs,
        doc_sigs,
    )[:, 0]
