from repro.kernels.hsf_score.ops import hsf_score  # noqa: F401
