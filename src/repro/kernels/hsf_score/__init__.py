from repro.kernels.hsf_score.ops import (  # noqa: F401
    hsf_score,
    hsf_score_batched,
)
