"""TenantRouter: per-request tenant id → mounted engine stack.

The router is the thin policy layer between the scheduler and the
``ContainerPool``: it admits (or rejects) the request against the
tenant's token-bucket quota, resolves the tenant to a *pinned* mount
for the duration of a flush or writer session, and exposes the writer
entry points (``writer()`` / ``publish()``) so drivers never touch the
pool's pin protocol by hand.

Admission happens *before* pinning: a quota-rejected request never
mounts a cold container, so an abusive tenant cannot use rejected
traffic to thrash the pool's LRU.
"""
from __future__ import annotations

import contextlib

from repro.tenancy.pool import ContainerPool, MountedTenant, validate_tenant
from repro.tenancy.quota import TenantQuotas

# the tenant the single-tenant serving path maps onto (== the result
# cache's DEFAULT_KEYSPACE, so cache semantics line up across modes)
DEFAULT_TENANT = "default"


class TenantRouter:
    """Quota gate + pin-scoped tenant resolution over a ContainerPool."""

    def __init__(self, pool: ContainerPool,
                 quotas: TenantQuotas | None = None):
        self.pool = pool
        self.quotas = quotas

    # ---- admission (scheduler submit path) -------------------------------

    def admit(self, tenant: str) -> bool:
        """Spend one quota token; True = admitted.  Unlimited when no
        quota table (or no bucket for this tenant) is configured."""
        if self.quotas is None:
            return True
        return self.quotas.try_acquire(tenant)

    def peek_generation(self, tenant: str) -> int | None:
        """Resident tenant's generation without mounting (cache probe);
        None when the tenant is cold."""
        return self.pool.peek_generation(tenant)

    # ---- pin protocol (scheduler flush path) -----------------------------

    def pin(self, tenant: str) -> MountedTenant:
        return self.pool.pin(tenant)

    def unpin(self, tenant: str) -> None:
        self.pool.unpin(tenant)

    # ---- writer plane ----------------------------------------------------

    @contextlib.contextmanager
    def writer(self, tenant: str):
        """Pin tenant for a writer session and yield the mount; the
        caller mutates ``mt.kb`` (single-writer contract) and then
        publishes.  The pin keeps eviction structurally impossible
        while the session holds references into the live stack."""
        mt = self.pool.pin(tenant)
        try:
            yield mt
        finally:
            self.pool.unpin(tenant)

    def publish(self, tenant: str, durable: bool = False) -> int:
        """Refresh + publish tenant's next generation (writer thread
        only); returns the published generation."""
        with self.writer(tenant) as mt:
            return mt.snapshots.publish(durable=durable).generation

    # ---- convenience -----------------------------------------------------

    def tenants(self) -> list[str]:
        return self.pool.resident_tenants()

    @staticmethod
    def validate(tenant: str) -> str:
        return validate_tenant(tenant)
