"""The tenancy plane (docs/ARCHITECTURE.md §13): many single-file
knowledge containers multiplexed through one serving runtime.

- ``ContainerPool`` (pool.py): lazy mounts, refcount pins, LRU
  eviction under a resident-tenant/byte budget with
  durability-before-teardown.
- ``TenantRouter`` (router.py): tenant id → pinned mount, plus the
  writer/publish entry points and quota admission.
- ``TokenBucket`` / ``TenantQuotas`` (quota.py): per-tenant admission
  control → ``RequestRejected(tenant)`` backpressure.

Single-tenant code never touches this package: ``ServingRuntime(kb)``
keeps the classic one-container path bit-identical, and
``DEFAULT_TENANT`` is the keyspace that path's cache entries live in.
"""
from repro.tenancy.pool import ContainerPool, MountedTenant, validate_tenant
from repro.tenancy.quota import TenantQuotas, TokenBucket
from repro.tenancy.router import DEFAULT_TENANT, TenantRouter

__all__ = [
    "ContainerPool",
    "DEFAULT_TENANT",
    "MountedTenant",
    "TenantQuotas",
    "TenantRouter",
    "TokenBucket",
    "validate_tenant",
]
