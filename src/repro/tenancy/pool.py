"""ContainerPool: lazily mounted, LRU-evicted per-tenant engine stacks.

The paper's single-file knowledge container implies *many* containers
in production — one per user/workspace — on hosts that cannot keep
them all resident (EdgeRAG, arXiv 2412.21023: lazy-load what the
request needs, evict what it doesn't).  The pool is that discipline
for this stack:

- **Lazy mount.**  The first request for tenant *t* opens
  ``<root>/<t>.ragdb`` (cheap: the PR 4 delta-journal load replays
  base + journal, O(container)) — or creates a fresh empty KB when the
  container does not exist yet — and wraps it in the standard
  ``SnapshotManager`` stack.  Subsequent requests reuse the mount.

- **Refcount pins.**  Every consumer (a scheduler flush serving the
  tenant, a writer session mutating it) holds a *pin* on the mount for
  the duration.  Pins are the teardown barrier: eviction of a mount
  with ``pins > 0`` is structurally refused, so an in-flight flush can
  never have its snapshot stack torn down underneath it.  The
  ``tenant-pin`` analysis rule (R6) enforces the discipline
  statically: ``_resident`` is mutated only inside the pool under its
  guard, and every evict path carries the ``pins == 0`` check.

- **LRU eviction under budget.**  ``max_resident`` (mount count) and
  ``max_resident_bytes`` (estimated device-array footprint) bound the
  pool; crossing either evicts cold tenants in LRU order, skipping
  pinned mounts.  **Eviction durably publishes first**: any state the
  persistence chain does not yet hold (``kb.unpersisted_changes``) is
  flushed through ``SnapshotManager.publish(durable=True)`` — the
  journal append + fsync + manifest rename protocol — *before* the
  mount is dropped, so eviction can never lose a generation a reader
  has seen (crash matrix: tests/test_persistence.py).  The durable
  publish itself runs under the KB's single-writer lock (save_delta
  takes it), which is the second half of the R6 contract.

Locking: one pool-wide guard (``_pool_guard``) covers the resident map
and all pin/evict transitions; it is held across a mount (cold-start
latency is charged to the requesting tenant, by design) but never
across query scoring — flushes hold only the *pin*, not the lock.
"""
from __future__ import annotations

import contextlib
import os
import re
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field

from repro.core.ingest import KnowledgeBase
from repro.obs import ledger as ledger_mod, trace as obs_trace
from repro.obs.ledger import ResourceLedger
from repro.obs.metrics import MetricsRegistry, global_registry

from repro.serving.snapshot import SnapshotManager

_TENANT_RE = re.compile(r"[A-Za-z0-9][A-Za-z0-9._-]{0,63}")


def validate_tenant(tenant: str) -> str:
    """Tenant ids name container files — keep them filesystem-safe."""
    if not isinstance(tenant, str) or not _TENANT_RE.fullmatch(tenant):
        raise ValueError(
            f"invalid tenant id {tenant!r}: want [A-Za-z0-9][A-Za-z0-9._-]*"
            " (max 64 chars)"
        )
    return tenant


@dataclass
class MountedTenant:
    """One resident tenant stack: KB + snapshot manager + pin count."""

    tenant: str
    path: str
    kb: KnowledgeBase
    snapshots: SnapshotManager
    pins: int = 0
    mounted_at: float = field(default_factory=time.perf_counter)
    last_used: float = field(default_factory=time.perf_counter)
    ledger: ResourceLedger | None = None

    @property
    def generation(self) -> int:
        return self.snapshots.generation

    @property
    def resident_bytes(self) -> int:
        """Device footprint per the resource ledger (doc matrix + IVF
        state + kernel operands, re-measured at mount and every
        publish) — the *same* accounting ``ServingRuntime.resources()``
        reports, so budget decisions and reported occupancy can never
        diverge.  Falls back to a raw array-nbytes estimate when no
        ledger is attached (standalone SnapshotManager in tests)."""
        if self.ledger is not None:
            return self.ledger.tenant_bytes(
                self.tenant, planes=ledger_mod.DEVICE_PLANES)
        eng = self.snapshots.engine
        total = 0
        for arr in (getattr(eng, "doc_vecs", None),
                    getattr(eng, "doc_sigs", None)):
            total += int(getattr(arr, "nbytes", 0) or 0)
        return total


class ContainerPool:
    """See module docstring.  Thread-safe; all mutation of the resident
    map happens under ``_pool_guard`` inside this class (R6)."""

    def __init__(
        self,
        root: str,
        *,
        max_resident: int = 8,
        max_resident_bytes: int | None = None,
        kb_kwargs: dict | None = None,
        compact_ratio: float | None = KnowledgeBase.DEFAULT_COMPACT_RATIO,
        registry: MetricsRegistry | None = None,
        **engine_kwargs,
    ):
        if max_resident < 1:
            raise ValueError("max_resident must be >= 1")
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        self.max_resident = max_resident
        self.max_resident_bytes = max_resident_bytes
        self.kb_kwargs = dict(kb_kwargs or {})
        self.compact_ratio = compact_ratio
        self.engine_kwargs = engine_kwargs
        # unmount hook (set by ServingRuntime): drops the tenant's
        # result-cache keyspace when its stack leaves memory
        self.on_evict = None
        self._registry = registry if registry is not None else global_registry()
        # the resource ledger (obs/ledger.py): every mount's
        # SnapshotManager measures its planes into it at mount/publish,
        # and budget eviction consumes its device-plane bytes
        self.ledger = ResourceLedger(registry=self._registry)
        self._lock = threading.RLock()
        # LRU order: oldest-used first; values are MountedTenant
        self._resident: OrderedDict[str, MountedTenant] = OrderedDict()
        self._mount_hist = self._registry.histogram(
            "ragdb_tenant_mount_seconds",
            "container mount latency (load + snapshot capture)")
        self._evict_hist = self._registry.histogram(
            "ragdb_tenant_evict_seconds",
            "eviction latency (durable publish + unmount)")
        self._resident_gauge = self._registry.gauge(
            "ragdb_tenant_resident", "mounted tenant stacks")
        self._resident_bytes_gauge = self._registry.gauge(
            "ragdb_tenant_resident_bytes",
            "estimated device bytes across resident tenants")

    # ---- the pool guard --------------------------------------------------

    @contextlib.contextmanager
    def _pool_guard(self, op: str):
        """All ``_resident`` transitions (mount/pin/unpin/evict) run
        under this one lock; scoring never does (flushes hold pins)."""
        with self._lock:
            yield

    # ---- paths -----------------------------------------------------------

    def container_path(self, tenant: str) -> str:
        return os.path.join(self.root, f"{validate_tenant(tenant)}.ragdb")

    # ---- pin / unpin (the only public mount entry points) ----------------

    def pin(self, tenant: str) -> MountedTenant:
        """Mount (if cold) and pin tenant's stack; the caller must
        ``unpin`` when done.  Pinning bumps LRU recency and may evict
        *other* cold tenants to stay under budget."""
        tenant = validate_tenant(tenant)
        with self._pool_guard("pin"):
            mt = self._resident.get(tenant)
            if mt is None:
                mt = self._mount_locked(tenant)
            mt.pins += 1
            mt.last_used = time.perf_counter()
            self._resident.move_to_end(tenant)  # MRU
            self._evict_over_budget_locked()
            return mt

    def unpin(self, tenant: str) -> None:
        with self._pool_guard("unpin"):
            mt = self._resident.get(tenant)
            if mt is None or mt.pins <= 0:
                raise RuntimeError(
                    f"unpin({tenant!r}) without a matching pin")
            mt.pins -= 1

    @contextlib.contextmanager
    def pinned(self, tenant: str):
        """``with pool.pinned(t) as mt:`` — pin for the block."""
        mt = self.pin(tenant)
        try:
            yield mt
        finally:
            self.unpin(tenant)

    # ---- mounting --------------------------------------------------------

    def _mount_locked(self, tenant: str) -> MountedTenant:
        path = self.container_path(tenant)
        t0 = time.perf_counter()
        with obs_trace.span("tenant_mount", tenant=tenant):
            if os.path.exists(path):
                kb = KnowledgeBase.load(path)
            else:
                kb = KnowledgeBase(**self.kb_kwargs)
            snaps = SnapshotManager(
                kb, container_path=path, compact_ratio=self.compact_ratio,
                tenant=tenant, ledger=self.ledger, **self.engine_kwargs,
            )
        mt = MountedTenant(tenant=tenant, path=path, kb=kb,
                           snapshots=snaps, ledger=self.ledger)
        self._resident[tenant] = mt
        dt = time.perf_counter() - t0
        self._mount_hist.record(dt)
        self._registry.counter(
            "ragdb_tenant_mounts_total", "container mounts",
            tenant=tenant).inc()
        self._update_gauges_locked()
        return mt

    # ---- eviction --------------------------------------------------------

    def evict(self, tenant: str) -> None:
        """Explicitly unmount one tenant (tests/operators).  Refuses
        while pinned — eviction may never tear a pinned stack."""
        with self._pool_guard("evict"):
            mt = self._resident.get(tenant)
            if mt is None:
                return
            if mt.pins > 0:
                raise RuntimeError(
                    f"evict({tenant!r}) refused: {mt.pins} pins held "
                    "(in-flight flush or writer session)")
            self._evict_locked(mt)

    def evict_over_budget(self) -> None:
        with self._pool_guard("evict_over_budget"):
            self._evict_over_budget_locked()

    def _evict_over_budget_locked(self) -> None:
        while self._over_budget_locked():
            victim = None
            for mt in self._resident.values():  # LRU order, oldest first
                if mt.pins == 0:
                    victim = mt
                    break
            if victim is None:
                return  # everything pinned: budget temporarily exceeded
            self._evict_locked(victim)

    def _over_budget_locked(self) -> bool:
        if len(self._resident) > self.max_resident:
            return True
        return (self.max_resident_bytes is not None
                and self.resident_bytes() > self.max_resident_bytes)

    def _evict_locked(self, mt: MountedTenant) -> None:
        # the teardown barrier: a pinned mount is serving an in-flight
        # flush (or writer session) right now — structurally unevictable
        assert mt.pins == 0, f"evicting pinned tenant {mt.tenant!r}"
        t0 = time.perf_counter()
        with obs_trace.span("tenant_evict", tenant=mt.tenant,
                            generation=mt.generation):
            if mt.kb.unpersisted_changes:
                # durability-before-teardown: publish every pending
                # generation through the journal protocol (fsync +
                # manifest rename) so the unmount can never lose state
                # a reader has seen.  save_delta takes the KB's
                # single-writer lock — pins==0 means no writer session
                # can be mid-mutation, so this never contends.
                mt.snapshots.publish(durable=True)
            self._resident.pop(mt.tenant)
        dt = time.perf_counter() - t0
        self._evict_hist.record(dt)
        # aggregate (unlabeled) eviction counter: a per-tenant labeled
        # series would be pruned right below, and under zipf churn it
        # would grow label cardinality without bound anyway
        self._registry.counter(
            "ragdb_tenant_evictions_total", "container evictions").inc()
        # series hygiene: the evicted tenant's accounting leaves memory
        # with its stack — the ledger drops its resident-bytes series,
        # and every other tenant-labeled series (mounts, publish lag)
        # is pruned from both the pool registry and the global one so
        # gauges can never go stale across an evict/remount cycle
        self.ledger.drop_tenant(mt.tenant)
        self._registry.prune(tenant=mt.tenant)
        if self._registry is not global_registry():
            global_registry().prune(tenant=mt.tenant)
        self._update_gauges_locked()
        if self.on_evict is not None:
            self.on_evict(mt.tenant)

    # ---- introspection ---------------------------------------------------

    def resident_tenants(self) -> list[str]:
        with self._pool_guard("resident_tenants"):
            return list(self._resident)

    def is_resident(self, tenant: str) -> bool:
        with self._pool_guard("is_resident"):
            return tenant in self._resident

    def peek_generation(self, tenant: str) -> int | None:
        """Resident tenant's published generation without mounting or
        pinning (None when cold) — the scheduler's cache-probe hook."""
        with self._pool_guard("peek_generation"):
            mt = self._resident.get(tenant)
            return None if mt is None else mt.generation

    def resident_bytes(self) -> int:
        return sum(mt.resident_bytes for mt in self._resident.values())

    def _update_gauges_locked(self) -> None:
        self._resident_gauge.set(len(self._resident))
        self._resident_bytes_gauge.set(self.resident_bytes())

    def stats(self) -> dict:
        with self._pool_guard("stats"):
            return {
                "resident": len(self._resident),
                "max_resident": self.max_resident,
                "resident_bytes": self.resident_bytes(),
                "max_resident_bytes": self.max_resident_bytes,
                "pinned": sum(1 for m in self._resident.values()
                              if m.pins > 0),
                "tenants": list(self._resident),
            }

    def drain(self) -> None:
        """Evict every unpinned tenant (shutdown hook): durably publish
        pending state and empty the pool."""
        with self._pool_guard("drain"):
            for mt in [m for m in self._resident.values() if m.pins == 0]:
                self._evict_locked(mt)
