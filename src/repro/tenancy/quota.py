"""Per-tenant admission quotas: token buckets at the serving front door.

The multiplexed pool has one flusher thread and one device; without
admission control a single hot tenant fills the shared queue and every
other tenant's tail latency follows it (the trade-off Shen et al.,
arXiv 2412.11854, measure for multiplexed RAG serving).  The remedy is
the classic token bucket: tenant *t* accrues ``rate`` tokens/second up
to a ``burst`` cap, each admitted request spends one token, and an
empty bucket turns into ``RequestRejected(tenant=t)`` at ``submit()``
— explicit per-tenant backpressure *before* the request touches the
shared queue, so an overloaded tenant is clipped at its own quota and
the pool's capacity stays available to everyone else.

Refill is computed lazily from a monotonic clock on each acquire (no
timer thread); ``now`` is injectable for deterministic tests.  A
``TenantQuotas`` table maps tenant ids to buckets, with an optional
default applied to tenants that have no explicit entry (``None``
default = unlimited, the single-tenant behavior).
"""
from __future__ import annotations

import threading
import time


class TokenBucket:
    """One tenant's admission budget: ``rate`` tokens/s, ``burst`` cap."""

    def __init__(self, rate: float, burst: float | None = None):
        if rate <= 0:
            raise ValueError(f"rate must be > 0, got {rate}")
        self.rate = float(rate)
        self.burst = float(burst if burst is not None else max(rate, 1.0))
        if self.burst < 1.0:
            raise ValueError(f"burst must be >= 1, got {self.burst}")
        self._tokens = self.burst  # start full: first requests admit
        self._t_last = None        # lazy: first acquire stamps the clock
        self._lock = threading.Lock()

    def try_acquire(self, now: float | None = None) -> bool:
        """Spend one token if available; never blocks."""
        if now is None:
            now = time.monotonic()
        with self._lock:
            if self._t_last is not None and now > self._t_last:
                self._tokens = min(
                    self.burst, self._tokens + (now - self._t_last) * self.rate
                )
            self._t_last = now
            if self._tokens >= 1.0:
                self._tokens -= 1.0
                return True
            return False

    @property
    def tokens(self) -> float:
        with self._lock:
            return self._tokens


class TenantQuotas:
    """Tenant id → TokenBucket, with an optional default for tenants
    not explicitly configured (``default_rate=None`` = unlimited)."""

    def __init__(self, default_rate: float | None = None,
                 default_burst: float | None = None):
        self.default_rate = default_rate
        self.default_burst = default_burst
        self._buckets: dict[str, TokenBucket] = {}
        self._lock = threading.Lock()

    def set(self, tenant: str, rate: float,
            burst: float | None = None) -> TokenBucket:
        """Install (or replace) tenant's bucket; returns it."""
        bucket = TokenBucket(rate, burst)
        with self._lock:
            self._buckets[tenant] = bucket
        return bucket

    def bucket(self, tenant: str) -> TokenBucket | None:
        """Tenant's bucket, lazily created from the default (None when
        neither an explicit bucket nor a default rate exists —
        unlimited admission)."""
        with self._lock:
            b = self._buckets.get(tenant)
            if b is None and self.default_rate is not None:
                b = TokenBucket(self.default_rate, self.default_burst)
                self._buckets[tenant] = b
            return b

    def try_acquire(self, tenant: str, now: float | None = None) -> bool:
        b = self.bucket(tenant)
        return True if b is None else b.try_acquire(now)
