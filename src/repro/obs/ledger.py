"""Resource ledger: resident bytes per (tenant, generation, plane).

One accounting surface for everything a serving process holds
resident, broken down by *plane*:

- ``doc_matrix``     device doc vectors + signature matrix
- ``ivf_state``      clustered-index arrays (centroids, bounds,
                     members, and the sharded resident blocks)
- ``kernel_operands`` block-aligned padded doc operands for the fused
                     kernel path
- ``result_cache``   per-generation result-cache entries (host)
- ``container``      the host-side KnowledgeBase (records, texts,
                     signatures) — an estimate, documented below
- ``journal_tail``   on-disk delta journal bytes (reported, but
                     excluded from *resident* sums — it is disk, not
                     memory)

The ledger is the **single source of truth for eviction**:
``ContainerPool`` budgets against ``tenant_bytes(..., DEVICE_PLANES)``
and ``ServingRuntime.resources()`` reports the same numbers, so budget
decisions and reported occupancy can never diverge.  Each ``update``
also sets ``ragdb_resident_bytes{tenant=,plane=}`` gauges in the bound
registry, and ``drop_tenant`` prunes them — bounded label cardinality
under tenant churn.

Byte numbers for device arrays are exact (``nbytes`` of the concrete
arrays); the host ``container`` plane is an estimate (text + record
overhead), clearly a lower bound, since Python object graphs have no
exact cheap size.  Pure stdlib + numpy-duck-typing: measurement
helpers import the heavier planes lazily so this module stays
importable from anywhere.
"""
from __future__ import annotations

import dataclasses
import threading

# planes that occupy accelerator/host *memory* for scoring — what the
# pool's resident budget constrains
DEVICE_PLANES = ("doc_matrix", "ivf_state", "kernel_operands")
# memory-resident planes (everything but the on-disk journal tail)
RESIDENT_PLANES = DEVICE_PLANES + ("result_cache", "container")
ALL_PLANES = RESIDENT_PLANES + ("journal_tail",)


class ResourceLedger:
    """Thread-safe (tenant → plane → bytes) accounting + gauges."""

    def __init__(self, registry=None):
        self._lock = threading.Lock()
        self._tenants: dict[str, dict] = {}
        self._registry = registry

    # ---- writes ---------------------------------------------------------

    def update(self, tenant: str, planes: dict, *,
               generation=None) -> None:
        """Replace ``tenant``'s accounting for the given planes (other
        planes it already has are kept — the result-cache plane is
        refreshed on a different cadence than the publish planes)."""
        with self._lock:
            ent = self._tenants.setdefault(
                tenant, {"generation": None, "planes": {}})
            if generation is not None:
                ent["generation"] = generation
            for plane, nbytes in planes.items():
                ent["planes"][plane] = int(nbytes)
        if self._registry is not None:
            for plane, nbytes in planes.items():
                self._registry.gauge(
                    "ragdb_resident_bytes",
                    "ledger-accounted resident bytes per plane",
                    tenant=tenant, plane=plane,
                ).set(int(nbytes))

    def set_plane(self, tenant: str, plane: str, nbytes: int) -> None:
        self.update(tenant, {plane: nbytes})

    def drop_tenant(self, tenant: str) -> None:
        """Forget a tenant (evict/unmount) and prune its gauge series."""
        with self._lock:
            self._tenants.pop(tenant, None)
        if self._registry is not None:
            self._registry.prune("ragdb_resident_bytes", tenant=tenant)

    # ---- reads ----------------------------------------------------------

    def tenant_bytes(self, tenant: str,
                     planes=RESIDENT_PLANES) -> int:
        with self._lock:
            ent = self._tenants.get(tenant)
            if ent is None:
                return 0
            return sum(ent["planes"].get(p, 0) for p in planes)

    def total_bytes(self, planes=RESIDENT_PLANES) -> int:
        with self._lock:
            return sum(
                sum(ent["planes"].get(p, 0) for p in planes)
                for ent in self._tenants.values()
            )

    def snapshot(self) -> dict:
        """Full accounting: {tenant: {generation, planes, resident_bytes,
        device_bytes}} plus totals — what ``ServingRuntime.resources()``
        returns."""
        with self._lock:
            tenants = {
                t: {
                    "generation": ent["generation"],
                    "planes": dict(ent["planes"]),
                    "resident_bytes": sum(
                        ent["planes"].get(p, 0) for p in RESIDENT_PLANES),
                    "device_bytes": sum(
                        ent["planes"].get(p, 0) for p in DEVICE_PLANES),
                }
                for t, ent in self._tenants.items()
            }
        return {
            "tenants": tenants,
            "resident_bytes": sum(
                e["resident_bytes"] for e in tenants.values()),
            "device_bytes": sum(
                e["device_bytes"] for e in tenants.values()),
        }


# --------------------------------------------------------------------------
# plane measurement (called at mount/publish — never on the query path)
# --------------------------------------------------------------------------

def _nbytes(obj) -> int:
    """Total ``nbytes`` of the array leaves hanging off ``obj``:
    arrays count directly; tuples/lists and (nested, one generation of)
    dataclasses are walked.  Non-array leaves count 0."""
    n = getattr(obj, "nbytes", None)
    if n is not None:
        return int(n)
    if isinstance(obj, (tuple, list)):
        return sum(_nbytes(x) for x in obj)
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return sum(
            _nbytes(getattr(obj, f.name))
            for f in dataclasses.fields(obj)
        )
    return 0


def measure_engine_planes(engine) -> dict:
    """Byte accounting of one engine's resident planes (exact for the
    device arrays, estimated for the host container)."""
    planes = {
        "doc_matrix": _nbytes(engine.doc_vecs) + _nbytes(engine.doc_sigs),
        "ivf_state": _nbytes(engine.ivf) if engine.ivf is not None else 0,
    }
    cache = getattr(engine, "_kernel_cache", None)
    planes["kernel_operands"] = (
        _nbytes(cache[2]) + _nbytes(cache[3]) if cache else 0)
    kb = engine.kb
    # host container estimate: per-doc signatures are exact; text +
    # per-record metadata (id, sha, term counts) approximated at
    # 256 B/record
    est = sum(_nbytes(s) for s in getattr(kb, "signatures", {}).values())
    est += sum(len(t) for t in getattr(kb, "texts", {}).values())
    est += 256 * len(getattr(kb, "records", {}))
    planes["container"] = est
    return planes


def measure_journal(base_path: str) -> int:
    """On-disk delta-journal tail bytes for a container path."""
    # lazy: core.container imports obs.trace — importing it at module
    # top would cycle obs.ledger back into core
    from repro.core.container import journal_size
    try:
        return journal_size(base_path)
    except OSError:
        return 0
