"""Query EXPLAIN plans: a structured record of what one query ran.

The paper's pitch is a deterministic, *inspectable* retrieval stack —
HSF scores are reproducible, so "why did this result rank where it
did, and what did the query cost" should be a queryable artifact, not
something reconstructed from spans after the fact.  A
:class:`QueryPlan` captures, per query:

- the **index kind** (``flat`` / ``ivf`` / ``ivf-sharded``) and
  **scoring path** (``map`` / ``gemm`` / ``kernel``) actually chosen;
- the **probe decomposition** for clustered indexes: clusters probed
  vs total, the probe ordering, exact-mode widening rounds, the final
  kth score vs the unprobed upper bound (the termination proof);
- **candidate volume**: rows gathered from probed clusters vs rows
  reranked;
- **caching**: query-vector cache hit, result-cache hit/miss/bypass,
  coalesce fanout, and the pinned snapshot generation;
- **per-stage durations** sourced from the existing span machinery via
  a thread-local :class:`~repro.obs.trace.StageCollector` — the same
  timed sections tracing records, so EXPLAIN timings and Chrome traces
  can never disagree.

Capture is allocation-light: the engine binds one collector per query
*chunk* (not per query), the index plane materializes its per-query
probe tuples only when ``explain=True``, and nothing touches the
jitted path — host syncs reuse the audited tracing sync points
(HostSyncRule pragmas), now gated on ``trace.active()``.

**Lazy materialization.**  Building a 20-field frozen dataclass per
query (~3 µs) plus a per-request enriched copy (~7 µs) is real money
against the serving plane's <5 % traced-QPS overhead budget, so the
hot path only *captures* plan ingredients: dispatches hand back a
:class:`PlanBatch` (a sequence that constructs its ``QueryPlan``s on
first access), and ``ServedResult.plan`` finalizes the per-request
copy on first read.  The closed-loop benchmark gate
(``bench_serving_traced``, every traced request submitted with
``explain=True``) is what holds this honest.

Pure stdlib, importable from anywhere in the tree without cycles.
"""
from __future__ import annotations

import copy
import json
from dataclasses import asdict, dataclass

from repro.obs.trace import StageCollector  # noqa: F401  (re-export)


@dataclass(frozen=True)
class QueryPlan:
    """One query's plan.  Frozen: enrich with :func:`finalize_plan`
    (or ``dataclasses.replace`` off the hot path)."""

    query: str
    k: int
    index: str = "flat"              # flat | ivf | ivf-sharded
    scoring_path: str = "map"        # map | gemm | kernel
    guarantee: str | None = None     # probe | exact (ivf only)
    n_docs: int = 0
    n_clusters: int = 0
    clusters_probed: int | None = None
    probe_order: tuple = ()          # cluster ids, probe order
    rounds: int | None = None        # exact-mode widening rounds
    kth_score: float | None = None   # final kth candidate score
    unprobed_bound: float | None = None  # max upper bound left unprobed
    rows_gathered: int | None = None
    rows_reranked: int | None = None
    vector_cache: str = "miss"       # hit | miss | none
    result_cache: str = "bypass"     # hit | miss | bypass
    coalesced: int = 1               # requests served by this dispatch
    generation: int | None = None
    tenant: str | None = None
    stages: tuple = ()               # (name, dur_s, args) engine stages
    request_stages: tuple = ()       # (name, dur_s) scheduler stages
    total_s: float = 0.0

    # ---- serialization --------------------------------------------------

    def to_dict(self) -> dict:
        d = asdict(self)
        d["probe_order"] = list(self.probe_order)
        d["stages"] = [[n, s, dict(a)] for n, s, a in self.stages]
        d["request_stages"] = [[n, s] for n, s in self.request_stages]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "QueryPlan":
        kw = dict(d)
        kw["probe_order"] = tuple(kw.get("probe_order") or ())
        kw["stages"] = tuple(
            (n, s, dict(a)) for n, s, a in kw.get("stages") or ())
        kw["request_stages"] = tuple(
            (n, s) for n, s in kw.get("request_stages") or ())
        known = {f for f in cls.__dataclass_fields__}
        return cls(**{k: v for k, v in kw.items() if k in known})

    # ---- rendering ------------------------------------------------------

    def render(self) -> str:
        """Text tree, the `EXPLAIN` a human reads."""
        L = []
        q = self.query if len(self.query) <= 60 else self.query[:57] + "..."
        head = f"EXPLAIN {q!r} (k={self.k}"
        if self.tenant:
            head += f", tenant={self.tenant}"
        if self.generation is not None:
            head += f", generation={self.generation}"
        head += f", {self.total_s * 1e3:.3f} ms)"
        L.append(head)
        if self.result_cache == "hit":
            L.append("└─ result cache: HIT (no scoring dispatch)")
            for name, dur in self.request_stages:
                L.append(f"     {name:<12s} {dur * 1e6:8.1f} µs")
            return "\n".join(L)
        L.append(f"├─ index: {self.index}  scoring_path: "
                 f"{self.scoring_path}"
                 + (f"  guarantee: {self.guarantee}" if self.guarantee
                    else ""))
        L.append(f"├─ corpus: {self.n_docs} docs"
                 + (f", {self.n_clusters} clusters" if self.n_clusters
                    else ""))
        if self.clusters_probed is not None:
            probe = (f"├─ probe: {self.clusters_probed}/{self.n_clusters} "
                     f"clusters")
            if self.rounds is not None:
                probe += f", {self.rounds} widen round(s)"
            L.append(probe)
            if self.probe_order:
                order = ",".join(str(c) for c in self.probe_order[:16])
                if len(self.probe_order) > 16:
                    order += f",…(+{len(self.probe_order) - 16})"
                L.append(f"│    order: [{order}]")
            if self.kth_score is not None:
                bound = ("-inf (all clusters probed)"
                         if self.unprobed_bound is None
                         else f"{self.unprobed_bound:.6f}")
                L.append(f"│    kth score {self.kth_score:.6f} ≥ "
                         f"unprobed bound {bound}")
        if self.rows_gathered is not None:
            L.append(f"├─ candidates: {self.rows_gathered} gathered → "
                     f"{self.rows_reranked} reranked")
        cache_bits = [f"result_cache={self.result_cache}"]
        if self.vector_cache != "none":
            cache_bits.append(f"vector_cache={self.vector_cache}")
        if self.coalesced > 1:
            cache_bits.append(f"coalesced×{self.coalesced}")
        L.append("├─ cache: " + "  ".join(cache_bits))
        if self.stages:
            L.append("├─ engine stages:")
            for name, dur, args in self.stages:
                extra = ""
                if args:
                    extra = "  " + " ".join(
                        f"{k}={v}" for k, v in sorted(args.items()))
                L.append(f"│    {name:<24s} {dur * 1e3:9.3f} ms{extra}")
        if self.request_stages:
            L.append("└─ request stages:")
            for name, dur in self.request_stages:
                L.append(f"     {name:<24s} {dur * 1e3:9.3f} ms")
        elif L[-1].startswith("├─"):
            L[-1] = "└─" + L[-1][2:]
        return "\n".join(L)


def plans_from_dispatch(texts, k, *, index, scoring_path, guarantee,
                        n_docs, stats=None, stages=(),
                        vector_cache_hits=None, generation=None,
                        total_s=0.0):
    """Build one QueryPlan per query of a scoring dispatch from the
    index stats + collected stages.  ``stats`` is the (possibly
    extended) ``IVFSearchStats`` for clustered dispatches, None for
    flat scans; ``vector_cache_hits`` is a per-query bool tuple or
    None when the caller has no query-vector cache."""
    stages = tuple(stages)
    plans = []
    for i, text in enumerate(texts):
        kw = dict(
            query=text, k=k, index=index, scoring_path=scoring_path,
            n_docs=n_docs, generation=generation, stages=stages,
            total_s=total_s,
            vector_cache=("none" if vector_cache_hits is None else
                          "hit" if vector_cache_hits[i] else "miss"),
        )
        if stats is not None:
            kw.update(
                guarantee=guarantee,
                n_clusters=stats.n_clusters,
                clusters_probed=stats.clusters_probed,
                rounds=stats.rounds,
                rows_gathered=stats.candidate_rows,
                rows_reranked=stats.candidate_rows,
            )
            if stats.probe_order:
                kw["probe_order"] = stats.probe_order[i]
            if stats.kth_scores:
                kw["kth_score"] = stats.kth_scores[i]
                kw["unprobed_bound"] = stats.unprobed_bounds[i]
        plans.append(QueryPlan(**kw))
    return plans


class PlanBatch:
    """A lazily-materialized sequence of ``QueryPlan``s.

    The scoring hot path constructs this with a zero-argument thunk
    (usually a closure over :func:`plans_from_dispatch` ingredients);
    the dataclasses are built on the first sequence access and cached.
    Materialization is idempotent, so a benign race between two
    consumers resolving concurrently just builds the same list twice.
    """

    __slots__ = ("_thunk", "_plans")

    def __init__(self, thunk):
        self._thunk = thunk
        self._plans = None

    @classmethod
    def concat(cls, batches: list) -> "PlanBatch":
        if len(batches) == 1:
            return batches[0]
        return cls(lambda: [p for b in batches for p in b])

    def _all(self) -> list:
        if self._plans is None:
            self._plans = list(self._thunk())
        return self._plans

    def __len__(self) -> int:
        return len(self._all())

    def __getitem__(self, i):
        return self._all()[i]

    def __iter__(self):
        return iter(self._all())


def finalize_plan(base: QueryPlan, **overrides) -> QueryPlan:
    """A cheaper ``dataclasses.replace`` for the per-request plan copy
    (~2.5x: ``replace`` re-runs the 20-field ``__init__``).  The copy
    is required — coalesced requests share one engine plan but differ
    in request stages / fanout / cache disposition."""
    plan = copy.copy(base)
    for k, v in overrides.items():
        # analysis: allow[snapshot-mutation] -- writes only to the
        # fresh private copy made on the line above, never to the
        # shared base plan; the copy escapes already-frozen
        object.__setattr__(plan, k, v)
    return plan


# ---- plan files (CI artifacts, `python -m repro.obs explain`) -----------

def write_plans(path: str, plans, extra: dict | None = None) -> None:
    doc = {"plans": [p.to_dict() for p in plans]}
    if extra:
        doc.update(extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)


def load_plans(path: str) -> list[QueryPlan]:
    with open(path) as f:
        doc = json.load(f)
    raw = doc.get("plans", doc) if isinstance(doc, dict) else doc
    return [QueryPlan.from_dict(d) for d in raw]
