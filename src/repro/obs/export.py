"""Exporters: Chrome trace-event JSON and Prometheus text exposition.

Chrome format: one ``"ph": "X"`` (complete) event per span, ``ts`` and
``dur`` in microseconds — the file loads directly in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``.  Trace/span/parent
ids and all span args ride in ``args`` so the round trip
(``write_chrome_trace`` → ``load_chrome_trace``) is lossless to ~1 ns
timestamp quantization (tier-1 tested).

Prometheus format: ``# HELP``/``# TYPE`` headers plus one sample line
per series; histograms render summary-style (``{quantile="0.5"}``,
``{quantile="0.99"}``, ``_count``, ``_sum``) since the log-bucket
layout is an implementation detail.
"""
from __future__ import annotations

import json

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import SpanRecord

_ID_KEYS = ("trace_id", "span_id", "parent_id")


# ---- Chrome trace-event JSON --------------------------------------------

def chrome_trace(spans, *, pid: int = 0) -> dict:
    """Spans → the Chrome trace-event JSON object (not yet serialized)."""
    events = []
    for r in spans:
        args = {k: v for k, v in r.args.items()}
        args["trace_id"] = r.trace_id
        args["span_id"] = r.span_id
        args["parent_id"] = r.parent_id
        events.append({
            "name": r.name,
            "cat": "ragdb",
            "ph": "X",
            "ts": r.t0_ns / 1e3,
            "dur": r.dur_ns / 1e3,
            "pid": pid,
            "tid": r.tid,
            "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, spans, *, pid: int = 0) -> int:
    """Serialize to ``path``; returns the number of events written."""
    doc = chrome_trace(spans, pid=pid)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, separators=(",", ":"))
    return len(doc["traceEvents"])


def load_chrome_trace(path: str) -> list[SpanRecord]:
    """Read a Chrome trace file back into SpanRecords (ids and args
    recovered from the event ``args``; foreign events without our id
    keys are skipped)."""
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    out = []
    for ev in events:
        if ev.get("ph") != "X":
            continue
        args = dict(ev.get("args") or {})
        if not all(k in args for k in _ID_KEYS):
            continue
        trace_id = args.pop("trace_id")
        span_id = args.pop("span_id")
        parent_id = args.pop("parent_id")
        out.append(SpanRecord(
            ev["name"], trace_id, span_id, parent_id,
            round(ev["ts"] * 1e3), round(ev.get("dur", 0) * 1e3),
            ev.get("tid", 0), args,
        ))
    return out


# ---- stage breakdown (the `python -m repro.obs` summary) ----------------

def stage_breakdown(spans) -> dict:
    """Per-span-name stats with *exact* percentiles (this is offline
    analysis of a bounded trace file, not the O(1) serving histogram).

    Returns ``{name: {count, total_s, p50_s, p99_s, max_s}}``.
    """
    by_name: dict[str, list[float]] = {}
    for r in spans:
        by_name.setdefault(r.name, []).append(r.dur_ns / 1e9)
    out = {}
    for name, durs in by_name.items():
        durs.sort()
        n = len(durs)
        out[name] = {
            "count": n,
            "total_s": sum(durs),
            "p50_s": durs[int(0.50 * (n - 1))],
            "p99_s": durs[int(0.99 * (n - 1))],
            "max_s": durs[-1],
        }
    return out


def request_decomposition(spans, stages=("queue_wait", "flush_wait",
                                         "score", "merge")) -> list[dict]:
    """Group spans by trace id and, for every non-cached ``request``
    root span, report its end-to-end duration plus the summed stage
    durations — the acceptance check that stages tile the request."""
    by_trace: dict[int, dict] = {}
    for r in spans:
        t = by_trace.setdefault(r.trace_id, {"request": None, "stages": {}})
        if r.name == "request":
            t["request"] = r
        elif r.name in stages:
            t["stages"][r.name] = t["stages"].get(r.name, 0.0) + r.dur_ns / 1e9
    out = []
    for tid, t in by_trace.items():
        req = t["request"]
        if req is None or req.args.get("cached"):
            continue
        out.append({
            "trace_id": tid,
            "request_s": req.dur_ns / 1e9,
            "stages_s": dict(t["stages"]),
            "stage_sum_s": sum(t["stages"].values()),
        })
    return out


def filter_tenant_traces(spans, tenant: str) -> list[SpanRecord]:
    """Keep only the traces whose ``request`` root span is labeled with
    ``tenant`` (the ``--tenant`` CLI filter).  Whole traces are kept or
    dropped — a request's child stages inherit the verdict via their
    trace id, so the filtered view still decomposes cleanly."""
    keep = {r.trace_id for r in spans
            if r.name == "request" and r.args.get("tenant") == tenant}
    return [r for r in spans if r.trace_id in keep]


def tenant_breakdown(spans) -> dict:
    """Per-tenant request stats from the ``request`` root spans:
    ``{tenant: {count, p50_s, p99_s, total_s}}``.  Requests without a
    tenant label (single-tenant serving) group under ``"-"``."""
    by_tenant: dict[str, list[float]] = {}
    for r in spans:
        if r.name != "request":
            continue
        by_tenant.setdefault(
            str(r.args.get("tenant", "-")), []).append(r.dur_ns / 1e9)
    out = {}
    for tenant, durs in by_tenant.items():
        durs.sort()
        n = len(durs)
        out[tenant] = {
            "count": n,
            "total_s": sum(durs),
            "p50_s": durs[int(0.50 * (n - 1))],
            "p99_s": durs[int(0.99 * (n - 1))],
        }
    return out


def format_breakdown(spans) -> str:
    """The ``python -m repro.obs`` table: per-stage count/p50/p99."""
    br = stage_breakdown(spans)
    if not br:
        return "no spans"
    lines = [f"{'span':<24}{'count':>8}{'total_ms':>12}"
             f"{'p50_ms':>10}{'p99_ms':>10}{'max_ms':>10}"]
    for name, s in sorted(br.items(), key=lambda kv: -kv[1]["total_s"]):
        lines.append(
            f"{name:<24}{s['count']:>8}{s['total_s'] * 1e3:>12.2f}"
            f"{s['p50_s'] * 1e3:>10.3f}{s['p99_s'] * 1e3:>10.3f}"
            f"{s['max_s'] * 1e3:>10.3f}")
    reqs = request_decomposition(spans)
    if reqs:
        mean_req = sum(r["request_s"] for r in reqs) / len(reqs)
        mean_sum = sum(r["stage_sum_s"] for r in reqs) / len(reqs)
        cov = mean_sum / mean_req if mean_req else 0.0
        lines.append(
            f"-- {len(reqs)} traced requests: mean {mean_req * 1e3:.2f} ms, "
            f"stage spans cover {cov * 100:.1f}% of end-to-end")
    tb = tenant_breakdown(spans)
    if tb and set(tb) != {"-"}:  # only when tenant-labeled requests exist
        lines.append("")
        lines.append(f"{'tenant':<24}{'requests':>8}{'total_ms':>12}"
                     f"{'p50_ms':>10}{'p99_ms':>10}")
        for tenant, s in sorted(tb.items(),
                                key=lambda kv: -kv[1]["total_s"]):
            lines.append(
                f"{tenant:<24}{s['count']:>8}{s['total_s'] * 1e3:>12.2f}"
                f"{s['p50_s'] * 1e3:>10.3f}{s['p99_s'] * 1e3:>10.3f}")
    return "\n".join(lines)


# ---- Prometheus text exposition -----------------------------------------

def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_value(v) -> str:
    return repr(float(v)) if isinstance(v, float) else str(v)


def render_prometheus(*registries: MetricsRegistry) -> str:
    """Render one or more registries as Prometheus text exposition."""
    lines = []
    for reg in registries:
        for name, kind, help_, series in reg.collect():
            if help_:
                lines.append(f"# HELP {name} {help_}")
            lines.append(
                f"# TYPE {name} "
                f"{'summary' if kind == 'histogram' else kind}")
            for labels, m in series:
                if kind == "histogram":
                    s = m.snapshot()
                    for q, key in (("0.5", "p50"), ("0.99", "p99")):
                        ql = dict(labels, quantile=q)
                        lines.append(
                            f"{name}{_fmt_labels(ql)} {_fmt_value(s[key])}")
                    lab = _fmt_labels(labels)
                    lines.append(f"{name}_count{lab} {s['count']}")
                    lines.append(f"{name}_sum{lab} {_fmt_value(s['sum'])}")
                else:
                    lines.append(
                        f"{name}{_fmt_labels(labels)} {_fmt_value(m.value)}")
    return "\n".join(lines) + ("\n" if lines else "")
