"""Observability plane: span tracer + metrics registry + exporters.

Zero-dependency (pure stdlib — importable from ``core/container.py``
upward without cycles), in the same spirit as the analysis plane:

- ``obs.trace`` — monotonic-clock spans with trace/parent ids in a
  bounded ring buffer; off by default, near-zero cost when off,
  sampled when on.  The serving request lifecycle (queue wait → flush
  wait → pack → snapshot pin → device dispatch → IVF probe/rerank →
  merge) and the write path (sync, extract, delta save, journal
  fsync, compact, publish) all record here.
- ``obs.metrics`` — labeled counters/gauges/log-bucket histograms in a
  ``MetricsRegistry``; ``global_registry()`` carries engine/index/
  ingest-level signals (IVF search stats, sanitizer trips, journal
  bytes, publish lag), per-runtime registries live in
  ``serving.metrics.ServingMetrics``.
- ``obs.export`` — Chrome trace-event JSON (Perfetto-loadable) and
  Prometheus text exposition; ``python -m repro.obs trace.json``
  renders a per-stage p50/p99 breakdown.

- ``obs.explain`` — structured per-query EXPLAIN plans (``QueryPlan``):
  index kind, probe set + exact-mode widen/bound evidence, candidate
  counts, cache disposition, and per-stage durations collected via the
  tracer's ``StageCollector``; ``python -m repro.obs explain plans.json``
  renders the text tree.
- ``obs.ledger`` — ``ResourceLedger``: resident bytes per (tenant,
  generation, plane); the container pool's byte-budget eviction and
  ``ServingRuntime.resources()`` both read from it.
- ``obs.health`` — ``HealthMonitor``: rolling-window SLO burn-rate
  alerting (``ok | degraded | critical``) over the serving metrics.

See docs/ARCHITECTURE.md §12 for the span model and overhead contract,
§14 for EXPLAIN / ledger / SLO semantics.
"""
from repro.obs import trace
from repro.obs.explain import QueryPlan, load_plans, write_plans
from repro.obs.export import (
    chrome_trace,
    format_breakdown,
    load_chrome_trace,
    render_prometheus,
    request_decomposition,
    stage_breakdown,
    write_chrome_trace,
)
from repro.obs.health import HealthMonitor, SLOTargets
from repro.obs.ledger import ResourceLedger
from repro.obs.metrics import (
    Counter,
    Gauge,
    LogHistogram,
    MetricsRegistry,
    global_registry,
)
from repro.obs.trace import SpanRecord, StageCollector, Tracer

__all__ = [
    "trace",
    "Tracer",
    "SpanRecord",
    "StageCollector",
    "MetricsRegistry",
    "LogHistogram",
    "Counter",
    "Gauge",
    "global_registry",
    "QueryPlan",
    "write_plans",
    "load_plans",
    "ResourceLedger",
    "HealthMonitor",
    "SLOTargets",
    "chrome_trace",
    "write_chrome_trace",
    "load_chrome_trace",
    "stage_breakdown",
    "request_decomposition",
    "format_breakdown",
    "render_prometheus",
]
