"""Observability plane: span tracer + metrics registry + exporters.

Zero-dependency (pure stdlib — importable from ``core/container.py``
upward without cycles), in the same spirit as the analysis plane:

- ``obs.trace`` — monotonic-clock spans with trace/parent ids in a
  bounded ring buffer; off by default, near-zero cost when off,
  sampled when on.  The serving request lifecycle (queue wait → flush
  wait → pack → snapshot pin → device dispatch → IVF probe/rerank →
  merge) and the write path (sync, extract, delta save, journal
  fsync, compact, publish) all record here.
- ``obs.metrics`` — labeled counters/gauges/log-bucket histograms in a
  ``MetricsRegistry``; ``global_registry()`` carries engine/index/
  ingest-level signals (IVF search stats, sanitizer trips, journal
  bytes, publish lag), per-runtime registries live in
  ``serving.metrics.ServingMetrics``.
- ``obs.export`` — Chrome trace-event JSON (Perfetto-loadable) and
  Prometheus text exposition; ``python -m repro.obs trace.json``
  renders a per-stage p50/p99 breakdown.

See docs/ARCHITECTURE.md §12 for the span model and overhead contract.
"""
from repro.obs import trace
from repro.obs.export import (
    chrome_trace,
    format_breakdown,
    load_chrome_trace,
    render_prometheus,
    request_decomposition,
    stage_breakdown,
    write_chrome_trace,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    LogHistogram,
    MetricsRegistry,
    global_registry,
)
from repro.obs.trace import SpanRecord, Tracer

__all__ = [
    "trace",
    "Tracer",
    "SpanRecord",
    "MetricsRegistry",
    "LogHistogram",
    "Counter",
    "Gauge",
    "global_registry",
    "chrome_trace",
    "write_chrome_trace",
    "load_chrome_trace",
    "stage_breakdown",
    "request_decomposition",
    "format_breakdown",
    "render_prometheus",
]
