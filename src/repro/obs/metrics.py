"""Generalized metrics registry: counters / gauges / histograms with labels.

Supersedes the ad-hoc counter fields that ``serving.metrics.
ServingMetrics`` used to carry: every plane (scheduler, engine, IVF,
ingest/persistence, sanitizers) records into a ``MetricsRegistry`` —
either the process-wide ``global_registry()`` for engine/index/ingest
level signals, or a per-runtime instance owned by ``ServingMetrics``.
Pure stdlib; rendering to Prometheus text exposition lives in
``obs/export.py``.

Memory is O(#distinct (name, labels) series); histograms are
fixed-bucket (``LogHistogram``), so nothing here grows with request
count.
"""
from __future__ import annotations

import threading
from bisect import bisect_left


class LogHistogram:
    """Fixed log-spaced buckets, 10 µs … ~79 s (×1.25 per bucket), plus
    one overflow bucket.

    ``percentile`` returns the geometric midpoint of the bucket holding
    the requested rank, clamped to the observed [min, max] — a ≤ ~12 %
    quantization error, plenty for p50/p99 serving dashboards, with
    O(1) memory forever.  The [min, max] clamp makes single-sample
    histograms exact (p50 == p99 == max) and keeps percentiles
    monotonic in q.  Thread-safe.
    """

    N_BUCKETS = 72
    BASE = 10e-6
    GROWTH = 1.25

    def __init__(self):
        self.bounds = [
            self.BASE * self.GROWTH ** i for i in range(self.N_BUCKETS)
        ]
        self.counts = [0] * (self.N_BUCKETS + 1)  # +1 overflow bucket
        self.n = 0
        self.total = 0.0
        self.max = 0.0
        self.min = 0.0
        self._lock = threading.Lock()

    def record(self, seconds: float) -> None:
        with self._lock:
            self.counts[bisect_left(self.bounds, seconds)] += 1
            if self.n == 0 or seconds < self.min:
                self.min = seconds
            self.n += 1
            self.total += seconds
            if seconds > self.max:
                self.max = seconds

    def percentile(self, q: float) -> float:
        """q in [0, 100] → seconds (0.0 when empty)."""
        if self.n == 0:
            return 0.0
        rank = q / 100.0 * (self.n - 1)
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum > rank:
                if i >= self.N_BUCKETS:
                    return self.max  # overflow bucket: > ~79 s
                if i == 0:
                    est = self.bounds[0] / self.GROWTH ** 0.5
                else:
                    # geometric bucket midpoint
                    est = self.bounds[i - 1] * self.GROWTH ** 0.5
                # clamp to the observed range: exact for single-sample
                # histograms, and never reports outside the data
                return min(max(est, self.min), self.max)
        return self.max

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def bucket_snapshot(self) -> tuple:
        """One coherent ``(counts, n, sum, min, max)`` read — the SLO
        health monitor diffs two of these to compute *windowed*
        percentiles from a cumulative histogram."""
        with self._lock:
            return (list(self.counts), self.n, self.total,
                    self.min, self.max)

    def snapshot(self) -> dict:
        """One coherent read (record() holds the same lock)."""
        with self._lock:
            return {
                "count": self.n,
                "sum": self.total,
                "p50": self.percentile(50),
                "p99": self.percentile(99),
                "max": self.max,
                "mean": self.total / self.n if self.n else 0.0,
            }


class Counter:
    """Monotonic counter (floats allowed: byte totals, seconds)."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n=1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v

    def inc(self, n=1) -> None:
        self.value += n

    def dec(self, n=1) -> None:
        self.value -= n


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": LogHistogram}


class MetricsRegistry:
    """Named, labeled metric families with get-or-create access.

    ``reg.counter("ragdb_requests_total", outcome="ok").inc()`` — the
    same (name, labels) pair always returns the same object, so call
    sites need no caching (though hot paths may hold the reference).
    """

    def __init__(self):
        self._lock = threading.Lock()
        # name -> {"kind", "help", "series": {sorted-label-items: metric}}
        self._families: dict[str, dict] = {}

    def _get(self, kind: str, name: str, help_: str, labels: dict):
        key = tuple(sorted(labels.items()))
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = {
                    "kind": kind, "help": help_, "series": {}}
            elif fam["kind"] != kind:
                raise ValueError(
                    f"metric {name!r} is a {fam['kind']}, not a {kind}")
            m = fam["series"].get(key)
            if m is None:
                m = fam["series"][key] = _KINDS[kind]()
            return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get("counter", name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get("gauge", name, help, labels)

    def histogram(self, name: str, help: str = "", **labels) -> LogHistogram:
        return self._get("histogram", name, help, labels)

    # ---- series lifecycle -----------------------------------------------

    def series(self, name: str) -> dict:
        """Read-only {labels-tuple: metric} for one family ({} when the
        family doesn't exist) — health detectors sum over this."""
        with self._lock:
            fam = self._families.get(name)
            return dict(fam["series"]) if fam else {}

    def prune(self, name: str | None = None, **labels) -> int:
        """Drop every series whose labels include all of ``labels``
        (optionally restricted to one family); empty families are
        removed entirely.  This is the tenant-evict path: without it,
        long-lived zipf traffic over many tenants grows label
        cardinality without bound and evicted tenants' gauges
        (publish-lag, resident-bytes) go stale instead of disappearing.
        Returns the number of series removed.  A later get-or-create
        with the same (name, labels) recreates the series fresh (and a
        pruned family's *kind* is forgotten with it)."""
        items = tuple(labels.items())
        removed = 0
        with self._lock:
            for fam_name in list(self._families):
                if name is not None and fam_name != name:
                    continue
                series = self._families[fam_name]["series"]
                for key in [k for k in series
                            if all(it in k for it in items)]:
                    del series[key]
                    removed += 1
                if not series:
                    del self._families[fam_name]
        return removed

    # ---- export ---------------------------------------------------------

    def collect(self) -> list:
        """[(name, kind, help, [(labels_dict, metric), ...]), ...] in
        registration order; the exporters consume this."""
        with self._lock:
            return [
                (name, fam["kind"], fam["help"],
                 [(dict(key), m) for key, m in fam["series"].items()])
                for name, fam in self._families.items()
            ]

    def snapshot(self) -> dict:
        """Flat dict for drivers/tests: ``name{k=v,...}`` -> value
        (histograms expand to their snapshot() sub-keys)."""
        out: dict = {}
        for name, kind, _help, series in self.collect():
            for labels, m in series:
                suffix = ("{" + ",".join(
                    f"{k}={v}" for k, v in sorted(labels.items())) + "}"
                    if labels else "")
                if kind == "histogram":
                    for k, v in m.snapshot().items():
                        out[f"{name}_{k}{suffix}"] = v
                else:
                    out[f"{name}{suffix}"] = m.value
        return out

    def reset(self) -> None:
        with self._lock:
            self._families.clear()


_GLOBAL = MetricsRegistry()


def global_registry() -> MetricsRegistry:
    """Process-wide registry for engine/index/ingest-level metrics."""
    return _GLOBAL
