"""Span tracer: monotonic-clock spans in a bounded ring buffer.

The serving/index/ingest planes are instrumented with spans (named,
timed intervals carrying a trace id, a parent id, and key=value args).
This module is the zero-dependency substrate they record into — pure
stdlib, importable from ``core/container.py`` upward without cycles,
in the same spirit as ``analysis/sanitizers.py``.

Contract (docs/ARCHITECTURE.md §12):

- **Off by default, near-zero cost when off.**  Every instrumentation
  site calls ``span(...)`` / ``record(...)``; when the tracer is
  disabled these return a shared no-op object after one attribute
  check — no allocation, no clock read, no lock.
- **O(1) memory forever.**  Completed spans land in a ``deque`` with a
  hard ``maxlen``; a long-running server can trace continuously and
  only ever holds the most recent ``capacity`` spans.
- **Sampling.**  ``enable(sample=0.01)`` keeps 1-in-100 *traces* (not
  spans): the sampling decision is made once per request at
  ``begin_trace`` and every child span of an unsampled trace
  short-circuits to the no-op, so a sampled request is always complete.
- **Monotonic clock.**  All timestamps are ``time.perf_counter_ns``
  (same epoch as ``time.perf_counter``), so manually-measured
  intervals from the scheduler can be recorded next to context-manager
  spans and line up on one timeline.

Parenting is implicit within a thread (a thread-local span stack) and
explicit across threads: the scheduler allocates a trace id at submit
time on the caller's thread and the flusher thread records that
request's stage spans against it via ``record(..., trace=tid)``.

Env knobs: ``RAGDB_TRACE=1`` enables the default tracer at import;
``RAGDB_TRACE_SAMPLE=0.01`` sets its sampling rate.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from collections import deque

DEFAULT_CAPACITY = 65536

_INHERIT = object()


class SpanRecord:
    """One completed span: what the ring buffer holds and exporters read."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id",
                 "t0_ns", "dur_ns", "tid", "args")

    def __init__(self, name, trace_id, span_id, parent_id,
                 t0_ns, dur_ns, tid, args):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0_ns = t0_ns
        self.dur_ns = dur_ns
        self.tid = tid
        self.args = args

    @property
    def dur_s(self) -> float:
        return self.dur_ns / 1e9

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"SpanRecord({self.name!r}, trace={self.trace_id}, "
                f"dur={self.dur_ns / 1e6:.3f}ms, args={self.args})")


class _NullSpan:
    """Shared no-op returned whenever a span would not be recorded."""

    __slots__ = ()
    trace_id = 0
    span_id = 0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args):
        return self


_NULL = _NullSpan()


class _SuppressScope:
    """Entered when a caller explicitly binds trace=0 (an unsampled
    request): pushes a zero trace onto this thread's stack so every
    nested span inherits 'unsampled' instead of starting a fresh
    trace.  Records nothing."""

    __slots__ = ("_tracer",)
    trace_id = 0
    span_id = 0

    def __init__(self, tracer):
        self._tracer = tracer

    def __enter__(self):
        self._tracer._push(0, 0)
        return self

    def __exit__(self, *exc):
        self._tracer._pop()
        return False

    def set(self, **args):
        return self


class _Span:
    """Context-manager span; emits a SpanRecord on exit.

    When a :class:`StageCollector` is active on this thread the span
    additionally feeds ``(name, dur_s, args)`` into it on exit — with
    ``trace_id=0`` that is the *only* output (EXPLAIN capture without
    the tracer buffering anything)."""

    __slots__ = ("_tracer", "name", "trace_id", "span_id",
                 "parent_id", "args", "_t0", "_col")

    def __init__(self, tracer, name, trace_id, span_id, parent_id, args,
                 col=None):
        self._tracer = tracer
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.args = args
        self._col = col

    def set(self, **args):
        """Attach args discovered mid-span (sizes, counts, outcomes)."""
        self.args.update(args)
        return self

    def __enter__(self):
        self._tracer._push(self.trace_id, self.span_id)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter_ns() - self._t0
        self._tracer._pop()
        if self._col is not None:
            self._col.add(self.name, dur / 1e9, self.args)
        if self.trace_id:
            # raw tuple in SpanRecord field order — materialized at drain
            self._tracer._buf.append((
                self.name, self.trace_id, self.span_id, self.parent_id,
                self._t0, dur, threading.get_ident(), self.args,
            ))
        return False


class StageCollector:
    """Accumulates ``(name, dur_s, args)`` stage tuples from spans and
    ``record()`` calls executed under :func:`collect` — the substrate
    EXPLAIN plans source their per-stage durations from.  Thread-local
    (one collector per query dispatch), so no lock."""

    __slots__ = ("stages",)

    def __init__(self):
        self.stages: list = []

    def add(self, name: str, dur_s: float, args) -> None:
        self.stages.append((name, dur_s, dict(args) if args else {}))


class _CollectScope:
    """Context manager binding a StageCollector to this thread."""

    __slots__ = ("_tracer", "_col", "_prev")

    def __init__(self, tracer, col):
        self._tracer = tracer
        self._col = col

    def __enter__(self):
        tls = self._tracer._tls
        self._prev = getattr(tls, "collector", None)
        tls.collector = self._col
        return self._col

    def __exit__(self, *exc):
        self._tracer._tls.collector = self._prev
        return False


class Tracer:
    """See module docstring.  One instance = one ring buffer."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 sample: float = 1.0):
        self._buf: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._enabled = False
        # itertools.count.__next__ is a single C call — atomic under
        # the GIL, so the emit path never takes a lock
        self._ids = itertools.count(1)
        self._trace_n = itertools.count()
        self._period = 1
        self.configure(sample=sample)

    # ---- lifecycle ------------------------------------------------------

    def configure(self, *, sample: float | None = None,
                  capacity: int | None = None) -> "Tracer":
        with self._lock:
            if sample is not None:
                if not 0.0 < sample <= 1.0:
                    raise ValueError("sample must be in (0, 1]")
                self._period = max(1, round(1.0 / sample))
            if capacity is not None and capacity != self._buf.maxlen:
                self._buf = deque(self._buf, maxlen=capacity)
        return self

    def enable(self, *, sample: float | None = None,
               capacity: int | None = None) -> "Tracer":
        self.configure(sample=sample, capacity=capacity)
        self._enabled = True
        return self

    def disable(self) -> None:
        self._enabled = False

    @property
    def enabled(self) -> bool:
        return self._enabled

    @property
    def capacity(self) -> int:
        return self._buf.maxlen or 0

    def collect(self, col: "StageCollector") -> "_CollectScope":
        """Bind ``col`` to this thread for the scope: spans and
        ``record()`` calls inside feed it even when tracing is off
        (EXPLAIN capture).  Nests; restores the previous collector."""
        return _CollectScope(self, col)

    def collecting(self) -> bool:
        return getattr(self._tls, "collector", None) is not None

    def active(self) -> bool:
        """True when instrumentation should run its timed path: the
        tracer is enabled *or* a collector is bound to this thread.
        Host-sync gates (``block_until_ready`` before reading the
        clock) key off this so EXPLAIN gets honest device-time
        attribution."""
        return self._enabled or getattr(self._tls, "collector",
                                        None) is not None

    # ---- ids / sampling -------------------------------------------------

    def alloc_id(self) -> int:
        """A fresh nonzero id (0 always means 'none'/'unsampled')."""
        if not self._enabled:
            return 0
        return next(self._ids)

    def begin_trace(self) -> int:
        """Per-request sampling decision: a nonzero trace id when this
        request should be traced, else 0 (all its spans become no-ops)."""
        if not self._enabled:
            return 0
        if next(self._trace_n) % self._period:
            return 0
        return next(self._ids)

    # ---- recording ------------------------------------------------------

    def span(self, name: str, *, trace=_INHERIT, parent=_INHERIT, **args):
        """Open a span as a context manager.

        ``trace`` defaults to the enclosing span's trace on this thread
        (or a fresh ``begin_trace`` at top level); pass an explicit id
        to attach to a request trace from another thread, or 0 to
        force a no-op.  ``parent`` defaults to the enclosing span.
        """
        col = getattr(self._tls, "collector", None)
        if not self._enabled:
            if col is None:
                return _NULL
            # collector-only span: timed, feeds the collector, buffers
            # nothing (trace_id=0 also suppresses descendants' traces
            # via the stack push, like _SuppressScope)
            return _Span(self, name, 0, 0, 0, args, col)
        stack = getattr(self._tls, "stack", None)
        explicit = trace is not _INHERIT
        if not explicit:
            trace = stack[-1][0] if stack else self.begin_trace()
        if not trace:
            if col is not None:
                return _Span(self, name, 0, 0, 0, args, col)
            # explicit 0 = an unsampled request: suppress descendants
            # too (otherwise they would each start orphan traces)
            return _SuppressScope(self) if explicit else _NULL
        if parent is _INHERIT:
            parent = stack[-1][1] if stack else 0
        return _Span(self, name, trace, self.alloc_id(), parent, args, col)

    def record(self, name: str, t0_s: float, dur_s: float, *,
               trace=_INHERIT, parent=_INHERIT, span_id: int = 0,
               **args) -> int:
        """Record an already-measured interval (``time.perf_counter``
        floats) as a span — for stages timed manually, either across
        threads (explicit ``trace``) or inside an enclosing span on
        this thread (inherited; dropped at top level rather than
        starting a trace).  Returns the span id (0 when dropped)."""
        col = getattr(self._tls, "collector", None)
        if col is not None:
            col.add(name, dur_s, args)
        if not self._enabled:
            return 0
        stack = getattr(self._tls, "stack", None)
        if trace is _INHERIT:
            trace = stack[-1][0] if stack else 0
        if not trace:
            return 0
        if parent is _INHERIT:
            parent = stack[-1][1] if stack else 0
        sid = span_id or self.alloc_id()
        self._buf.append((
            name, trace, sid, parent,
            int(t0_s * 1e9), max(int(dur_s * 1e9), 0),
            threading.get_ident(), args,
        ))
        return sid

    def record_batch(self, trace: int, intervals) -> None:
        """Emit several already-measured intervals of one trace in a
        single call — the scheduler's per-request stage records, where
        per-call API overhead would otherwise be paid five times per
        request on the flush hot path.

        ``intervals``: iterable of ``(name, t0_s, dur_s, span_id,
        parent_id, args_or_None)``; a zero ``span_id`` allocates one.
        """
        if not self._enabled or not trace:
            return
        tid = threading.get_ident()
        emit = self._buf.append
        ids = self._ids
        for name, t0_s, dur_s, sid, parent, args in intervals:
            emit((
                name, trace, sid or next(ids), parent,
                int(t0_s * 1e9), max(int(dur_s * 1e9), 0),
                tid, args if args is not None else {},
            ))

    # ---- buffer access --------------------------------------------------

    def spans(self) -> list[SpanRecord]:
        return [SpanRecord(*t) for t in list(self._buf)]

    def drain(self) -> list[SpanRecord]:
        """Atomically take everything buffered (oldest first).  The
        ring holds raw tuples (emit-path economy); materialization to
        SpanRecord happens here, on the cold path."""
        out = []
        buf = self._buf
        while True:
            try:
                out.append(SpanRecord(*buf.popleft()))
            except IndexError:
                return out

    def __len__(self) -> int:
        return len(self._buf)

    # ---- internals ------------------------------------------------------

    def _push(self, trace_id: int, span_id: int) -> None:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        stack.append((trace_id, span_id))

    def _pop(self) -> None:
        stack = getattr(self._tls, "stack", None)
        if stack:
            stack.pop()
    # note: emits append raw tuples straight to the deque — append with
    # maxlen is atomic under the GIL, so the hot path takes no lock


# ---- module-level default tracer (what the instrumentation uses) --------

_DEFAULT = Tracer()


def get() -> Tracer:
    return _DEFAULT


def enable(*, sample: float | None = None,
           capacity: int | None = None) -> Tracer:
    return _DEFAULT.enable(sample=sample, capacity=capacity)


def disable() -> None:
    _DEFAULT.disable()


def enabled() -> bool:
    return _DEFAULT._enabled


def active() -> bool:
    """Tracing enabled or a collector bound to this thread (EXPLAIN)."""
    return _DEFAULT.active()


span = _DEFAULT.span
record = _DEFAULT.record
record_batch = _DEFAULT.record_batch
begin_trace = _DEFAULT.begin_trace
alloc_id = _DEFAULT.alloc_id
drain = _DEFAULT.drain
collect = _DEFAULT.collect
collecting = _DEFAULT.collecting


if os.environ.get("RAGDB_TRACE", "") not in ("", "0"):  # pragma: no cover
    _DEFAULT.enable(
        sample=float(os.environ.get("RAGDB_TRACE_SAMPLE", "1.0")))
