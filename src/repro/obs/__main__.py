"""CLI: render a per-stage breakdown from a Chrome trace file, or an
EXPLAIN plan file.

    PYTHONPATH=src python -m repro.obs trace.json [--json] [--tenant T]
    PYTHONPATH=src python -m repro.obs explain plans.json

Trace mode loads a trace written by ``obs.export.write_chrome_trace``
(e.g. from ``benchmarks/bench_serving.py --trace`` or ``launch/serve.py
--trace``) and prints per-span-name count / total / p50 / p99 / max,
the request-decomposition coverage line (how much of end-to-end request
time the stage spans account for), and — when requests carry tenant
labels — a per-tenant table.  ``--tenant T`` keeps only the traces
whose request root is labeled with tenant ``T``.

Explain mode loads a plan file written by ``obs.explain.write_plans``
(e.g. from ``bench_serving --explain-out`` or ``serve.py --explain``)
and renders each plan's text tree.

Exit 0 on success, 2 on a missing/unreadable file.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.explain import load_plans
from repro.obs.export import (
    filter_tenant_traces,
    format_breakdown,
    load_chrome_trace,
    request_decomposition,
    stage_breakdown,
    tenant_breakdown,
)


def _explain_main(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs explain",
        description="Render EXPLAIN plans from a plan JSON file")
    ap.add_argument("plans", help="plan file from obs.explain.write_plans")
    args = ap.parse_args(argv)
    try:
        plans = load_plans(args.plans)
    except (OSError, ValueError, KeyError, TypeError) as exc:
        print(f"error: cannot read plans {args.plans!r}: {exc}",
              file=sys.stderr)
        return 2
    try:
        for i, p in enumerate(plans):
            if i:
                print()
            print(p.render())
    except BrokenPipeError:
        sys.stderr.close()
    return 0


def main(argv=None) -> int:
    if argv is None:
        argv = sys.argv[1:]
    if argv and argv[0] == "explain":
        return _explain_main(argv[1:])
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Per-stage latency breakdown from a Chrome trace file "
                    "(or `explain plans.json` to render EXPLAIN plans)")
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable breakdown instead of the table")
    ap.add_argument("--tenant", default=None,
                    help="keep only traces whose request root span is "
                         "labeled with this tenant")
    args = ap.parse_args(argv)
    try:
        spans = load_chrome_trace(args.trace)
    except (OSError, ValueError, KeyError) as exc:
        print(f"error: cannot read trace {args.trace!r}: {exc}",
              file=sys.stderr)
        return 2
    if args.tenant is not None:
        spans = filter_tenant_traces(spans, args.tenant)
    try:
        if args.json:
            print(json.dumps({
                "stages": stage_breakdown(spans),
                "requests": request_decomposition(spans),
                "tenants": tenant_breakdown(spans),
            }, indent=2, sort_keys=True))
        else:
            print(f"{len(spans)} spans from {args.trace}"
                  + (f" (tenant={args.tenant})" if args.tenant else ""))
            print(format_breakdown(spans))
    except BrokenPipeError:  # output piped into head/less that closed
        sys.stderr.close()   # suppress the interpreter's epipe warning
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
