"""CLI: render a per-stage breakdown from a Chrome trace file.

    PYTHONPATH=src python -m repro.obs trace.json [--json]

Loads a trace written by ``obs.export.write_chrome_trace`` (e.g. from
``benchmarks/bench_serving.py --trace`` or ``launch/serve.py
--trace``) and prints per-span-name count / total / p50 / p99 / max,
plus the request-decomposition coverage line (how much of end-to-end
request time the stage spans account for).  Exit 0 on success, 2 on a
missing/unreadable file.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.export import (
    format_breakdown,
    load_chrome_trace,
    request_decomposition,
    stage_breakdown,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Per-stage latency breakdown from a Chrome trace file")
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable breakdown instead of the table")
    args = ap.parse_args(argv)
    try:
        spans = load_chrome_trace(args.trace)
    except (OSError, ValueError, KeyError) as exc:
        print(f"error: cannot read trace {args.trace!r}: {exc}",
              file=sys.stderr)
        return 2
    try:
        if args.json:
            print(json.dumps({
                "stages": stage_breakdown(spans),
                "requests": request_decomposition(spans),
            }, indent=2, sort_keys=True))
        else:
            print(f"{len(spans)} spans from {args.trace}")
            print(format_breakdown(spans))
    except BrokenPipeError:  # output piped into head/less that closed
        sys.stderr.close()   # suppress the interpreter's epipe warning
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
