"""SLO health monitor: rolling windows + multi-window burn-rate alerts.

Turns the cumulative counters/histograms the serving plane already
records into an operational verdict: ``ok | degraded | critical`` with
machine-readable reasons.  The design follows SRE burn-rate alerting:

- Every ``check()`` appends one *sample* (cumulative counter values +
  a latency bucket-snapshot) to a bounded deque; windowed rates are
  **deltas between samples**, so the monitor is O(1) memory and never
  rescans request history.
- Each SLO signal (error rate, reject rate, p99 latency) is evaluated
  over a **fast** and a **slow** window.  The *burn rate* is
  observed/target; ``degraded`` fires when the fast window burns ≥
  ``degraded_burn`` (default 1.0 — burning exactly the budget), and
  ``critical`` requires the fast window to burn ≥ ``critical_burn``
  *and* the slow window to confirm (≥ ``degraded_burn``) — a brief
  spike can degrade, but only sustained burn escalates.
- **Degradation detectors** ride along on signals other planes emit:
  exact-mode widen-round spikes (``ragdb_ivf_widen_rounds``),
  result-cache hit-rate collapse, and sanitizer trips
  (``ragdb_sanitizer_trips_total`` — any trip in the fast window is
  critical: a non-finite score or steady-state recompile is never
  routine).
- Publish lag is an instantaneous gauge (per tenant), compared
  directly against its target.

``ServingRuntime.health()`` wires a monitor to its ``ServingMetrics``
and exports ``ragdb_health_status`` (0 ok / 1 degraded / 2 critical)
plus per-signal burn gauges into the runtime registry so the verdict
ships in the Prometheus rendering.  The clock is injectable for
deterministic fault-injection tests.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass

from repro.obs.metrics import LogHistogram, global_registry

_STATUS_RANK = {"ok": 0, "degraded": 1, "critical": 2}


@dataclass(frozen=True)
class SLOTargets:
    """Objectives + alerting policy.  ``None`` disables a signal."""

    p99_ms: float | None = 250.0      # end-to-end latency objective
    error_rate: float | None = 0.02   # failed / (completed + failed)
    reject_rate: float | None = 0.10  # rejected / submitted
    publish_lag_s: float | None = None
    widen_rounds_mean: float | None = 3.0   # exact-mode widen spike
    cache_hit_floor: float | None = None    # hit-rate collapse detector
    fast_window_s: float = 60.0
    slow_window_s: float = 300.0
    min_samples: int = 20             # min fast-window requests to judge
    degraded_burn: float = 1.0
    critical_burn: float = 2.0


class HealthMonitor:
    """See module docstring.  One monitor per serving runtime."""

    def __init__(self, metrics, *, targets: SLOTargets | None = None,
                 registries=None, clock=time.monotonic,
                 export_registry=None):
        self.metrics = metrics          # ServingMetrics (health_sample())
        self.targets = targets or SLOTargets()
        # registries scanned for cross-plane signals (widen rounds,
        # sanitizer trips, publish lag); () isolates tests from global
        # state
        self.registries = (tuple(registries) if registries is not None
                           else (global_registry(),))
        self.clock = clock
        self.export_registry = export_registry
        cap = max(8, int(self.targets.slow_window_s) * 4)
        self._samples: deque = deque(maxlen=min(cap, 4096))

    # ---- sampling -------------------------------------------------------

    def _scan_registries(self) -> dict:
        widen_n = 0
        widen_sum = 0.0
        trips = 0
        lags: dict[str, float] = {}
        for reg in self.registries:
            for _labels, h in reg.series("ragdb_ivf_widen_rounds").items():
                widen_n += h.n
                widen_sum += h.total
            for _labels, c in reg.series(
                    "ragdb_sanitizer_trips_total").items():
                trips += c.value
            for labels, g in reg.series(
                    "ragdb_publish_lag_seconds").items():
                lags[dict(labels).get("tenant", "-")] = g.value
        return {"widen_n": widen_n, "widen_sum": widen_sum,
                "sanitizer_trips": trips, "publish_lag": lags}

    def sample(self) -> dict:
        """Append one cumulative sample (call on every ``check()``)."""
        s = {"t": self.clock()}
        s.update(self.metrics.health_sample())
        s.update(self._scan_registries())
        self._samples.append(s)
        return s

    def _window_delta(self, now: float, window_s: float):
        """(old, new) sample pair spanning ≈ the window: the anchor is
        the newest sample at least ``window_s`` old (else the oldest
        available).  None until two samples exist."""
        if len(self._samples) < 2:
            return None
        new = self._samples[-1]
        anchor = None
        for s in self._samples:
            if now - s["t"] >= window_s:
                anchor = s
            else:
                break
        if anchor is None or anchor is new:
            anchor = self._samples[0]
        if anchor is new:
            anchor = self._samples[-2]
        return anchor, new

    # ---- windowed signal math ------------------------------------------

    @staticmethod
    def _rates(old: dict, new: dict) -> dict:
        req = new["requests"] - old["requests"]
        comp = new["completed"] - old["completed"]
        rej = new["rejected"] - old["rejected"]
        fail = new["failed"] - old["failed"]
        hits = new["cache_hits"] - old["cache_hits"]
        miss = new["cache_misses"] - old["cache_misses"]
        served = comp + fail
        lookups = hits + miss
        return {
            "requests": req,
            "error_rate": fail / served if served else 0.0,
            "reject_rate": rej / req if req else 0.0,
            "cache_hit_rate": hits / lookups if lookups else None,
            "p99_s": _bucket_diff_p99(old["latency_buckets"],
                                      new["latency_buckets"]),
            "widen_mean": (
                (new["widen_sum"] - old["widen_sum"])
                / (new["widen_n"] - old["widen_n"])
                if new["widen_n"] > old["widen_n"] else None),
            "sanitizer_trips": (new["sanitizer_trips"]
                                - old["sanitizer_trips"]),
        }

    def status(self) -> dict:
        """Evaluate the SLOs against the buffered samples (read-only —
        ``check()`` is sample + status + export)."""
        t = self.targets
        now = self._samples[-1]["t"] if self._samples else self.clock()
        fast = self._window_delta(now, t.fast_window_s)
        slow = self._window_delta(now, t.slow_window_s)
        out = {"status": "ok", "reasons": [], "signals": {}}
        if fast is None:
            out["signals"]["note"] = "warming up (<2 samples)"
            return out
        fr = self._rates(*fast)
        sr = self._rates(*slow) if slow else fr
        out["signals"]["fast"] = fr
        out["signals"]["slow"] = sr

        def escalate(level: str, reason: str) -> None:
            if _STATUS_RANK[level] > _STATUS_RANK[out["status"]]:
                out["status"] = level
            out["reasons"].append(reason)

        def burn_signal(name: str, fast_v, slow_v, target) -> None:
            if target is None or fast_v is None:
                return
            burn_f = fast_v / target if target > 0 else 0.0
            burn_s = (slow_v / target
                      if target > 0 and slow_v is not None else 0.0)
            out["signals"][f"{name}_burn_fast"] = round(burn_f, 3)
            out["signals"][f"{name}_burn_slow"] = round(burn_s, 3)
            if burn_f >= t.critical_burn and burn_s >= t.degraded_burn:
                escalate("critical",
                         f"{name} burn {burn_f:.2f}x fast / "
                         f"{burn_s:.2f}x slow (target {target})")
            elif burn_f >= t.degraded_burn:
                escalate("degraded",
                         f"{name} burn {burn_f:.2f}x in fast window "
                         f"(target {target})")

        judged = fr["requests"] >= t.min_samples
        if judged:
            burn_signal("error_rate", fr["error_rate"],
                        sr["error_rate"], t.error_rate)
            burn_signal("reject_rate", fr["reject_rate"],
                        sr["reject_rate"], t.reject_rate)
            if t.p99_ms is not None:
                burn_signal("p99", fr["p99_s"], sr["p99_s"],
                            t.p99_ms / 1e3)
        else:
            out["signals"]["note"] = (
                f"fast window below min_samples "
                f"({fr['requests']}/{t.min_samples})")

        # ---- degradation detectors --------------------------------------
        if fr["sanitizer_trips"] > 0:
            escalate("critical",
                     f"{fr['sanitizer_trips']} sanitizer trip(s) in "
                     f"fast window (non-finite scores or steady-state "
                     f"recompiles)")
        if (t.widen_rounds_mean is not None
                and fr["widen_mean"] is not None
                and fr["widen_mean"] > t.widen_rounds_mean):
            escalate("degraded",
                     f"ivf widen-round spike: mean "
                     f"{fr['widen_mean']:.1f} rounds/dispatch "
                     f"(> {t.widen_rounds_mean})")
        if (t.cache_hit_floor is not None and judged
                and fr["cache_hit_rate"] is not None
                and fr["cache_hit_rate"] < t.cache_hit_floor):
            escalate("degraded",
                     f"cache hit-rate collapse: "
                     f"{fr['cache_hit_rate']:.2f} "
                     f"(< {t.cache_hit_floor})")
        if t.publish_lag_s is not None:
            for tenant, lag in self._samples[-1]["publish_lag"].items():
                if lag > t.publish_lag_s:
                    escalate("degraded",
                             f"publish lag {lag:.2f}s for tenant "
                             f"{tenant} (> {t.publish_lag_s}s)")
        return out

    def check(self) -> dict:
        """Sample + evaluate + export: the one call drivers make."""
        self.sample()
        out = self.status()
        if self.export_registry is not None:
            reg = self.export_registry
            reg.gauge("ragdb_health_status",
                      "0 ok / 1 degraded / 2 critical").set(
                _STATUS_RANK[out["status"]])
            for key in ("error_rate_burn_fast", "reject_rate_burn_fast",
                        "p99_burn_fast"):
                if key in out["signals"]:
                    reg.gauge(f"ragdb_health_{key}",
                              "fast-window SLO burn rate").set(
                        out["signals"][key])
        return out


def _bucket_diff_p99(old: tuple, new: tuple) -> float | None:
    """p99 of the *window* between two cumulative bucket snapshots
    (geometric bucket midpoints, same estimator as LogHistogram)."""
    old_counts, old_n = old[0], old[1]
    new_counts, new_n, _total, new_min, new_max = new
    n = new_n - old_n
    if n <= 0:
        return None
    counts = [b - a for a, b in zip(old_counts, new_counts)]
    rank = 0.99 * (n - 1)
    bounds = [LogHistogram.BASE * LogHistogram.GROWTH ** i
              for i in range(LogHistogram.N_BUCKETS)]
    cum = 0
    for i, c in enumerate(counts):
        cum += c
        if cum > rank:
            if i >= LogHistogram.N_BUCKETS:
                return new_max
            if i == 0:
                est = bounds[0] / LogHistogram.GROWTH ** 0.5
            else:
                est = bounds[i - 1] * LogHistogram.GROWTH ** 0.5
            return min(max(est, new_min), new_max)
    return new_max
