"""Suppression pragma grammar (docs/ARCHITECTURE.md §11).

A pragma makes an intentional rule exception *reviewable*::

    cos = q @ dv.T  # analysis: allow[unpinned-reduction] -- opt-in gemm
                    #   path, documented non-bit-stable (ARCHITECTURE §5)

Grammar (one pragma per comment)::

    "# analysis: allow[" rule-id "]" [ separator justification ]

- ``rule-id`` is a registered rule (``runner.RULES``) — unknown ids are
  themselves findings, so a typo cannot silently disable nothing.
- ``separator`` is ``--``, ``—`` or ``:``; the justification is free
  text.  ``--strict`` requires a non-empty justification on every
  pragma (the acceptance contract: suppressions are *audited*, not
  waved through).
- A trailing pragma applies to its own physical line; a comment-only
  pragma line applies to the next *logical* source line — continuation
  comment lines are skipped, and a statement spanning several physical
  lines (open brackets) is covered to its closing line.
- A pragma that suppresses nothing is reported (``unused pragma``) so
  stale suppressions cannot linger after the code they excused is gone.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PRAGMA_RE = re.compile(
    r"#\s*analysis:\s*allow\[(?P<rule>[a-z0-9-]*)\]"
    r"(?:\s*(?:--|—|:)\s*(?P<why>.*?))?\s*$"
)


@dataclass
class Pragma:
    """One parsed suppression comment."""

    path: str
    line: int          # line the pragma comment sits on (1-based)
    applies_to: int    # first line whose findings it suppresses
    applies_end: int   # last covered line (logical-statement span)
    rule: str
    justification: str
    used: bool = field(default=False, compare=False)


def parse_pragmas(relpath: str, lines: list[str]) -> list[Pragma]:
    """Scan raw source lines for pragmas.

    Purely lexical: a pragma inside a string literal would be honored
    too, which is fine — the analyzer's own fixture tests are the only
    place that happens, and they build sources from fragments.
    """
    out: list[Pragma] = []
    for i, text in enumerate(lines, start=1):
        m = PRAGMA_RE.search(text)
        if m is None:
            continue
        applies_to = applies_end = i
        why = [(m.group("why") or "").strip()]
        if text.lstrip().startswith("#"):
            # comment-only pragma: applies to the next source line;
            # further comment lines continue the justification
            applies_to = i + 1
            while (applies_to <= len(lines)
                   and lines[applies_to - 1].lstrip().startswith("#")):
                why.append(lines[applies_to - 1].lstrip().lstrip("#").strip())
                applies_to += 1
            applies_end = _statement_end(lines, applies_to)
        out.append(
            Pragma(
                path=relpath,
                line=i,
                applies_to=applies_to,
                applies_end=applies_end,
                rule=m.group("rule"),
                justification=" ".join(w for w in why if w),
            )
        )
    return out


def _statement_end(lines: list[str], start: int) -> int:
    """Last physical line of the logical statement starting at ``start``
    (1-based), found by bracket balance.  Lexical — string literals
    containing brackets could fool it — but the covered code is the
    repo's own scoring/persistence modules, where that doesn't arise."""
    depth = 0
    i = start
    while i <= len(lines):
        text = lines[i - 1].split("#", 1)[0]
        depth += sum(text.count(c) for c in "([{")
        depth -= sum(text.count(c) for c in ")]}")
        if depth <= 0:
            return i
        i += 1
    return len(lines)


class PragmaIndex:
    """Per-file suppression lookup with use tracking."""

    def __init__(self, pragmas: list[Pragma]):
        self.pragmas = pragmas
        self._by_rule: dict[str, list[Pragma]] = {}
        for p in pragmas:
            self._by_rule.setdefault(p.rule, []).append(p)

    def suppresses(self, rule: str, line: int) -> bool:
        for p in self._by_rule.get(rule, ()):
            if p.applies_to <= line <= p.applies_end:
                p.used = True
                return True
        return False
