"""Opt-in runtime sanitizers: retrace detection + NaN/Inf score guard.

Static rules catch contract violations the AST can see; these catch the
two failure modes it cannot — a jit recompile sneaking into the steady-
state serving loop (a latency cliff EdgeRAG measures in seconds on edge
hardware), and a non-finite score escaping a scoring path (which top-k
silently absorbs until results are garbage).

Both are disabled by default and cost nothing when off.  Enable with::

    RAGDB_SANITIZERS=1 python -m benchmarks.bench_serving --smoke

or programmatically via :func:`enable`.  A tripped sanitizer raises
:class:`SanitizerError` (an ``AssertionError`` subclass, so test
harnesses that catch assertion failures see it naturally).

This module is stdlib-only and imports neither jax nor numpy — hot
modules import it at load time; it duck-types on the objects handed to
it (``_cache_size`` for jitted callables, elementwise comparison for
score arrays).
"""
from __future__ import annotations

import os
import threading

from repro.obs.metrics import global_registry

ENV_FLAG = "RAGDB_SANITIZERS"

_TRUTHY = {"1", "true", "yes", "on"}

_enabled: bool | None = None  # None → read ENV_FLAG lazily
_lock = threading.Lock()


class SanitizerError(AssertionError):
    """A runtime invariant the sanitizers guard was violated."""


def _count_trip(rule: str, where: str) -> None:
    """Surface a trip as a first-class metric before the raise — the
    exception may be swallowed by a request future, but the counter
    survives in the obs registry for the metrics endpoint."""
    global_registry().counter(
        "ragdb_sanitizer_trips_total",
        "runtime sanitizer violations (finite-score / retrace guards)",
        rule=rule, where=where,
    ).inc()


def enabled() -> bool:
    if _enabled is not None:
        return _enabled
    return os.environ.get(ENV_FLAG, "").strip().lower() in _TRUTHY


def enable(on: bool = True) -> None:
    """Programmatic override of the env flag (tests, bench harness)."""
    global _enabled
    _enabled = on


# --------------------------------------------------------------------------
# NaN/Inf score guard
# --------------------------------------------------------------------------

def check_finite_scores(vals, n_rows: int, where: str) -> None:
    """Raise if any selected top-k score in the first ``n_rows`` rows is
    NaN or ±Inf.

    ``vals`` is the host-side (row, k) score array at the one audited
    device→host boundary (``engine.results_from_topk``).  Rows beyond
    ``n_rows`` are bucket padding and legitimately hold -inf sentinels;
    selected scores of real rows must be finite — probe widening
    guarantees every returned slot holds a real candidate.
    """
    if not enabled():
        return
    head = vals[:n_rows]
    # duck-typed finiteness: x != x catches NaN; the comparisons catch
    # ±inf without importing numpy here
    bad = (head != head) | (head == float("inf")) | (head == float("-inf"))
    if bool(bad.any()):
        _count_trip("finite-scores", where)
        raise SanitizerError(
            f"non-finite score escaped the scoring path at {where}: "
            f"{int(bad.sum())} of {head.size} selected scores are "
            "NaN/Inf — upstream vectors or masks are corrupt"
        )


# --------------------------------------------------------------------------
# Retrace guard
# --------------------------------------------------------------------------

# name → jitted callable.  Modules register their steady-state jitted
# entry points at import; kmeans training fns are deliberately absent
# (retrains legitimately trace new shapes).
_registry: dict[str, object] = {}


def register_jit(name: str, fn) -> None:
    """Register a jitted callable for retrace accounting.  Idempotent
    per name; costs one dict slot when sanitizers are off."""
    _registry[name] = fn


def jit_cache_sizes() -> dict[str, int]:
    """Current compiled-variant count per registered jit function.

    Uses the ``_cache_size()`` introspection hook on jitted callables;
    functions not exposing it (API drift, plain-function stubs in
    tests) are skipped rather than failing the guard.
    """
    out: dict[str, int] = {}
    for name, fn in _registry.items():
        probe = getattr(fn, "_cache_size", None)
        if probe is None:
            continue
        try:
            out[name] = int(probe())
        except Exception:
            continue
    return out


class RetraceGuard:
    """Asserts zero steady-state recompiles after an explicit warmup.

    Protocol (wired through ``ServingRuntime``):

    1. warm every power-of-two batch bucket the serving loop can emit;
    2. :meth:`arm` — baseline the per-function jit cache sizes;
    3. the scheduler calls :meth:`check` after each flush — any cache
       growth means a shape/dtype escaped the bucket discipline and
       recompiled on the hot path;
    4. a snapshot publish calls :meth:`reset` (new corpus generation
       may legitimately trace new padded shapes); the caller re-arms
       after re-warming.

    After a trip the baseline is rebased to the current sizes, so one
    regression raises once instead of failing every later batch.
    """

    def __init__(self) -> None:
        self._baseline: dict[str, int] | None = None
        self._lock = threading.Lock()

    @property
    def armed(self) -> bool:
        return self._baseline is not None

    def arm(self) -> None:
        with self._lock:
            self._baseline = jit_cache_sizes()

    def reset(self) -> None:
        with self._lock:
            self._baseline = None

    def report(self) -> dict[str, int]:
        """Cache growth per function since arming (empty when clean)."""
        with self._lock:
            if self._baseline is None:
                return {}
            now = jit_cache_sizes()
            return {
                name: size - self._baseline.get(name, 0)
                for name, size in now.items()
                if size > self._baseline.get(name, 0)
            }

    def check(self, where: str) -> None:
        if not enabled():
            return
        with self._lock:
            if self._baseline is None:
                return
            now = jit_cache_sizes()
            grew = {
                name: (self._baseline.get(name, 0), size)
                for name, size in now.items()
                if size > self._baseline.get(name, 0)
            }
            if grew:
                self._baseline = now  # rebase: report each regression once
        if grew:
            _count_trip("retrace", where)
            detail = ", ".join(
                f"{name}: {a}→{b}" for name, (a, b) in sorted(grew.items())
            )
            raise SanitizerError(
                f"steady-state jit recompile at {where}: {detail} — a "
                "shape or dtype escaped the power-of-two bucket "
                "discipline (warm every bucket before arming)"
            )
