"""Shared analyzer machinery: findings, the rule interface, AST helpers.

Everything here is pure stdlib (``ast`` + ``fnmatch``) — the analyzer
must be importable and runnable on the barest edge install, matching
the paper's zero-dependency thesis.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from fnmatch import fnmatch


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str      # package-relative, e.g. "core/engine.py"
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


class Rule:
    """One invariant checker.

    Subclasses set ``id`` (the pragma-facing kebab-case name), ``title``
    and ``rationale`` (the §11 docs table is generated from these), and
    ``scope`` — fnmatch patterns over package-relative paths.  ``check``
    returns raw findings; the runner applies pragma suppression.
    """

    id: str = ""
    title: str = ""
    rationale: str = ""
    scope: tuple[str, ...] = ("*",)

    def applies_to(self, relpath: str) -> bool:
        return any(fnmatch(relpath, pat) for pat in self.scope)

    def check(self, tree: ast.Module, relpath: str) -> list[Finding]:
        raise NotImplementedError

    def finding(self, relpath: str, node: ast.AST, message: str) -> Finding:
        return Finding(
            rule=self.id,
            path=relpath,
            line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0),
            message=message,
        )


# --------------------------------------------------------------------------
# AST helpers
# --------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> str | None:
    """``jnp.dot`` / ``jax.lax.top_k`` → their dotted string, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> str | None:
    return dotted_name(node.func)


def is_self_attr(node: ast.AST, attrs: set[str] | None = None) -> str | None:
    """``self.<attr>`` → attr (optionally restricted to ``attrs``)."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        if attrs is None or node.attr in attrs:
            return node.attr
    return None


def decorator_names(fn: ast.FunctionDef) -> list[str]:
    """Dotted names of a function's decorators; for ``Call`` decorators
    (``@partial(jax.jit, ...)``) both the callee and — when the callee
    is ``partial`` — the first argument's dotted name are reported, so
    jit detection sees through the ``functools.partial`` idiom."""
    names: list[str] = []
    for dec in fn.decorator_list:
        if isinstance(dec, ast.Call):
            callee = dotted_name(dec.func)
            if callee is not None:
                names.append(callee)
            if callee in ("partial", "functools.partial") and dec.args:
                inner = dotted_name(dec.args[0])
                if inner is not None:
                    names.append(inner)
        else:
            name = dotted_name(dec)
            if name is not None:
                names.append(name)
    return names


JIT_NAMES = {"jax.jit", "jit", "pjit", "jax.pjit"}


def is_jitted(fn: ast.FunctionDef) -> bool:
    return any(n in JIT_NAMES for n in decorator_names(fn))


def assigned_jit_targets(tree: ast.Module) -> set[str]:
    """Function names wrapped by a module-level ``x = jax.jit(fn, ...)``
    — the non-decorator jit idiom (index/sharded.py)."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and call_name(node) in JIT_NAMES:
            if node.args and isinstance(node.args[0], ast.Name):
                out.add(node.args[0].id)
    return out


def walk_functions(tree: ast.Module):
    """Yield every FunctionDef/AsyncFunctionDef (including nested)."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
