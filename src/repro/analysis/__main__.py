"""``python -m repro.analysis`` — the invariant-analyzer CLI.

Exit codes:
    0  tree is clean (no findings; under --strict, all pragmas justified)
    1  findings (or parse errors)
    2  usage error (argparse)
    3  --check-audit drift: the committed suppression audit does not
       match the tree — regenerate with --write-audit and review
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.runner import RULES, render_audit, run_analysis


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="RAGdb invariant analyzer (rules: "
                    + ", ".join(r.id for r in RULES) + ")",
    )
    parser.add_argument(
        "--root", default=".",
        help="repo root (or a bare package dir for fixtures)")
    parser.add_argument(
        "--strict", action="store_true",
        help="require a justification on every suppression pragma")
    parser.add_argument(
        "--json", action="store_true",
        help="machine-readable findings on stdout")
    parser.add_argument(
        "--write-audit", metavar="PATH",
        help="write the suppression audit (docs/ANALYSIS_AUDIT.md)")
    parser.add_argument(
        "--check-audit", metavar="PATH",
        help="exit 3 unless PATH matches the regenerated audit")
    args = parser.parse_args(argv)

    report = run_analysis(args.root, strict=args.strict)

    if args.json:
        print(json.dumps(
            {
                "files": len(report.files),
                "findings": [
                    {"rule": f.rule, "path": f.path, "line": f.line,
                     "col": f.col, "message": f.message}
                    for f in report.findings
                ],
                "errors": report.errors,
                "suppressions": sum(1 for p in report.pragmas if p.used),
            },
            indent=2,
        ))
    else:
        print(report.format())

    if args.write_audit:
        # plain write, not the durability protocol: this is a dev/CI
        # artifact regenerated from source, not a crash-safe publish
        with open(args.write_audit, "w", encoding="utf-8") as fh:
            fh.write(render_audit(report))
        print(f"wrote {args.write_audit}", file=sys.stderr)

    if not report.ok:
        return 1

    if args.check_audit:
        expected = render_audit(report)
        actual = ""
        if os.path.exists(args.check_audit):
            with open(args.check_audit, encoding="utf-8") as fh:
                actual = fh.read()
        if actual != expected:
            print(
                f"{args.check_audit} is stale — suppressions changed; "
                "regenerate with --write-audit and commit the diff",
                file=sys.stderr,
            )
            return 3

    return 0


if __name__ == "__main__":
    sys.exit(main())
