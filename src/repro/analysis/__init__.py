"""Zero-dependency invariant analyzer + runtime sanitizers
(docs/ARCHITECTURE.md §11).

Six PRs of hard invariants back the paper's claims — pinned-order
``stable_rowdot`` for every map-path cosine, the ``KnowledgeBase``
single-writer lock, fsync-then-rename commits, immutable
generation-pinned snapshots, power-of-two jit buckets.  Until now they
were enforced only by tests and reviewer memory; PR 6 showed how easily
one slips (XLA reduction-order drift broke cross-plane bit-identity).
This package encodes them as machine-checked contracts:

- **Static rules** (pure ``ast``, no new dependencies — the analyzer
  obeys the same zero-dependency thesis it guards):

  =====================  ==================================================
  ``unpinned-reduction``  raw ``@``/``dot``/``einsum`` over the feature
                          axis in scoring modules must route through
                          ``hsf.stable_rowdot`` (R1)
  ``writer-lock``         public ``KnowledgeBase`` mutators must hold the
                          ``_single_writer`` guard (R2)
  ``durability``          container/journal publishes must go through the
                          fsync-then-rename helpers, never bare
                          ``open(.., "w")`` + rename (R3)
  ``snapshot-mutation``   ``EngineSnapshot`` is written only at
                          construction — frozen dataclass, no attribute
                          stores, no ``object.__setattr__`` (R4)
  ``host-sync``           no ``.item()``/``float()``/``np.asarray``/
                          ``jax.device_get`` inside jitted scoring
                          functions (R5)
  =====================  ==================================================

  Intentional exceptions carry an inline, reviewable pragma::

      # analysis: allow[unpinned-reduction] -- opt-in gemm path, ...

  ``python -m repro.analysis --strict`` is the CI gate: exit 0 only when
  the tree is clean and every pragma carries a justification.

- **Runtime sanitizers** (``sanitizers.py``, opt-in via
  ``RAGDB_SANITIZERS=1``): a NaN/Inf guard on every scoring path's
  host-boundary output and a retrace guard asserting zero steady-state
  jit recompiles in the serving loop after warmup.

Import note: this ``__init__`` stays dependency-free and cheap — hot
modules (core/engine.py) import ``repro.analysis.sanitizers`` at module
load, so nothing here may pull in jax or the analyzer runner.  The CLI
(``__main__``) imports the runner lazily.
"""
from __future__ import annotations

__all__ = ["run_analysis", "RULES", "Finding"]


def __getattr__(name):
    # lazy re-exports: keep `import repro.analysis.sanitizers` from
    # paying for the ast runner (and vice versa)
    if name in __all__:
        from repro.analysis import runner

        return getattr(runner, name)
    raise AttributeError(name)
