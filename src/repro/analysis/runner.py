"""File discovery, pragma application, and report assembly.

The runner walks the package tree, parses each module once, runs every
in-scope rule, then applies the suppression pragmas.  Pragma *hygiene*
problems (unknown rule id, unused pragma, missing justification under
``--strict``) are reported as findings with rule id ``pragma`` so the
same exit-code contract covers them.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field

from repro.analysis.base import Finding, Rule
from repro.analysis.pragmas import Pragma, PragmaIndex, parse_pragmas
from repro.analysis.rules import RULES

__all__ = ["RULES", "Finding", "Report", "run_analysis", "render_audit"]

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache"}
# the analyzer does not analyze itself: its fixtures and rule sources
# quote every forbidden pattern verbatim
_SKIP_PREFIXES = ("analysis/",)


@dataclass
class Report:
    """Everything one analysis run produced."""

    root: str
    files: list[str] = field(default_factory=list)
    findings: list[Finding] = field(default_factory=list)
    pragmas: list[Pragma] = field(default_factory=list)
    errors: list[str] = field(default_factory=list)  # unparsable files

    @property
    def ok(self) -> bool:
        return not self.findings and not self.errors

    def format(self) -> str:
        lines = [f.format() for f in self.findings]
        lines.extend(f"{p}: [parse-error]" for p in self.errors)
        lines.append(
            f"{len(self.files)} files, {len(self.findings)} findings, "
            f"{sum(1 for p in self.pragmas if p.used)} suppressions"
        )
        return "\n".join(lines)


def _package_root(root: str) -> str:
    """Analysis is rooted at the ``repro`` package so rule scopes read
    as package-relative paths (``core/hsf.py``).  A bare directory (the
    fixture case in tests) is used as-is."""
    for cand in (os.path.join(root, "src", "repro"), os.path.join(root, "repro")):
        if os.path.isdir(cand):
            return cand
    return root


def _discover(pkg_root: str) -> list[str]:
    out: list[str] = []
    for dirpath, dirnames, filenames in os.walk(pkg_root):
        dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS)
        for name in sorted(filenames):
            if not name.endswith(".py"):
                continue
            rel = os.path.relpath(os.path.join(dirpath, name), pkg_root)
            rel = rel.replace(os.sep, "/")
            if rel.startswith(_SKIP_PREFIXES):
                continue
            out.append(rel)
    return out


def _hygiene_findings(
    relpath: str,
    index: PragmaIndex,
    known_rules: set[str],
    strict: bool,
) -> list[Finding]:
    out: list[Finding] = []
    for p in index.pragmas:
        if p.rule not in known_rules:
            out.append(Finding(
                rule="pragma", path=relpath, line=p.line, col=0,
                message=f"pragma names unknown rule `{p.rule}` — "
                        "a typo here silently disables nothing; known "
                        "rules: " + ", ".join(sorted(known_rules)),
            ))
            continue
        if not p.used:
            out.append(Finding(
                rule="pragma", path=relpath, line=p.line, col=0,
                message=f"unused pragma allow[{p.rule}] — the code it "
                        "excused is gone; remove it",
            ))
        if strict and not p.justification:
            out.append(Finding(
                rule="pragma", path=relpath, line=p.line, col=0,
                message=f"pragma allow[{p.rule}] has no justification — "
                        "--strict requires `-- <why>` on every "
                        "suppression",
            ))
    return out


def run_analysis(
    root: str,
    strict: bool = False,
    rules: tuple[Rule, ...] = RULES,
) -> Report:
    pkg_root = _package_root(root)
    report = Report(root=pkg_root)
    known_rules = {r.id for r in rules}
    for relpath in _discover(pkg_root):
        report.files.append(relpath)
        full = os.path.join(pkg_root, relpath)
        with open(full, encoding="utf-8") as fh:
            source = fh.read()
        try:
            tree = ast.parse(source, filename=relpath)
        except SyntaxError as exc:
            report.errors.append(f"{relpath}:{exc.lineno}")
            continue
        index = PragmaIndex(parse_pragmas(relpath, source.splitlines()))
        report.pragmas.extend(index.pragmas)
        for rule in rules:
            if not rule.applies_to(relpath):
                continue
            for f in rule.check(tree, relpath):
                if not index.suppresses(f.rule, f.line):
                    report.findings.append(f)
        report.findings.extend(
            _hygiene_findings(relpath, index, known_rules, strict)
        )
    report.findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return report


def render_audit(report: Report, rules: tuple[Rule, ...] = RULES) -> str:
    """The checked-in suppression audit (docs/ANALYSIS_AUDIT.md): every
    active pragma with its justification, grouped by rule.  CI diffs
    this against the committed copy so a new suppression is a visible
    reviewed line, never a silent one."""
    lines = [
        "# Analysis suppression audit",
        "",
        "Generated by `python -m repro.analysis --write-audit`; CI",
        "verifies it with `--check-audit`.  Every entry is an inline",
        "`# analysis: allow[rule]` pragma in the tree — the set below is",
        "the complete list of places the invariants are intentionally",
        "relaxed, each with its reviewed justification.",
        "",
    ]
    by_rule: dict[str, list[Pragma]] = {}
    for p in report.pragmas:
        if p.used:
            by_rule.setdefault(p.rule, []).append(p)
    for rule in rules:
        pragmas = by_rule.pop(rule.id, [])
        if not pragmas:
            continue
        lines.append(f"## {rule.id} — {rule.title}")
        lines.append("")
        for p in sorted(pragmas, key=lambda p: (p.path, p.line)):
            lines.append(f"- `{p.path}:{p.line}` — {p.justification}")
        lines.append("")
    for rule_id, pragmas in sorted(by_rule.items()):  # unregistered ids
        lines.append(f"## {rule_id}")
        lines.append("")
        for p in sorted(pragmas, key=lambda p: (p.path, p.line)):
            lines.append(f"- `{p.path}:{p.line}` — {p.justification}")
        lines.append("")
    if len(lines) == 8:
        lines.append("(no active suppressions)")
        lines.append("")
    return "\n".join(lines)
