"""The invariant rules (R1–R6).  See docs/ARCHITECTURE.md §11 for the
rationale table; each rule's ``rationale`` string is the one-line form.

Every rule is a conservative *syntactic* checker: it flags the pattern
wherever it appears in scope and relies on the pragma grammar
(pragmas.py) to make intentional exceptions explicit and justified.
False positives are cheap (one reviewed pragma line); false negatives
are the expensive failure mode — PR 6's reduction-order drift survived
two review passes before a parity test caught it.
"""
from __future__ import annotations

import ast

from repro.analysis.base import (
    Finding,
    Rule,
    assigned_jit_targets,
    call_name,
    decorator_names,
    dotted_name,
    is_jitted,
    is_self_attr,
    walk_functions,
)

# --------------------------------------------------------------------------
# R1 — pinned-reduction discipline in scoring modules
# --------------------------------------------------------------------------

_REDUCTION_FNS = {"dot", "matmul", "einsum", "inner", "tensordot", "vdot"}
_NUMERIC_MODULES = {"jnp", "np", "numpy", "jax.numpy"}
_LAX_REDUCTIONS = {"jax.lax.dot", "jax.lax.dot_general",
                   "lax.dot", "lax.dot_general"}


class PinnedReductionRule(Rule):
    """R1: every cosine on a bit-identity path routes through
    ``hsf.stable_rowdot``."""

    id = "unpinned-reduction"
    title = "Pinned-order reductions in scoring modules"
    rationale = (
        "XLA leaves dot-product reduction order unspecified, so a raw "
        "`@`/`dot`/`einsum` over the feature axis can round differently "
        "between the flat scan, a gathered IVF block, and a shard — "
        "silently breaking every bit-identity contract.  Scoring-module "
        "reductions must route through hsf.stable_rowdot (the explicit "
        "pairwise-halving tree) or carry a pragma stating why the path "
        "is intentionally unpinned (e.g. the opt-in gemm/kernel paths)."
    )
    scope = (
        "core/hsf.py",
        "core/engine.py",
        "core/retrieval.py",
        "index/*.py",
    )
    # the pinned formulation itself (and clones of it in fixtures) is
    # the one place elementwise-multiply trees may live
    exempt_functions = ("stable_rowdot",)

    def check(self, tree: ast.Module, relpath: str) -> list[Finding]:
        exempt_spans: list[tuple[int, int]] = [
            (fn.lineno, fn.end_lineno or fn.lineno)
            for fn in walk_functions(tree)
            if fn.name in self.exempt_functions
        ]

        def exempt(node: ast.AST) -> bool:
            line = getattr(node, "lineno", 0)
            return any(a <= line <= b for a, b in exempt_spans)

        out: list[Finding] = []
        for node in ast.walk(tree):
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.MatMult):
                if not exempt(node):
                    out.append(self.finding(
                        relpath, node,
                        "raw `@` matmul in a scoring module — route the "
                        "cosine through hsf.stable_rowdot or justify the "
                        "unpinned reduction with a pragma",
                    ))
            elif isinstance(node, ast.Call):
                name = call_name(node)
                if name is None or exempt(node):
                    continue
                mod, _, fn = name.rpartition(".")
                if ((mod in _NUMERIC_MODULES and fn in _REDUCTION_FNS)
                        or name in _LAX_REDUCTIONS):
                    out.append(self.finding(
                        relpath, node,
                        f"unpinned reduction `{name}` in a scoring module "
                        "— route through hsf.stable_rowdot or justify "
                        "with a pragma",
                    ))
        return out


# --------------------------------------------------------------------------
# R2 — single-writer lock discipline on KnowledgeBase mutators
# --------------------------------------------------------------------------

# authoritative writer state: doc regions, the change log, the df/idf
# statistics (via vectorizer), the index state, and the persistence
# chain.  Derived caches (_matrix/_dirty/_postings/...) are excluded:
# they are rebuilt idempotently and guarded by the same contract.
_WRITER_ATTRS = {
    "records", "texts", "term_counts", "signatures", "vectorizer",
    "index_state", "loaded_generation",
    "_version", "_changed_at", "_removed_at", "_meta_changed_at",
    "_index_rev", "_index_persisted_rev", "_index_persisted_centroid_sha",
    "_persisted_version", "_persisted_ids", "_persisted_path", "_base_uid",
}
_MUTATING_METHODS = {
    "pop", "clear", "update", "setdefault", "add", "discard", "remove",
    "append", "extend", "add_doc", "remove_doc", "popitem",
}
_GUARD_NAME = "_single_writer"


def _method_mutates_directly(fn: ast.FunctionDef) -> list[str]:
    """Attr names of authoritative state this method writes directly."""
    hits: list[str] = []
    for node in ast.walk(fn):
        targets: list[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        for t in targets:
            # self.attr = ... / self.attr[...] = ... / self.vectorizer.df = ...
            probe = t
            if isinstance(probe, ast.Subscript):
                probe = probe.value
            if isinstance(probe, ast.Attribute) and is_self_attr(probe.value):
                probe = probe.value  # nested: self.vectorizer.df
            attr = is_self_attr(probe, _WRITER_ATTRS)
            if attr is not None:
                hits.append(attr)
        if isinstance(node, ast.Call):
            # self.<state>.pop(...) / self.vectorizer.add_doc(...)
            f = node.func
            if (isinstance(f, ast.Attribute)
                    and f.attr in _MUTATING_METHODS
                    and is_self_attr(f.value, _WRITER_ATTRS) is not None):
                hits.append(f.value.attr)  # type: ignore[union-attr]
    return hits


def _has_writer_guard(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.With):
            for item in node.items:
                expr = item.context_expr
                if (isinstance(expr, ast.Call)
                        and isinstance(expr.func, ast.Attribute)
                        and expr.func.attr == _GUARD_NAME
                        and isinstance(expr.func.value, ast.Name)
                        and expr.func.value.id == "self"):
                    return True
    return False


class WriterLockRule(Rule):
    """R2: public mutators of a single-writer class hold the guard."""

    id = "writer-lock"
    title = "Single-writer lock discipline"
    rationale = (
        "KnowledgeBase is not a concurrent structure: a second writer "
        "silently corrupts df counts and change-log ordering, which the "
        "serving snapshots then pin forever.  Every public method that "
        "mutates authoritative state (doc regions, change log, df, "
        "index state, persistence chain) must run under the "
        "non-blocking `_single_writer` guard; internal `_*` helpers are "
        "called under it by their public wrappers."
    )
    scope = ("core/ingest.py",)

    def check(self, tree: ast.Module, relpath: str) -> list[Finding]:
        out: list[Finding] = []
        for cls in ast.walk(tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            members = {n.name for n in cls.body
                       if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
            fields = {t.target.id for t in cls.body
                      if isinstance(t, ast.AnnAssign)
                      and isinstance(t.target, ast.Name)}
            if _GUARD_NAME not in members and "_write_lock" not in fields:
                continue  # not a single-writer class
            methods = {n.name: n for n in cls.body
                       if isinstance(n, ast.FunctionDef)}
            # transitive closure: a method mutates if it writes state or
            # calls a sibling method that does
            mutates: dict[str, list[str]] = {
                name: _method_mutates_directly(fn)
                for name, fn in methods.items()
            }
            changed = True
            while changed:
                changed = False
                for name, fn in methods.items():
                    for node in ast.walk(fn):
                        if (isinstance(node, ast.Call)
                                and isinstance(node.func, ast.Attribute)
                                and isinstance(node.func.value, ast.Name)
                                and node.func.value.id == "self"
                                and node.func.attr in methods
                                and mutates[node.func.attr]
                                and not mutates[name]):
                            mutates[name] = [f"{node.func.attr}()"]
                            changed = True
            for name, fn in methods.items():
                if name.startswith("_") or not mutates[name]:
                    continue  # internal helper / read-only method
                if any("staticmethod" in d for d in decorator_names(fn)):
                    continue  # no self: constructs a fresh instance
                if not _has_writer_guard(fn):
                    what = ", ".join(sorted(set(mutates[name]))[:4])
                    out.append(self.finding(
                        relpath, fn,
                        f"public method `{cls.name}.{name}` mutates writer "
                        f"state ({what}) without `with "
                        f"self.{_GUARD_NAME}(...)`",
                    ))
        return out


# --------------------------------------------------------------------------
# R3 — durability discipline for container/journal publishes
# --------------------------------------------------------------------------

_WRITE_MODE_CHARS = set("wax+")
# The fsync-then-rename commit protocol lives in exactly these
# functions; new publish sites must either call them or be added here
# with a review of their crash-safety story.
_DURABILITY_HELPERS = {
    "_atomic_write_json",    # fsync'd JSON + atomic rename + dir fsync
    "write_container",       # fsync'd container image + atomic rename
    "append_journal_record", # truncate-to-commit, append, fsync, manifest
    "reset_journal",         # unlink-only (journal fold)
    "publish_sharded",       # content-addressed rename before manifest commit
    "_gc_shard_files",       # unlink-only (post-publish collection)
}


def _open_mode(node: ast.Call) -> str | None:
    """The mode literal of an ``open``/``os.fdopen`` call, if constant."""
    mode: ast.AST | None = None
    if len(node.args) >= 2:
        mode = node.args[1]
    for kw in node.keywords:
        if kw.arg == "mode":
            mode = kw.value
    if mode is None:
        return "r"  # default mode: read-only
    if isinstance(mode, ast.Constant) and isinstance(mode.value, str):
        return mode.value
    return None  # dynamic — conservatively unknown


class DurabilityRule(Rule):
    """R3: artifact publishes go through the fsync-then-rename helpers."""

    id = "durability"
    title = "Durability discipline for file publishes"
    rationale = (
        "Crash-safe persistence hangs on one protocol: write to a temp "
        "file, fsync, atomic-rename, fsync the directory "
        "(core/container.py).  A bare `open(.., 'w')` or `os.rename` "
        "publish can surface a torn or vanishing artifact after power "
        "loss — every write/rename in a persistence module must live "
        "inside one of the audited durability helpers."
    )
    scope = (
        "core/container.py",
        "core/ingest.py",
        "checkpoint/*.py",
        "serving/*.py",
        "index/*.py",
    )

    def check(self, tree: ast.Module, relpath: str) -> list[Finding]:
        helper_spans = [
            (fn.lineno, fn.end_lineno or fn.lineno)
            for fn in walk_functions(tree)
            if fn.name in _DURABILITY_HELPERS
        ]

        def inside_helper(node: ast.AST) -> bool:
            line = getattr(node, "lineno", 0)
            return any(a <= line <= b for a, b in helper_spans)

        out: list[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if name == "os.rename":
                # flagged even inside helpers: the blessed primitive is
                # os.replace (clobbering atomic rename) — os.rename has
                # platform-dependent failure on existing targets
                out.append(self.finding(
                    relpath, node,
                    "`os.rename` is never the publish primitive — use "
                    "the fsync-then-`os.replace` helpers "
                    "(core/container.py)",
                ))
            elif name == "os.replace" and not inside_helper(node):
                out.append(self.finding(
                    relpath, node,
                    "bare `os.replace` outside the durability helpers — "
                    "a rename-commit without fsync is not power-loss "
                    "durable; route through _atomic_write_json/"
                    "write_container or justify with a pragma",
                ))
            elif name in ("open", "os.fdopen") and not inside_helper(node):
                mode = _open_mode(node)
                if mode is None or _WRITE_MODE_CHARS & set(mode):
                    out.append(self.finding(
                        relpath, node,
                        f"writable `{name}(..., {mode!r})` outside the "
                        "durability helpers — artifact writes must use "
                        "the fsync-then-rename protocol or justify with "
                        "a pragma",
                    ))
        return out


# --------------------------------------------------------------------------
# R4 — snapshot immutability
# --------------------------------------------------------------------------

_SNAPSHOT_CLASSES = {"EngineSnapshot"}


def _is_frozen_dataclass(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        if (isinstance(dec, ast.Call)
                and dotted_name(dec.func) in ("dataclass", "dataclasses.dataclass")):
            for kw in dec.keywords:
                if (kw.arg == "frozen"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value is True):
                    return True
    return False


def _snapshot_sources(node: ast.AST) -> bool:
    """Expressions that yield a published snapshot: the class
    constructor, ``EngineSnapshot.capture(...)``, a ``.current``
    property read, or the manager's ``self._current``."""
    if isinstance(node, ast.Call):
        name = call_name(node)
        if name in _SNAPSHOT_CLASSES:
            return True
        if name is not None:
            head, _, tail = name.rpartition(".")
            if tail == "capture" and head.rpartition(".")[2] in _SNAPSHOT_CLASSES:
                return True
    if isinstance(node, ast.Attribute) and node.attr in ("current", "_current"):
        return True
    return False


class SnapshotMutationRule(Rule):
    """R4: ``EngineSnapshot`` attributes are assigned only in
    construction."""

    id = "snapshot-mutation"
    title = "Snapshot immutability"
    rationale = (
        "Readers serve published EngineSnapshots lock-free; the torn-"
        "read guarantee is exactly that a snapshot's attributes never "
        "change after capture.  The class must stay a frozen dataclass, "
        "and no code may assign attributes on a captured snapshot or "
        "bypass freezing via `object.__setattr__`."
    )
    scope = ("*",)

    def check(self, tree: ast.Module, relpath: str) -> list[Finding]:
        out: list[Finding] = []
        for cls in ast.walk(tree):
            if (isinstance(cls, ast.ClassDef)
                    and cls.name in _SNAPSHOT_CLASSES
                    and not _is_frozen_dataclass(cls)):
                out.append(self.finding(
                    relpath, cls,
                    f"`{cls.name}` must be declared "
                    "`@dataclass(frozen=True)` — snapshots are the "
                    "lock-free read plane",
                ))
        for fn in walk_functions(tree):
            tainted: set[str] = set()
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign):
                    if _snapshot_sources(node.value):
                        for t in node.targets:
                            if isinstance(t, ast.Name):
                                tainted.add(t.id)
                    for t in node.targets:
                        if (isinstance(t, ast.Attribute)
                                and (( isinstance(t.value, ast.Name)
                                       and t.value.id in tainted)
                                     or _snapshot_sources(t.value))):
                            out.append(self.finding(
                                relpath, t,
                                "attribute store on a captured "
                                "EngineSnapshot — snapshots are "
                                "immutable after construction; build a "
                                "new snapshot and swap the reference",
                            ))
                elif (isinstance(node, ast.Call)
                        and call_name(node) == "object.__setattr__"):
                    out.append(self.finding(
                        relpath, node,
                        "`object.__setattr__` bypasses frozen-dataclass "
                        "immutability — construct new state instead, or "
                        "justify with a pragma",
                    ))
        return out


# --------------------------------------------------------------------------
# R5 — no host synchronization inside jitted scoring functions
# --------------------------------------------------------------------------

_HOST_SYNC_CALLS = {
    "jax.device_get", "np.asarray", "numpy.asarray", "np.array",
    "numpy.array",
}
_HOST_SYNC_BUILTINS = {"float", "int", "bool"}


class HostSyncRule(Rule):
    """R5: jitted scoring functions never force a device round-trip."""

    id = "host-sync"
    title = "Hot-path host-sync hygiene"
    rationale = (
        "A `.item()`, `float()`, `np.asarray` or `jax.device_get` "
        "inside a jitted function either fails tracing or (via "
        "callbacks / implicit conversion at trace boundaries) forces a "
        "device→host sync per dispatch — the silent serving-latency "
        "cliff EdgeRAG warns about.  Host materialization belongs at "
        "the one audited boundary (score_batch_arrays' return).  "
        "`block_until_ready` is flagged *anywhere* in a scoped module, "
        "jitted or not: it stalls the dispatch pipeline, so every call "
        "site must carry a pragma stating why the barrier is deliberate "
        "(e.g. tracing-only span attribution, gated off the hot path)."
    )
    scope = ("core/*.py", "index/*.py", "serving/*.py", "kernels/*")

    def check(self, tree: ast.Module, relpath: str) -> list[Finding]:
        jit_assigned = assigned_jit_targets(tree)
        out: list[Finding] = []
        for fn in walk_functions(tree):
            if not (is_jitted(fn) or fn.name in jit_assigned):
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = call_name(node)
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "item"
                        and not node.args):
                    out.append(self.finding(
                        relpath, node,
                        f"`.item()` inside jitted `{fn.name}` — host "
                        "sync per dispatch",
                    ))
                elif name in _HOST_SYNC_CALLS:
                    out.append(self.finding(
                        relpath, node,
                        f"`{name}` inside jitted `{fn.name}` — host "
                        "materialization belongs outside the traced "
                        "function",
                    ))
                elif (name in _HOST_SYNC_BUILTINS and node.args
                        and not isinstance(node.args[0], ast.Constant)):
                    out.append(self.finding(
                        relpath, node,
                        f"`{name}(...)` on a traced value inside jitted "
                        f"`{fn.name}` — concretization forces a host "
                        "sync (static-arg coercions: justify with a "
                        "pragma)",
                    ))
        # explicit barriers are audited everywhere in scope, not just
        # inside jitted bodies — `jax.block_until_ready(x)` and the
        # `x.block_until_ready()` method both stall the dispatch queue
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            is_barrier = (
                (name is not None
                 and name.rpartition(".")[2] == "block_until_ready")
                or (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "block_until_ready")
            )
            if is_barrier:
                out.append(self.finding(
                    relpath, node,
                    "`block_until_ready` in a hot-path module — an "
                    "explicit device barrier must be a deliberate, "
                    "pragma-justified boundary (tracing attribution, "
                    "measurement), never ambient synchronization",
                ))
        return out


# --------------------------------------------------------------------------
# R6 — tenant pool pin/lock discipline
# --------------------------------------------------------------------------

_POOL_CLASS = "ContainerPool"
_POOL_STATE = "_resident"
_POOL_GUARD = "_pool_guard"
# OrderedDict mutators split by severity: removals tear a mount down
# (must be pins-checked eviction paths), reorders/inserts merely need
# the pool guard
_POOL_REMOVALS = {"pop", "popitem", "clear"}
_POOL_MUTATORS = _POOL_REMOVALS | {"update", "setdefault", "move_to_end"}


def _resident_attr(node: ast.AST) -> bool:
    return isinstance(node, ast.Attribute) and node.attr == _POOL_STATE


def _resident_mutations(fn: ast.FunctionDef) -> tuple[bool, bool]:
    """(mutates, removes) for direct ``<expr>._resident`` operations in
    ``fn``: subscript/attribute stores, ``del``, and the dict-mutator
    method calls."""
    mutates = removes = False
    for node in ast.walk(fn):
        targets: list[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        elif isinstance(node, ast.Delete):
            targets = list(node.targets)
            for t in targets:
                probe = t.value if isinstance(t, ast.Subscript) else t
                if _resident_attr(probe):
                    mutates = removes = True
            continue
        for t in targets:
            probe = t.value if isinstance(t, ast.Subscript) else t
            if _resident_attr(probe):
                mutates = True
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _POOL_MUTATORS
                and _resident_attr(node.func.value)):
            mutates = True
            if node.func.attr in _POOL_REMOVALS:
                removes = True
    return mutates, removes


def _holds_pool_guard(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.With):
            for item in node.items:
                expr = item.context_expr
                if (isinstance(expr, ast.Call)
                        and isinstance(expr.func, ast.Attribute)
                        and expr.func.attr == _POOL_GUARD
                        and isinstance(expr.func.value, ast.Name)
                        and expr.func.value.id == "self"):
                    return True
    return False


def _has_pins_check(fn: ast.FunctionDef) -> bool:
    """A refcount comparison against a ``pins`` attribute anywhere in
    the function (``if mt.pins > 0: raise`` / ``assert mt.pins == 0`` /
    the LRU scan's ``if mt.pins == 0``)."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Compare):
            operands = [node.left, *node.comparators]
            if any(isinstance(o, ast.Attribute) and o.attr == "pins"
                   for o in operands):
                return True
    return False


class TenantPinRule(Rule):
    """R6: pool residency transitions hold the guard; eviction paths
    carry the refcount check."""

    id = "tenant-pin"
    title = "Tenant pool pin/evict discipline"
    rationale = (
        "A tenant mount serving an in-flight flush holds a refcount "
        "pin; evicting it anyway tears the snapshot stack under the "
        "flush, and mutating the pool's resident map outside its guard "
        "races pin/evict transitions.  `ContainerPool._resident` may "
        "be mutated only inside the pool, under `with "
        "self._pool_guard(...)` (or in `*_locked` helpers called under "
        "it), and every method that removes a mount must contain an "
        "explicit `pins == 0` refcount comparison before teardown."
    )
    scope = ("*",)

    def check(self, tree: ast.Module, relpath: str) -> list[Finding]:
        out: list[Finding] = []
        pool_fns: set[int] = set()
        for cls in ast.walk(tree):
            if not isinstance(cls, ast.ClassDef) or cls.name != _POOL_CLASS:
                continue
            for fn in cls.body:
                if not isinstance(fn, ast.FunctionDef):
                    continue
                pool_fns.add(id(fn))
                mutates, removes = _resident_mutations(fn)
                if fn.name == "__init__":
                    continue  # construction: the map is not shared yet
                if mutates and not (fn.name.endswith("_locked")
                                    or _holds_pool_guard(fn)):
                    out.append(self.finding(
                        relpath, fn,
                        f"`{_POOL_CLASS}.{fn.name}` mutates "
                        f"`{_POOL_STATE}` without `with "
                        f"self.{_POOL_GUARD}(...)` (and is not a "
                        "`*_locked` helper called under it)",
                    ))
                if removes and not _has_pins_check(fn):
                    out.append(self.finding(
                        relpath, fn,
                        f"`{_POOL_CLASS}.{fn.name}` removes a mount "
                        f"from `{_POOL_STATE}` without a `pins == 0` "
                        "refcount check — eviction may never tear a "
                        "pinned snapshot stack",
                    ))
        # outside the pool class, _resident is read-only everywhere
        for fn in walk_functions(tree):
            if id(fn) in pool_fns:
                continue
            mutates, _ = _resident_mutations(fn)
            if mutates:
                out.append(self.finding(
                    relpath, fn,
                    f"direct `{_POOL_STATE}` mutation outside "
                    f"`{_POOL_CLASS}` — all residency transitions go "
                    "through the pool's pin/unpin/evict API",
                ))
        return out


RULES: tuple[Rule, ...] = (
    PinnedReductionRule(),
    WriterLockRule(),
    DurabilityRule(),
    SnapshotMutationRule(),
    HostSyncRule(),
    TenantPinRule(),
)
