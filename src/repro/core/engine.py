"""Batched query engine with incremental materialization (serving plane).

This is the single entry point for retrieval at serving time.  It owns
the device-resident copies of the ⟨V⟩/⟨I⟩ regions and adds three things
the single-query `Retriever` could not give a multi-user deployment:

1. **Batched queries** — ``query_batch(texts, k)`` vectorizes query
   embedding + signature construction on the host, pads the batch to a
   power-of-two bucket (so jit recompiles are bounded by
   log2(max_batch) shapes, not one per batch size), and scores all
   queries in one dispatch.

   Determinism contract: the default scoring path maps the *single-query*
   HSF formulation over the batch (``lax.map`` of a [N,D]·[D] matvec),
   so each query's scores are **bit-identical** to `Retriever.query` on
   the same corpus regardless of batch size.  A [B,D]×[D,N] GEMM is
   mathematically equal but not bit-stable across batch sizes (BLAS
   reduction order depends on the M dimension); deployments that prefer
   MXU-saturating throughput over bit-stability opt in via
   ``gemm_batch=True`` — or via ``use_kernel=True``, which dispatches
   the fused batched Pallas kernel (one pass over HBM, in-kernel top-k,
   no [B, N] score intermediate; see kernels/hsf_score).  Both opt-in
   paths return the same ranking with doc-index tie-breaking.  The
   default ``scoring_path="auto"`` resolves per backend: the kernel on
   real TPUs, the bit-stable map path everywhere else (see
   ``resolve_scoring_path``).

2. **Incremental materialization** — the `KnowledgeBase` logs dirty rows
   on ``add_text``/``sync``/remove (``changes_since``); ``refresh()``
   re-vectorizes only those documents and patches the device arrays in
   place.  The factored form ``v_d = normalize(u_d ⊙ idf)``
   (vectorizer.py) is what makes this exact: per-doc ``u_d`` rows are
   cached, and the global idf reweight is a cheap elementwise pass —
   the same O(U) split the paper uses for ingest (§3.3), applied to the
   query plane.  The refreshed arrays are bit-identical to a cold
   ``materialize()`` rebuild.

3. **Query-vector LRU cache** — keyed on the canonicalized query text
   (tokenizer.normalize), invalidated only when the idf statistics
   actually change.  Repeated queries skip tokenize/hash/scatter.

4. **Clustered index plane** — ``index="ivf"`` (default ``"flat"``)
   routes queries through the IVF probe/rerank subsystem
   (src/repro/index/): score √N centroids, gather the top-``nprobe``
   clusters' rows, rerank with the exact HSF through the same
   ``score_batch_arrays`` dispatch — sublinear scan cost, exact scores
   within the probed set, and ``guarantee="exact"`` widens probes until
   the top-k is provably identical to the flat scan.  The index rides
   the same dirty-row log as the arrays (reassign-on-refresh, drift-
   triggered retrain) and persists via ``kb.index_state``.

See docs/ARCHITECTURE.md §5/§9 for how this composes with the
mesh-sharded path (retrieval.py) and the index plane.
"""
from __future__ import annotations

import contextlib
import time
from collections import OrderedDict
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitizers
from repro.core import hsf, signature as sigmod
from repro.core.ingest import KnowledgeBase
from repro.core.tokenizer import normalize
from repro.obs import trace as obs_trace
from repro.obs.metrics import global_registry

# shared reentrant no-op scope for the explain=False query path
_NULL_CTX = contextlib.nullcontext()


@dataclass
class RetrievalResult:
    """One retrieved document (re-exported by retrieval.py for compat)."""

    doc_id: str
    score: float
    cosine: float
    boosted: bool


@dataclass
class RefreshStats:
    """What one ``refresh()`` actually did."""

    changed: int = 0        # docs re-vectorized (the O(U) part)
    removed: int = 0        # docs dropped
    rows_patched: int = 0   # device rows updated in place (.at[].set)
    restacked: bool = False  # row layout changed (add/remove) → host restack
    reweighted: bool = False  # idf changed → global reweight pass
    index_reassigned: int = 0  # dirty rows re-clustered (index plane)
    index_retrained: bool = False  # drift threshold hit → k-means retrain
    n_docs: int = 0
    seconds: float = 0.0

    @property
    def no_op(self) -> bool:
        return self.changed == 0 and self.removed == 0


# --------------------------------------------------------------------------
# jitted scoring core (module-level so all engines share the jit cache)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "alpha", "beta", "gemm"))
def _score_topk(doc_vecs, doc_sigs, q_vecs, q_sigs, n_valid,
                *, k, alpha, beta, gemm):
    """HSF scores + top-k for a padded query batch.

    Returns (vals [B,k], idx [B,k], cos [B,k], ind [B,k]) — ``ind`` is
    the exact containment indicator of each selected doc (0.0/1.0), the
    ground truth for the ``boosted`` flag (never inferred from float
    score arithmetic, which misfires at β=0).  The non-gemm path scores
    with ``hsf.stable_rowdot`` — the pinned-reduction-order matvec — so
    every row's cosine is the same bits whether it is scored here, in a
    gathered IVF candidate block, or on a shard's resident block.

    ``n_valid`` (traced) masks doc rows ≥ n_valid to −inf before the
    top-k — the index plane's candidate-gather path pads the doc
    operands to a power-of-two row bucket (index/ivf.py); full-matrix
    callers pass n_valid == N, where the mask is the identity (the
    ``where`` keeps every score bit-exactly).
    """
    dv = doc_vecs.astype(jnp.float32)
    if gemm:
        # analysis: allow[unpinned-reduction] -- opt-in gemm branch
        #   (scoring_path="gemm"), documented non-bit-stable
        cos = q_vecs.astype(jnp.float32) @ dv.T
    else:
        cos = jax.lax.map(lambda q: hsf.stable_rowdot(dv, q), q_vecs)
    ind = jax.vmap(lambda s: hsf.containment(doc_sigs, s))(q_sigs)
    scores = alpha * cos + beta * ind
    scores = jnp.where(
        jnp.arange(scores.shape[1])[None, :] < n_valid, scores, -jnp.inf
    )
    vals, idx = jax.lax.top_k(scores, k)
    return (vals, idx, jnp.take_along_axis(cos, idx, axis=1),
            jnp.take_along_axis(ind, idx, axis=1))


def _selected_cos_ind(doc_vecs, doc_sigs, q_vecs, q_sigs, idx):
    """Per-result cosine + exact containment for selected docs only —
    O(B·k·D) instead of the O(B·N·D) full recompute."""
    sel_vecs = jnp.take(doc_vecs, idx, axis=0).astype(jnp.float32)  # [B,k,D]
    # analysis: allow[unpinned-reduction] -- pallas-path per-result
    #   diagnostics only; ranking comes from the kernel scores, and the
    #   kernel path is already documented non-bit-stable vs map
    cos = jnp.einsum("bkd,bd->bk", sel_vecs, q_vecs.astype(jnp.float32))
    sel_sigs = jnp.take(doc_sigs, idx, axis=0)                      # [B,k,W]
    qs = q_sigs[:, None, :]
    ind = jnp.all((sel_sigs & qs) == qs, axis=-1).astype(jnp.float32)
    return cos, ind


@partial(jax.jit, static_argnames=("k", "alpha", "beta"))
def _score_topk_pallas(doc_vecs, doc_sigs, q_vecs, q_sigs, n_valid,
                       *, k, alpha, beta):
    """Fused batched Pallas path (kernels/hsf_score.hsf_score_batched).

    One kernel dispatch scores the whole query batch and reduces to
    top-k in VMEM — the [B, N] score matrix never reaches HBM, and no
    per-query ``lax.map`` dispatch remains.  ``doc_vecs``/``doc_sigs``
    arrive block-aligned from the engine's operand cache (appended zero
    rows masked via the traced ``n_valid``), so the wrapper's ragged-N
    pad is a no-op in the hot loop.  Ties break by doc index
    (``retrieval._stable_top_k`` order, same as ``lax.top_k`` on the
    full score matrix).  Like ``gemm_batch``, this path is opt-in
    w.r.t. the bit-stability contract: the kernel's [B, D]×[D, block]
    MXU reduction is mathematically equal to the single-query matvec
    but not guaranteed bit-identical across backends.
    """
    vals, idx = hsf.hsf_topk_batched_kernel(
        doc_vecs, doc_sigs, q_vecs, q_sigs, k=k, alpha=alpha, beta=beta,
        n_valid=n_valid,
    )
    cos, ind = _selected_cos_ind(doc_vecs, doc_sigs, q_vecs, q_sigs, idx)
    return vals, idx, cos, ind


# steady-state retrace accounting (no-op unless RAGDB_SANITIZERS is on)
sanitizers.register_jit("engine._score_topk", _score_topk)
sanitizers.register_jit("engine._score_topk_pallas", _score_topk_pallas)


def _bucket(b: int) -> int:
    """Next power of two ≥ b (query-batch shape bucket)."""
    return 1 << max(b - 1, 0).bit_length() if b > 1 else 1


# --------------------------------------------------------------------------
# scoring-path selection
# --------------------------------------------------------------------------

SCORING_PATHS = ("map", "gemm", "kernel")


def _default_backend() -> str:
    """The live jax backend name (monkeypatch point for tests)."""
    try:
        return jax.default_backend()
    except Exception:  # noqa: BLE001 — no devices at all → host semantics
        return "cpu"


def resolve_scoring_path(
    scoring_path: str = "auto",
    use_kernel: bool = False,
    gemm_batch: bool = False,
) -> str:
    """Resolve the effective scoring path: "map" | "gemm" | "kernel".

    The legacy boolean flags are explicit overrides and win over
    ``scoring_path``.  ``"auto"`` picks the fused Pallas kernel only on
    a real TPU backend — PR 2's shoot-out showed the kernel ~4x slower
    than gemm in CPU interpret mode, so auto never routes a CPU host
    through it; the bit-stable ``lax.map`` default is used instead.
    Pass ``scoring_path="kernel"`` (or ``use_kernel=True``) to force the
    kernel anywhere (e.g. interpret-mode plumbing tests), or
    ``scoring_path="map"`` to force the bit-stable path on TPU.
    """
    if use_kernel and gemm_batch:
        raise ValueError("use_kernel and gemm_batch are mutually exclusive")
    if use_kernel:
        return "kernel"
    if gemm_batch:
        return "gemm"
    if scoring_path == "auto":
        return "kernel" if _default_backend() == "tpu" else "map"
    if scoring_path not in SCORING_PATHS:
        raise ValueError(
            f"scoring_path must be 'auto' or one of {SCORING_PATHS}, "
            f"got {scoring_path!r}"
        )
    return scoring_path


def score_batch_arrays(
    doc_vecs, doc_sigs, qv: np.ndarray, qs: np.ndarray, *,
    scoring_path: str, k: int, alpha: float, beta: float, n_docs: int,
    kernel_operands=None,
):
    """One padded-batch scoring dispatch → numpy (vals, idx, cos, ind).

    Pure function of its operands (no engine state): the serving-plane
    snapshot (serving/snapshot.py) calls this against frozen arrays, the
    engine against its live ones, and the index plane against gathered
    candidate subsets (``n_docs`` < doc rows masks the pad; full-matrix
    callers pass n_docs == rows, a bit-exact no-op).  ``kernel_operands``
    is the optional pre-padded (block-aligned) doc operand pair for the
    kernel path.

    ``n_docs == 0`` (a freshly-mounted empty tenant container, or a
    corpus whose every doc was removed) short-circuits to empty [B, 0]
    result arrays on every path: the padded-bucket dispatch would
    otherwise ask top_k for k of 0 candidate columns and trip inside
    the jitted function.
    """
    if n_docs <= 0:
        b = int(np.asarray(qv).shape[0])
        empty_f = np.zeros((b, 0), dtype=np.float32)
        empty_i = np.zeros((b, 0), dtype=np.int32)
        return empty_f, empty_i, empty_f.copy(), empty_f.copy()
    with obs_trace.span("device_dispatch", path=scoring_path,
                        rows=int(n_docs), k=k):
        if scoring_path == "kernel":
            if kernel_operands is None:
                kernel_operands = hsf.hsf_kernel_pad_docs(doc_vecs, doc_sigs)
            dv, ds = kernel_operands
            vals, idx, cos, ind = _score_topk_pallas(
                dv, ds, jnp.asarray(qv), jnp.asarray(qs), jnp.int32(n_docs),
                k=k, alpha=alpha, beta=beta,
            )
        else:
            vals, idx, cos, ind = _score_topk(
                doc_vecs, doc_sigs, jnp.asarray(qv), jnp.asarray(qs),
                jnp.int32(n_docs),
                k=k, alpha=alpha, beta=beta, gemm=scoring_path == "gemm",
            )
        if obs_trace.active():
            # tracing/explain-only audited sync: without it the async
            # dispatch returns immediately and all device time would be
            # charged to the host_transfer span below.  Never runs when
            # neither a trace nor an EXPLAIN collector is active.
            jax.block_until_ready(vals)  # analysis: allow[host-sync] -- tracing/explain-only audited boundary attributing device time to the dispatch span; no-op when both are off
    with obs_trace.span("host_transfer", k=k):
        return (np.asarray(vals), np.asarray(idx),
                np.asarray(cos), np.asarray(ind))


def results_from_topk(
    doc_ids, b: int, vals, idx, cos, ind
) -> list[list[RetrievalResult]]:
    """Materialize RetrievalResult rows for the first ``b`` queries of a
    padded batch (the ``boosted`` flag is the exact containment
    indicator returned by the scoring path, never inferred from
    score − α·cos).

    This is the one audited device→host boundary every scoring path
    funnels through (flat scan, IVF rerank, sharded merge, scheduler),
    so the opt-in NaN/Inf sanitizer hooks here: only the first ``b``
    rows are checked — rows beyond are bucket padding and legitimately
    hold -inf sentinels."""
    sanitizers.check_finite_scores(vals, b, "engine.results_from_topk")
    with obs_trace.span("materialize", rows=b):
        out = _materialize_rows(doc_ids, b, vals, idx, cos, ind)
    return out


def _materialize_rows(doc_ids, b, vals, idx, cos, ind):
    out = []
    for i in range(b):
        row = []
        for v, j, c, bi in zip(vals[i], idx[i], cos[i], ind[i]):
            row.append(
                RetrievalResult(
                    doc_id=doc_ids[int(j)],
                    score=float(v),
                    cosine=float(c),
                    boosted=bool(bi > 0.5),
                )
            )
        out.append(row)
    return out


def pack_query_arrays(
    pairs: list[tuple[np.ndarray, np.ndarray]], dim: int, sig_words: int
) -> tuple[np.ndarray, np.ndarray]:
    """Stack per-query (vector, signature) pairs into a padded
    power-of-two bucket (zero rows beyond len(pairs))."""
    bucket = _bucket(len(pairs))
    qv = np.zeros((bucket, dim), np.float32)
    qs = np.zeros((bucket, sig_words), np.int32)
    for i, (v, s) in enumerate(pairs):
        qv[i] = v
        qs[i] = s
    return qv, qs


def _record_ivf_stats(s) -> None:
    """Surface the per-dispatch ``IVFSearchStats`` — previously computed
    and dropped — as first-class metrics in the obs global registry."""
    if s is None:
        return
    reg = global_registry()
    reg.histogram("ragdb_ivf_probed_fraction",
                  "fraction of clusters probed per dispatch").record(
        float(s.probed_fraction))
    reg.histogram("ragdb_ivf_widen_rounds",
                  "probe/widen rounds per dispatch").record(float(s.rounds))
    reg.counter("ragdb_ivf_candidate_rows_total",
                "candidate rows gathered for rerank").inc(
        int(s.candidate_rows))
    reg.counter("ragdb_ivf_searches_total", "ivf dispatches").inc()
    merge_s = getattr(s, "merge_seconds", None)
    if merge_s is not None:
        reg.histogram("ragdb_ivf_merge_seconds",
                      "sharded local-top-k merge per dispatch").record(
            float(merge_s))


def _pad_row_update(rows: np.ndarray, block: np.ndarray):
    """Pad a row-scatter update to a power-of-two row count.

    Device row patches jit-compile per rows-shape; bucketing bounds the
    compile count just like query batching.  Padding duplicates row 0 —
    a scatter-set writing identical content twice is deterministic.
    """
    pad = _bucket(len(rows)) - len(rows)
    if pad:
        rows = np.concatenate([rows, np.repeat(rows[:1], pad)])
        block = np.concatenate([block, np.repeat(block[:1], pad, axis=0)])
    return rows, block


class QueryEngine:
    """Batched retrieval over a live KnowledgeBase.

    ``query_batch`` auto-refreshes from the KB's dirty log first, so an
    engine constructed once keeps serving correct results across
    ``add_text``/``sync``/removal — that is the point: refresh cost is
    O(changed docs), not O(corpus).
    """

    INDEX_KINDS = ("flat", "ivf", "ivf-sharded")
    GUARANTEES = ("probe", "exact")

    def __init__(
        self,
        kb: KnowledgeBase,
        alpha: float = hsf.DEFAULT_ALPHA,
        beta: float = hsf.DEFAULT_BETA,
        use_kernel: bool = False,
        gemm_batch: bool = False,
        scoring_path: str = "auto",
        cache_size: int = 256,
        max_batch: int = 256,
        index: str = "flat",
        nprobe: int = 8,
        guarantee: str = "probe",
        n_clusters: int | None = None,
        retrain_drift: float = 0.3,
        ivf_seed: int = 0,
        n_shards: int | None = None,
    ):
        self.kb = kb
        self.alpha = float(alpha)
        self.beta = float(beta)
        # ---- index plane (docs/ARCHITECTURE.md §9/§10) ------------------
        # "flat" (default) scans all N docs — the bit-stability baseline.
        # "ivf" probes the top-`nprobe` clusters and reranks candidates
        # with the exact HSF; `guarantee="exact"` widens probes until the
        # top-k provably equals the flat scan (bit-identical).
        # "ivf-sharded" partitions the clusters across a device mesh
        # (`n_shards`, default = the device count): each device reranks
        # its own cluster subset and only [B, k] candidates merge — the
        # same guarantees, applied per shard.
        if index not in self.INDEX_KINDS:
            raise ValueError(
                f"index must be one of {self.INDEX_KINDS}, got {index!r}"
            )
        if guarantee not in self.GUARANTEES:
            raise ValueError(
                f"guarantee must be one of {self.GUARANTEES}, "
                f"got {guarantee!r}"
            )
        if index != "flat" and (self.alpha < 0 or self.beta < 0):
            # the cluster pruning bound assumes non-negative HSF weights
            raise ValueError(
                f"index={index!r} requires alpha >= 0 and beta >= 0"
            )
        if nprobe < 1:
            raise ValueError(f"nprobe must be >= 1, got {nprobe}")
        self.index = index
        self.nprobe = int(nprobe)
        self.guarantee = guarantee
        self.n_clusters = n_clusters
        self.retrain_drift = float(retrain_drift)
        self.ivf_seed = int(ivf_seed)
        self.ivf = None  # IVFIndex | ShardedIVFIndex | None (see refresh)
        self._last_index_stats = None
        self.retrains = 0  # cumulative k-means (re)trains this engine ran
        # "auto" resolves at construction: kernel on real TPU backends,
        # the bit-stable map path elsewhere.  The booleans are kept as
        # resolved views for back-compat (retrieval.py checks them).
        self.scoring_path = resolve_scoring_path(
            scoring_path, use_kernel=use_kernel, gemm_batch=gemm_batch
        )
        if index == "ivf-sharded":
            if n_shards is not None and n_shards < 1:
                raise ValueError(f"n_shards must be >= 1, got {n_shards}")
            # the per-shard local rerank always scores with the
            # bit-stable map formulation ("auto" coerces; an explicit
            # gemm/kernel request would silently change numerics, so it
            # is rejected rather than ignored)
            if self.scoring_path != "map":
                if scoring_path == "auto" and not use_kernel \
                        and not gemm_batch:
                    self.scoring_path = "map"
                else:
                    raise ValueError(
                        "index='ivf-sharded' reranks with the bit-stable "
                        "map formulation; scoring_path must be 'map' or "
                        f"'auto', got {self.scoring_path!r}"
                    )
            self.n_shards = int(n_shards) if n_shards is not None \
                else max(1, jax.device_count())
        else:
            if n_shards is not None:
                raise ValueError(
                    "n_shards is only meaningful with index='ivf-sharded'"
                )
            self.n_shards = None
        self.use_kernel = self.scoring_path == "kernel"
        self.gemm_batch = self.scoring_path == "gemm"
        self.cache_size = cache_size
        self.max_batch = max_batch

        self.doc_ids: list[str] = []
        self.doc_vecs = jnp.zeros((0, kb.dim), jnp.float32)
        self.doc_sigs = jnp.zeros((0, kb.sig_words), jnp.int32)
        self._row_of: dict[str, int] = {}
        self._u = np.zeros((0, kb.dim), np.float32)  # cached tf·sign rows
        self._idf = np.zeros((0,), np.float32)
        self._synced = -1  # KB version the device arrays reflect

        # kernel-path operand cache: (src_vecs, src_sigs, padded_vecs,
        # padded_sigs) — holding the source refs both keys the cache and
        # pins them against id reuse
        self._kernel_cache: tuple | None = None

        self._qcache: OrderedDict[str, tuple[np.ndarray, np.ndarray]] = (
            OrderedDict()
        )
        self.cache_hits = 0
        self.cache_misses = 0

        self.refresh()

    # ---- incremental materialization -----------------------------------

    def refresh(self) -> RefreshStats:
        """Bring device arrays up to date with the KB (O(changed docs)).

        When ``index="ivf"`` the cluster index rides the same dirty-row
        delta: changed docs reassign to their nearest centroid (O(U)),
        layout restacks remap assignments by doc id, and the drift
        counter triggers a full k-means retrain past ``retrain_drift``
        (see ``_sync_ivf``).
        """
        t0 = time.perf_counter()
        kb = self.kb
        stats = RefreshStats()
        target = kb.version
        changed_ids: list[str] | None = None
        old_row_of: dict[str, int] = {}
        if self._synced < 0:
            stats.changed = kb.n_docs
            stats.restacked = True
            self._cold_build()
            stats.reweighted = True
        elif target != self._synced:
            changed, removed = kb.changes_since(self._synced)
            stats.changed, stats.removed = len(changed), len(removed)
            changed_ids = changed
            old_row_of = self._row_of  # pre-delta layout (for ivf remap)
            self._apply_delta(changed, stats)
        if self.index != "flat" and (self.ivf is None
                                     or changed_ids is not None):
            self._sync_ivf(changed_ids, old_row_of, stats)
        self._synced = target
        stats.n_docs = len(self.doc_ids)
        stats.seconds = time.perf_counter() - t0
        return stats

    def _cold_build(self) -> None:
        kb = self.kb
        if not kb._dirty and kb._matrix is not None:
            # a clean materialized matrix exists (e.g. a container loaded
            # with include_matrix=True): adopt it instead of re-vectorizing
            # — that skip is the whole point of persisting ⟨V⟩ (RQ3).
            # The u-row cache is built lazily on the first delta.
            matrix, sigs, ids = kb.materialize()
            self._u = None
            self._idf = kb.vectorizer.idf()
            self.doc_vecs = jnp.asarray(matrix)
            self.doc_sigs = jnp.asarray(sigs)
        else:
            ids = sorted(kb.records)
            tcs = [kb.term_counts[i] for i in ids]
            self._u = kb.vectorizer.build_unweighted_matrix(tcs)
            self._idf = kb.vectorizer.idf()
            self.doc_vecs = jnp.asarray(kb.vectorizer.finalize_matrix(self._u))
            self.doc_sigs = jnp.asarray(
                np.stack([kb.signatures[i] for i in ids])
                if ids
                else np.zeros((0, kb.sig_words), np.int32)
            )
        self.doc_ids = ids
        self._row_of = {i: r for r, i in enumerate(ids)}

    def _ensure_u(self) -> None:
        """Materialize the u-row cache for the engine's current layout.

        Deferred when the cold build adopted a persisted matrix; rows for
        docs since removed from the KB are left zero (they are never read
        — the restack path only copies rows for surviving ids), and rows
        for since-changed docs are recomputed from the new term counts,
        identical to the values the delta is about to write anyway.
        """
        if self._u is not None:
            return
        kb = self.kb
        rows = np.zeros((len(self.doc_ids), kb.dim), np.float32)
        for r, i in enumerate(self.doc_ids):
            tc = kb.term_counts.get(i)
            if tc is not None:
                rows[r] = kb.vectorizer.unweighted_row(tc)
        self._u = rows

    def _apply_delta(self, changed: list[str], stats: RefreshStats) -> None:
        kb = self.kb
        if not changed and sorted(kb.records) == self.doc_ids:
            # metadata-only mutation (e.g. the KB re-armed stat fast-path
            # keys on a touched-but-unchanged file): no rows to patch and
            # df cannot have moved — skip the u-cache materialization
            return
        self._ensure_u()
        # the O(U) part: re-vectorize only the dirty docs
        new_u = {
            i: kb.vectorizer.unweighted_row(kb.term_counts[i])
            for i in changed
        }
        new_ids = sorted(kb.records)
        if new_ids == self.doc_ids:
            if changed:
                rows = np.array(
                    [self._row_of[i] for i in changed], np.int32
                )
                for r, i in zip(rows, changed):
                    self._u[r] = new_u[i]
                sig_block = np.stack([kb.signatures[i] for i in changed])
                rows_p, sig_p = _pad_row_update(rows, sig_block)
                self.doc_sigs = self.doc_sigs.at[rows_p].set(
                    jnp.asarray(sig_p)
                )
        else:
            # layout changed: restack cached rows on the host (pure
            # memcpy for unchanged docs — no re-vectorization)
            u = np.zeros((len(new_ids), kb.dim), np.float32)
            sig = np.zeros((len(new_ids), kb.sig_words), np.int32)
            old_sig = np.asarray(self.doc_sigs)
            for r, i in enumerate(new_ids):
                if i in new_u:
                    u[r] = new_u[i]
                    sig[r] = kb.signatures[i]
                else:
                    old_r = self._row_of[i]
                    u[r] = self._u[old_r]
                    sig[r] = old_sig[old_r]
            self._u = u
            self.doc_sigs = jnp.asarray(sig)
            self.doc_ids = new_ids
            self._row_of = {i: r for r, i in enumerate(new_ids)}
            stats.restacked = True

        idf = kb.vectorizer.idf()
        if stats.restacked or not np.array_equal(idf, self._idf):
            # idf moved: the cheap global stage — elementwise reweight +
            # renormalize of the cached U, nothing re-vectorized
            self._idf = idf
            self.doc_vecs = jnp.asarray(kb.vectorizer.finalize_matrix(self._u))
            stats.reweighted = True
            self._qcache.clear()  # query vectors depend on idf
        elif changed:
            # idf stable: patch only the dirty rows on device
            rows = np.array([self._row_of[i] for i in changed], np.int32)
            block = kb.vectorizer.finalize_matrix(self._u[rows])
            rows_p, block_p = _pad_row_update(rows, block)
            self.doc_vecs = self.doc_vecs.at[rows_p].set(jnp.asarray(block_p))
            stats.rows_patched = len(rows)

    # ---- index plane maintenance (index="ivf") --------------------------

    def _sync_ivf(self, changed_ids: list[str] | None,
                  old_row_of: dict[str, int], stats: RefreshStats) -> None:
        """Keep the cluster index aligned with the device arrays.

        Cold: adopt the KB's persisted index state when it matches the
        current doc layout (no cold retrain on load — the acceptance
        contract of the persistence plane), else train.  Delta: changed
        rows reassign (O(U)); restacks remap assignments by doc id; the
        drift counter triggers a retrain past ``retrain_drift``.  Every
        state change is written back to ``kb.index_state`` so
        ``save``/``save_delta`` persist it (the writer thread calls
        refresh before a durable publish — serving/snapshot.py).
        """
        from repro.index.ivf import IVFIndex, ids_digest
        from repro.index.sharded import ShardedIVFIndex

        sharded = self.index == "ivf-sharded"

        def _train():
            if sharded:
                return ShardedIVFIndex.train(
                    self.doc_vecs, np.asarray(self.doc_sigs),
                    n_clusters=self.n_clusters, seed=self.ivf_seed,
                    n_shards=self.n_shards,
                )
            return IVFIndex.train(
                self.doc_vecs, np.asarray(self.doc_sigs),
                n_clusters=self.n_clusters, seed=self.ivf_seed,
            )

        n = len(self.doc_ids)
        if n == 0:
            self.ivf = None
            return
        if self.ivf is None:
            st = self.kb.index_state
            if (st is not None and st.get("kind") == "ivf"
                    and len(st["assign"]) == n
                    and st.get("ids_sha") == ids_digest(self._ivf_state_key())):
                # the key covers doc ids AND content hashes: a stale
                # state (doc rewritten in place with no live index
                # maintenance) must never adopt — its sig_union/radius
                # could underestimate a cluster and break exactness.
                # Both kinds persist kind="ivf": a sharded engine adopts
                # flat-written state (deriving its deterministic
                # partition) and vice versa — bit-identical, no retrain
                if sharded:
                    self.ivf = ShardedIVFIndex.from_state(
                        st, self.doc_vecs, self.doc_sigs,
                        n_shards=self.n_shards,
                    )
                else:
                    self.ivf = IVFIndex.from_state(st)
                return
            self.ivf = _train()
            stats.index_retrained = True
            self._note_retrain()
            self._write_index_state()
            return
        if stats.restacked:
            # layout changed: carry surviving rows' clusters by doc id;
            # new/changed rows (−1) assign to their nearest centroid
            # (the restack itself is already O(N), so full-array
            # recomputation is in budget here)
            old_assign = self.ivf.assign
            changed_set = set(changed_ids or ())
            carried = np.full((n,), -1, np.int32)
            for r, i in enumerate(self.doc_ids):
                old_r = old_row_of.get(i)
                if old_r is not None and i not in changed_set:
                    carried[r] = old_assign[old_r]
            self.ivf = self.ivf.remap(carried, self.doc_vecs,
                                      np.asarray(self.doc_sigs))
            stats.index_reassigned = int(np.sum(carried < 0))
        elif changed_ids:
            # O(U) path: gather only the dirty rows on device before the
            # host transfer — never a full [N, ·] device→host copy.
            # The sharded plane additionally routes each dirty row to
            # its owning shard's resident block (index/sharded.py), so
            # it takes the live doc arrays for cross-shard regathers
            rows = np.array([self._row_of[i] for i in changed_ids], np.int32)
            rows_j = jnp.asarray(rows)
            row_vecs = np.asarray(jnp.take(self.doc_vecs, rows_j, axis=0))
            row_sigs = np.asarray(jnp.take(self.doc_sigs, rows_j, axis=0))
            if sharded:
                # reweighted => the refresh rebuilt every doc vector
                # (idf moved), so the resident blocks regather in full;
                # otherwise only the dirty rows patch (O(U))
                self.ivf = self.ivf.reassign(
                    rows, row_vecs, row_sigs,
                    self.doc_vecs, self.doc_sigs,
                    reweighted=stats.reweighted,
                )
            else:
                self.ivf = self.ivf.reassign(rows, row_vecs, row_sigs)
            stats.index_reassigned = len(rows)
        else:
            return  # metadata-only mutation: index untouched
        if self.ivf.needs_retrain(self.retrain_drift):
            self.ivf = _train()
            stats.index_retrained = True
            self._note_retrain()
        self._write_index_state()

    def _note_retrain(self) -> None:
        self.retrains += 1
        global_registry().counter(
            "ragdb_ivf_retrains_total",
            "k-means (re)trains across all engines").inc()

    def _ivf_state_key(self) -> list[str]:
        """Layout **and content** key the persisted index is pinned to:
        one ``"id\\x01sha256"`` token per doc in engine row order."""
        recs = self.kb.records
        return [f"{i}\x01{recs[i].sha256}" for i in self.doc_ids]

    def _write_index_state(self) -> None:
        """Publish the index state into the KB so the persistence plane
        journals it alongside the doc segments (core/ingest.py).

        The layout-key digest is O(N) string hashing per refresh —
        noise next to the O(N·D) idf reweight the same refresh performs
        whenever df moved (i.e. on any content change)."""
        self.kb.set_index_state(self.ivf.state_dict(self._ivf_state_key()))

    def index_stats(self) -> dict:
        """Probe accounting of the most recent ivf dispatch (None fields
        when the engine is flat or hasn't served an ivf query yet)."""
        s = self._last_index_stats
        return {
            "index": self.index,
            "n_clusters": self.ivf.n_clusters if self.ivf else 0,
            "drift": self.ivf.drift if self.ivf else 0,
            "retrains": self.retrains,
            "probed_fraction": s.probed_fraction if s else None,
            "clusters_probed": s.clusters_probed if s else None,
            "candidate_rows": s.candidate_rows if s else None,
            "rounds": s.rounds if s else None,
            # distribution terms (None unless the sharded plane served)
            "n_shards": getattr(s, "n_shards", None) if s else None,
            "merge_seconds": getattr(s, "merge_seconds", None) if s else None,
        }

    # ---- query-vector cache --------------------------------------------

    def _query_arrays(self, text: str) -> tuple[np.ndarray, np.ndarray]:
        key = normalize(text)
        hit = self._qcache.get(key)
        if hit is not None:
            self._qcache.move_to_end(key)
            self.cache_hits += 1
            return hit
        self.cache_misses += 1
        out = (
            self.kb.vectorizer.query_vector(text),
            sigmod.query_signature(text, width_words=self.kb.sig_words),
        )
        self._qcache[key] = out
        if len(self._qcache) > self.cache_size:
            self._qcache.popitem(last=False)
        return out

    # ---- batched queries ------------------------------------------------

    def query_batch(
        self, texts: list[str], k: int = 5, *, explain: bool = False
    ):
        """Retrieve top-k for every query; one device dispatch per chunk.

        ``k`` must be ≥ 1 (a clear ValueError, not a silent fall-through
        to the padded top-k); ``k`` > corpus size clamps to the corpus
        size.  Results per query are identical to ``Retriever.query`` on
        the same KB — bit-identical when the resolved scoring path is
        ``"map"`` (what ``"auto"`` picks everywhere except real TPU
        backends, where it resolves to the non-bit-stable kernel; force
        ``scoring_path="map"`` to keep the bit-stability contract there).

        ``explain=True`` returns ``(results, plans)`` where ``plans``
        is one :class:`repro.obs.explain.QueryPlan` per query — the
        index/probe decomposition, cache status, and per-stage timings
        of the dispatch that served it (docs/ARCHITECTURE.md §14).
        """
        if k <= 0:
            raise ValueError(f"k must be a positive integer, got {k}")
        self.refresh()
        if not self.doc_ids or not texts:
            empty = [[] for _ in texts]
            if explain:
                from repro.obs import explain as explain_mod
                plans = explain_mod.plans_from_dispatch(
                    texts, k, index=self.index,
                    scoring_path=self.scoring_path, guarantee=self.guarantee,
                    n_docs=0)
                return empty, plans
            return empty
        out: list[list[RetrievalResult]] = []
        batches = []
        for start in range(0, len(texts), self.max_batch):
            chunk = texts[start: start + self.max_batch]
            if explain:
                res, ps = self._query_chunk(chunk, k, explain=True)
                out.extend(res)
                batches.append(ps)
            else:
                out.extend(self._query_chunk(chunk, k))
        if explain:
            from repro.obs.explain import PlanBatch
            return out, PlanBatch.concat(batches)
        return out

    def query(self, text: str, k: int = 5) -> list[RetrievalResult]:
        """Single-query convenience wrapper (batch of one)."""
        return self.query_batch([text], k)[0]

    def _query_chunk(self, texts: list[str], k: int, *,
                     explain: bool = False):
        b = len(texts)
        if explain:
            from repro.obs import explain as explain_mod
            col = obs_trace.StageCollector()
            scope = obs_trace.get().collect(col)
            vec_hits = tuple(normalize(t) in self._qcache for t in texts)
            t0 = time.perf_counter()
        else:
            scope = _NULL_CTX
        with scope:
            with obs_trace.span("query_embed", queries=b):
                pairs = [self._query_arrays(t) for t in texts]
                qv, qs = pack_query_arrays(
                    pairs, self.kb.dim, self.kb.sig_words)
            n = len(self.doc_ids)
            stats = None
            if self.index != "flat" and self.ivf is not None:
                vals, idx, cos, ind, stats = self.ivf.search(
                    self.doc_vecs, self.doc_sigs, qv, qs,
                    b=b, k=min(k, n), nprobe=self.nprobe,
                    guarantee=self.guarantee,
                    scoring_path=self.scoring_path,
                    alpha=self.alpha, beta=self.beta, explain=explain,
                )
                self._last_index_stats = stats
                _record_ivf_stats(stats)
            else:
                vals, idx, cos, ind = score_batch_arrays(
                    self.doc_vecs, self.doc_sigs, qv, qs,
                    scoring_path=self.scoring_path, k=min(k, n),
                    alpha=self.alpha, beta=self.beta, n_docs=n,
                    kernel_operands=(
                        self._kernel_operands() if self.use_kernel else None
                    ),
                )
            results = results_from_topk(self.doc_ids, b, vals, idx, cos, ind)
        if not explain:
            return results
        # capture only: the QueryPlan dataclasses are built on first
        # access (PlanBatch) — the hot path pays one closure + one
        # tuple() of the collected stages, not 20-field inits per query
        stages = tuple(col.stages)
        total_s = time.perf_counter() - t0
        index, path, guar = self.index, self.scoring_path, self.guarantee
        return results, explain_mod.PlanBatch(
            lambda: explain_mod.plans_from_dispatch(
                texts, k, index=index, scoring_path=path, guarantee=guar,
                n_docs=n, stats=stats, stages=stages,
                vector_cache_hits=vec_hits, total_s=total_s))

    def _kernel_operands(self):
        """Block-aligned doc operands for the fused kernel, re-padded
        only when refresh() rebound the device arrays — the per-dispatch
        O(N·D) pad copy never runs in the serving hot loop."""
        cache = self._kernel_cache
        if (cache is None or cache[0] is not self.doc_vecs
                or cache[1] is not self.doc_sigs):
            dv, ds = hsf.hsf_kernel_pad_docs(self.doc_vecs, self.doc_sigs)
            cache = (self.doc_vecs, self.doc_sigs, dv, ds)
            self._kernel_cache = cache
        return cache[2], cache[3]

    # ---- introspection ---------------------------------------------------

    @property
    def synced_version(self) -> int:
        """The KB mutation version the device arrays reflect — the
        generation a snapshot captured from this engine is pinned at,
        and the state a durable publish persists
        (serving/snapshot.py ``SnapshotManager.publish(durable=True)``).
        -1 until the first ``refresh()``."""
        return self._synced

    @property
    def n_docs(self) -> int:
        return len(self.doc_ids)

    def cache_stats(self) -> dict:
        return {
            "hits": self.cache_hits,
            "misses": self.cache_misses,
            "entries": len(self._qcache),
            "capacity": self.cache_size,
        }
