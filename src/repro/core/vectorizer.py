"""Hashed sublinear TF-IDF vectorizer (paper §4.1, TPU-adapted).

Paper formulas (kept exactly):

    tf(t, d)  = 1 + ln f_{t,d}
    idf(t)    = ln(N / (1 + df_t)) + 1
    v_d       = l2_normalize( [tf·idf]_t )

Adaptation (docs/ARCHITECTURE.md §2): the paper stores vocabulary-dimensional sparse
vectors; a TPU MXU wants dense, bounded-width operands.  We apply *signed
feature hashing* (hashing trick): term t → bucket ``h(t) mod D`` with sign
``±1`` from a decorrelated hash bit.  Cosine similarity is preserved in
expectation; D is a build-time constant (multiple of 128 → lane-aligned).

Document frequency is maintained *per bucket* and updated incrementally
(`add_doc` / `remove_doc`), which is what keeps re-indexing O(U) in the
number of updated documents (paper §3.3): unchanged documents keep their
stored `TermCounts`; only the cheap re-weighting pass is global.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import hashing
from repro.core.tokenizer import TermCounts, tokenize

DEFAULT_DIM = 4096


def bucket_sign(term_hashes: np.ndarray, dim: int) -> tuple[np.ndarray, np.ndarray]:
    """Signed feature hashing: bucket = h mod D, sign = ±1 from mixed bit."""
    h = term_hashes.astype(np.uint64)
    buckets = (h % np.uint64(dim)).astype(np.int32)
    signs = np.where(
        (hashing.mix64(h) >> np.uint64(63)).astype(np.int8) == 1, -1, 1
    ).astype(np.int8)
    return buckets, signs


@dataclass
class HashedTfIdf:
    """Stateful hashed TF-IDF model.  State = (dim, n_docs, df[dim])."""

    dim: int = DEFAULT_DIM
    n_docs: int = 0
    df: np.ndarray = field(default=None)  # int64 [dim]

    def __post_init__(self):
        if self.df is None:
            self.df = np.zeros((self.dim,), dtype=np.int64)
        assert self.dim % 128 == 0, "hashed dim must be lane-aligned (×128)"

    # ---- incremental df maintenance (O(U) ingestion path) -------------

    def _doc_buckets(self, tc: TermCounts) -> np.ndarray:
        buckets, _ = bucket_sign(tc.term_hashes, self.dim)
        return np.unique(buckets)

    def add_doc(self, tc: TermCounts) -> None:
        self.df[self._doc_buckets(tc)] += 1
        self.n_docs += 1

    def remove_doc(self, tc: TermCounts) -> None:
        self.df[self._doc_buckets(tc)] -= 1
        self.n_docs -= 1

    # ---- weighting -----------------------------------------------------

    def idf(self) -> np.ndarray:
        """idf(t) = ln(N / (1 + df)) + 1  (float32 [dim])."""
        n = max(self.n_docs, 1)
        return (np.log(n / (1.0 + self.df.astype(np.float64))) + 1.0).astype(
            np.float32
        )

    # ---- factored materialization: U (per-doc) × idf (global) ----------
    #
    # The weighted row of a document factors as
    #     v_d = normalize( u_d ⊙ idf ),   u_d[b] = Σ_{t: h(t)=b} tf(t)·sign(t)
    # where u_d depends ONLY on the document and idf ONLY on global df.
    # That split is what makes query-plane refresh O(U): unchanged docs
    # keep their cached u_d rows and only the cheap elementwise
    # reweight + renormalize pass is global (core/engine.py).  The sign
    # multiply is exact (±1), so the factored form is deterministic.

    def unweighted_row(self, tc: TermCounts) -> np.ndarray:
        """tf·sign scatter of one document — no idf, no normalization.

        Bit-identical to the corresponding row of
        ``build_unweighted_matrix`` (same scatter-add order), which is
        what lets the incremental engine patch single rows.
        """
        v = np.zeros((self.dim,), dtype=np.float32)
        if tc.term_hashes.size:
            buckets, signs = bucket_sign(tc.term_hashes, self.dim)
            tf = 1.0 + np.log(tc.counts.astype(np.float32))
            np.add.at(v, buckets, tf * signs.astype(np.float32))
        return v

    def build_unweighted_matrix(self, term_counts: list[TermCounts]) -> np.ndarray:
        """Batch tf·sign scatter, float32 [n, dim].

        One concatenated scatter-add instead of a per-doc loop — this is
        the same bag-accumulation dataflow as the recsys EmbeddingBag
        (models/recsys/embedding.py); on TPU it lowers to the
        embedding_bag kernel.
        """
        n = len(term_counts)
        out = np.zeros((n, self.dim), dtype=np.float32)
        if n == 0:
            return out
        rows, cols, vals = [], [], []
        for i, tc in enumerate(term_counts):
            if tc.term_hashes.size == 0:
                continue
            buckets, signs = bucket_sign(tc.term_hashes, self.dim)
            tf = 1.0 + np.log(tc.counts.astype(np.float32))
            rows.append(np.full(buckets.shape, i, dtype=np.int64))
            cols.append(buckets.astype(np.int64))
            vals.append(tf * signs.astype(np.float32))
        if rows:
            flat = np.concatenate(rows) * self.dim + np.concatenate(cols)
            np.add.at(out.reshape(-1), flat, np.concatenate(vals))
        return out

    def finalize_matrix(self, unweighted: np.ndarray,
                        idf: np.ndarray | None = None) -> np.ndarray:
        """idf reweight + row ℓ2-normalize (the cheap global stage).

        ``idf`` defaults to the live statistics; pass a snapshot to
        reproduce vectors against archived df state.
        """
        if idf is None:
            idf = self.idf()
        out = unweighted * idf[None, :]
        norms = np.linalg.norm(out, axis=1, keepdims=True)
        np.divide(out, norms, out=out, where=norms > 0)
        return out

    def doc_vector(self, tc: TermCounts, idf: np.ndarray | None = None) -> np.ndarray:
        """Dense ℓ2-normalized doc vector (float32 [dim])."""
        return self.finalize_matrix(self.unweighted_row(tc)[None, :], idf)[0]

    def build_matrix(self, term_counts: list[TermCounts]) -> np.ndarray:
        """Weighted, normalized doc matrix [n, dim] (cold build)."""
        return self.finalize_matrix(self.build_unweighted_matrix(term_counts))

    def query_vector(self, query: str) -> np.ndarray:
        """Vectorize a query with the *current* idf statistics."""
        return self.doc_vector(TermCounts.from_text(query))

    # ---- (de)serialization for the knowledge container ----------------

    def state(self) -> dict:
        return {"dim": self.dim, "n_docs": self.n_docs}

    @staticmethod
    def from_state(state: dict, df: np.ndarray) -> "HashedTfIdf":
        return HashedTfIdf(dim=int(state["dim"]), n_docs=int(state["n_docs"]), df=df)


def tokenize_query(query: str) -> list[str]:
    return tokenize(query)
