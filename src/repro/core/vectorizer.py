"""Hashed sublinear TF-IDF vectorizer (paper §4.1, TPU-adapted).

Paper formulas (kept exactly):

    tf(t, d)  = 1 + ln f_{t,d}
    idf(t)    = ln(N / (1 + df_t)) + 1
    v_d       = l2_normalize( [tf·idf]_t )

Adaptation (DESIGN.md §3): the paper stores vocabulary-dimensional sparse
vectors; a TPU MXU wants dense, bounded-width operands.  We apply *signed
feature hashing* (hashing trick): term t → bucket ``h(t) mod D`` with sign
``±1`` from a decorrelated hash bit.  Cosine similarity is preserved in
expectation; D is a build-time constant (multiple of 128 → lane-aligned).

Document frequency is maintained *per bucket* and updated incrementally
(`add_doc` / `remove_doc`), which is what keeps re-indexing O(U) in the
number of updated documents (paper §3.3): unchanged documents keep their
stored `TermCounts`; only the cheap re-weighting pass is global.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import hashing
from repro.core.tokenizer import TermCounts, tokenize

DEFAULT_DIM = 4096


def bucket_sign(term_hashes: np.ndarray, dim: int) -> tuple[np.ndarray, np.ndarray]:
    """Signed feature hashing: bucket = h mod D, sign = ±1 from mixed bit."""
    h = term_hashes.astype(np.uint64)
    buckets = (h % np.uint64(dim)).astype(np.int32)
    signs = np.where(
        (hashing.mix64(h) >> np.uint64(63)).astype(np.int8) == 1, -1, 1
    ).astype(np.int8)
    return buckets, signs


@dataclass
class HashedTfIdf:
    """Stateful hashed TF-IDF model.  State = (dim, n_docs, df[dim])."""

    dim: int = DEFAULT_DIM
    n_docs: int = 0
    df: np.ndarray = field(default=None)  # int64 [dim]

    def __post_init__(self):
        if self.df is None:
            self.df = np.zeros((self.dim,), dtype=np.int64)
        assert self.dim % 128 == 0, "hashed dim must be lane-aligned (×128)"

    # ---- incremental df maintenance (O(U) ingestion path) -------------

    def _doc_buckets(self, tc: TermCounts) -> np.ndarray:
        buckets, _ = bucket_sign(tc.term_hashes, self.dim)
        return np.unique(buckets)

    def add_doc(self, tc: TermCounts) -> None:
        self.df[self._doc_buckets(tc)] += 1
        self.n_docs += 1

    def remove_doc(self, tc: TermCounts) -> None:
        self.df[self._doc_buckets(tc)] -= 1
        self.n_docs -= 1

    # ---- weighting -----------------------------------------------------

    def idf(self) -> np.ndarray:
        """idf(t) = ln(N / (1 + df)) + 1  (float32 [dim])."""
        n = max(self.n_docs, 1)
        return (np.log(n / (1.0 + self.df.astype(np.float64))) + 1.0).astype(
            np.float32
        )

    def _weights(self, tc: TermCounts, idf: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        buckets, signs = bucket_sign(tc.term_hashes, self.dim)
        tf = 1.0 + np.log(tc.counts.astype(np.float32))
        w = tf * idf[buckets] * signs.astype(np.float32)
        return buckets, w

    def doc_vector(self, tc: TermCounts, idf: np.ndarray | None = None) -> np.ndarray:
        """Dense ℓ2-normalized doc vector (float32 [dim])."""
        if idf is None:
            idf = self.idf()
        v = np.zeros((self.dim,), dtype=np.float32)
        if tc.term_hashes.size:
            buckets, w = self._weights(tc, idf)
            np.add.at(v, buckets, w)
            norm = np.linalg.norm(v)
            if norm > 0:
                v /= norm
        return v

    def build_matrix(self, term_counts: list[TermCounts]) -> np.ndarray:
        """Vectorized batch build of the weighted doc matrix [n, dim].

        One concatenated scatter-add instead of a per-doc loop — this is
        the same bag-accumulation dataflow as the recsys EmbeddingBag
        (models/recsys/embedding.py); on TPU it lowers to the
        embedding_bag kernel.
        """
        n = len(term_counts)
        out = np.zeros((n, self.dim), dtype=np.float32)
        if n == 0:
            return out
        idf = self.idf()
        rows, cols, vals = [], [], []
        for i, tc in enumerate(term_counts):
            if tc.term_hashes.size == 0:
                continue
            buckets, w = self._weights(tc, idf)
            rows.append(np.full(buckets.shape, i, dtype=np.int64))
            cols.append(buckets.astype(np.int64))
            vals.append(w)
        if rows:
            flat = np.concatenate(rows) * self.dim + np.concatenate(cols)
            np.add.at(out.reshape(-1), flat, np.concatenate(vals))
        norms = np.linalg.norm(out, axis=1, keepdims=True)
        np.divide(out, norms, out=out, where=norms > 0)
        return out

    def query_vector(self, query: str) -> np.ndarray:
        """Vectorize a query with the *current* idf statistics."""
        return self.doc_vector(TermCounts.from_text(query))

    # ---- (de)serialization for the knowledge container ----------------

    def state(self) -> dict:
        return {"dim": self.dim, "n_docs": self.n_docs}

    @staticmethod
    def from_state(state: dict, df: np.ndarray) -> "HashedTfIdf":
        return HashedTfIdf(dim=int(state["dim"]), n_docs=int(state["n_docs"]), df=df)


def tokenize_query(query: str) -> list[str]:
    return tokenize(query)
