"""RAG orchestration: retrieve → pack context → generate.

The paper's end-to-end loop (§1): the deterministic HSF retriever feeds
the generator's prompt window.  Generation here is the framework's own
LM serving path (prefill + greedy decode with KV caches) — the paper
treats the LLM as a black box; we treat it as the generation plane of
the same framework.

Serving is batched at the retrieval tier: ``answer_batch`` scores all
questions in one ``QueryEngine.query_batch`` dispatch (core/engine.py),
then generates per question (prompt lengths differ, so generation stays
per-request; retrieval is where multi-user batching pays — see
docs/ARCHITECTURE.md §5).

Tokenization for the LM uses the same stable hashing as the retrieval
plane (word → fnv1a64 mod vocab): real deployments plug a trained
subword tokenizer here (one `text_to_tokens` function), and nothing
about retrieval, packing, prefill or decode changes.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.engine import QueryEngine
from repro.core.ingest import KnowledgeBase
from repro.core.retrieval import RetrievalResult
from repro.core.tokenizer import tokenize
from repro.models import transformer as T


def text_to_tokens(text: str, vocab: int) -> list[int]:
    return [hashing.fnv1a64(w) % vocab for w in tokenize(text)]


@dataclass
class RAGOutput:
    retrieved: list[RetrievalResult]
    token_ids: list[int]
    prompt_len: int


@dataclass
class RAGPipeline:
    kb: KnowledgeBase
    params: dict
    cfg: T.LMConfig
    max_context_tokens: int = 512
    alpha: float = 1.0
    beta: float = 1.0
    use_kernel: bool = False
    # injectable: serving drivers pass the runtime's engine so the
    # retrieval arrays exist once, not once per plane (serving/ owns
    # the scheduler; RAGPipeline owns context packing + decode).
    # Threading contract when injecting a ServingRuntime's engine:
    # retrieval entry points here (answer/answer_batch) call
    # engine.refresh() and so count as *writer-thread* operations under
    # the single-writer contract (docs/ARCHITECTURE.md §7) — concurrent
    # callers must retrieve via runtime.submit() and use generate()
    # with the served results, as launch/serve.py does.
    engine: QueryEngine | None = field(default=None, repr=False)

    def __post_init__(self):
        if self.engine is None:
            self.engine = QueryEngine(self.kb, self.alpha, self.beta,
                                      use_kernel=self.use_kernel)
        elif self.engine.kb is not self.kb:
            raise ValueError("injected engine serves a different "
                             "KnowledgeBase than this pipeline")

    def _pack_context(self, results: list[RetrievalResult]) -> list[int]:
        """Greedy context packing: best-scored docs first, truncated to
        the token budget (the paper's 'inject into the prompt window')."""
        budget = self.max_context_tokens
        packed: list[int] = []
        for r in results:
            toks = text_to_tokens(self.kb.texts[r.doc_id], self.cfg.vocab)
            take = min(len(toks), budget - len(packed))
            packed.extend(toks[:take])
            if len(packed) >= budget:
                break
        return packed

    def answer(self, question: str, max_new_tokens: int = 16,
               top_k_docs: int = 3) -> RAGOutput:
        return self.answer_batch([question], max_new_tokens=max_new_tokens,
                                 top_k_docs=top_k_docs)[0]

    def answer_batch(self, questions: list[str], max_new_tokens: int = 16,
                     top_k_docs: int = 3) -> list[RAGOutput]:
        """Serve a request batch: one retrieval dispatch, then generate.

        Retrieval results per question are identical to serial
        ``answer`` calls (the engine's bit-stability contract), so
        batching changes throughput, never answers.
        """
        retrieved = self.engine.query_batch(questions, k=top_k_docs)
        return [
            self.generate(question, results, max_new_tokens)
            for question, results in zip(questions, retrieved)
        ]

    def generate(self, question: str, results: list[RetrievalResult],
                 max_new_tokens: int) -> RAGOutput:
        """Generation stage alone: pack pre-retrieved context + decode.

        Public so drivers can time retrieval (``engine.query_batch``)
        and generation separately while staying on the library path.
        """
        prompt = self._pack_context(results) + text_to_tokens(
            question, self.cfg.vocab
        )
        prompt = prompt[-self.max_context_tokens:] or [0]
        max_len = len(prompt) + max_new_tokens

        tokens = jnp.asarray(np.array(prompt, np.int32))[None, :]
        logits, caches, lengths = T.prefill(self.params, tokens, self.cfg,
                                            max_len)
        out: list[int] = []
        next_tok = int(jnp.argmax(logits[0, -1]))
        for _ in range(max_new_tokens):
            out.append(next_tok)
            lengths = lengths + 1
            logits, caches = T.decode_step(
                self.params, caches,
                jnp.asarray([[next_tok]], jnp.int32), lengths, self.cfg,
            )
            next_tok = int(jnp.argmax(logits[0, 0]))
        return RAGOutput(retrieved=results, token_ids=out,
                         prompt_len=len(prompt))
