"""Hybrid Scoring Function (paper §4):

    Score(Q, D) = α · cos(v_Q, v_D) + β · 1_substr(Q, D)

with the TPU-native containment form of the indicator (signature.py).
This module is the *reference* (pure jnp) implementation plus the
dispatcher that routes the hot loop to the fused Pallas kernel
(kernels/hsf_score) when requested.

Default weights follow the paper's reported top score for the injected
entity (1.5753 with cosine ≈ 0.575 and a unit boost): α = 1.0, β = 1.0.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitizers

DEFAULT_ALPHA = 1.0
DEFAULT_BETA = 1.0


def stable_rowdot(mat: jnp.ndarray, vec: jnp.ndarray) -> jnp.ndarray:
    """Deterministic [n, D] · [D] matvec — float32 [n].

    XLA's ``dot`` leaves the reduction order unspecified: the compiled
    schedule varies with operand height, gather fusion, and thread
    partitioning, so the *same row* can round to different last ulps
    between a flat [N, D] scan and a gathered candidate block — which
    silently breaks every bit-identity contract in this repo (flat vs
    IVF rerank, flat vs the sharded mesh plane, snapshot pins).  This
    formulation pins the order instead of hoping: elementwise products,
    then an explicit pairwise-halving tree over the feature axis
    (zero-padded to a power of two; padding with +0.0 is exact).
    Separate HLO adds are not reassociated by XLA, so each row's dot is
    a pure function of that row's values — independent of how many rows
    ride along, which device scores them, or where they were gathered
    from.  Every "map"-path cosine (engine, IVF rerank, sharded shard
    blocks) routes through here; that shared formulation *is* the
    exactness guarantee.
    """
    p = mat.astype(jnp.float32) * vec.astype(jnp.float32)[None, :]
    d = p.shape[-1]
    width = 1 << max(0, d - 1).bit_length() if d > 1 else 1
    if width != d:
        p = jnp.pad(p, ((0, 0), (0, width - d)))
    while width > 1:
        width //= 2
        p = p[:, :width] + p[:, width:]
    return p[:, 0]


def containment(doc_sigs: jnp.ndarray, query_sig: jnp.ndarray) -> jnp.ndarray:
    """Bloom containment indicator, float32 [n_docs].

    doc_sigs int32 [n, W], query_sig int32 [W].  Bitwise ops on int32 are
    well-defined (two's complement); equality is what matters.
    """
    hits = (doc_sigs & query_sig) == query_sig
    return jnp.all(hits, axis=-1).astype(jnp.float32)


@partial(jax.jit, static_argnames=("alpha", "beta"))
def hsf_scores(
    doc_vecs: jnp.ndarray,  # float32/bf16 [n, D], rows ℓ2-normalized
    doc_sigs: jnp.ndarray,  # int32 [n, W]
    query_vec: jnp.ndarray,  # [D]
    query_sig: jnp.ndarray,  # int32 [W]
    alpha: float = DEFAULT_ALPHA,
    beta: float = DEFAULT_BETA,
) -> jnp.ndarray:
    """Reference HSF: α·(docs @ q) + β·containment.  float32 [n].

    The cosine rides the pinned-order ``stable_rowdot`` so this
    reference is bit-identical to the engine's map path row for row.
    """
    cos = stable_rowdot(doc_vecs, query_vec)
    return alpha * cos + beta * containment(doc_sigs, query_sig)


@partial(jax.jit, static_argnames=("alpha", "beta"))
def hsf_scores_batched(
    doc_vecs: jnp.ndarray,  # [n, D]
    doc_sigs: jnp.ndarray,  # int32 [n, W]
    query_vecs: jnp.ndarray,  # [q, D]
    query_sigs: jnp.ndarray,  # int32 [q, W]
    alpha: float = DEFAULT_ALPHA,
    beta: float = DEFAULT_BETA,
) -> jnp.ndarray:
    """Multi-query HSF (serving batch): float32 [q, n]."""
    # analysis: allow[unpinned-reduction] -- opt-in batched gemm path,
    #   documented non-bit-stable vs the map path (ARCHITECTURE §5)
    cos = query_vecs.astype(jnp.float32) @ doc_vecs.astype(jnp.float32).T
    hits = (doc_sigs[None, :, :] & query_sigs[:, None, :]) == query_sigs[:, None, :]
    ind = jnp.all(hits, axis=-1).astype(jnp.float32)
    return alpha * cos + beta * ind


# steady-state retrace accounting (no-op unless RAGDB_SANITIZERS is on)
sanitizers.register_jit("hsf.hsf_scores", hsf_scores)
sanitizers.register_jit("hsf.hsf_scores_batched", hsf_scores_batched)


def hsf_scores_kernel(
    doc_vecs, doc_sigs, query_vec, query_sig,
    alpha: float = DEFAULT_ALPHA,
    beta: float = DEFAULT_BETA,
    interpret: bool | None = None,
):
    """Fused Pallas path (see kernels/hsf_score).  Lazy import — keeps
    core/ importable without the kernels package in minimal builds."""
    from repro.kernels.hsf_score import ops as _ops

    return _ops.hsf_score(
        doc_vecs, doc_sigs, query_vec, query_sig,
        alpha=alpha, beta=beta, interpret=interpret,
    )


def hsf_topk_batched_kernel(
    doc_vecs, doc_sigs, query_vecs, query_sigs,
    *,
    k: int,
    alpha: float = DEFAULT_ALPHA,
    beta: float = DEFAULT_BETA,
    n_valid=None,
    interpret: bool | None = None,
):
    """Batched-kernel dispatcher: fused multi-query HSF with in-kernel
    top-k (kernels/hsf_score).  Returns (vals [B, k'], ids [B, k']),
    k' = min(k, N), tie-broken by doc index exactly like
    `retrieval._stable_top_k`.  The [B, N] score matrix never
    materializes in HBM — this is the serving-plane hot loop.

    Lazy import for the same minimal-build reason as above."""
    from repro.kernels.hsf_score import ops as _ops

    return _ops.hsf_score_batched(
        doc_vecs, doc_sigs, query_vecs, query_sigs,
        k=k, alpha=alpha, beta=beta, n_valid=n_valid, interpret=interpret,
    )


def hsf_kernel_pad_docs(doc_vecs, doc_sigs):
    """Block-align doc operands for the batched kernel once (e.g. at
    engine refresh) instead of per dispatch; see
    `kernels/hsf_score/ops.pad_docs_for_kernel`."""
    from repro.kernels.hsf_score import ops as _ops

    return _ops.pad_docs_for_kernel(doc_vecs, doc_sigs)


def top_k(scores: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(values, indices) of the k best scores."""
    return jax.lax.top_k(scores, k)


def numpy_reference(doc_vecs, doc_sigs, query_vec, query_sig, alpha, beta):
    """Pure-numpy oracle for tests (no jax involvement at all)."""
    # analysis: allow[unpinned-reduction] -- float64 test oracle; extra
    #   mantissa absorbs reduction-order error, tests allow an eps band
    cos = doc_vecs.astype(np.float64) @ query_vec.astype(np.float64)
    d = doc_sigs.view(np.uint32)
    q = query_sig.view(np.uint32)
    ind = np.all((d & q) == q, axis=-1).astype(np.float64)
    return alpha * cos + beta * ind
