"""Automated multimodal ingestion + the O(U) incremental algorithm
(paper §3.2–§3.3).

Pipeline per document:  sniff → extract → normalize → vectorize.

Incremental algorithm (paper §3.3, kept exactly):
  1. scan the target directory,
  2. SHA-256 of each file's bitstream,
  3. compare against the metadata region M,
  4. unchanged → skip; new/changed → run the pipeline; vanished → remove.

Cost is O(U) in *updated* files — the expensive stages (extraction,
tokenization, signature construction) are only run for the delta.  The
cheap global stage (IDF re-weighting + matrix materialization) is a single
vectorized pass; it is deferred until `materialize()` so a burst of syncs
pays it once.  Every mutation is also recorded in a dirty-row change log
(`version` / `changes_since`) so the serving plane (core/engine.py) can
patch its device-resident arrays incrementally instead of rebuilding.

Modality frontends: text/CSV/JSON extractors are real; PDF/image/DOCX are
**stubs** per the task rules (the paper uses ONNX OCR — a model frontend
we intentionally do not ship).  The sniffing/routing layer itself is real
and tested.
"""
from __future__ import annotations

import contextlib
import csv
import hashlib
import io
import json
import os
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import signature as sigmod
from repro.core.postings import PostingsIndex
from repro.core.container import (
    Container,
    append_journal_record,
    decode_texts,
    encode_texts,
    journal_size,
    read_journal,
    reset_journal,
    write_container,
)
from repro.core.tokenizer import TermCounts
from repro.core.vectorizer import HashedTfIdf
from repro.obs import trace as obs_trace

# --------------------------------------------------------------------------
# modality sniffing (paper §3.2 "magic-byte analysis")
# --------------------------------------------------------------------------

MAGIC_TABLE = [
    (b"%PDF-", "pdf"),
    (b"\x89PNG", "image"),
    (b"\xff\xd8\xff", "image"),
    (b"GIF8", "image"),
    (b"PK\x03\x04", "zip"),  # docx/xlsx/zip
]

# bytes of file head handed to the sniffer: wide enough that leading
# whitespace (pretty-printed / BOM-ish JSON) cannot push the first
# structural byte out of the probe window (a 16-byte head used to
# misroute JSON with >15 leading whitespace bytes to "text")
SNIFF_WINDOW = 512

_EXTENSION_HINTS = {".csv": "csv", ".json": "json", ".jsonl": "json"}


def sniff_modality(head: bytes, path: str = "") -> str:
    """Route a file head to a modality frontend (paper §3.2).

    Precedence: binary magic bytes → extension hints → structural
    probe.  Extension hints must outrank the ``{``/``[`` probe: a CSV
    whose first cell starts with ``[`` is CSV, not JSON.
    """
    for magic, kind in MAGIC_TABLE:
        if head.startswith(magic):
            return kind
    hint = _EXTENSION_HINTS.get(os.path.splitext(path)[1].lower())
    if hint is not None:
        return hint
    stripped = head.lstrip()
    if stripped[:1] in (b"{", b"["):
        return "json"
    return "text"


# --------------------------------------------------------------------------
# extractors (normalize heterogeneous sources to text, paper §3.2)
# --------------------------------------------------------------------------

def _extract_text(data: bytes) -> str:
    return data.decode("utf-8", errors="replace")


def _extract_json(data: bytes) -> str:
    """Flatten JSON into `key: value` lines (structure-preserving)."""
    try:
        obj = json.loads(data.decode("utf-8", errors="replace"))
    except json.JSONDecodeError:
        return _extract_text(data)
    lines: list[str] = []

    def walk(prefix: str, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}.{k}" if prefix else str(k), v)
        elif isinstance(node, list):
            for i, v in enumerate(node):
                walk(f"{prefix}[{i}]", v)
        else:
            lines.append(f"{prefix}: {node}")

    walk("", obj)
    return "\n".join(lines)


def _extract_csv(data: bytes) -> str:
    """Row serialization with headers as context keys (paper §3.2:
    'preserving column headers as context keys')."""
    text = data.decode("utf-8", errors="replace")
    reader = csv.reader(io.StringIO(text))
    rows = list(reader)
    if not rows:
        return ""
    header = rows[0]
    out = []
    for row in rows[1:]:
        cells = [f"{h}={v}" for h, v in zip(header, row)]
        # rows longer than the header used to lose their tail to zip
        # truncation; keep overflow cells under positional colN keys
        cells += [
            f"col{j}={v}"
            for j, v in enumerate(row[len(header):], start=len(header))
        ]
        out.append(", ".join(cells))
    return "\n".join(out)


def _extract_stub(kind: str):
    def extract(data: bytes) -> str:
        # Modality frontend stub: production would run the ONNX OCR /
        # docx parser here.  We surface a deterministic marker so tests
        # can verify routing without shipping a vision model.
        digest = hashlib.sha256(data).hexdigest()[:12]
        return f"[{kind}-frontend-stub content={digest} bytes={len(data)}]"

    return extract


EXTRACTORS = {
    "text": _extract_text,
    "json": _extract_json,
    "csv": _extract_csv,
    "pdf": _extract_stub("pdf"),
    "image": _extract_stub("image"),
    "zip": _extract_stub("zip"),
}


def extract(data: bytes, path: str = "") -> tuple[str, str]:
    kind = sniff_modality(data[:SNIFF_WINDOW], path)
    return EXTRACTORS[kind](data), kind


# --------------------------------------------------------------------------
# knowledge base (in-memory state behind a container)
# --------------------------------------------------------------------------

@dataclass
class IngestStats:
    scanned: int = 0
    skipped: int = 0
    added: int = 0
    updated: int = 0
    removed: int = 0
    seconds: float = 0.0

    @property
    def processed(self) -> int:
        return self.added + self.updated


@dataclass
class DocRecord:
    path: str
    sha256: str
    modality: str
    mtime: float
    size: int = -1      # -1 = unknown (pre-size containers, add_text docs)
    mtime_ns: int = -1  # ns mtime for the O(stat) quick check; -1 = unarmed


@dataclass
class KnowledgeBase:
    """The live object behind a knowledge container.

    Regions: M = `records`, C = `texts`, V = `term_counts` (+ the
    materialized matrix), I = signatures (+ df inside the vectorizer).
    """

    dim: int = 4096
    sig_words: int = sigmod.DEFAULT_WIDTH_WORDS
    vectorizer: HashedTfIdf = None
    records: dict[str, DocRecord] = field(default_factory=dict)
    texts: dict[str, str] = field(default_factory=dict)
    term_counts: dict[str, TermCounts] = field(default_factory=dict)
    signatures: dict[str, np.ndarray] = field(default_factory=dict)
    _dirty: bool = True
    _matrix: np.ndarray | None = None
    _doc_ids: list[str] | None = None
    _sig_matrix: np.ndarray | None = None
    _postings: PostingsIndex | None = None
    # dirty-row change log for incremental query-plane refresh
    # (core/engine.py): doc id → version of the mutation that last
    # touched it.  ``version`` increases on every add/update/remove.
    _version: int = 0
    _changed_at: dict[str, int] = field(default_factory=dict)
    _removed_at: dict[str, int] = field(default_factory=dict)
    # metadata-only changes (re-armed stat fast-path keys on docs whose
    # content did not change): invisible to changes_since — the engine
    # has nothing to re-vectorize — but save_delta persists them so the
    # O(stat) sync win survives a restart
    _meta_changed_at: dict[str, int] = field(default_factory=dict)
    # clustered-index state (src/repro/index/): an opaque dict of raw
    # arrays + scalars the engine writes via ``set_index_state`` after
    # training/maintaining its IVF index.  Persisted as ``ivf_*``
    # container segments + ``meta["index"]`` so a loaded KB serves
    # queries without a cold retrain; ``_index_rev`` vs
    # ``_index_persisted_rev`` decides whether a delta record must
    # carry it.
    index_state: dict | None = None
    _index_rev: int = 0
    _index_persisted_rev: int = 0
    # centroid digest of the last persisted index state: delta records
    # omit the ivf_centroids segment (the dominant byte term, ~√N·D·4)
    # when the chain already carries it — centroids only change on
    # retrain, while assignments/bounds move on every reassign
    _index_persisted_centroid_sha: str | None = None
    # single-writer guard (see _single_writer below)
    _write_lock: threading.Lock = field(
        default_factory=threading.Lock, repr=False, compare=False
    )
    # ---- persistence chain (save/save_delta/load bookkeeping) ----------
    # container generation of the last save/save_delta/load; -1 = never
    # persisted.  save()/save_delta() default to continuing it
    # monotonically, and load() restores it (it used to be parsed by
    # Container.open and then dropped, resetting the lineage the serving
    # plane pins snapshots against).
    loaded_generation: int = -1
    _persisted_version: int = -1     # KB version covered by the last save
    _persisted_ids: set[str] = field(default_factory=set)
    _persisted_path: str | None = None  # abspath of the journal chain's base
    _base_uid: str | None = None     # data_sha256 of the base container
    # observability: perf_counter stamp of the oldest mutation no
    # snapshot publish has absorbed yet (-1 = nothing pending); read +
    # cleared by serving/snapshot.py to gauge publish lag
    _pending_first_t: float = field(default=-1.0, repr=False, compare=False)

    def __post_init__(self):
        if self.vectorizer is None:
            self.vectorizer = HashedTfIdf(dim=self.dim)

    # ---- single-writer contract -----------------------------------------
    #
    # A KnowledgeBase is NOT a concurrent data structure.  The serving
    # plane (serving/snapshot.py) relies on exactly this contract:
    #
    #   - exactly ONE thread performs mutations (``sync``/``add_text``/
    #     removal) and the subsequent engine ``refresh()``/snapshot
    #     ``publish()``;
    #   - any number of threads may read *published snapshots* — never
    #     the live dicts/arrays here — concurrently with that writer.
    #
    # ``version``/``changes_since`` are safe for the writer thread to
    # interleave with its own mutations (they are how the engine's
    # refresh discovers the delta) but are only meaningful to other
    # threads via the generation a snapshot was pinned at.  The guard
    # below turns a second concurrent writer — a latent torn-index bug —
    # into an immediate, attributable error instead of silent corruption
    # of df counts / change-log ordering.

    @contextlib.contextmanager
    def _single_writer(self, op: str):
        if not self._write_lock.acquire(blocking=False):
            raise RuntimeError(
                f"concurrent KnowledgeBase.{op}: mutations follow a "
                "single-writer contract (one ingest thread; readers go "
                "through serving snapshots — docs/ARCHITECTURE.md §7)"
            )
        try:
            yield
        finally:
            self._write_lock.release()

    # ---- pipeline for a single document --------------------------------

    def _ingest_doc(self, path: str, data: bytes, digest: str, mtime: float,
                    size: int = -1, mtime_ns: int = -1):
        with obs_trace.span("extract") as sp:
            text, kind = extract(data, path)
            sp.set(modality=kind, bytes=len(data))
        if path in self.term_counts:  # changed file: retire old stats
            self.vectorizer.remove_doc(self.term_counts[path])
        tc = TermCounts.from_text(text)
        self.vectorizer.add_doc(tc)
        self.records[path] = DocRecord(path, digest, kind, mtime, size,
                                       mtime_ns)
        self.texts[path] = text
        self.term_counts[path] = tc
        self.signatures[path] = sigmod.signature_of_text(
            text, width_words=self.sig_words
        )
        self._version += 1
        self._changed_at[path] = self._version
        self._removed_at.pop(path, None)
        self._meta_changed_at.pop(path, None)  # superseded by full change
        self._dirty = True
        self._note_mutation()

    # Removal-log bound: entries beyond this are dropped oldest-first.
    # Consumers must treat the removed list as advisory (the engine
    # derives actual removals from the doc-id set, see core/engine.py);
    # only removal *stats* can undercount for consumers further than
    # this many deletions behind.
    REMOVED_LOG_MAX = 4096

    def _remove_doc(self, path: str):
        self.vectorizer.remove_doc(self.term_counts.pop(path))
        self.records.pop(path)
        self.texts.pop(path)
        self.signatures.pop(path)
        self._version += 1
        self._changed_at.pop(path, None)
        self._meta_changed_at.pop(path, None)
        self._removed_at[path] = self._version
        while len(self._removed_at) > self.REMOVED_LOG_MAX:
            self._removed_at.pop(next(iter(self._removed_at)))
        self._dirty = True
        self._note_mutation()

    # ---- publish-lag accounting (read by serving/snapshot.py) -----------

    def _note_mutation(self) -> None:
        if self._pending_first_t < 0:
            self._pending_first_t = time.perf_counter()

    def take_publish_lag(self) -> float | None:
        """Seconds since the oldest mutation no snapshot publish has
        absorbed, clearing the stamp (writer thread only — the
        snapshot manager calls this right after its reference swap).
        None when nothing was pending."""
        t = self._pending_first_t
        if t < 0:
            return None
        self._pending_first_t = -1.0
        return time.perf_counter() - t

    # ---- dirty-row accounting (consumed by core/engine.py) --------------

    @property
    def version(self) -> int:
        """Monotonic mutation counter (0 = as-constructed/loaded).

        Thread-safety: exact only on the writer thread (the
        single-writer contract above).  Other threads must consume
        versions via a pinned snapshot's ``generation``, never by
        polling this property concurrently with mutations.
        """
        return self._version

    def changes_since(self, version: int) -> tuple[list[str], list[str]]:
        """(changed_ids, removed_ids) strictly after ``version``.

        Writer-thread API (single-writer contract): the engine's
        ``refresh()`` calls this between mutations it itself observed;
        calling it from a second thread mid-mutation can see a torn
        change log.

        ``changed`` covers both new and updated documents; a doc that
        was removed and re-added since ``version`` appears only in
        ``changed``.  Ids are sorted for deterministic consumption.
        ``removed`` is advisory (bounded by ``REMOVED_LOG_MAX``):
        consumers must derive authoritative removals from the current
        ``records`` key set, as core/engine.py does.
        """
        changed = sorted(
            p for p, v in self._changed_at.items() if v > version
        )
        removed = sorted(
            p for p, v in self._removed_at.items() if v > version
        )
        return changed, removed

    # ---- the paper's incremental sync ----------------------------------

    def sync(self, source_dir: str, verify_hashes: bool = False) -> IngestStats:
        """Incremental directory sync (paper §3.3).

        Unchanged files are skipped by an O(stat) quick check
        (size + nanosecond mtime, rsync-style) before falling back to
        the content hash.  On filesystems with coarse mtime granularity
        a same-size in-place edit inside one timestamp tick could evade
        the quick check — pass ``verify_hashes=True`` to force content
        hashing for every scanned file (the paper's original O(N·hash)
        scan).

        Single-writer: concurrent mutation from a second thread raises
        (see ``_single_writer``).
        """
        with self._single_writer("sync"), \
                obs_trace.span("ingest_sync") as sp:
            stats = self._sync_locked(source_dir, verify_hashes)
            sp.set(scanned=stats.scanned, added=stats.added,
                   updated=stats.updated, removed=stats.removed,
                   skipped=stats.skipped)
            return stats

    def _sync_locked(self, source_dir: str, verify_hashes: bool) -> IngestStats:
        t0 = time.perf_counter()
        stats = IngestStats()
        seen: set[str] = set()
        for root, _, files in os.walk(source_dir):
            for name in sorted(files):
                full = os.path.join(root, name)
                rel = os.path.relpath(full, source_dir)
                seen.add(rel)
                stats.scanned += 1
                rec = self.records.get(rel)
                st = os.stat(full)
                if (not verify_hashes
                        and rec is not None and rec.size >= 0
                        and rec.mtime_ns >= 0
                        and rec.size == st.st_size
                        and rec.mtime_ns == st.st_mtime_ns):
                    stats.skipped += 1  # O(stat) fast path: no read, no hash
                    continue
                with open(full, "rb") as f:
                    data = f.read()
                digest = hashlib.sha256(data).hexdigest()
                if rec is not None and rec.sha256 == digest:
                    stats.skipped += 1  # content unchanged (e.g. touch)
                    if (rec.size, rec.mtime_ns) != (st.st_size,
                                                    st.st_mtime_ns):
                        # re-arm the stat fast path AND log the metadata
                        # change so save_delta persists the new keys —
                        # otherwise every load() re-hashes this file
                        # forever (the engine sees nothing: content and
                        # vectors are untouched)
                        self._version += 1
                        self._meta_changed_at[rel] = self._version
                    rec.mtime = st.st_mtime
                    rec.size = st.st_size
                    rec.mtime_ns = st.st_mtime_ns
                    continue
                self._ingest_doc(rel, data, digest, st.st_mtime, st.st_size,
                                 st.st_mtime_ns)
                if rec is None:
                    stats.added += 1
                else:
                    stats.updated += 1
        for rel in sorted(set(self.records) - seen):
            self._remove_doc(rel)
            stats.removed += 1
        stats.seconds = time.perf_counter() - t0
        return stats

    def add_text(self, doc_id: str, text: str):
        """Direct ingestion of an already-extracted document.

        Single-writer: concurrent mutation from a second thread raises
        (see ``_single_writer``).
        """
        with self._single_writer("add_text"):
            digest = hashlib.sha256(text.encode("utf-8")).hexdigest()
            self._ingest_doc(doc_id, text.encode("utf-8"), digest, 0.0)

    # ---- materialization (cheap, vectorized, deferred) ------------------

    def materialize(self) -> tuple[np.ndarray, np.ndarray, list[str]]:
        """(doc_matrix [n,D] f32, signatures [n,W] i32, doc_ids)."""
        if self._dirty or self._matrix is None:
            ids = sorted(self.records)
            tcs = [self.term_counts[i] for i in ids]
            self._matrix = self.vectorizer.build_matrix(tcs)
            self._sig_matrix = (
                np.stack([self.signatures[i] for i in ids])
                if ids
                else np.zeros((0, self.sig_words), np.int32)
            )
            self._postings = PostingsIndex.build(tcs)
            self._doc_ids = ids
            self._dirty = False
        return self._matrix, self._sig_matrix, list(self._doc_ids)

    def postings(self) -> PostingsIndex:
        """The ⟨I⟩ region: inverted index over term hashes.

        Never returns None: a container loaded with a matrix but no
        postings segments (pre-postings format) skips the materialize
        rebuild, so build the index from term counts here.
        """
        self.materialize()
        if self._postings is None:
            self._postings = PostingsIndex.build(
                [self.term_counts[i] for i in self._doc_ids]
            )
        return self._postings

    @property
    def n_docs(self) -> int:
        return len(self.records)

    @property
    def unpersisted_changes(self) -> bool:
        """True when this KB holds state the persistence chain does not:
        mutations since the last save/save_delta, index-state movement,
        or any content on a KB that has never been persisted at all.
        The tenancy pool consults this before an eviction so unmounting
        a never-touched tenant does not write an empty container.
        Writer-thread accuracy only (single-writer contract above)."""
        if self._persisted_path is None:
            return self._version > 0 or bool(self.records)
        return (self._version != self._persisted_version
                or self._persisted_ids != set(self.records)
                or self._index_rev > self._index_persisted_rev)

    # ---- clustered-index state (written by core/engine.py) --------------

    def set_index_state(self, state: dict) -> None:
        """Adopt the serving plane's index state (writer thread — the
        engine calls this from ``refresh()``, which the single-writer
        contract puts on the same thread as mutations and publishes).
        Bumps the index revision so the next ``save_delta`` journals it
        even when no documents changed (e.g. a first train on an
        already-persisted corpus)."""
        with self._single_writer("set_index_state"):
            self.index_state = state
            self._index_rev += 1

    def _index_aligned(self) -> bool:
        """True when the index state matches the current doc layout
        (stale state — e.g. docs mutated with no live ivf engine — is
        skipped at save time; the next ivf engine retrains anyway)."""
        return (self.index_state is not None
                and len(self.index_state.get("assign", ()))
                == len(self.records))

    def _index_segments(self, include_centroids: bool = True
                        ) -> dict[str, np.ndarray]:
        st = self.index_state
        segs = {
            "ivf_sig_union": st["sig_union"],
            "ivf_radius": st["radius"],
            "ivf_assign": st["assign"],
        }
        if include_centroids:
            segs["ivf_centroids"] = st["centroids"]
        if st.get("shard_of_cluster") is not None:
            # sharded plane (index/sharded.py): the cluster→shard
            # ownership map rides as one more tiny segment so a reload
            # adopts the exact same partition — small like the
            # assignment array, so it journals with every index delta
            segs["ivf_shard_of_cluster"] = np.asarray(
                st["shard_of_cluster"], np.int32)
        return segs

    def _index_meta(self) -> dict:
        st = self.index_state
        meta = {k: st[k] for k in
                ("kind", "drift", "trained_n", "seed", "ids_sha",
                 "centroid_sha")}
        if st.get("n_shards") is not None:
            meta["n_shards"] = int(st["n_shards"])
        return meta

    @staticmethod
    def _index_state_from(segs: dict, imeta: dict | None,
                          prev: dict | None = None) -> dict | None:
        """Index state from a container image / delta record.  A record
        without the centroid segment inherits centroids from ``prev``
        (the chain's prior state) when the digests agree; a broken
        chain yields None — the next ivf engine retrains (safe)."""
        if imeta is None:
            return None
        if "ivf_centroids" in segs:
            centroids = segs["ivf_centroids"]
        elif (prev is not None
                and prev.get("centroid_sha") == imeta.get("centroid_sha")):
            centroids = prev["centroids"]
        else:
            return None
        state = {
            "kind": imeta.get("kind", "ivf"),
            "centroids": centroids,
            "sig_union": segs["ivf_sig_union"],
            "radius": segs["ivf_radius"],
            "assign": segs["ivf_assign"],
            "drift": int(imeta["drift"]),
            "trained_n": int(imeta["trained_n"]),
            "seed": int(imeta["seed"]),
            "ids_sha": imeta["ids_sha"],
            "centroid_sha": imeta.get("centroid_sha"),
        }
        if (imeta.get("n_shards") is not None
                and "ivf_shard_of_cluster" in segs):
            # the sharded plane's ownership map (absent from states
            # written by a flat-ivf engine — the sharded engine then
            # derives its deterministic partition on adoption)
            state["n_shards"] = int(imeta["n_shards"])
            state["shard_of_cluster"] = segs["ivf_shard_of_cluster"]
        return state

    # ---- container round-trip ------------------------------------------

    def _doc_meta(self, ids: list[str]) -> list[dict]:
        return [
            {
                "id": i,
                "sha256": self.records[i].sha256,
                "modality": self.records[i].modality,
                "mtime": self.records[i].mtime,
                # persist the O(stat) quick-check keys (§3.3): without
                # them the first sync() after a load re-hashes every
                # file, silently losing the incremental-sync win
                "size": self.records[i].size,
                "mtime_ns": self.records[i].mtime_ns,
            }
            for i in ids
        ]

    def _doc_segments(self, ids: list[str],
                      sigs: np.ndarray | None = None) -> dict[str, np.ndarray]:
        """Raw per-doc state (term stats, signatures, texts) + the df
        array, for ``ids`` — the schema shared by the full container and
        journal delta records.  ``sigs`` lets the full save reuse the
        signature matrix ``materialize()`` already stacked."""
        tcs = [self.term_counts[i] for i in ids]
        ptr = np.zeros((len(ids) + 1,), np.int64)
        np.cumsum([t.term_hashes.size for t in tcs], out=ptr[1:])
        if sigs is None:
            sigs = (
                np.stack([self.signatures[i] for i in ids])
                if ids else np.zeros((0, self.sig_words), np.int32)
            )
        return {
            "signatures": sigs,
            "df": self.vectorizer.df,
            "term_hashes": (
                np.concatenate([t.term_hashes for t in tcs])
                if ids else np.zeros((0,), np.uint64)
            ),
            "term_counts": (
                np.concatenate([t.counts for t in tcs])
                if ids else np.zeros((0,), np.int32)
            ),
            "term_ptr": ptr,
            "n_tokens": np.array([t.n_tokens for t in tcs], np.int64),
            **encode_texts([self.texts[i] for i in ids]),
        }

    def save(self, path: str, generation: int | None = None,
             include_matrix: bool = True) -> str:
        """Full (cold) publish: re-serializes every segment.

        ``generation=None`` (the default) continues the persisted
        lineage monotonically — ``loaded_generation + 1``, or 0 for a
        never-persisted KB — so a save/load/save round-trip never resets
        the generation the serving plane pins snapshots against.  A full
        save folds any delta journal next to ``path`` into the base and
        resets it (the stale chain could never replay anyway: the
        journal manifest pins the old base image's ``data_sha256``).

        ``include_matrix=False`` drops the materialized ⟨V⟩ dense
        matrix — it is fully derivable from the stored term counts + df,
        so edge deployments can trade first-query latency for a much
        smaller single file (see RQ3)."""
        with self._single_writer("save"), \
                obs_trace.span("container_save", cold=True):
            return self._save_locked(path, generation=generation,
                                     include_matrix=include_matrix)

    def _save_locked(self, path: str, generation: int | None = None,
                     include_matrix: bool = True) -> str:
        matrix, sigs, ids = self.materialize()
        if generation is None:
            generation = self.loaded_generation + 1
        segments = self._doc_segments(ids, sigs=sigs)
        if include_matrix:
            segments["doc_matrix"] = matrix
        segments.update(self.postings().segments())
        meta = {
            "vectorizer": self.vectorizer.state(),
            "sig_words": self.sig_words,
            "docs": self._doc_meta(ids),
        }
        if self._index_aligned():
            segments.update(self._index_segments())
            meta["index"] = self._index_meta()
            self._index_persisted_centroid_sha = \
                self.index_state.get("centroid_sha")
        digest = write_container(path, segments, meta, generation)
        reset_journal(path)
        self.loaded_generation = int(generation)
        self._persisted_version = self._version
        self._persisted_ids = set(ids)
        self._persisted_path = os.path.abspath(path)
        self._base_uid = digest
        self._index_persisted_rev = self._index_rev
        return digest

    # journal auto-compaction threshold: fold when the journal outgrows
    # this fraction of the base container (replay work stays bounded)
    DEFAULT_COMPACT_RATIO = 0.5

    def save_delta(self, path: str,
                   compact_ratio: float | None = DEFAULT_COMPACT_RATIO) -> int:
        """Durable incremental publish: O(U) bytes, not O(N).

        Appends one delta record — the docs changed/removed since the
        last save (derived from the same change log the engine's
        ``refresh()`` consumes) plus the new df state — to the
        append-only journal next to the base container, then commits it
        via the fsync'd journal manifest (core/container.py).  ``load``
        replays base + journal to a state bit-identical to a full
        ``save()`` of the same KB.  Falls back to a full save when there
        is no base container at ``path`` (or the KB's persisted lineage
        belongs to a different path); no-ops when nothing changed.
        Auto-compacts once the journal exceeds ``compact_ratio`` × base
        size (``None`` disables).  Returns the published generation.

        Single-writer: same contract as ``sync``/``add_text``.
        """
        with self._single_writer("save_delta"):
            return self._save_delta_locked(path, compact_ratio)

    def _save_delta_locked(self, path: str,
                           compact_ratio: float | None) -> int:
        apath = os.path.abspath(path)
        if (self._base_uid is None or self._persisted_path != apath
                or not os.path.exists(path)):
            with obs_trace.span("container_save", cold=True):
                self._save_locked(path)  # cold publish (re)starts the chain
            return self.loaded_generation
        changed = sorted(
            p for p, v in self._changed_at.items()
            if v > self._persisted_version and p in self.records
        )
        # authoritative removals: diff against the persisted id set (the
        # in-memory removal log is advisory/bounded — see changes_since)
        removed = sorted(self._persisted_ids - set(self.records))
        # metadata-only updates (re-armed stat keys, content untouched):
        # persisted as record metadata, no segment payload
        changed_set = set(changed)
        meta_changed = sorted(
            p for p, v in self._meta_changed_at.items()
            if v > self._persisted_version and p in self.records
            and p not in changed_set
        )
        # the clustered index journals alongside the docs: a record is
        # due when the engine trained/maintained it since the last
        # persist (possibly with zero doc changes, e.g. a first train
        # over an already-persisted corpus)
        index_changed = (self._index_rev > self._index_persisted_rev
                         and self._index_aligned())
        if not changed and not removed and not meta_changed \
                and not index_changed:
            return self.loaded_generation  # nothing new: zero bytes written
        gen = self.loaded_generation + 1
        meta = {
            "kind": "delta",
            "vectorizer": self.vectorizer.state(),
            "sig_words": self.sig_words,
            "docs": self._doc_meta(changed),
            "meta_docs": self._doc_meta(meta_changed),
            "removed": removed,
        }
        segments = self._doc_segments(changed)
        if index_changed:
            # centroids ride the record only when they actually moved
            # (train/retrain) — assignments/bounds are the O(N + √N·W)
            # small terms that change on every reassign
            csha = self.index_state.get("centroid_sha")
            segments.update(self._index_segments(
                include_centroids=csha != self._index_persisted_centroid_sha
            ))
            meta["index"] = self._index_meta()
        append_journal_record(path, segments, meta, gen, self._base_uid)
        if index_changed:
            self._index_persisted_rev = self._index_rev
            self._index_persisted_centroid_sha = \
                self.index_state.get("centroid_sha")
        self.loaded_generation = gen
        self._persisted_version = self._version
        self._persisted_ids = set(self.records)
        if (compact_ratio is not None
                and journal_size(path) > compact_ratio * os.path.getsize(path)):
            with obs_trace.span("compact", auto=True):
                self._compact_locked(path)
        return self.loaded_generation

    def compact(self, path: str) -> str:
        """Fold the delta journal back into a fresh base container.

        The rewrite publishes through the same atomic ``os.replace`` as
        any full save, then resets the journal.  A crash in between is
        safe: the new base's ``data_sha256`` no longer matches the stale
        journal manifest, so replay ignores it.  When every mutation is
        already persisted the on-disk state is equivalent, so the
        generation is retained; unpersisted changes fold in and bump it
        (the compact is then also a publish)."""
        with self._single_writer("compact"), \
                obs_trace.span("compact"):
            return self._compact_locked(path)

    def _compact_locked(self, path: str) -> str:
        fully_persisted = (self._persisted_version == self._version
                           and self._persisted_ids == set(self.records))
        gen = (self.loaded_generation
               if fully_persisted and self.loaded_generation >= 0 else None)
        return self._save_locked(path, generation=gen)

    @staticmethod
    def _record_from_meta(d: dict) -> DocRecord:
        # pre-size containers lack size/mtime_ns → -1 (fast path
        # unarmed; the first sync falls back to content hashing and
        # re-arms it)
        return DocRecord(d["id"], d["sha256"], d["modality"], d["mtime"],
                         int(d.get("size", -1)), int(d.get("mtime_ns", -1)))

    def _restore_doc_rows(self, docs_meta: list[dict], segs: dict) -> None:
        """Rebuild per-doc state from the shared container/record schema
        (used by both ``load`` and journal-delta replay)."""
        texts = decode_texts(segs["content_blob"], segs["content_offsets"])
        ptr = segs["term_ptr"]
        for j, d in enumerate(docs_meta):
            i = d["id"]
            self.records[i] = self._record_from_meta(d)
            self.texts[i] = texts[j]
            self.term_counts[i] = TermCounts(
                segs["term_hashes"][ptr[j]: ptr[j + 1]],
                segs["term_counts"][ptr[j]: ptr[j + 1]],
                int(segs["n_tokens"][j]),
            )
            self.signatures[i] = segs["signatures"][j]

    def _apply_delta_record(self, meta: dict, segs: dict) -> None:
        """Structural replay of one journal delta record (load path).

        Writes the raw per-doc state + df directly — no change-log or
        version bump: a replayed KB presents as freshly loaded (version
        0), exactly like a KB loaded from the equivalent full save."""
        for rid in meta.get("removed", []):
            self.records.pop(rid, None)
            self.texts.pop(rid, None)
            self.term_counts.pop(rid, None)
            self.signatures.pop(rid, None)
        self._restore_doc_rows(meta["docs"], segs)
        for d in meta.get("meta_docs", []):
            if d["id"] in self.records:  # stat-key refresh, content as-is
                self.records[d["id"]] = self._record_from_meta(d)
        # df/idf state is an authoritative copy from the record — bit-
        # identical to the saver's live statistics, never re-derived
        self.vectorizer.df = segs["df"]
        self.vectorizer.n_docs = int(meta["vectorizer"]["n_docs"])
        if meta.get("index") is not None:
            # later records win, replayed verbatim; centroids inherit
            # from the chain's prior state when the record omitted them
            self.index_state = self._index_state_from(
                segs, meta["index"], prev=self.index_state
            )
        if meta["docs"] or meta.get("removed"):
            self._dirty = True  # meta-only records leave ⟨V⟩/⟨I⟩ intact

    @staticmethod
    def load(path: str) -> "KnowledgeBase":
        """Open base container + replay its delta journal (if any).

        The replayed state is bit-identical to loading a full ``save()``
        of the same KB: doc order, matrix, signatures, postings and df
        all match (tests/test_persistence.py).  Restores the container
        generation into ``loaded_generation`` so subsequent saves
        continue the lineage."""
        c = Container.open(path)
        segs = c.read_all()
        meta = c.meta
        vec = HashedTfIdf.from_state(meta["vectorizer"], segs["df"])
        kb = KnowledgeBase(dim=vec.dim, sig_words=int(meta["sig_words"]),
                           vectorizer=vec)
        kb._restore_doc_rows(meta["docs"], segs)
        if "doc_matrix" in segs:
            kb._matrix = segs["doc_matrix"]
            kb._sig_matrix = segs["signatures"]
            kb._doc_ids = [d["id"] for d in meta["docs"]]
            kb._postings = PostingsIndex.from_segments(segs)
            kb._dirty = False
        # else: matrix rebuilds lazily from term counts at first query
        kb.index_state = kb._index_state_from(segs, meta.get("index"))
        kb.loaded_generation = int(c.generation)
        kb._persisted_version = 0
        kb._persisted_path = os.path.abspath(path)
        kb._base_uid = c.uid
        if c.uid is not None:
            # journal replay: committed records only; torn/corrupt tails
            # were already dropped by read_journal, and a generation gap
            # (stale chain) stops the replay at the last coherent state
            for gen, rmeta, rsegs in read_journal(path, c.uid):
                if (rmeta.get("kind") != "delta"
                        or gen != kb.loaded_generation + 1):
                    break
                kb._apply_delta_record(rmeta, rsegs)
                kb.loaded_generation = gen
        kb._persisted_ids = set(kb.records)
        if kb.index_state is not None:
            kb._index_persisted_centroid_sha = \
                kb.index_state.get("centroid_sha")
        return kb
