"""Automated multimodal ingestion + the O(U) incremental algorithm
(paper §3.2–§3.3).

Pipeline per document:  sniff → extract → normalize → vectorize.

Incremental algorithm (paper §3.3, kept exactly):
  1. scan the target directory,
  2. SHA-256 of each file's bitstream,
  3. compare against the metadata region M,
  4. unchanged → skip; new/changed → run the pipeline; vanished → remove.

Cost is O(U) in *updated* files — the expensive stages (extraction,
tokenization, signature construction) are only run for the delta.  The
cheap global stage (IDF re-weighting + matrix materialization) is a single
vectorized pass; it is deferred until `materialize()` so a burst of syncs
pays it once.  Every mutation is also recorded in a dirty-row change log
(`version` / `changes_since`) so the serving plane (core/engine.py) can
patch its device-resident arrays incrementally instead of rebuilding.

Modality frontends: text/CSV/JSON extractors are real; PDF/image/DOCX are
**stubs** per the task rules (the paper uses ONNX OCR — a model frontend
we intentionally do not ship).  The sniffing/routing layer itself is real
and tested.
"""
from __future__ import annotations

import contextlib
import csv
import hashlib
import io
import json
import os
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import signature as sigmod
from repro.core.postings import PostingsIndex
from repro.core.container import (
    Container,
    decode_texts,
    encode_texts,
    write_container,
)
from repro.core.tokenizer import TermCounts
from repro.core.vectorizer import HashedTfIdf

# --------------------------------------------------------------------------
# modality sniffing (paper §3.2 "magic-byte analysis")
# --------------------------------------------------------------------------

MAGIC_TABLE = [
    (b"%PDF-", "pdf"),
    (b"\x89PNG", "image"),
    (b"\xff\xd8\xff", "image"),
    (b"GIF8", "image"),
    (b"PK\x03\x04", "zip"),  # docx/xlsx/zip
]


def sniff_modality(head: bytes, path: str = "") -> str:
    for magic, kind in MAGIC_TABLE:
        if head.startswith(magic):
            return kind
    stripped = head.lstrip()
    if stripped[:1] in (b"{", b"["):
        return "json"
    if path.endswith(".csv"):
        return "csv"
    return "text"


# --------------------------------------------------------------------------
# extractors (normalize heterogeneous sources to text, paper §3.2)
# --------------------------------------------------------------------------

def _extract_text(data: bytes) -> str:
    return data.decode("utf-8", errors="replace")


def _extract_json(data: bytes) -> str:
    """Flatten JSON into `key: value` lines (structure-preserving)."""
    try:
        obj = json.loads(data.decode("utf-8", errors="replace"))
    except json.JSONDecodeError:
        return _extract_text(data)
    lines: list[str] = []

    def walk(prefix: str, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}.{k}" if prefix else str(k), v)
        elif isinstance(node, list):
            for i, v in enumerate(node):
                walk(f"{prefix}[{i}]", v)
        else:
            lines.append(f"{prefix}: {node}")

    walk("", obj)
    return "\n".join(lines)


def _extract_csv(data: bytes) -> str:
    """Row serialization with headers as context keys (paper §3.2:
    'preserving column headers as context keys')."""
    text = data.decode("utf-8", errors="replace")
    reader = csv.reader(io.StringIO(text))
    rows = list(reader)
    if not rows:
        return ""
    header = rows[0]
    out = []
    for row in rows[1:]:
        out.append(", ".join(f"{h}={v}" for h, v in zip(header, row)))
    return "\n".join(out)


def _extract_stub(kind: str):
    def extract(data: bytes) -> str:
        # Modality frontend stub: production would run the ONNX OCR /
        # docx parser here.  We surface a deterministic marker so tests
        # can verify routing without shipping a vision model.
        digest = hashlib.sha256(data).hexdigest()[:12]
        return f"[{kind}-frontend-stub content={digest} bytes={len(data)}]"

    return extract


EXTRACTORS = {
    "text": _extract_text,
    "json": _extract_json,
    "csv": _extract_csv,
    "pdf": _extract_stub("pdf"),
    "image": _extract_stub("image"),
    "zip": _extract_stub("zip"),
}


def extract(data: bytes, path: str = "") -> tuple[str, str]:
    kind = sniff_modality(data[:16], path)
    return EXTRACTORS[kind](data), kind


# --------------------------------------------------------------------------
# knowledge base (in-memory state behind a container)
# --------------------------------------------------------------------------

@dataclass
class IngestStats:
    scanned: int = 0
    skipped: int = 0
    added: int = 0
    updated: int = 0
    removed: int = 0
    seconds: float = 0.0

    @property
    def processed(self) -> int:
        return self.added + self.updated


@dataclass
class DocRecord:
    path: str
    sha256: str
    modality: str
    mtime: float
    size: int = -1      # -1 = unknown (pre-size containers, add_text docs)
    mtime_ns: int = -1  # ns mtime for the O(stat) quick check; -1 = unarmed


@dataclass
class KnowledgeBase:
    """The live object behind a knowledge container.

    Regions: M = `records`, C = `texts`, V = `term_counts` (+ the
    materialized matrix), I = signatures (+ df inside the vectorizer).
    """

    dim: int = 4096
    sig_words: int = sigmod.DEFAULT_WIDTH_WORDS
    vectorizer: HashedTfIdf = None
    records: dict[str, DocRecord] = field(default_factory=dict)
    texts: dict[str, str] = field(default_factory=dict)
    term_counts: dict[str, TermCounts] = field(default_factory=dict)
    signatures: dict[str, np.ndarray] = field(default_factory=dict)
    _dirty: bool = True
    _matrix: np.ndarray | None = None
    _doc_ids: list[str] | None = None
    _sig_matrix: np.ndarray | None = None
    _postings: PostingsIndex | None = None
    # dirty-row change log for incremental query-plane refresh
    # (core/engine.py): doc id → version of the mutation that last
    # touched it.  ``version`` increases on every add/update/remove.
    _version: int = 0
    _changed_at: dict[str, int] = field(default_factory=dict)
    _removed_at: dict[str, int] = field(default_factory=dict)
    # single-writer guard (see _single_writer below)
    _write_lock: threading.Lock = field(
        default_factory=threading.Lock, repr=False, compare=False
    )

    def __post_init__(self):
        if self.vectorizer is None:
            self.vectorizer = HashedTfIdf(dim=self.dim)

    # ---- single-writer contract -----------------------------------------
    #
    # A KnowledgeBase is NOT a concurrent data structure.  The serving
    # plane (serving/snapshot.py) relies on exactly this contract:
    #
    #   - exactly ONE thread performs mutations (``sync``/``add_text``/
    #     removal) and the subsequent engine ``refresh()``/snapshot
    #     ``publish()``;
    #   - any number of threads may read *published snapshots* — never
    #     the live dicts/arrays here — concurrently with that writer.
    #
    # ``version``/``changes_since`` are safe for the writer thread to
    # interleave with its own mutations (they are how the engine's
    # refresh discovers the delta) but are only meaningful to other
    # threads via the generation a snapshot was pinned at.  The guard
    # below turns a second concurrent writer — a latent torn-index bug —
    # into an immediate, attributable error instead of silent corruption
    # of df counts / change-log ordering.

    @contextlib.contextmanager
    def _single_writer(self, op: str):
        if not self._write_lock.acquire(blocking=False):
            raise RuntimeError(
                f"concurrent KnowledgeBase.{op}: mutations follow a "
                "single-writer contract (one ingest thread; readers go "
                "through serving snapshots — docs/ARCHITECTURE.md §7)"
            )
        try:
            yield
        finally:
            self._write_lock.release()

    # ---- pipeline for a single document --------------------------------

    def _ingest_doc(self, path: str, data: bytes, digest: str, mtime: float,
                    size: int = -1, mtime_ns: int = -1):
        text, kind = extract(data, path)
        if path in self.term_counts:  # changed file: retire old stats
            self.vectorizer.remove_doc(self.term_counts[path])
        tc = TermCounts.from_text(text)
        self.vectorizer.add_doc(tc)
        self.records[path] = DocRecord(path, digest, kind, mtime, size,
                                       mtime_ns)
        self.texts[path] = text
        self.term_counts[path] = tc
        self.signatures[path] = sigmod.signature_of_text(
            text, width_words=self.sig_words
        )
        self._version += 1
        self._changed_at[path] = self._version
        self._removed_at.pop(path, None)
        self._dirty = True

    # Removal-log bound: entries beyond this are dropped oldest-first.
    # Consumers must treat the removed list as advisory (the engine
    # derives actual removals from the doc-id set, see core/engine.py);
    # only removal *stats* can undercount for consumers further than
    # this many deletions behind.
    REMOVED_LOG_MAX = 4096

    def _remove_doc(self, path: str):
        self.vectorizer.remove_doc(self.term_counts.pop(path))
        self.records.pop(path)
        self.texts.pop(path)
        self.signatures.pop(path)
        self._version += 1
        self._changed_at.pop(path, None)
        self._removed_at[path] = self._version
        while len(self._removed_at) > self.REMOVED_LOG_MAX:
            self._removed_at.pop(next(iter(self._removed_at)))
        self._dirty = True

    # ---- dirty-row accounting (consumed by core/engine.py) --------------

    @property
    def version(self) -> int:
        """Monotonic mutation counter (0 = as-constructed/loaded).

        Thread-safety: exact only on the writer thread (the
        single-writer contract above).  Other threads must consume
        versions via a pinned snapshot's ``generation``, never by
        polling this property concurrently with mutations.
        """
        return self._version

    def changes_since(self, version: int) -> tuple[list[str], list[str]]:
        """(changed_ids, removed_ids) strictly after ``version``.

        Writer-thread API (single-writer contract): the engine's
        ``refresh()`` calls this between mutations it itself observed;
        calling it from a second thread mid-mutation can see a torn
        change log.

        ``changed`` covers both new and updated documents; a doc that
        was removed and re-added since ``version`` appears only in
        ``changed``.  Ids are sorted for deterministic consumption.
        ``removed`` is advisory (bounded by ``REMOVED_LOG_MAX``):
        consumers must derive authoritative removals from the current
        ``records`` key set, as core/engine.py does.
        """
        changed = sorted(
            p for p, v in self._changed_at.items() if v > version
        )
        removed = sorted(
            p for p, v in self._removed_at.items() if v > version
        )
        return changed, removed

    # ---- the paper's incremental sync ----------------------------------

    def sync(self, source_dir: str, verify_hashes: bool = False) -> IngestStats:
        """Incremental directory sync (paper §3.3).

        Unchanged files are skipped by an O(stat) quick check
        (size + nanosecond mtime, rsync-style) before falling back to
        the content hash.  On filesystems with coarse mtime granularity
        a same-size in-place edit inside one timestamp tick could evade
        the quick check — pass ``verify_hashes=True`` to force content
        hashing for every scanned file (the paper's original O(N·hash)
        scan).

        Single-writer: concurrent mutation from a second thread raises
        (see ``_single_writer``).
        """
        with self._single_writer("sync"):
            return self._sync_locked(source_dir, verify_hashes)

    def _sync_locked(self, source_dir: str, verify_hashes: bool) -> IngestStats:
        t0 = time.perf_counter()
        stats = IngestStats()
        seen: set[str] = set()
        for root, _, files in os.walk(source_dir):
            for name in sorted(files):
                full = os.path.join(root, name)
                rel = os.path.relpath(full, source_dir)
                seen.add(rel)
                stats.scanned += 1
                rec = self.records.get(rel)
                st = os.stat(full)
                if (not verify_hashes
                        and rec is not None and rec.size >= 0
                        and rec.mtime_ns >= 0
                        and rec.size == st.st_size
                        and rec.mtime_ns == st.st_mtime_ns):
                    stats.skipped += 1  # O(stat) fast path: no read, no hash
                    continue
                with open(full, "rb") as f:
                    data = f.read()
                digest = hashlib.sha256(data).hexdigest()
                if rec is not None and rec.sha256 == digest:
                    stats.skipped += 1  # content unchanged (e.g. touch)
                    rec.mtime = st.st_mtime  # re-arm the stat fast path
                    rec.size = st.st_size
                    rec.mtime_ns = st.st_mtime_ns
                    continue
                self._ingest_doc(rel, data, digest, st.st_mtime, st.st_size,
                                 st.st_mtime_ns)
                if rec is None:
                    stats.added += 1
                else:
                    stats.updated += 1
        for rel in sorted(set(self.records) - seen):
            self._remove_doc(rel)
            stats.removed += 1
        stats.seconds = time.perf_counter() - t0
        return stats

    def add_text(self, doc_id: str, text: str):
        """Direct ingestion of an already-extracted document.

        Single-writer: concurrent mutation from a second thread raises
        (see ``_single_writer``).
        """
        with self._single_writer("add_text"):
            digest = hashlib.sha256(text.encode("utf-8")).hexdigest()
            self._ingest_doc(doc_id, text.encode("utf-8"), digest, 0.0)

    # ---- materialization (cheap, vectorized, deferred) ------------------

    def materialize(self) -> tuple[np.ndarray, np.ndarray, list[str]]:
        """(doc_matrix [n,D] f32, signatures [n,W] i32, doc_ids)."""
        if self._dirty or self._matrix is None:
            ids = sorted(self.records)
            tcs = [self.term_counts[i] for i in ids]
            self._matrix = self.vectorizer.build_matrix(tcs)
            self._sig_matrix = (
                np.stack([self.signatures[i] for i in ids])
                if ids
                else np.zeros((0, self.sig_words), np.int32)
            )
            self._postings = PostingsIndex.build(tcs)
            self._doc_ids = ids
            self._dirty = False
        return self._matrix, self._sig_matrix, list(self._doc_ids)

    def postings(self) -> PostingsIndex:
        """The ⟨I⟩ region: inverted index over term hashes.

        Never returns None: a container loaded with a matrix but no
        postings segments (pre-postings format) skips the materialize
        rebuild, so build the index from term counts here.
        """
        self.materialize()
        if self._postings is None:
            self._postings = PostingsIndex.build(
                [self.term_counts[i] for i in self._doc_ids]
            )
        return self._postings

    @property
    def n_docs(self) -> int:
        return len(self.records)

    # ---- container round-trip ------------------------------------------

    def save(self, path: str, generation: int = 0,
             include_matrix: bool = True) -> str:
        """``include_matrix=False`` drops the materialized ⟨V⟩ dense
        matrix — it is fully derivable from the stored term counts + df,
        so edge deployments can trade first-query latency for a much
        smaller single file (see RQ3)."""
        matrix, sigs, ids = self.materialize()
        tcs = [self.term_counts[i] for i in ids]
        ptr = np.zeros((len(ids) + 1,), np.int64)
        np.cumsum([t.term_hashes.size for t in tcs], out=ptr[1:])
        segments = {
            "signatures": sigs,
            "df": self.vectorizer.df,
            "term_hashes": (
                np.concatenate([t.term_hashes for t in tcs])
                if ids else np.zeros((0,), np.uint64)
            ),
            "term_counts": (
                np.concatenate([t.counts for t in tcs])
                if ids else np.zeros((0,), np.int32)
            ),
            "term_ptr": ptr,
            "n_tokens": np.array([t.n_tokens for t in tcs], np.int64),
            **encode_texts([self.texts[i] for i in ids]),
        }
        if include_matrix:
            segments["doc_matrix"] = matrix
        segments.update(self.postings().segments())
        meta = {
            "vectorizer": self.vectorizer.state(),
            "sig_words": self.sig_words,
            "docs": [
                {
                    "id": i,
                    "sha256": self.records[i].sha256,
                    "modality": self.records[i].modality,
                    "mtime": self.records[i].mtime,
                    # persist the O(stat) quick-check keys (§3.3): without
                    # them the first sync() after a load re-hashes every
                    # file, silently losing the incremental-sync win
                    "size": self.records[i].size,
                    "mtime_ns": self.records[i].mtime_ns,
                }
                for i in ids
            ],
        }
        return write_container(path, segments, meta, generation)

    @staticmethod
    def load(path: str) -> "KnowledgeBase":
        c = Container.open(path)
        segs = c.read_all()
        meta = c.meta
        vec = HashedTfIdf.from_state(meta["vectorizer"], segs["df"])
        kb = KnowledgeBase(dim=vec.dim, sig_words=int(meta["sig_words"]),
                           vectorizer=vec)
        texts = decode_texts(segs["content_blob"], segs["content_offsets"])
        ptr = segs["term_ptr"]
        for j, d in enumerate(meta["docs"]):
            i = d["id"]
            # pre-size containers lack size/mtime_ns → -1 (fast path
            # unarmed; the first sync falls back to content hashing and
            # re-arms it)
            kb.records[i] = DocRecord(i, d["sha256"], d["modality"],
                                      d["mtime"], int(d.get("size", -1)),
                                      int(d.get("mtime_ns", -1)))
            kb.texts[i] = texts[j]
            kb.term_counts[i] = TermCounts(
                segs["term_hashes"][ptr[j]: ptr[j + 1]],
                segs["term_counts"][ptr[j]: ptr[j + 1]],
                int(segs["n_tokens"][j]),
            )
            kb.signatures[i] = segs["signatures"][j]
        if "doc_matrix" in segs:
            kb._matrix = segs["doc_matrix"]
            kb._sig_matrix = segs["signatures"]
            kb._doc_ids = [d["id"] for d in meta["docs"]]
            kb._postings = PostingsIndex.from_segments(segs)
            kb._dirty = False
        # else: matrix rebuilds lazily from term counts at first query
        return kb
