"""Deterministic word tokenizer + normalization (paper §4.1 substrate).

The paper's vectorizer is a classic TF-IDF pipeline: lowercase, split on
non-alphanumeric runs.  We keep that exact semantic so the HSF scores are
reproducible and the substring-boost normalization (``lowercase(Q) ⊆
lowercase(D)``) shares the same canonical form.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

from repro.core import hashing

_TOKEN_RE = re.compile(r"[a-z0-9_]+")


def normalize(text: str) -> str:
    """Paper's canonical form: casefolded text (used for both the
    vectorizer and the substring indicator)."""
    return text.lower()


def tokenize(text: str) -> list[str]:
    """Lowercase word tokens (alnum + underscore runs)."""
    return _TOKEN_RE.findall(normalize(text))


@dataclass(frozen=True)
class TermCounts:
    """Per-document term statistics: unique hashed terms and raw counts.

    This is the ⟨V⟩-region precursor stored in the knowledge container —
    keeping *counts* (not weights) is what makes incremental IDF refresh
    possible without re-tokenizing unchanged documents (paper §3.3).
    """

    term_hashes: np.ndarray  # uint64 [T_unique]
    counts: np.ndarray  # int32  [T_unique]
    n_tokens: int

    @staticmethod
    def from_text(text: str) -> "TermCounts":
        tokens = tokenize(text)
        if not tokens:
            return TermCounts(
                np.zeros((0,), np.uint64), np.zeros((0,), np.int32), 0
            )
        hashes = hashing.hash_tokens(tokens)
        uniq, counts = np.unique(hashes, return_counts=True)
        return TermCounts(uniq, counts.astype(np.int32), len(tokens))
