"""Retrieval engine: edge-parity single-device path + mesh-sharded path.

Sharding design (docs/ARCHITECTURE.md §4): documents are
range-partitioned along the *flattened* mesh (every axis participates —
retrieval has no tensor dimension worth model-parallelism, so all
256/512 devices hold disjoint doc shards).  Per query:

    local HSF scores  →  local top-k  →  all_gather((k vals, k ids))
                      →  global top-k merge (replicated)

The collective payload is O(k · n_shards) scalars — independent of corpus
size — which is what makes retrieval collective-trivial at pod scale.

Determinism: HSF is pure arithmetic, so the sharded result equals the
single-device result exactly (tested in tests/test_sharded.py).
Ties are broken by document index (lower wins) to keep that equality
bit-stable.

The single-process ``Retriever`` here is a thin compatibility wrapper
over the batched ``QueryEngine`` (core/engine.py) — the serving-time
entry point with incremental materialization and a query cache.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import hsf
from repro.core.engine import QueryEngine, RetrievalResult  # noqa: F401 — re-export
from repro.core.ingest import KnowledgeBase

shard_map = jax.shard_map


# --------------------------------------------------------------------------
# tie-stable scoring helper
# --------------------------------------------------------------------------

def _stable_top_k(scores: jnp.ndarray, ids: jnp.ndarray, k: int):
    """Top-k by (score desc, id asc): deterministic under score ties.

    Exact lexicographic sort (no epsilon arithmetic, no float64): the
    merge set is only k·n_shards wide, so a full sort is cheap.
    """
    order = jnp.lexsort((ids, -scores), axis=-1)[..., :k]
    return jnp.take_along_axis(scores, order, axis=-1), jnp.take_along_axis(
        ids, order, axis=-1
    )


# --------------------------------------------------------------------------
# edge-parity retriever (the paper's laptop deployment)
# --------------------------------------------------------------------------

class Retriever:
    """Single-process retriever over a KnowledgeBase (paper's deployment).

    Thin single-query wrapper over the batched ``QueryEngine`` — kept
    for API compatibility; multi-query serving should call
    ``QueryEngine.query_batch`` directly.  Unlike the pre-engine
    implementation, queries see KB mutations automatically (the engine
    refreshes incrementally from the KB's dirty log).

    ``prefilter=True`` uses the ⟨I⟩-region postings to restrict HSF
    scoring to documents sharing at least one query term — sub-linear
    for selective queries.  Recall caveat (documented): char-level
    substring matches inside *longer tokens* have no shared term and
    are only found by the full scan, so prefiltering is an opt-in
    accelerator (exact for whole-token queries, e.g. entity codes).
    This is a *different* caveat from ``QueryEngine(index="ivf")``'s:
    the IVF probe plane ranks clusters by cosine **and** a
    signature-union containment test, so substring-only matches are
    still probeable (and ``guarantee="exact"`` recovers them
    provably); the postings prefilter simply cannot see them.  The
    candidate subset is scored through the index plane's shared
    gather helper (``index.ivf.score_candidate_rows`` →
    ``score_batch_arrays``), so subset scores are bit-identical to the
    corresponding rows of the full scan and ties break by global doc
    index, same as every other path.
    """

    def __init__(
        self,
        kb: KnowledgeBase,
        alpha: float = hsf.DEFAULT_ALPHA,
        beta: float = hsf.DEFAULT_BETA,
        use_kernel: bool = False,
        prefilter: bool = False,
        engine: QueryEngine | None = None,
        scoring_path: str = "auto",
    ):
        from repro.core.engine import resolve_scoring_path

        self.kb = kb
        self.alpha = alpha
        self.beta = beta
        # same backend-aware resolution as the engine, so a default
        # Retriever and a default QueryEngine always agree on the path
        path = resolve_scoring_path(scoring_path, use_kernel=use_kernel)
        self.use_kernel = path == "kernel"
        self.prefilter = prefilter
        if engine is not None and (
            engine.kb is not kb
            or engine.alpha != alpha
            or engine.beta != beta
            or engine.scoring_path != path
        ):
            raise ValueError(
                "shared engine disagrees with Retriever parameters "
                f"(engine: same_kb={engine.kb is kb} alpha={engine.alpha} "
                f"beta={engine.beta} scoring_path={engine.scoring_path} "
                f"vs {path})"
            )
        self.engine = engine or QueryEngine(
            kb, alpha=alpha, beta=beta, scoring_path=path
        )

    # materialized state lives in the engine; expose it for compat
    @property
    def doc_vecs(self):
        return self.engine.doc_vecs

    @property
    def doc_sigs(self):
        return self.engine.doc_sigs

    @property
    def doc_ids(self):
        return self.engine.doc_ids

    def query(self, text: str, k: int = 5) -> list[RetrievalResult]:
        if not self.prefilter:
            return self.engine.query(text, k)
        return self._query_prefiltered(text, k)

    def _query_prefiltered(self, text: str, k: int) -> list[RetrievalResult]:
        from repro.core.engine import (
            pack_query_arrays,
            results_from_topk,
            score_batch_arrays,
        )
        from repro.index.ivf import score_candidate_rows

        if k <= 0:
            raise ValueError(f"k must be a positive integer, got {k}")
        self.engine.refresh()
        if not self.doc_ids:
            return []
        qv, qs = self.engine._query_arrays(text)
        qvp, qsp = pack_query_arrays([(qv, qs)], self.kb.dim,
                                     self.kb.sig_words)
        cand = self.kb.postings().candidates(
            text, mode="union",
            max_candidates=max(256, len(self.doc_ids) // 4),
        )
        if cand is not None and len(cand) == 0:
            return []
        n = len(self.doc_ids)
        if cand is None:  # unselective query: full scan is cheaper
            vals, idx, cos, ind = score_batch_arrays(
                self.doc_vecs, self.doc_sigs, qvp, qsp,
                scoring_path=self.engine.scoring_path, k=min(k, n),
                alpha=self.alpha, beta=self.beta, n_docs=n,
            )
        else:
            vals, idx, cos, ind = score_candidate_rows(
                self.doc_vecs, self.doc_sigs,
                np.sort(np.asarray(cand, np.int32)), qvp, qsp,
                scoring_path=self.engine.scoring_path,
                k=min(k, len(cand)), alpha=self.alpha, beta=self.beta,
            )
        return results_from_topk(self.doc_ids, 1, vals, idx, cos, ind)[0]


# --------------------------------------------------------------------------
# mesh-sharded retrieval
# --------------------------------------------------------------------------

def pad_corpus(
    doc_vecs: np.ndarray, doc_sigs: np.ndarray, n_shards: int
) -> tuple[np.ndarray, np.ndarray, int]:
    """Pad doc count to a multiple of n_shards (padding is masked out at
    query time via the global-index < n_docs test)."""
    n = doc_vecs.shape[0]
    padded = math.ceil(max(n, 1) / n_shards) * n_shards
    if padded != n:
        doc_vecs = np.concatenate(
            [doc_vecs, np.zeros((padded - n, doc_vecs.shape[1]), doc_vecs.dtype)]
        )
        doc_sigs = np.concatenate(
            [doc_sigs, np.zeros((padded - n, doc_sigs.shape[1]), doc_sigs.dtype)]
        )
    return doc_vecs, doc_sigs, n


def build_sharded_retrieve(
    mesh: jax.sharding.Mesh,
    doc_axes: tuple[str, ...],
    n_docs: int,
    k: int,
    alpha: float = hsf.DEFAULT_ALPHA,
    beta: float = hsf.DEFAULT_BETA,
    use_kernel: bool = False,
):
    """Returns retrieve(doc_vecs, doc_sigs, q_vecs, q_sigs) -> (vals, ids).

    - doc_vecs [N, D], doc_sigs [N, W]: sharded over ``doc_axes`` on dim 0
      (N must be divisible by prod(mesh.shape[a] for a in doc_axes)).
    - q_vecs [B, D], q_sigs [B, W]: replicated.
    - returns (vals [B, k], ids [B, k]): replicated, globally merged.

    ``use_kernel=True`` scores each shard with the fused batched Pallas
    kernel (kernels/hsf_score) instead of the jnp batched GEMM — same
    ranking and tie order whenever k ≤ n_docs (the always-true serving
    case); only the unreachable -inf filler rows can differ, because the
    kernel tags them with sentinel ids rather than padding-doc ids.
    """
    axis_sizes = [mesh.shape[a] for a in doc_axes]
    n_shards = int(np.prod(axis_sizes))

    def local_fn(dv, ds, qv, qs):
        # global shard index along the flattened doc axes
        shard = jnp.int32(0)
        for a in doc_axes:
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        per_shard = dv.shape[0]
        base = shard * per_shard
        gids = base + jnp.arange(per_shard, dtype=jnp.int32)

        kk = min(k, per_shard)
        if use_kernel:
            # fused batched kernel scores the whole query batch against
            # this shard and reduces to top-k in VMEM — no per-query
            # dispatch, no [B, per_shard] HBM intermediate.  The shard's
            # padding suffix is masked inside the kernel via the traced
            # n_valid scalar (rows that cannot fill carry -inf with
            # sentinel ids, which lose every merge below).
            from repro.kernels.hsf_score import ops as _ops

            n_valid = jnp.clip(jnp.int32(n_docs) - base, 0, per_shard)
            v, li = _ops.hsf_score_batched(
                dv, ds, qv, qs, k=kk, alpha=alpha, beta=beta,
                n_valid=n_valid,
            )
            gi = jnp.where(li < per_shard, base + li, jnp.int32(2**31 - 1))
        else:
            scores = hsf.hsf_scores_batched(dv, ds, qv, qs, alpha, beta)
            scores = jnp.where(gids[None, :] < n_docs, scores, -jnp.inf)
            v, i = jax.lax.top_k(scores, kk)  # [B, kk]
            gi = jnp.take(gids, i)

        v_all = jax.lax.all_gather(v, doc_axes, axis=1, tiled=True)
        gi_all = jax.lax.all_gather(gi, doc_axes, axis=1, tiled=True)
        return _stable_top_k(v_all, gi_all, k)

    spec_docs = P(doc_axes, None)
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(spec_docs, spec_docs, P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )


def single_device_reference(doc_vecs, doc_sigs, q_vecs, q_sigs, n_docs, k,
                            alpha=hsf.DEFAULT_ALPHA, beta=hsf.DEFAULT_BETA):
    """Unsharded oracle for the sharded path (same masking + tie rule)."""
    scores = hsf.hsf_scores_batched(
        jnp.asarray(doc_vecs), jnp.asarray(doc_sigs),
        jnp.asarray(q_vecs), jnp.asarray(q_sigs), alpha, beta,
    )
    gids = jnp.arange(doc_vecs.shape[0], dtype=jnp.int32)
    scores = jnp.where(gids[None, :] < n_docs, scores, -jnp.inf)
    return _stable_top_k(scores, jnp.broadcast_to(gids, scores.shape), k)
