"""The Single-File Knowledge Container K = ⟨M, C, V, I⟩ (paper §3.1).

One ``.ragdb`` file is a self-describing, content-hashed binary container:

    bytes 0..7    magic  b"RAGDB1\\0\\n"
    bytes 8..15   header length (uint64 LE)
    header JSON   {"generation": g, "meta": {...},          ← M region
                   "segments": {name: {offset, length, sha256,
                                        dtype, shape}}}
    data          raw segment bytes (C, V, I regions as named segments)

Design goals carried over from the paper:
- **Referential integrity**: every segment's SHA-256 is in the header;
  ``load(verify=True)`` refuses corrupted containers.
- **ACID-by-rename**: writes go to a temp file in the same directory and
  are published with ``os.replace`` (atomic on POSIX).  Readers never see
  a torn container.
- **Right to be forgotten**: deleting the file deletes all regions.

Scale-out (docs/ARCHITECTURE.md §1): a *sharded* container is a directory with a
``manifest.json`` naming content-addressed shard files.  The manifest is
itself atomically replaced, and carries a monotonically increasing
``generation`` — the WAL-mode analogue: readers pin a generation; the
ingester publishes the next one without disturbing them.  A 1-shard
container degenerates to exactly one data file, matching the paper.

This same format backs the training checkpointer (checkpoint/).
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from dataclasses import dataclass

import numpy as np

MAGIC = b"RAGDB1\x00\n"


def _sha256(data: bytes | memoryview) -> str:
    return hashlib.sha256(data).hexdigest()


# --------------------------------------------------------------------------
# text <-> array codecs (the C region is "blob + offsets")
# --------------------------------------------------------------------------

def encode_texts(texts: list[str]) -> dict[str, np.ndarray]:
    blobs = [t.encode("utf-8") for t in texts]
    offsets = np.zeros((len(blobs) + 1,), dtype=np.int64)
    np.cumsum([len(b) for b in blobs], out=offsets[1:])
    blob = np.frombuffer(b"".join(blobs), dtype=np.uint8).copy()
    return {"content_blob": blob, "content_offsets": offsets}


def decode_texts(blob: np.ndarray, offsets: np.ndarray) -> list[str]:
    raw = blob.tobytes()
    return [
        raw[offsets[i]: offsets[i + 1]].decode("utf-8")
        for i in range(len(offsets) - 1)
    ]


# --------------------------------------------------------------------------
# single-file container
# --------------------------------------------------------------------------

def write_container(
    path: str,
    segments: dict[str, np.ndarray],
    meta: dict | None = None,
    generation: int = 0,
) -> str:
    """Atomically write a container; returns the sha256 of the data area."""
    names = sorted(segments)
    header_segs: dict[str, dict] = {}
    offset = 0
    payloads: list[bytes] = []
    whole = hashlib.sha256()
    for name in names:
        arr = np.asarray(segments[name])
        shape = list(arr.shape)  # before ascontiguousarray (it promotes 0-d)
        arr = np.ascontiguousarray(arr)
        data = arr.tobytes()
        header_segs[name] = {
            "offset": offset,
            "length": len(data),
            "sha256": _sha256(data),
            "dtype": arr.dtype.str,
            "shape": shape,
        }
        offset += len(data)
        payloads.append(data)
        whole.update(data)
    header = json.dumps(
        {"generation": generation, "meta": meta or {}, "segments": header_segs},
        sort_keys=True,
    ).encode("utf-8")

    dirname = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(dirname, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=dirname, prefix=".ragdb-tmp-")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(MAGIC)
            f.write(len(header).to_bytes(8, "little"))
            f.write(header)
            for data in payloads:
                f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic publish
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return whole.hexdigest()


@dataclass
class Container:
    path: str
    generation: int
    meta: dict
    _segments: dict[str, dict]
    _data_start: int

    @staticmethod
    def open(path: str) -> "Container":
        with open(path, "rb") as f:
            magic = f.read(8)
            if magic != MAGIC:
                raise ValueError(f"{path}: not a RAGdb container (bad magic)")
            hlen = int.from_bytes(f.read(8), "little")
            header = json.loads(f.read(hlen).decode("utf-8"))
            data_start = 16 + hlen
        return Container(
            path=path,
            generation=int(header["generation"]),
            meta=header["meta"],
            _segments=header["segments"],
            _data_start=data_start,
        )

    def segment_names(self) -> list[str]:
        return sorted(self._segments)

    def read(self, name: str, verify: bool = True) -> np.ndarray:
        info = self._segments[name]
        with open(self.path, "rb") as f:
            f.seek(self._data_start + info["offset"])
            data = f.read(info["length"])
        if verify and _sha256(data) != info["sha256"]:
            raise IOError(
                f"{self.path}:{name}: segment sha256 mismatch (corruption)"
            )
        return np.frombuffer(data, dtype=np.dtype(info["dtype"])).reshape(
            info["shape"]
        ).copy()

    def read_all(self, verify: bool = True) -> dict[str, np.ndarray]:
        return {n: self.read(n, verify) for n in self._segments}


# --------------------------------------------------------------------------
# sharded container (directory + manifest)
# --------------------------------------------------------------------------

MANIFEST = "manifest.json"


def publish_sharded(
    root: str,
    shard_segments: list[dict[str, np.ndarray]],
    shard_metas: list[dict] | None = None,
    meta: dict | None = None,
) -> int:
    """Write shard files + atomically publish the next-generation manifest.

    Shard files are content-addressed (name includes the data hash) so an
    elastic re-shard or replica copy is a pure manifest edit.  Returns the
    published generation.
    """
    os.makedirs(root, exist_ok=True)
    prev_gen = -1
    mpath = os.path.join(root, MANIFEST)
    if os.path.exists(mpath):
        with open(mpath) as f:
            prev_gen = int(json.load(f)["generation"])
    gen = prev_gen + 1
    shard_metas = shard_metas or [{} for _ in shard_segments]

    shard_entries = []
    for i, segs in enumerate(shard_segments):
        tmp_name = os.path.join(root, f".shard-{gen}-{i}.ragdb")
        digest = write_container(tmp_name, segs, shard_metas[i], generation=gen)
        final = f"shard-{digest[:16]}.ragdb"
        os.replace(tmp_name, os.path.join(root, final))
        shard_entries.append({"file": final, "sha256": digest, "index": i})

    manifest = {
        "generation": gen,
        "meta": meta or {},
        "shards": shard_entries,
    }
    fd, tmp = tempfile.mkstemp(dir=root, prefix=".manifest-tmp-")
    with os.fdopen(fd, "w") as f:
        json.dump(manifest, f, sort_keys=True, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, mpath)
    return gen


@dataclass
class ShardedContainer:
    root: str
    generation: int
    meta: dict
    shards: list[dict]

    @staticmethod
    def open(root: str) -> "ShardedContainer":
        """Pin the current generation (readers are isolated from later
        publishes — the paper's WAL concurrent-reader analogue)."""
        with open(os.path.join(root, MANIFEST)) as f:
            m = json.load(f)
        return ShardedContainer(
            root=root,
            generation=int(m["generation"]),
            meta=m["meta"],
            shards=m["shards"],
        )

    def open_shard(self, i: int) -> Container:
        return Container.open(os.path.join(self.root, self.shards[i]["file"]))

    @property
    def n_shards(self) -> int:
        return len(self.shards)
