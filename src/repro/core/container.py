"""The Single-File Knowledge Container K = ⟨M, C, V, I⟩ (paper §3.1).

One ``.ragdb`` file is a self-describing, content-hashed binary container:

    bytes 0..7    magic  b"RAGDB1\\0\\n"
    bytes 8..15   header length (uint64 LE)
    header JSON   {"generation": g, "meta": {...},          ← M region
                   "data_sha256": <digest of the data area>,
                   "segments": {name: {offset, length, sha256,
                                        dtype, shape}}}
    data          raw segment bytes (C, V, I regions as named segments)

Design goals carried over from the paper:
- **Referential integrity**: every segment's SHA-256 is in the header;
  ``load(verify=True)`` refuses corrupted containers.  A short read
  (truncated file) is reported as corruption too, in *both* verify
  modes — never as an opaque reshape error or silent wrong data.
- **ACID-by-rename**: writes go to a temp file in the same directory and
  are published with ``os.replace`` (atomic on POSIX).  Readers never see
  a torn container.
- **Right to be forgotten**: deleting the file deletes all regions.

Durable incremental persistence (docs/ARCHITECTURE.md §8): a base
container can carry an append-only **delta journal** next to it
(``kb.ragdb`` → ``kb.ragdbj``).  Each journal record is a framed,
self-verifying container image (magic + uint64 length + raw SHA-256 +
payload); a tiny fsync-then-rename **journal manifest**
(``kb.ragdbj.manifest``) is the commit point: bytes beyond its
``committed_bytes`` are a torn append and are truncated on the next
append / ignored on replay, and a per-record digest check degrades an
externally truncated or bit-flipped tail to the longest intact prefix.
The manifest also pins ``base_uid`` — the ``data_sha256`` of the base
image the journal extends — so a stale journal left beside a re-saved
base is discarded, never mis-applied.  This is what carries the paper's
O(U) incremental-ingest contract (§3.3) through to disk: a 1-doc update
appends O(doc) bytes instead of rewriting the O(N) container
(core/ingest.py ``KnowledgeBase.save_delta`` / ``compact``).

Scale-out (docs/ARCHITECTURE.md §1): a *sharded* container is a directory with a
``manifest.json`` naming content-addressed shard files.  The manifest is
itself atomically replaced, and carries a monotonically increasing
``generation`` — the WAL-mode analogue: readers pin a generation; the
ingester publishes the next one without disturbing them.  A 1-shard
container degenerates to exactly one data file, matching the paper.
``publish_sharded_delta`` appends per-shard journal records instead of
rewriting shard files (each manifest entry records the exact journal
byte window its generation sees), and every publish garbage-collects
shard/journal files no manifest within the ``gc_grace`` generation
window references — repeated publishes no longer grow the directory
without bound.

This same format backs the training checkpointer (checkpoint/).
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
import tempfile
from dataclasses import dataclass

import numpy as np

from repro.obs import trace as obs_trace
from repro.obs.metrics import global_registry

MAGIC = b"RAGDB1\x00\n"


def _sha256(data: bytes | memoryview) -> str:
    return hashlib.sha256(data).hexdigest()


# --------------------------------------------------------------------------
# text <-> array codecs (the C region is "blob + offsets")
# --------------------------------------------------------------------------

def encode_texts(texts: list[str]) -> dict[str, np.ndarray]:
    blobs = [t.encode("utf-8") for t in texts]
    offsets = np.zeros((len(blobs) + 1,), dtype=np.int64)
    np.cumsum([len(b) for b in blobs], out=offsets[1:])
    blob = np.frombuffer(b"".join(blobs), dtype=np.uint8).copy()
    return {"content_blob": blob, "content_offsets": offsets}


def decode_texts(blob: np.ndarray, offsets: np.ndarray) -> list[str]:
    raw = blob.tobytes()
    return [
        raw[offsets[i]: offsets[i + 1]].decode("utf-8")
        for i in range(len(offsets) - 1)
    ]


# --------------------------------------------------------------------------
# single-file container
# --------------------------------------------------------------------------

def _container_bytes(
    segments: dict[str, np.ndarray],
    meta: dict | None,
    generation: int,
) -> tuple[list[bytes], str]:
    """Serialize a container image: ([magic, hlen, header, *payloads],
    data_sha256).  Shared by file writes and journal records."""
    names = sorted(segments)
    header_segs: dict[str, dict] = {}
    offset = 0
    payloads: list[bytes] = []
    whole = hashlib.sha256()
    for name in names:
        arr = np.asarray(segments[name])
        shape = list(arr.shape)  # before ascontiguousarray (it promotes 0-d)
        arr = np.ascontiguousarray(arr)
        data = arr.tobytes()
        header_segs[name] = {
            "offset": offset,
            "length": len(data),
            "sha256": _sha256(data),
            "dtype": arr.dtype.str,
            "shape": shape,
        }
        offset += len(data)
        payloads.append(data)
        whole.update(data)
    digest = whole.hexdigest()
    header = json.dumps(
        {
            "generation": generation,
            "meta": meta or {},
            "data_sha256": digest,
            "segments": header_segs,
        },
        sort_keys=True,
    ).encode("utf-8")
    parts = [MAGIC, len(header).to_bytes(8, "little"), header, *payloads]
    return parts, digest


def parse_container_bytes(buf: bytes) -> tuple[int, dict, dict[str, np.ndarray]]:
    """Parse an in-memory container image → (generation, meta, segments).

    Used for journal-record replay; the caller has already verified the
    record's whole-payload SHA-256, so per-segment digests are not
    re-checked here.
    """
    if buf[:8] != MAGIC:
        raise ValueError("journal record: bad container-image magic")
    hlen = int.from_bytes(buf[8:16], "little")
    header = json.loads(buf[16: 16 + hlen].decode("utf-8"))
    data_start = 16 + hlen
    segs: dict[str, np.ndarray] = {}
    for name, info in header["segments"].items():
        start = data_start + info["offset"]
        data = buf[start: start + info["length"]]
        if len(data) != info["length"]:
            raise IOError(f"journal record:{name}: truncated segment")
        segs[name] = np.frombuffer(
            data, dtype=np.dtype(info["dtype"])
        ).reshape(info["shape"]).copy()
    return int(header["generation"]), header["meta"], segs


def _fsync_dir(path: str) -> None:
    """Flush directory entries to disk.  A rename-based commit is not
    power-loss durable until the directory itself is fsync'd — without
    this, a published file (or manifest rename) can vanish on power
    failure even though every data fsync succeeded."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return  # platform/filesystem without directory fsync
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _atomic_write_json(path: str, obj: dict, prefix: str,
                       indent: int | None = None) -> None:
    """fsync-then-atomic-rename JSON publish (+ directory fsync).
    Cleans up the temp file if the write fails mid-way."""
    dirname = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=dirname, prefix=prefix)
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f, sort_keys=True, indent=indent)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        with contextlib.suppress(FileNotFoundError):
            os.unlink(tmp)
        raise
    _fsync_dir(dirname)


def write_container(
    path: str,
    segments: dict[str, np.ndarray],
    meta: dict | None = None,
    generation: int = 0,
) -> str:
    """Atomically write a container; returns the sha256 of the data area
    (also embedded in the header as ``data_sha256`` — the container's
    identity for journal chaining)."""
    parts, digest = _container_bytes(segments, meta, generation)
    dirname = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(dirname, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=dirname, prefix=".ragdb-tmp-")
    try:
        with os.fdopen(fd, "wb") as f:
            for data in parts:
                f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic publish
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    _fsync_dir(dirname)
    return digest


@dataclass
class Container:
    path: str
    generation: int
    meta: dict
    _segments: dict[str, dict]
    _data_start: int
    uid: str | None = None  # header data_sha256 (None: pre-uid container)

    @staticmethod
    def open(path: str) -> "Container":
        with open(path, "rb") as f:
            magic = f.read(8)
            if magic != MAGIC:
                raise ValueError(f"{path}: not a RAGdb container (bad magic)")
            hlen = int.from_bytes(f.read(8), "little")
            header = json.loads(f.read(hlen).decode("utf-8"))
            data_start = 16 + hlen
        return Container(
            path=path,
            generation=int(header["generation"]),
            meta=header["meta"],
            _segments=header["segments"],
            _data_start=data_start,
            uid=header.get("data_sha256"),
        )

    def segment_names(self) -> list[str]:
        return sorted(self._segments)

    def read(self, name: str, verify: bool = True) -> np.ndarray:
        info = self._segments[name]
        with open(self.path, "rb") as f:
            f.seek(self._data_start + info["offset"])
            data = f.read(info["length"])
        if len(data) != info["length"]:
            # checked in BOTH verify modes: a short read used to surface
            # as an opaque frombuffer/reshape error (or, with a ragged
            # trailing segment, as silently wrong data under verify=False)
            raise IOError(
                f"{self.path}:{name}: truncated segment (expected "
                f"{info['length']} bytes, got {len(data)}) — file corrupt"
            )
        if verify and _sha256(data) != info["sha256"]:
            raise IOError(
                f"{self.path}:{name}: segment sha256 mismatch (corruption)"
            )
        return np.frombuffer(data, dtype=np.dtype(info["dtype"])).reshape(
            info["shape"]
        ).copy()

    def read_all(self, verify: bool = True) -> dict[str, np.ndarray]:
        return {n: self.read(n, verify) for n in self._segments}


# --------------------------------------------------------------------------
# delta journal (append-only .ragdbj next to a base container)
# --------------------------------------------------------------------------

JOURNAL_SUFFIX = ".ragdbj"
RECORD_MAGIC = b"RDJR"
_FRAME_HEAD = len(RECORD_MAGIC) + 8 + 32  # magic + uint64 length + sha256


def journal_path(base_path: str) -> str:
    """``kb.ragdb`` → ``kb.ragdbj`` (next to the base container)."""
    if base_path.endswith(".ragdb"):
        return base_path[: -len(".ragdb")] + JOURNAL_SUFFIX
    return base_path + JOURNAL_SUFFIX


def journal_manifest_path(base_path: str) -> str:
    return journal_path(base_path) + ".manifest"


def read_journal_manifest(base_path: str) -> dict | None:
    mp = journal_manifest_path(base_path)
    if not os.path.exists(mp):
        return None
    with open(mp) as f:
        return json.load(f)


def _publish_journal_manifest(base_path: str, man: dict) -> None:
    """fsync-then-atomic-rename — the journal's commit point.  The
    directory fsync inside also makes a freshly created journal file's
    directory entry durable (same directory)."""
    _atomic_write_json(journal_manifest_path(base_path), man,
                       prefix=".ragdbj-man-")


def append_journal_record(
    base_path: str,
    segments: dict[str, np.ndarray],
    meta: dict | None,
    generation: int,
    base_uid: str,
) -> dict:
    """Append one framed delta record and commit it via the manifest.

    Protocol (crash-safe at every step):
      1. truncate the journal to the last *committed* byte count — this
         drops the torn tail of a previously crashed append;
      2. append ``RECORD_MAGIC + len(payload) + sha256(payload) +
         payload`` (payload = a full container image) and fsync;
      3. publish the new manifest (fsync + atomic rename).  Only now is
         the record visible to replay.

    A crash before (3) leaves the manifest at the previous commit; the
    appended bytes are invisible garbage that step (1) of the next
    append reclaims.  Returns the new manifest dict plus
    ``appended_at`` — the byte offset the record starts at (used by
    sharded manifests to pin per-generation journal windows).
    """
    man = read_journal_manifest(base_path)
    committed, records = 0, 0
    if man is not None and man.get("base_uid") == base_uid:
        committed = int(man["committed_bytes"])
        records = int(man["records"])
    parts, _ = _container_bytes(segments, meta, generation)
    payload = b"".join(parts)
    frame = (
        RECORD_MAGIC
        + len(payload).to_bytes(8, "little")
        + hashlib.sha256(payload).digest()
        + payload
    )
    with obs_trace.span("journal_append", bytes=len(frame),
                        generation=generation):
        fd = os.open(journal_path(base_path), os.O_RDWR | os.O_CREAT, 0o644)
        with os.fdopen(fd, "r+b") as f:
            f.truncate(committed)
            f.seek(committed)
            f.write(frame)
            f.flush()
            os.fsync(f.fileno())
    man = {
        "base_uid": base_uid,
        "committed_bytes": committed + len(frame),
        "records": records + 1,
        "generation": generation,
    }
    with obs_trace.span("journal_commit", generation=generation):
        _publish_journal_manifest(base_path, man)
    reg = global_registry()
    reg.counter("ragdb_journal_bytes_total",
                "delta-record bytes appended (frame incl. header)").inc(
        len(frame))
    reg.counter("ragdb_journal_records_total",
                "delta records appended").inc()
    return {**man, "appended_at": committed}


def read_journal(
    base_path: str,
    base_uid: str | None,
    start: int = 0,
    max_bytes: int | None = None,
) -> list[tuple[int, dict, dict[str, np.ndarray]]]:
    """Replay committed journal records → [(generation, meta, segments)].

    Reads at most ``manifest.committed_bytes`` (a torn append past the
    commit point is invisible) and stops at the first frame that fails
    its magic/length/sha256 check (an externally truncated or corrupted
    tail degrades to the longest intact prefix).  ``base_uid`` mismatch
    means the journal extends a different base image — it is ignored
    wholesale.  ``start``/``max_bytes`` select the byte window a sharded
    manifest entry pinned (``start`` must be a frame boundary recorded
    at publish time).
    """
    man = read_journal_manifest(base_path)
    jp = journal_path(base_path)
    if man is None or not os.path.exists(jp):
        return []
    if base_uid is not None and man.get("base_uid") != base_uid:
        return []
    limit = int(man["committed_bytes"])
    if max_bytes is not None:
        limit = min(limit, max_bytes)
    with open(jp, "rb") as f:
        # ``start`` is a frame boundary recorded at publish time: skip
        # the prefix instead of reading bytes the window ignores
        f.seek(start)
        data = f.read(max(limit - start, 0))
    out: list[tuple[int, dict, dict[str, np.ndarray]]] = []
    off = 0
    n = len(data)
    while off + _FRAME_HEAD <= n:
        if data[off: off + 4] != RECORD_MAGIC:
            break
        plen = int.from_bytes(data[off + 4: off + 12], "little")
        p0 = off + _FRAME_HEAD
        p1 = p0 + plen
        if p1 > n:
            break  # torn tail
        payload = data[p0:p1]
        if hashlib.sha256(payload).digest() != data[off + 12: off + 44]:
            break  # corrupted record: stop at the last intact one
        out.append(parse_container_bytes(payload))
        off = p1
    return out


def reset_journal(base_path: str) -> None:
    """Drop the journal chain (after a full save folded it into the base)."""
    for p in (journal_path(base_path), journal_manifest_path(base_path)):
        with contextlib.suppress(FileNotFoundError):
            os.unlink(p)


def journal_size(base_path: str) -> int:
    """On-disk journal bytes (journal + manifest), 0 if absent."""
    total = 0
    for p in (journal_path(base_path), journal_manifest_path(base_path)):
        with contextlib.suppress(FileNotFoundError):
            total += os.path.getsize(p)
    return total


# --------------------------------------------------------------------------
# sharded container (directory + manifest)
# --------------------------------------------------------------------------

MANIFEST = "manifest.json"


def _entry_files(entry: dict) -> list[str]:
    """All directory file names a manifest shard entry depends on."""
    files = [entry["file"]]
    if entry.get("journal"):
        jp = journal_path(entry["file"])
        files += [jp, jp + ".manifest"]
    return files


def _load_manifest(root: str) -> dict | None:
    mpath = os.path.join(root, MANIFEST)
    if not os.path.exists(mpath):
        return None
    with open(mpath) as f:
        return json.load(f)


def _publish_manifest(
    root: str, gen: int, shard_entries: list[dict], meta: dict | None,
    prev: dict | None, gc_grace: int,
) -> dict:
    """Atomically publish the next-generation manifest.  ``history``
    carries the file sets of the last ``gc_grace`` generations so GC can
    spare files a recently pinned reader may still hold."""
    history = []
    if prev is not None and gc_grace > 0:
        history = list(prev.get("history", []))
        history.append({
            "generation": int(prev["generation"]),
            "files": sorted({
                f for e in prev["shards"] for f in _entry_files(e)
            }),
        })
        history = history[-gc_grace:]
    manifest = {
        "generation": gen,
        "meta": meta or {},
        "shards": shard_entries,
        "history": history,
    }
    _atomic_write_json(os.path.join(root, MANIFEST), manifest,
                       prefix=".manifest-tmp-", indent=1)
    return manifest


def _gc_shard_files(root: str, manifest: dict) -> list[str]:
    """Delete shard/journal files no retained manifest references.

    Retained = the freshly published manifest + its ``history`` window
    (the last ``gc_grace`` generations, for readers pinned on a prior
    generation).  Only ``shard-*`` data/journal files are touched; temp
    files (``.shard-*``, ``.manifest-tmp-*``) belong to in-flight
    writers.  Returns the deleted names (for tests/benchmarks).
    """
    keep: set[str] = set()
    for e in manifest["shards"]:
        keep.update(_entry_files(e))
    for h in manifest.get("history", []):
        keep.update(h["files"])
    deleted = []
    for f in sorted(os.listdir(root)):
        if not f.startswith("shard-"):
            continue
        if not (f.endswith(".ragdb") or f.endswith(JOURNAL_SUFFIX)
                or f.endswith(".manifest")):
            continue
        if f not in keep:
            with contextlib.suppress(FileNotFoundError):
                os.unlink(os.path.join(root, f))
            deleted.append(f)
    return deleted


def publish_sharded(
    root: str,
    shard_segments: list[dict[str, np.ndarray]],
    shard_metas: list[dict] | None = None,
    meta: dict | None = None,
    gc: bool = True,
    gc_grace: int = 1,
) -> int:
    """Write shard files + atomically publish the next-generation manifest.

    Shard files are content-addressed (name includes the data hash) so an
    elastic re-shard or replica copy is a pure manifest edit.  Files from
    superseded generations are garbage-collected after the publish:
    anything unreferenced by the new manifest or by the last ``gc_grace``
    generations (the grace window for readers pinned on a prior
    generation; ``gc=False`` disables collection).  Returns the
    published generation.
    """
    os.makedirs(root, exist_ok=True)
    prev = _load_manifest(root)
    gen = (int(prev["generation"]) if prev else -1) + 1
    shard_metas = shard_metas or [{} for _ in shard_segments]

    shard_entries = []
    for i, segs in enumerate(shard_segments):
        tmp_name = os.path.join(root, f".shard-{gen}-{i}.ragdb")
        digest = write_container(tmp_name, segs, shard_metas[i], generation=gen)
        final = f"shard-{digest[:16]}.ragdb"
        os.replace(tmp_name, os.path.join(root, final))
        shard_entries.append({"file": final, "sha256": digest, "index": i})

    manifest = _publish_manifest(root, gen, shard_entries, meta, prev, gc_grace)
    if gc:
        _gc_shard_files(root, manifest)
    return gen


def publish_sharded_delta(
    root: str,
    shard_patches: dict[int, dict[str, np.ndarray]],
    patch_metas: dict[int, dict] | None = None,
    meta: dict | None = None,
    gc: bool = True,
    gc_grace: int = 1,
) -> int:
    """Publish the next generation by appending per-shard journal patches.

    ``shard_patches`` maps shard index → replacement segments (whole
    segments replace or extend the shard's current view; later records
    win).  Untouched shards carry over from the previous manifest
    unchanged, so a publish writes O(patch) bytes, not O(container) —
    the sharded analogue of ``KnowledgeBase.save_delta``.  Each manifest
    entry records the exact journal byte window (``from``/``bytes``) its
    generation sees, so pinned readers are isolated from later appends
    exactly like they are from later manifests.  Fold journals back into
    fresh shard files by calling ``publish_sharded`` (full write resets
    the windows; GC reclaims the journals once they age out of the grace
    window).
    """
    prev = _load_manifest(root)
    if prev is None:
        raise FileNotFoundError(
            f"{root}: publish_sharded_delta needs a published base manifest"
        )
    gen = int(prev["generation"]) + 1
    entries = [dict(e) for e in prev["shards"]]
    patch_metas = patch_metas or {}
    for i, segs in sorted(shard_patches.items()):
        entry = entries[i]
        base = os.path.join(root, entry["file"])
        uid = Container.open(base).uid
        if uid is None:
            raise ValueError(
                f"{base}: pre-uid shard container cannot anchor a journal "
                "chain — republish it with publish_sharded first"
            )
        man = append_journal_record(
            base, segs, patch_metas.get(i, {}), gen, uid
        )
        prev_win = entry.get("journal")
        entry["journal"] = {
            # chain start: a prior windowed entry extends its chain; a
            # freshly full-written shard starts at this record
            "from": prev_win["from"] if prev_win else man["appended_at"],
            "bytes": man["committed_bytes"],
            "records": (prev_win["records"] if prev_win else 0) + 1,
        }
    manifest = _publish_manifest(root, gen, entries, meta, prev, gc_grace)
    if gc:
        _gc_shard_files(root, manifest)
    return gen


@dataclass
class PatchedShard:
    """A shard view with its pinned journal window applied (duck-types
    ``Container``'s read API).  Patched segments are served from memory;
    untouched ones fall through to the base container."""

    base: Container
    generation: int
    _patches: dict[str, np.ndarray]

    @property
    def path(self) -> str:
        return self.base.path

    @property
    def meta(self) -> dict:
        return self.base.meta

    def segment_names(self) -> list[str]:
        return sorted(set(self.base.segment_names()) | set(self._patches))

    def read(self, name: str, verify: bool = True) -> np.ndarray:
        if name in self._patches:
            return self._patches[name].copy()
        return self.base.read(name, verify)

    def read_all(self, verify: bool = True) -> dict[str, np.ndarray]:
        return {n: self.read(n, verify) for n in self.segment_names()}


@dataclass
class ShardedContainer:
    root: str
    generation: int
    meta: dict
    shards: list[dict]

    @staticmethod
    def open(root: str) -> "ShardedContainer":
        """Pin the current generation (readers are isolated from later
        publishes — the paper's WAL concurrent-reader analogue)."""
        with open(os.path.join(root, MANIFEST)) as f:
            m = json.load(f)
        return ShardedContainer(
            root=root,
            generation=int(m["generation"]),
            meta=m["meta"],
            shards=m["shards"],
        )

    def open_shard(self, i: int) -> Container | PatchedShard:
        entry = self.shards[i]
        base = Container.open(os.path.join(self.root, entry["file"]))
        win = entry.get("journal")
        if not win:
            return base
        records = read_journal(
            base.path, base.uid,
            start=int(win.get("from", 0)), max_bytes=int(win["bytes"]),
        )
        if len(records) < int(win["records"]):
            raise IOError(
                f"{base.path}: journal window truncated "
                f"({len(records)}/{win['records']} records intact)"
            )
        patches: dict[str, np.ndarray] = {}
        for _, _, segs in records:
            patches.update(segs)  # later records win
        return PatchedShard(base, self.generation, patches)

    @property
    def n_shards(self) -> int:
        return len(self.shards)
