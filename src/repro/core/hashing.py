"""Deterministic, dependency-free hashing primitives.

Everything in the retrieval plane must be *exactly* reproducible across
hosts, processes and restarts (the paper's determinism guarantee), so we
never use Python's salted ``hash()``.  Two families:

- ``fnv1a64`` / ``fnv1a64_bytes``: scalar FNV-1a for strings (token
  hashing).  Cached — token distributions are Zipfian so the cache hit
  rate is high during ingestion.
- ``rolling_ngram_hashes``: vectorized polynomial rolling hash over the
  byte stream for character n-grams (Bloom signature construction).
  O(len) numpy ops, no per-gram Python loop.
"""
from __future__ import annotations

import functools

import numpy as np

_FNV_OFFSET = np.uint64(0xCBF29CE484222325)
_FNV_PRIME = np.uint64(0x100000001B3)

# Multiplier for the secondary (derived) hash — splitmix64 finalizer constant.
_MIX = np.uint64(0xFF51AFD7ED558CCD)

_U64 = np.uint64
_MASK64 = np.uint64(0xFFFFFFFFFFFFFFFF)


def fnv1a64_bytes(data: bytes) -> int:
    """FNV-1a 64-bit over raw bytes. Returns a Python int in [0, 2^64)."""
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


@functools.lru_cache(maxsize=1 << 20)
def fnv1a64(token: str) -> int:
    """Cached FNV-1a of a unicode string (utf-8)."""
    return fnv1a64_bytes(token.encode("utf-8"))


def mix64(h: np.ndarray | int):
    """splitmix64-style finalizer; decorrelates derived hashes."""
    if isinstance(h, (int, np.integer)):
        h = int(h)
        h ^= h >> 33
        h = (h * 0xFF51AFD7ED558CCD) & 0xFFFFFFFFFFFFFFFF
        h ^= h >> 33
        return h
    h = h.astype(np.uint64)
    h = h ^ (h >> _U64(33))
    h = (h * _MIX) & _MASK64
    h = h ^ (h >> _U64(33))
    return h


def hash_tokens(tokens: list[str]) -> np.ndarray:
    """Vector of FNV-1a hashes, one per token (uint64)."""
    return np.fromiter(
        (fnv1a64(t) for t in tokens), dtype=np.uint64, count=len(tokens)
    )


# Polynomial base for the rolling hash.  Any odd constant works; this is
# the FNV prime for symmetry with the token hash.
_POLY_BASE = 0x100000001B3


def rolling_ngram_hashes(data: bytes, n: int) -> np.ndarray:
    """All char n-gram hashes of ``data``, vectorized.

    h(i) = sum_j data[i+j] * BASE^(n-1-j)  (mod 2^64), then mixed.
    Returns uint64 array of length max(0, len(data) - n + 1).
    """
    if len(data) < n:
        return np.zeros((0,), dtype=np.uint64)
    b = np.frombuffer(data, dtype=np.uint8).astype(np.uint64)
    with np.errstate(over="ignore"):
        acc = np.zeros(len(data) - n + 1, dtype=np.uint64)
        for j in range(n):
            power = _U64(pow(_POLY_BASE, n - 1 - j, 1 << 64))
            acc = (acc + b[j : j + len(acc)] * power) & _MASK64
    return mix64(acc)
