"""The ⟨I⟩ region proper: an inverted index (term hash → posting list).

Paper §3.1 defines K = ⟨M, C, V, I⟩ with I "an inverted index mapping
vocabulary tokens to document IDs".  The Bloom signatures cover the
substring indicator; this module adds the classic postings structure and
the query paths it unlocks:

- **candidate pre-filtering**: intersect/union postings of the query's
  terms and run HSF only over the candidate set — sub-linear query cost
  when query terms are selective (the common entity-lookup case);
- **exact term lookups** (`docs_with_term`) for the RAG orchestrator.

Storage is CSR-style (sorted unique term hashes + offsets + doc-id
lists), so it serializes as three flat arrays into the container and
merges across shards by concatenation.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import hashing
from repro.core.tokenizer import TermCounts, tokenize


@dataclass
class PostingsIndex:
    term_hashes: np.ndarray  # uint64 [T] sorted unique
    offsets: np.ndarray  # int64 [T+1]
    doc_ids: np.ndarray  # int32 [total_postings] (local doc indices)

    @staticmethod
    def build(term_counts: list[TermCounts]) -> "PostingsIndex":
        """Build from per-doc unique term hashes (doc index = position)."""
        if not term_counts:
            return PostingsIndex(np.zeros(0, np.uint64),
                                 np.zeros(1, np.int64),
                                 np.zeros(0, np.int32))
        all_terms = np.concatenate([tc.term_hashes for tc in term_counts])
        all_docs = np.concatenate([
            np.full(tc.term_hashes.size, i, np.int32)
            for i, tc in enumerate(term_counts)
        ])
        order = np.lexsort((all_docs, all_terms))
        terms_sorted = all_terms[order]
        docs_sorted = all_docs[order]
        uniq, starts = np.unique(terms_sorted, return_index=True)
        offsets = np.concatenate([starts, [len(terms_sorted)]]).astype(
            np.int64)
        return PostingsIndex(uniq, offsets, docs_sorted)

    # ---- lookups --------------------------------------------------------

    def docs_with_term(self, term: str) -> np.ndarray:
        h = np.uint64(hashing.fnv1a64(term))
        i = np.searchsorted(self.term_hashes, h)
        if i >= len(self.term_hashes) or self.term_hashes[i] != h:
            return np.zeros(0, np.int32)
        return self.doc_ids[self.offsets[i]: self.offsets[i + 1]]

    def candidates(self, query: str, mode: str = "union",
                   max_candidates: int | None = None) -> np.ndarray | None:
        """Docs containing query terms.  ``union`` (recall-safe for HSF
        re-ranking) or ``intersect`` (high precision).  Returns None when
        the query has no indexed terms (caller falls back to full scan).
        """
        terms = tokenize(query)
        if not terms:
            return None
        lists = [self.docs_with_term(t) for t in terms]
        if all(len(l) == 0 for l in lists):
            return np.zeros(0, np.int32)
        if mode == "intersect":
            out = lists[0]
            for l in lists[1:]:
                out = np.intersect1d(out, l, assume_unique=False)
        else:
            out = np.unique(np.concatenate(lists))
        if max_candidates is not None and len(out) > max_candidates:
            return None  # unselective query: full HSF scan is cheaper
        return out.astype(np.int32)

    # ---- container (de)serialization ------------------------------------

    def segments(self) -> dict[str, np.ndarray]:
        return {"post_terms": self.term_hashes, "post_offsets": self.offsets,
                "post_docs": self.doc_ids}

    @staticmethod
    def from_segments(segs: dict) -> "PostingsIndex | None":
        if "post_terms" not in segs:
            return None
        return PostingsIndex(segs["post_terms"], segs["post_offsets"],
                             segs["post_docs"])
