"""N-gram Bloom signatures — the TPU-native form of the paper's substring
indicator (docs/ARCHITECTURE.md §3).

Paper (§4.2): ``1_substr(Q, D) = 1 if lowercase(Q) ⊆ lowercase(D)``.
A byte-scan is unvectorizable on a TPU VPU, so we encode each document's
character n-gram set into a fixed-width Bloom signature and test
*containment*:

    1_bloom(Q, D) = all((sig(D) & sig(Q)) == sig(Q))

Soundness: if Q is a substring of D then every char n-gram of Q is a char
n-gram of D, so every bit of sig(Q) is set in sig(D) — **no false
negatives**, which preserves the paper's 100 % Recall@1 guarantee for
known entities.  False positives are bounded by signature width; with
W=128 words (4096 bits), k=2 probes and typical doc gram counts (~1e3)
the per-doc FP rate is < (m/4096·k)^k ≈ 1e-1..1e-2 — and a false positive
only *adds* β to an unrelated doc, it never demotes a true match.

Signatures are int32 (TPU-friendly lane type); W is a multiple of 128 so a
(block_docs × W) tile is lane-aligned in VMEM.
"""
from __future__ import annotations

import numpy as np

from repro.core import hashing, tokenizer

# Defaults: 4096-bit signatures, 4-byte grams, 2 probes per gram.
DEFAULT_WIDTH_WORDS = 128
DEFAULT_NGRAM = 4
DEFAULT_PROBES = 2

_U64 = np.uint64


def _bit_positions(gram_hashes: np.ndarray, width_words: int, probes: int) -> np.ndarray:
    """Map gram hashes to Bloom bit positions (probes per gram)."""
    nbits = _U64(width_words * 32)
    positions = []
    h = gram_hashes.astype(np.uint64)
    for _ in range(probes):
        positions.append((h % nbits).astype(np.int64))
        h = hashing.mix64(h)
    if not positions:
        return np.zeros((0,), np.int64)
    return np.concatenate(positions)


def signature_of_text(
    text: str,
    width_words: int = DEFAULT_WIDTH_WORDS,
    ngram: int = DEFAULT_NGRAM,
    probes: int = DEFAULT_PROBES,
) -> np.ndarray:
    """Bloom signature (int32 [width_words]) of the canonicalized text."""
    data = tokenizer.normalize(text).encode("utf-8")
    grams = hashing.rolling_ngram_hashes(data, ngram)
    sig = np.zeros((width_words,), dtype=np.uint32)
    if grams.size:
        pos = _bit_positions(grams, width_words, probes)
        words = (pos >> 5).astype(np.int64)
        bits = (pos & 31).astype(np.uint32)
        np.bitwise_or.at(sig, words, np.uint32(1) << bits)
    return sig.view(np.int32)


def batch_signatures(
    texts: list[str],
    width_words: int = DEFAULT_WIDTH_WORDS,
    ngram: int = DEFAULT_NGRAM,
    probes: int = DEFAULT_PROBES,
) -> np.ndarray:
    """Stacked signatures, int32 [n_docs, width_words]."""
    if not texts:
        return np.zeros((0, width_words), dtype=np.int32)
    return np.stack(
        [signature_of_text(t, width_words, ngram, probes) for t in texts]
    )


def contains(doc_sigs: np.ndarray, query_sig: np.ndarray) -> np.ndarray:
    """Vectorized containment test (numpy oracle; the JAX/Pallas versions
    live in hsf.py / kernels/hsf_score).  Returns bool [n_docs]."""
    d = doc_sigs.view(np.uint32)
    q = query_sig.view(np.uint32)
    return np.all((d & q) == q, axis=-1)


def query_signature(
    query: str,
    width_words: int = DEFAULT_WIDTH_WORDS,
    ngram: int = DEFAULT_NGRAM,
    probes: int = DEFAULT_PROBES,
) -> np.ndarray:
    """Signature of a query string.

    Queries shorter than the gram size produce an *empty* signature
    (all-zero), whose containment test is trivially true for every doc —
    i.e. the boost degenerates to a rank-preserving constant.  Documented
    edge case; matches the paper's behaviour of boosting on any exact
    occurrence without ever demoting the true match.
    """
    return signature_of_text(query, width_words, ngram, probes)
