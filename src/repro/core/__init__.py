"""RAGdb's primary contribution, reimplemented as a TPU-scale system:

- hashing / tokenizer / vectorizer : sublinear hashed TF-IDF (paper §4.1)
- signature                        : Bloom n-gram substring indicator (§4.2)
- hsf                              : Hybrid Scoring Function (§4)
- container                        : Single-File Knowledge Container (§3.1)
- ingest                           : O(U) incremental multimodal ingestion (§3.2-3.3)
- retrieval                        : edge-parity + mesh-sharded retrieval
- rag                              : retrieve → pack → generate orchestration
"""

from repro.core.hsf import hsf_scores, hsf_scores_batched  # noqa: F401
from repro.core.ingest import IngestStats, KnowledgeBase  # noqa: F401
from repro.core.retrieval import Retriever  # noqa: F401
