"""Shared neural building blocks (pure functions over param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(rng, d_in: int, d_out: int, scale: float | None = None):
    scale = (1.0 / d_in) ** 0.5 if scale is None else scale
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale)


def embed_init(rng, vocab: int, d_model: int):
    return jax.random.normal(rng, (vocab, d_model), jnp.float32) * 0.01


# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
             unit_offset: bool = False) -> jnp.ndarray:
    """RMSNorm.  ``unit_offset=True`` uses the gemma convention
    (weights parameterized around 0, applied as 1 + w)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = (1.0 + weight) if unit_offset else weight
    return (x * w).astype(dtype)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------

def rope_table(positions: jnp.ndarray, head_dim: int, base: float):
    """(sin, cos) tables for positions [..., L] → [..., L, head_dim/2]."""
    half = head_dim // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, base: float) -> jnp.ndarray:
    """Rotate pairs (split-half convention).  x: [B, H, L, D],
    positions: [B, L]."""
    sin, cos = rope_table(positions, x.shape[-1], base)
    sin = sin[:, None, :, :]  # [B, 1, L, D/2]
    cos = cos[:, None, :, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# --------------------------------------------------------------------------

def mlp_init(rng, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff),
        "w_up": dense_init(k2, d_model, d_ff),
        "w_down": dense_init(k3, d_ff, d_model),
    }


def mlp_apply(params: dict, x: jnp.ndarray, activation: str = "silu") -> jnp.ndarray:
    dtype = x.dtype
    gate = x @ params["w_gate"].astype(dtype)
    up = x @ params["w_up"].astype(dtype)
    act = jax.nn.gelu(gate) if activation == "gelu" else jax.nn.silu(gate)
    return (act * up) @ params["w_down"].astype(dtype)


def dense_mlp_init(rng, dims: tuple[int, ...]) -> dict:
    """Plain MLP (recsys towers): dims = (in, h1, ..., out)."""
    keys = jax.random.split(rng, len(dims) - 1)
    return {
        f"w{i}": dense_init(keys[i], dims[i], dims[i + 1])
        for i in range(len(dims) - 1)
    } | {
        f"b{i}": jnp.zeros((dims[i + 1],), jnp.float32)
        for i in range(len(dims) - 1)
    }


def dense_mlp_apply(params: dict, x: jnp.ndarray, n_layers: int,
                    final_activation: bool = False) -> jnp.ndarray:
    for i in range(n_layers):
        x = x @ params[f"w{i}"].astype(x.dtype) + params[f"b{i}"].astype(x.dtype)
        if i + 1 < n_layers or final_activation:
            x = jax.nn.relu(x)
    return x
