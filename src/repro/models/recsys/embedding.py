"""Sparse embedding substrate for the recsys family.

JAX has no native EmbeddingBag / CSR tables — this module IS that
substrate (task rules; kernel_taxonomy §RecSys):

- all categorical fields share one fused row table [Σ vocab_f, dim] with
  per-field offsets (the FBGEMM table-batched layout), so one gather
  serves every field;
- multi-hot fields reduce via the embedding_bag kernel path
  (jnp.take + segment_sum on CPU/dry-run, kernels/embedding_bag on TPU);
- distribution: rows are range-sharded over the model axis.  Under the
  ``sharding_ctx`` the lookup runs a shard_map that is MANUAL over the
  row axis and AUTO elsewhere: each shard gathers the rows it owns
  (out-of-range → zero) and a psum over the row axis assembles the
  result.  Collective payload = the looked-up rows, never the table.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

TABLE_ROW_MULTIPLE = 512  # rows padded so any mesh axis divides evenly

_CTX = threading.local()


@contextlib.contextmanager
def sharding_ctx(mesh, row_axis: str = "model"):
    prev = getattr(_CTX, "value", None)
    _CTX.value = (mesh, row_axis)
    try:
        yield
    finally:
        _CTX.value = prev


def _get_ctx():
    return getattr(_CTX, "value", None)


def field_offsets(vocab_sizes: tuple[int, ...]) -> jnp.ndarray:
    """Static per-field row offsets into the fused table (trace-time
    constant — never a trainable leaf, so grads stay float-only)."""
    offsets = np.zeros(len(vocab_sizes), np.int64)
    np.cumsum(vocab_sizes[:-1], out=offsets[1:])
    return jnp.asarray(offsets, jnp.int32)


def padded_rows(vocab_sizes: tuple[int, ...]) -> int:
    total = int(sum(vocab_sizes))
    return total + (-total) % TABLE_ROW_MULTIPLE


def init_tables(rng, vocab_sizes: tuple[int, ...], dim: int) -> dict:
    return {
        "table": jax.random.normal(
            rng, (padded_rows(vocab_sizes), dim), jnp.float32
        ) * (1.0 / dim) ** 0.5,
    }


def lookup_rows(table: jnp.ndarray, flat_idx: jnp.ndarray) -> jnp.ndarray:
    """Gather rows by already-offset indices; ctx-aware.

    table [V, E]; flat_idx int32 [...]; returns [..., E].
    """
    ctx = _get_ctx()
    if ctx is None:
        return jnp.take(table, flat_idx, axis=0)
    mesh, axis = ctx

    def local(tshard, idx):
        v_local = tshard.shape[0]
        lo = jax.lax.axis_index(axis) * v_local
        li = idx - lo
        valid = (li >= 0) & (li < v_local)
        rows = jnp.take(tshard, jnp.clip(li, 0, v_local - 1), axis=0)
        rows = rows * valid[..., None].astype(rows.dtype)
        return jax.lax.psum(rows, axis)

    # check_vma=True: the psum result is provably invariant over the row
    # axis, and the varying-manual-axes typing is what lets jax transpose
    # this shard_map for gradients in eager mode.
    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P()),
        out_specs=P(),
        axis_names={axis},
    )(table, flat_idx.astype(jnp.int32))


def lookup(table: jnp.ndarray, offsets: jnp.ndarray,
           sparse_idx: jnp.ndarray) -> jnp.ndarray:
    """One-hot-per-field lookup: sparse_idx [B, F] → [B, F, dim]."""
    flat = sparse_idx.astype(jnp.int32) + offsets[None, :]
    return lookup_rows(table, flat)


def lookup_scores(table: jnp.ndarray, flat_idx: jnp.ndarray,
                  q_vec: jnp.ndarray) -> jnp.ndarray:
    """Fused lookup-and-score: out[i] = table[idx[i]] · q — WITHOUT
    materializing the gathered rows across shards.

    This is the paper's retrieval-plane insight applied to candidate
    scoring (RAGdb: score at the shard, move scores): each shard dots
    the candidate rows it owns against the query locally and the psum
    carries [n_cand] scalars instead of [n_cand, dim] rows — dim× less
    collective payload and no replicated row matrix.
    """
    ctx = _get_ctx()
    if ctx is None:
        return jnp.take(table, flat_idx, axis=0) @ q_vec
    mesh, axis = ctx

    def local(tshard, idx, q):
        v_local = tshard.shape[0]
        lo = jax.lax.axis_index(axis) * v_local
        li = idx - lo
        valid = (li >= 0) & (li < v_local)
        rows = jnp.take(tshard, jnp.clip(li, 0, v_local - 1), axis=0)
        s = rows @ q  # [n] — scored before any communication
        return jax.lax.psum(s * valid.astype(s.dtype), axis)

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(), P()),
        out_specs=P(),
        axis_names={axis},
    )(table, flat_idx.astype(jnp.int32), q_vec)


def lookup_bags(table, offsets, indices, field_ids, bag_ids, n_bags,
                weights=None, use_kernel: bool = False):
    """Multi-hot lookup: ragged (bag, field, index) triples reduced per
    bag — the EmbeddingBag path."""
    flat = indices.astype(jnp.int32) + offsets[field_ids]
    if use_kernel:
        from repro.kernels.embedding_bag import ops as _ops

        return _ops.embedding_bag(table, flat, bag_ids, n_bags, weights)
    rows = lookup_rows(table, flat)
    if weights is not None:
        rows = rows * weights[:, None]
    return jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)
