"""Shared recsys config + loss."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

# Criteo 1TB per-field vocabulary sizes (MLPerf DLRM reference;
# facebookresearch/dlrm README).  dlrm archs use these 26 directly.
CRITEO_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)

# 39-field layout (deepfm/autoint convention): 13 bucketized dense
# fields (small vocabs) + the 26 categorical fields, capped per the
# usual Criteo-Kaggle preprocessing (hash-capped at 1e6 rows/field).
DEEPFM_VOCABS = tuple([101] * 13) + tuple(
    min(v, 1_000_000) for v in CRITEO_VOCABS
)


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    vocab_sizes: tuple[int, ...]
    embed_dim: int
    n_dense: int = 0
    bot_mlp: tuple[int, ...] = ()
    top_mlp: tuple[int, ...] = ()
    mlp_dims: tuple[int, ...] = ()  # deepfm deep tower
    n_attn_layers: int = 0  # autoint
    n_attn_heads: int = 0
    d_attn: int = 0
    interaction: str = "dot"  # dot | fm | self-attn
    dtype: str = "float32"

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    def param_count(self) -> int:
        n = sum(self.vocab_sizes) * self.embed_dim
        if self.interaction == "fm":
            n += sum(self.vocab_sizes)  # first-order weights
        dims_chains = []
        if self.bot_mlp:
            dims_chains.append((self.n_dense,) + self.bot_mlp)
        if self.top_mlp:
            n_inter = self.n_sparse + (1 if self.bot_mlp else 0)
            d_top_in = n_inter * (n_inter - 1) // 2 + (
                self.bot_mlp[-1] if self.bot_mlp else 0
            )
            dims_chains.append((d_top_in,) + self.top_mlp)
        if self.mlp_dims:
            dims_chains.append(
                (self.n_sparse * self.embed_dim,) + self.mlp_dims + (1,)
            )
        for dims in dims_chains:
            for i in range(len(dims) - 1):
                n += dims[i] * dims[i + 1] + dims[i + 1]
        if self.n_attn_layers:
            per = 3 * self.embed_dim * self.d_attn + self.embed_dim * self.d_attn
            d = self.d_attn
            per += 3 * d * d + d * d  # subsequent layers operate at d_attn
            n += per * self.n_attn_layers  # approximate (first layer differs)
        return n


def bce_with_logits(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Numerically stable binary cross entropy."""
    z = jnp.clip(logits, -30.0, 30.0)
    return jnp.mean(
        jnp.maximum(z, 0.0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z)))
    )
