"""DeepFM (arXiv:1703.04247): shared embeddings feeding an FM branch and
a deep MLP branch; logit = first_order + fm + deep."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.recsys import embedding
from repro.models.recsys.base import RecsysConfig


def init(rng, cfg: RecsysConfig) -> dict:
    k_emb, k_w, k_deep = jax.random.split(rng, 3)
    tables = embedding.init_tables(k_emb, cfg.vocab_sizes, cfg.embed_dim)
    return {
        "table": tables["table"],
        "first_order": jax.random.normal(
            k_w, (embedding.padded_rows(cfg.vocab_sizes),), jnp.float32
        ) * 0.01,
        "deep": layers.dense_mlp_init(
            k_deep, (cfg.n_sparse * cfg.embed_dim,) + cfg.mlp_dims + (1,)
        ),
        "bias": jnp.zeros((), jnp.float32),
    }


def forward(params, dense: jnp.ndarray | None, sparse_idx: jnp.ndarray,
            cfg: RecsysConfig) -> jnp.ndarray:
    """sparse_idx [B, F] int → logits [B] (dense unused: 39-field form)."""
    dt = jnp.dtype(cfg.dtype)
    flat = sparse_idx.astype(jnp.int32) + embedding.field_offsets(cfg.vocab_sizes)[None, :]
    emb = embedding.lookup_rows(params["table"].astype(dt), flat)  # [B, F, D]

    first = embedding.lookup_rows(
        params["first_order"].astype(dt)[:, None], flat
    )[..., 0].sum(-1)

    # FM second order: ½ Σ_d [(Σ_f v)² − Σ_f v²]
    sum_v = emb.sum(axis=1)
    sum_sq = jnp.square(emb).sum(axis=1)
    fm = 0.5 * (jnp.square(sum_v) - sum_sq).sum(axis=-1)

    deep = layers.dense_mlp_apply(
        params["deep"], emb.reshape(emb.shape[0], -1), len(cfg.mlp_dims) + 1
    )[:, 0]
    return first + fm + deep + params["bias"].astype(dt)


def retrieval_scores(params, dense_query, candidate_ids, cfg: RecsysConfig,
                     field: int = 0) -> jnp.ndarray:
    """Score candidates by FM affinity with a fixed query field-context:
    dot of candidate embedding against the query's summed field vector."""
    dt = jnp.dtype(cfg.dtype)
    q_emb = embedding.lookup_rows(
        params["table"].astype(dt),
        dense_query.astype(jnp.int32)
        + embedding.field_offsets(cfg.vocab_sizes)[None, :],
    ).sum(axis=1)  # [1, D]
    offs = embedding.field_offsets(cfg.vocab_sizes)[field]
    return embedding.lookup_scores(params["table"].astype(dt),
                                   candidate_ids + offs, q_emb[0])
