"""AutoInt (arXiv:1810.11921): multi-head self-attention over field
embeddings with residual connections, then a linear scoring head."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.recsys import embedding
from repro.models.recsys.base import RecsysConfig


def init(rng, cfg: RecsysConfig) -> dict:
    ks = jax.random.split(rng, 2 + cfg.n_attn_layers)
    tables = embedding.init_tables(ks[0], cfg.vocab_sizes, cfg.embed_dim)
    params = {"table": tables["table"], "layers": []}
    d_in = cfg.embed_dim
    for i in range(cfg.n_attn_layers):
        lk = jax.random.split(ks[1 + i], 4)
        params["layers"].append({
            "w_q": layers.dense_init(lk[0], d_in, cfg.d_attn),
            "w_k": layers.dense_init(lk[1], d_in, cfg.d_attn),
            "w_v": layers.dense_init(lk[2], d_in, cfg.d_attn),
            "w_res": layers.dense_init(lk[3], d_in, cfg.d_attn),
        })
        d_in = cfg.d_attn
    params["head"] = layers.dense_init(ks[-1], cfg.n_sparse * d_in, 1)
    return params


def forward(params, dense, sparse_idx: jnp.ndarray,
            cfg: RecsysConfig) -> jnp.ndarray:
    dt = jnp.dtype(cfg.dtype)
    x = embedding.lookup(params["table"].astype(dt), embedding.field_offsets(cfg.vocab_sizes),
                         sparse_idx)  # [B, F, D]
    b, f, _ = x.shape
    h = cfg.n_attn_heads
    dh = cfg.d_attn // h
    for lp in params["layers"]:
        q = (x @ lp["w_q"].astype(dt)).reshape(b, f, h, dh)
        k = (x @ lp["w_k"].astype(dt)).reshape(b, f, h, dh)
        v = (x @ lp["w_v"].astype(dt)).reshape(b, f, h, dh)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, f, cfg.d_attn)
        x = jax.nn.relu(o + x @ lp["w_res"].astype(dt))
    return (x.reshape(b, -1) @ params["head"].astype(dt))[:, 0]


def retrieval_scores(params, dense_query, candidate_ids, cfg: RecsysConfig,
                     field: int = 0) -> jnp.ndarray:
    dt = jnp.dtype(cfg.dtype)
    q_emb = embedding.lookup_rows(
        params["table"].astype(dt),
        dense_query.astype(jnp.int32)
        + embedding.field_offsets(cfg.vocab_sizes)[None, :],
    ).mean(axis=1)  # [1, D]
    offs = embedding.field_offsets(cfg.vocab_sizes)[field]
    return embedding.lookup_scores(params["table"].astype(dt),
                                   candidate_ids + offs, q_emb[0])
