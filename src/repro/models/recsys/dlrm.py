"""DLRM (arXiv:1906.00091): bottom MLP ∥ embedding lookups → dot
interaction → top MLP.  Covers dlrm-rm2 and dlrm-mlperf via config."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.recsys import embedding
from repro.models.recsys.base import RecsysConfig


def init(rng, cfg: RecsysConfig) -> dict:
    k_emb, k_bot, k_top = jax.random.split(rng, 3)
    tables = embedding.init_tables(k_emb, cfg.vocab_sizes, cfg.embed_dim)
    n_inter = cfg.n_sparse + 1  # sparse fields + bottom output
    d_top_in = n_inter * (n_inter - 1) // 2 + cfg.bot_mlp[-1]
    return {
        "table": tables["table"],
        "bot": layers.dense_mlp_init(k_bot, (cfg.n_dense,) + cfg.bot_mlp),
        "top": layers.dense_mlp_init(k_top, (d_top_in,) + cfg.top_mlp),
    }


def _interact_dot(feats: jnp.ndarray) -> jnp.ndarray:
    """Pairwise dot interaction: feats [B, F, D] → [B, F(F-1)/2]."""
    b, f, _ = feats.shape
    z = jnp.einsum("bfd,bgd->bfg", feats, feats)
    iu, ju = jnp.triu_indices(f, k=1)
    return z[:, iu, ju]


def forward(params, dense: jnp.ndarray, sparse_idx: jnp.ndarray,
            cfg: RecsysConfig) -> jnp.ndarray:
    """dense [B, n_dense] f32, sparse_idx [B, F] int → logits [B]."""
    dt = jnp.dtype(cfg.dtype)
    bot = layers.dense_mlp_apply(params["bot"], dense.astype(dt),
                                 len(cfg.bot_mlp), final_activation=True)
    emb = embedding.lookup(params["table"].astype(dt), embedding.field_offsets(cfg.vocab_sizes),
                           sparse_idx)  # [B, F, D]
    feats = jnp.concatenate([bot[:, None, :], emb], axis=1)
    inter = _interact_dot(feats)
    top_in = jnp.concatenate([inter, bot], axis=-1)
    out = layers.dense_mlp_apply(params["top"], top_in, len(cfg.top_mlp))
    return out[:, 0]


def retrieval_scores(params, dense_query: jnp.ndarray,
                     candidate_ids: jnp.ndarray, cfg: RecsysConfig,
                     field: int = 0) -> jnp.ndarray:
    """retrieval_cand shape: one query against n candidates — the query
    tower (bottom MLP) dotted with candidate embedding rows.  Batched
    MXU dot, not a loop; merges with the paper's top-k machinery."""
    dt = jnp.dtype(cfg.dtype)
    q = layers.dense_mlp_apply(params["bot"], dense_query.astype(dt),
                               len(cfg.bot_mlp), final_activation=True)  # [1, D]
    offs = embedding.field_offsets(cfg.vocab_sizes)[field]
    return embedding.lookup_scores(params["table"].astype(dt),
                                   candidate_ids + offs, q[0])
