"""Attention math: XLA-lowerable flash paths + Pallas dispatch + decode.

Three execution strategies, one semantics (tested against each other):

- ``backend="pallas"``: the fused kernel (kernels/flash_attention) — the
  TPU runtime path.  Not used for dry-run lowering: interpret-mode
  pallas unrolls the grid into enormous HLO.
- ``backend="xla"``: blockwise online-softmax attention as a
  ``lax.scan`` over kv blocks — compact HLO, bounded live memory (no
  L×L score materialization), correct FLOP accounting for the roofline.
- sliding-window layers use the *banded* chunked form: query chunk i
  attends key chunks {i-1, i} only, so window layers cost O(L·2w)
  instead of O(L²) — this mirrors the kernel's block-skipping and is
  what makes gemma3's 5:1 local:global stack cheap.

GQA is computed in *grouped-einsum* form — queries reshaped to
[B, Hkv, G, ...] against un-repeated KV — so KV is never materialized
per-q-head (memory + HLO-FLOPs accuracy) and KV tensors shard cleanly
on the head axis regardless of the q:kv ratio.

Decode (single new token against a KV cache) is a separate, memory-bound
path; its sequence-sharded distributed variant lives in launch/steps.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MASK_VALUE = -1e30

# Roofline accounting mode: XLA's cost_analysis counts while-loop bodies
# ONCE regardless of trip count, so the kv-block scan hides (nk-1)/nk of
# the attention FLOPs from the report.  The cost-exact variants compiled
# by benchmarks/roofline.py set this to True to fully unroll the scan
# (identical arithmetic, exact op counting).  Never set for production
# lowering — it inflates HLO size nk-fold.
COST_EXACT_UNROLL = False


def _softcap(s: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    return s if cap is None else cap * jnp.tanh(s / cap)


def _group_q(q: jnp.ndarray, hkv: int) -> jnp.ndarray:
    """[B, Hq, L, D] → [B, Hkv, G, L, D]."""
    b, hq, l, d = q.shape
    return q.reshape(b, hkv, hq // hkv, l, d)


def _ungroup(o: jnp.ndarray) -> jnp.ndarray:
    """[B, Hkv, G, L, D] → [B, Hq, L, D]."""
    b, hkv, g, l, d = o.shape
    return o.reshape(b, hkv * g, l, d)


# --------------------------------------------------------------------------
# XLA flash attention (scan over kv blocks)
# --------------------------------------------------------------------------

def flash_attention_xla(
    q: jnp.ndarray,  # [B, Hq, Lq, D]
    k: jnp.ndarray,  # [B, Hkv, Lk, D]
    v: jnp.ndarray,
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
    block_k: int = 1024,
) -> jnp.ndarray:
    b, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    dv = v.shape[-1]  # may differ from d (MLA: qk dim != v dim)
    g = hq // hkv
    block_k = min(block_k, lk)
    pad = (-lk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = k.shape[2] // block_k
    kb = k.reshape(b, hkv, nk, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nk, block_k, dv).transpose(2, 0, 1, 3, 4)

    # operands stay in model dtype (bf16 on TPU → MXU-native); all
    # reductions/accumulators are f32 via preferred_element_type — the
    # canonical flash-attention mixed-precision recipe.
    qf = _group_q(q, hkv)  # [B, Hkv, G, Lq, D]
    q_pos = q_offset + jnp.arange(lq)

    def body(carry, blk):
        m_prev, l_prev, acc = carry
        kblk, vblk, ki = blk  # [B, Hkv, bk, D]
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kblk,
                       preferred_element_type=jnp.float32) * scale
        s = _softcap(s, softcap)
        k_pos = ki * block_k + jnp.arange(block_k)
        mask = (k_pos < lk)[None, :]
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        if window is not None:
            mask = mask & ((q_pos[:, None] - k_pos[None, :]) < window)
        mb = mask[None, None, None]  # [1,1,1,Lq,bk]
        s = jnp.where(mb, s, MASK_VALUE)
        m_curr = jnp.max(s, axis=-1, keepdims=True)
        m_next = jnp.maximum(m_prev, m_curr)
        p = jnp.exp(s - m_next) * mb
        alpha = jnp.exp(m_prev - m_next)
        l_next = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32,
        )
        return (m_next, l_next, acc), None

    init = (
        jnp.full((b, hkv, g, lq, 1), MASK_VALUE, jnp.float32),
        jnp.zeros((b, hkv, g, lq, 1), jnp.float32),
        jnp.zeros((b, hkv, g, lq, dv), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        body, init, (kb, vb, jnp.arange(nk)),
        unroll=nk if COST_EXACT_UNROLL else 1,
    )
    out = acc / jnp.where(l == 0.0, 1.0, l)
    return _ungroup(out).astype(q.dtype)


# --------------------------------------------------------------------------
# banded (sliding-window) attention: O(L · 2w) instead of O(L²)
# --------------------------------------------------------------------------

def local_attention_xla(
    q: jnp.ndarray,  # [B, Hq, L, D]
    k: jnp.ndarray,  # [B, Hkv, L, D]
    v: jnp.ndarray,
    *,
    scale: float,
    window: int,
    softcap: float | None = None,
) -> jnp.ndarray:
    """Causal sliding-window attention via chunked band matmuls.

    Chunk size = window; query chunk i attends key chunks {i-1, i}.
    Exact for the mask 0 <= q_pos - k_pos < window.
    """
    b, hq, l, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    w = window
    pad = (-l) % w
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    lp = q.shape[2]
    nb = lp // w
    qb = _group_q(q, hkv).reshape(b, hkv, g, nb, w, d)
    kb = k.reshape(b, hkv, nb, w, d)
    vb = v.reshape(b, hkv, nb, w, d)
    # previous chunk (zeros before chunk 0)
    kprev = jnp.pad(kb[:, :, :-1], ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))
    vprev = jnp.pad(vb[:, :, :-1], ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))
    kext = jnp.concatenate([kprev, kb], axis=3)  # [B, Hkv, nb, 2w, D]
    vext = jnp.concatenate([vprev, vb], axis=3)

    s = jnp.einsum("bhgnqd,bhnkd->bhgnqk", qb, kext,
                   preferred_element_type=jnp.float32) * scale
    s = _softcap(s, softcap)

    a = jnp.arange(w)[:, None]  # in-chunk q offset
    bcol = jnp.arange(2 * w)[None, :]  # extended k offset
    delta = a + w - bcol  # q_pos - k_pos
    mask = (delta >= 0) & (delta < w)
    chunk = jnp.arange(nb)[:, None, None]
    k_pos = chunk * w + (bcol[None] - w)  # absolute key position
    mask = mask[None] & (k_pos >= 0) & (k_pos < l)  # [nb, w, 2w]
    mb = mask[None, None, None]  # [1,1,1,nb,w,2w]
    s = jnp.where(mb, s, MASK_VALUE)

    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * mb
    lsum = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgnqk,bhnkd->bhgnqd", p.astype(vext.dtype), vext,
                   preferred_element_type=jnp.float32)
    o = o / jnp.where(lsum == 0.0, 1.0, lsum)
    o = o.reshape(b, hkv, g, lp, d)[:, :, :, :l]
    return _ungroup(o).astype(q.dtype)


# --------------------------------------------------------------------------
# unified entry point
# --------------------------------------------------------------------------

def attention(
    q, k, v, *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
    backend: str = "xla",
):
    if backend == "pallas":
        from repro.kernels.flash_attention import ops as _ops

        return _ops.flash_attention(
            q, k, v, scale=scale, causal=causal, window=window,
            softcap=softcap, q_offset=q_offset,
        )
    if window is not None and causal and q_offset == 0 \
            and q.shape[2] == k.shape[2] and q.shape[2] > window:
        return local_attention_xla(
            q, k, v, scale=scale, window=window, softcap=softcap
        )
    return flash_attention_xla(
        q, k, v, scale=scale, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset,
    )


# --------------------------------------------------------------------------
# decode attention (one query token against a KV cache)
# --------------------------------------------------------------------------

def decode_attention(
    q: jnp.ndarray,  # [B, Hq, 1, D]
    k_cache: jnp.ndarray,  # [B, Hkv, S, D]
    v_cache: jnp.ndarray,
    length: jnp.ndarray | int,  # current cache fill (scalar or [B])
    *,
    scale: float,
    window: int | None = None,
    softcap: float | None = None,
) -> jnp.ndarray:
    """Memory-bound decode attention (the query position is length-1)."""
    b, hq, _, d = q.shape
    hkv, s_max = k_cache.shape[1], k_cache.shape[2]
    if isinstance(length, int):
        length = jnp.full((b,), length, jnp.int32)
    k_pos = jnp.arange(s_max)
    q_pos = (length - 1)[:, None]  # [B, 1]
    mask = k_pos[None, :] < length[:, None]
    if window is not None:
        mask &= (q_pos - k_pos[None, :]) < window
    return masked_decode_attention(q, k_cache, v_cache, mask,
                                   scale=scale, softcap=softcap)


def masked_decode_attention(
    q: jnp.ndarray,  # [B, Hq, 1, D]
    k_cache: jnp.ndarray,  # [B, Hkv, S, D]
    v_cache: jnp.ndarray,
    mask: jnp.ndarray,  # [B, S] bool — slot validity
    *,
    scale: float,
    softcap: float | None = None,
) -> jnp.ndarray:
    b, hq, _, d = q.shape
    hkv = k_cache.shape[1]
    qg = _group_q(q, hkv)  # [B, Hkv, G, 1, D]
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = _softcap(s, softcap)
    mb = mask[:, None, None, None, :]
    s = jnp.where(mb, s, MASK_VALUE)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * mb
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = o / jnp.where(l == 0.0, 1.0, l)
    return _ungroup(o).astype(q.dtype)
