"""Decoder-only LM covering the five assigned architectures.

One config drives: GQA vs MLA attention, dense vs MoE FFN, uniform vs
local:global layer patterns (gemma2/3), qk-norm, logit softcaps, per-kind
RoPE bases, tied embeddings.

HLO-size discipline (the dry-run compiles 27 B–30 B models on one host):
layers are scanned, not unrolled.  The scan unit is the architecture's
repeating *pattern* (gemma3: 5 local + 1 global = 6 layers/unit; uniform
archs: 1 layer/unit); pattern remainders and deepseek's leading dense
layer(s) are unrolled as head/tail layers.  Remat (jax.checkpoint) wraps
the scan body, so backward memory is O(units · layer-boundary), not
O(layers · activations).

KV caches: global layers cache the full horizon; sliding-window layers
cache a *ring buffer of exactly window slots* — at long_500k this is the
difference between a 24 GB and a ~0.1 GB cache for gemma3's 51 local
layers.  Ring indexing: position p lives in slot p mod W; slot validity
and masking are recomputed from the current length, so no positions
tensor is stored.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import attention as attn
from repro.models import layers, mla as mla_mod, moe as moe_mod
from repro.models.moe import MoEConfig
from repro.models.mla import MLAConfig

# ---------------------------------------------------------------------------
# activation-sharding context.  The embedding gather (vocab-sharded table
# × batch-sharded tokens) gives the SPMD partitioner a reason to abandon
# batch sharding for the whole downstream graph (observed: activations
# replicated over 'data', logits at 4.3 GB/device).  An explicit
# with_sharding_constraint on the embedding output (and the pre-unembed
# hidden state) pins activations to batch-over-data, which propagation
# then carries through every layer.  Set by launch/steps.py.
# ---------------------------------------------------------------------------

# Cost-exact mode (see attention.COST_EXACT_UNROLL): unroll the layer
# scans so XLA cost_analysis counts every trip.  Set only by the
# roofline variant builder, never for production lowering.
COST_EXACT_UNROLL = False


def _scan_unroll() -> bool | int:
    return True if COST_EXACT_UNROLL else 1


_ACT_CTX = threading.local()


@contextlib.contextmanager
def act_sharding_ctx(mesh, dp_axes: tuple[str, ...]):
    prev = getattr(_ACT_CTX, "value", None)
    _ACT_CTX.value = (mesh, tuple(dp_axes))
    try:
        yield
    finally:
        _ACT_CTX.value = prev


def _constrain_batch(x: jnp.ndarray) -> jnp.ndarray:
    """Pin dim 0 (batch) to the data axes; no-op without context or when
    the batch does not divide the axis."""
    ctx = getattr(_ACT_CTX, "value", None)
    if ctx is None:
        return x
    mesh, dp = ctx
    dpn = 1
    for a in dp:
        dpn *= mesh.shape[a]
    if dpn <= 1 or x.shape[0] % dpn != 0 or x.shape[0] < dpn:
        return x
    spec = P(dp, *((None,) * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: tuple[str, ...] = ("global",)
    window: int | None = None
    attn_softcap: float | None = None
    final_softcap: float | None = None
    qk_norm: bool = False
    post_norms: bool = False
    rope_base: float = 10000.0
    rope_base_local: float | None = None
    activation: str = "silu"
    embed_scale: bool = False
    tie_embeddings: bool = True
    query_scale: float | None = None
    moe: MoEConfig | None = None
    n_dense_head_layers: int = 0  # leading dense layers when moe != None
    dense_d_ff: int | None = None
    mla: MLAConfig | None = None
    dtype: str = "bfloat16"
    remat: bool = True
    # KV-head replication factor for tensor parallelism: when
    # n_kv_heads < TP degree, caches/attention replicate each KV head
    # kv_repeat× so the head axis shards cleanly (llama2-70B-style KV
    # replication).  Exact — pure layout change.  Set by launch/steps.py
    # from the mesh; 1 = paper-faithful baseline.
    kv_repeat: int = 1

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_scan_layers(self) -> int:
        return self.n_layers - self.n_dense_head_layers

    @property
    def n_units(self) -> int:
        return self.n_scan_layers // len(self.pattern)

    @property
    def tail_kinds(self) -> tuple[str, ...]:
        r = self.n_scan_layers % len(self.pattern)
        return self.pattern[:r]

    def kind_of(self, pos_in_pattern: int) -> str:
        return self.pattern[pos_in_pattern]

    @property
    def n_kv_eff(self) -> int:
        return self.n_kv_heads * self.kv_repeat

    @property
    def attn_scale(self) -> float:
        if self.query_scale is not None:
            return self.query_scale
        if self.mla is not None:
            return (self.mla.nope_head_dim + self.mla.rope_head_dim) ** -0.5
        return self.head_dim ** -0.5

    def param_count(self) -> int:
        """Total parameters (for 6·N·D roofline accounting)."""
        d, hd = self.d_model, self.head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        n = emb + d  # final norm
        def attn_params():
            if self.mla is not None:
                m = self.mla
                qdim = m.nope_head_dim + m.rope_head_dim
                return (d * self.n_heads * qdim + d * m.kv_lora_rank
                        + d * m.rope_head_dim + m.kv_lora_rank
                        + m.kv_lora_rank * self.n_heads * m.nope_head_dim
                        + m.kv_lora_rank * self.n_heads * m.v_head_dim
                        + self.n_heads * m.v_head_dim * d)
            p = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
            if self.qk_norm:
                p += 2 * hd
            return p
        def mlp_params(moe_layer: bool):
            if moe_layer and self.moe is not None:
                m = self.moe
                p = d * m.n_experts + 3 * m.n_experts * d * m.d_ff_expert
                if m.n_shared:
                    p += 3 * d * m.d_ff_expert * m.n_shared
                return p
            ff = self.dense_d_ff or self.d_ff
            return 3 * d * ff
        norms = d * (4 if self.post_norms else 2)
        for i in range(self.n_layers):
            moe_layer = self.moe is not None and i >= self.n_dense_head_layers
            n += attn_params() + mlp_params(moe_layer) + norms
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full_expert = 3 * m.n_experts * self.d_model * m.d_ff_expert
        active_expert = 3 * m.top_k * self.d_model * m.d_ff_expert
        n_moe_layers = self.n_layers - self.n_dense_head_layers
        return self.param_count() - n_moe_layers * (full_expert - active_expert)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_gqa(rng, cfg: LMConfig) -> dict:
    ks = jax.random.split(rng, 4)
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "w_q": layers.dense_init(ks[0], d, h * hd),
        "w_k": layers.dense_init(ks[1], d, hkv * hd),
        "w_v": layers.dense_init(ks[2], d, hkv * hd),
        "w_o": layers.dense_init(ks[3], h * hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _init_layer(rng, cfg: LMConfig, moe_layer: bool) -> dict:
    k_attn, k_mlp = jax.random.split(rng)
    d = cfg.d_model
    p = {"ln1": jnp.zeros((d,), jnp.float32), "ln2": jnp.zeros((d,), jnp.float32)}
    if cfg.post_norms:
        p["post_ln1"] = jnp.zeros((d,), jnp.float32)
        p["post_ln2"] = jnp.zeros((d,), jnp.float32)
    if cfg.mla is not None:
        p["attn"] = mla_mod.init(k_attn, cfg.mla, d, cfg.n_heads)
    else:
        p["attn"] = _init_gqa(k_attn, cfg)
    if moe_layer:
        p["mlp"] = moe_mod.init(k_mlp, cfg.moe, d)
    else:
        p["mlp"] = layers.mlp_init(k_mlp, d, cfg.dense_d_ff or cfg.d_ff)
    return p


def init(rng, cfg: LMConfig) -> dict:
    k_embed, k_head, k_scan, k_tail, k_lmh = jax.random.split(rng, 5)
    params = {"embed": layers.embed_init(k_embed, cfg.vocab, cfg.d_model),
              "final_norm": jnp.zeros((cfg.d_model,), jnp.float32)}
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(k_lmh, cfg.d_model, cfg.vocab)
    params["head"] = [
        _init_layer(k, cfg, moe_layer=False)
        for k in jax.random.split(k_head, max(cfg.n_dense_head_layers, 1))
    ][: cfg.n_dense_head_layers]

    def init_unit(rng):
        ks = jax.random.split(rng, len(cfg.pattern))
        return {
            f"l{j}": _init_layer(ks[j], cfg, moe_layer=cfg.moe is not None)
            for j in range(len(cfg.pattern))
        }

    if cfg.n_units > 0:
        params["scan"] = jax.vmap(init_unit)(
            jax.random.split(k_scan, cfg.n_units)
        )
    params["tail"] = [
        _init_layer(k, cfg, moe_layer=cfg.moe is not None)
        for k in jax.random.split(k_tail, max(len(cfg.tail_kinds), 1))
    ][: len(cfg.tail_kinds)]
    return params


# --------------------------------------------------------------------------
# layer application
# --------------------------------------------------------------------------

def _norm(x, w, cfg):
    return layers.rms_norm(x, w, unit_offset=True)


def _gqa_project(lp, x, cfg: LMConfig, positions, base):
    b, l, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ lp["w_q"].astype(dt)).reshape(b, l, h, hd).transpose(0, 2, 1, 3)
    k = (x @ lp["w_k"].astype(dt)).reshape(b, l, hkv, hd).transpose(0, 2, 1, 3)
    v = (x @ lp["w_v"].astype(dt)).reshape(b, l, hkv, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = layers.rms_norm(q, lp["q_norm"], unit_offset=True)
        k = layers.rms_norm(k, lp["k_norm"], unit_offset=True)
    q = layers.apply_rope(q, positions, base)
    k = layers.apply_rope(k, positions, base)
    if cfg.kv_repeat > 1:
        k = jnp.repeat(k, cfg.kv_repeat, axis=1)
        v = jnp.repeat(v, cfg.kv_repeat, axis=1)
    return q, k, v


def _rope_base_for(cfg: LMConfig, kind: str) -> float:
    if kind == "local" and cfg.rope_base_local is not None:
        return cfg.rope_base_local
    return cfg.rope_base


def _attn_sublayer_train(lp, x, cfg: LMConfig, kind: str, positions, backend):
    window = cfg.window if kind == "local" else None
    if cfg.mla is not None:
        o, _ = mla_mod.apply(
            lp["attn"], x, cfg.mla, cfg.n_heads, positions,
            _rope_base_for(cfg, kind), backend=backend,
        )
        return o
    q, k, v = _gqa_project(lp["attn"], x, cfg, positions,
                           _rope_base_for(cfg, kind))
    o = attn.attention(
        q, k, v, scale=cfg.attn_scale, causal=True, window=window,
        softcap=cfg.attn_softcap, backend=backend,
    )
    b, h, l, hd = o.shape
    o = o.transpose(0, 2, 1, 3).reshape(b, l, h * hd)
    return o @ lp["attn"]["w_o"].astype(x.dtype)


def _layer_train(lp, x, cfg: LMConfig, kind: str, positions, backend):
    a = _attn_sublayer_train(lp, _norm(x, lp["ln1"], cfg), cfg, kind,
                             positions, backend)
    if cfg.post_norms:
        a = _norm(a, lp["post_ln1"], cfg)
    x = x + a
    h_in = _norm(x, lp["ln2"], cfg)
    if cfg.moe is not None and "router" in lp["mlp"]:
        b, l, d = h_in.shape
        m, aux = moe_mod.apply(lp["mlp"], h_in.reshape(b * l, d), cfg.moe)
        m = m.reshape(b, l, d)
    else:
        m = layers.mlp_apply(lp["mlp"], h_in, activation=cfg.activation)
        aux = jnp.zeros((), jnp.float32)
    if cfg.post_norms:
        m = _norm(m, lp["post_ln2"], cfg)
    return x + m, aux


# --------------------------------------------------------------------------
# training / scoring forward
# --------------------------------------------------------------------------

def _embed(params, tokens, cfg: LMConfig):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    return _constrain_batch(x)


def _unembed(params, x, cfg: LMConfig):
    x = _constrain_batch(x)
    x = layers.rms_norm(x, params["final_norm"], unit_offset=True)
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def forward(params, tokens, cfg: LMConfig, backend: str = "xla"):
    """Full-sequence forward.  tokens [B, L] → logits [B, L, V] f32,
    plus summed MoE aux loss."""
    b, l = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(l), (b, l))
    x = _embed(params, tokens, cfg)
    aux_total = jnp.zeros((), jnp.float32)

    for i, lp in enumerate(params["head"]):
        x, aux = _layer_train(lp, x, cfg, cfg.pattern[0], positions, backend)
        aux_total += aux

    if cfg.n_units > 0:
        def unit_body(x, unit_params):
            aux_sum = jnp.zeros((), jnp.float32)
            for j, kind in enumerate(cfg.pattern):
                x, aux = _layer_train(
                    unit_params[f"l{j}"], x, cfg, kind, positions, backend
                )
                aux_sum += aux
            return x, aux_sum

        body = jax.checkpoint(unit_body) if cfg.remat else unit_body
        x, auxs = jax.lax.scan(body, x, params["scan"], unroll=_scan_unroll())
        aux_total += auxs.sum()

    for j, kind in enumerate(cfg.tail_kinds):
        x, aux = _layer_train(params["tail"][j], x, cfg, kind, positions,
                              backend)
        aux_total += aux

    return _unembed(params, x, cfg), aux_total


def lm_loss(params, tokens, targets, cfg: LMConfig, backend: str = "xla"):
    """Next-token cross entropy (mean over tokens) + MoE aux.

    The gold-logit pick uses a broadcast-compare mask instead of
    take_along_axis: a gather along the vocab dim would force the SPMD
    partitioner to all-gather the (huge, vocab-sharded) logits, while
    the masked sum partitions shard-locally.
    """
    logits, aux = forward(params, tokens, cfg, backend)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(
        jnp.where(vocab_iota == targets[..., None], logits, 0.0), axis=-1
    )
    return (logz - gold).mean() + aux


# --------------------------------------------------------------------------
# KV-cache serving: prefill + decode
# --------------------------------------------------------------------------

def _cache_len(cfg: LMConfig, kind: str, max_len: int) -> int:
    if kind == "local" and cfg.window is not None:
        return min(cfg.window, max_len)
    return max_len


def _ring_slot_positions(n_slots: int, length) -> jnp.ndarray:
    """Absolute position held by each ring slot given current fill
    ``length`` ([B] or scalar): largest p < length with p ≡ slot (mod W).
    Slots never written have negative p."""
    s = jnp.arange(n_slots)
    length = jnp.asarray(length)
    lm1 = length[..., None] - 1  # [B?,1]
    return s + n_slots * jnp.floor_divide(lm1 - s, n_slots)


def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    """Cache pytree matching the params tree structure."""
    dtype = dtype or cfg.compute_dtype

    def one(kind: str):
        s = _cache_len(cfg, kind, max_len)
        if cfg.mla is not None:
            m = cfg.mla
            return {
                "c_kv": jnp.zeros((batch, s, m.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((batch, 1, s, m.rope_head_dim), dtype),
            }
        return {
            "k": jnp.zeros((batch, cfg.n_kv_eff, s, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, cfg.n_kv_eff, s, cfg.head_dim), dtype),
        }

    caches = {
        "head": [one(cfg.pattern[0]) for _ in range(cfg.n_dense_head_layers)],
        "tail": [one(k) for k in cfg.tail_kinds],
    }
    if cfg.n_units > 0:
        unit = {f"l{j}": one(k) for j, k in enumerate(cfg.pattern)}
        caches["scan"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_units,) + a.shape), unit
        )
    return caches


def _fill_cache_from_seq(k_seq, n_slots: int, length: int):
    """Write the last n_slots entries of k_seq [B, H, L, D] into ring
    order (slot = p mod n_slots)."""
    l = k_seq.shape[2]
    p = _ring_slot_positions(n_slots, length)  # [n_slots]
    p = jnp.clip(p, 0, l - 1).astype(jnp.int32)
    return jnp.take(k_seq, p, axis=2)


def _layer_prefill(lp, x, cfg: LMConfig, kind: str, positions, max_len,
                   backend):
    """Like _layer_train but also returns this layer's filled cache."""
    b, l, _ = x.shape
    n_slots = _cache_len(cfg, kind, max_len)
    xin = _norm(x, lp["ln1"], cfg)
    base = _rope_base_for(cfg, kind)
    window = cfg.window if kind == "local" else None
    if cfg.mla is not None:
        o, (c_kv, k_rope) = mla_mod.apply(
            lp["attn"], xin, cfg.mla, cfg.n_heads, positions, base, backend=backend
        )
        pad = n_slots - l
        if pad >= 0:
            cache = {
                "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
                "k_rope": jnp.pad(k_rope, ((0, 0), (0, 0), (0, pad), (0, 0))),
            }
        else:
            cache = {
                "c_kv": _fill_cache_from_seq(
                    c_kv[:, None], n_slots, l
                )[:, 0],
                "k_rope": _fill_cache_from_seq(k_rope, n_slots, l),
            }
        a = o
    else:
        q, k, v = _gqa_project(lp["attn"], xin, cfg, positions, base)
        o = attn.attention(
            q, k, v, scale=cfg.attn_scale, causal=True, window=window,
            softcap=cfg.attn_softcap, backend=backend,
        )
        o = o.transpose(0, 2, 1, 3).reshape(b, l, -1)
        a = o @ lp["attn"]["w_o"].astype(x.dtype)
        if n_slots >= l:
            pad = n_slots - l
            cache = {
                "k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))),
                "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))),
            }
        else:
            cache = {
                "k": _fill_cache_from_seq(k, n_slots, l),
                "v": _fill_cache_from_seq(v, n_slots, l),
            }
    if cfg.post_norms:
        a = _norm(a, lp["post_ln1"], cfg)
    x = x + a
    h_in = _norm(x, lp["ln2"], cfg)
    if cfg.moe is not None and "router" in lp["mlp"]:
        m, _ = moe_mod.apply(lp["mlp"], h_in.reshape(b * l, -1), cfg.moe)
        m = m.reshape(b, l, -1)
    else:
        m = layers.mlp_apply(lp["mlp"], h_in, activation=cfg.activation)
    if cfg.post_norms:
        m = _norm(m, lp["post_ln2"], cfg)
    return x + m, cache


def prefill(params, tokens, cfg: LMConfig, max_len: int,
            backend: str = "xla"):
    """Process the prompt; returns (logits [B, L, V], caches, lengths)."""
    b, l = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(l), (b, l))
    x = _embed(params, tokens, cfg)
    caches = {"head": [], "tail": []}

    for lp in params["head"]:
        x, c = _layer_prefill(lp, x, cfg, cfg.pattern[0], positions, max_len,
                              backend)
        caches["head"].append(c)

    if cfg.n_units > 0:
        def unit_body(x, unit_params):
            cs = {}
            for j, kind in enumerate(cfg.pattern):
                x, c = _layer_prefill(
                    unit_params[f"l{j}"], x, cfg, kind, positions, max_len,
                    backend,
                )
                cs[f"l{j}"] = c
            return x, cs

        body = jax.checkpoint(unit_body) if cfg.remat else unit_body
        x, scan_caches = jax.lax.scan(body, x, params["scan"],
                                      unroll=_scan_unroll())
        caches["scan"] = scan_caches

    for j, kind in enumerate(cfg.tail_kinds):
        x, c = _layer_prefill(params["tail"][j], x, cfg, kind, positions,
                              max_len, backend)
        caches["tail"].append(c)

    logits = _unembed(params, x, cfg)
    lengths = jnp.full((b,), l, jnp.int32)
    return logits, caches, lengths


def _layer_decode(lp, x, cache, cfg: LMConfig, kind: str, lengths, backend):
    """One decoded token through one layer; returns (x, new_cache)."""
    b = x.shape[0]
    xin = _norm(x, lp["ln1"], cfg)
    base = _rope_base_for(cfg, kind)
    positions = (lengths - 1)[:, None]  # [B, 1]
    if cfg.mla is not None:
        a, (c_kv, k_rope) = mla_mod.decode_absorbed(
            lp["attn"], xin, cfg.mla, cfg.n_heads, cache["c_kv"], cache["k_rope"],
            lengths, positions, base,
        )
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
    else:
        q, k_new, v_new = _gqa_project(lp["attn"], xin, cfg, positions, base)
        n_slots = cache["k"].shape[2]
        slot = (lengths - 1) % n_slots  # [B]
        # scatter update (one slot per sequence): in-place-aliasable
        # under buffer donation, touching O(B·H·hd) bytes per step —
        # a one-hot multiply would read+rewrite the entire cache
        b_idx = jnp.arange(b)
        k_cache = cache["k"].at[b_idx, :, slot, :].set(
            k_new[:, :, 0, :].astype(cache["k"].dtype))
        v_cache = cache["v"].at[b_idx, :, slot, :].set(
            v_new[:, :, 0, :].astype(cache["v"].dtype))
        if kind == "local" and cfg.window is not None \
                and n_slots == min(cfg.window, n_slots):
            # ring cache: validity = slot holds a real position
            slot_pos = _ring_slot_positions(n_slots, lengths)  # [B, S]
            mask = (slot_pos >= 0) & (slot_pos < lengths[:, None])
            o = _masked_decode(q, k_cache, v_cache, mask, cfg)
        else:
            o = attn.decode_attention(
                q, k_cache, v_cache, lengths, scale=cfg.attn_scale,
                window=cfg.window if kind == "local" else None,
                softcap=cfg.attn_softcap,
            )
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, -1)
        a = o @ lp["attn"]["w_o"].astype(x.dtype)
        new_cache = {"k": k_cache, "v": v_cache}
    if cfg.post_norms:
        a = _norm(a, lp["post_ln1"], cfg)
    x = x + a
    h_in = _norm(x, lp["ln2"], cfg)
    if cfg.moe is not None and "router" in lp["mlp"]:
        m, _ = moe_mod.apply(lp["mlp"], h_in.reshape(b, -1), cfg.moe)
        m = m.reshape(b, 1, -1)
    else:
        m = layers.mlp_apply(lp["mlp"], h_in, activation=cfg.activation)
    if cfg.post_norms:
        m = _norm(m, lp["post_ln2"], cfg)
    return x + m, new_cache


def _masked_decode(q, k_cache, v_cache, mask, cfg: LMConfig):
    """Decode attention with an explicit slot-validity mask [B, S]."""
    return attn.masked_decode_attention(
        q, k_cache, v_cache, mask, scale=cfg.attn_scale,
        softcap=cfg.attn_softcap,
    )


def decode_step(params, caches, tokens, lengths, cfg: LMConfig,
                backend: str = "xla"):
    """One decode step.  tokens [B, 1] (the token just sampled), lengths
    [B] = cache fill INCLUDING this token.  Returns (logits [B, 1, V],
    new caches)."""
    x = _embed(params, tokens, cfg)

    new_head = []
    for i, lp in enumerate(params["head"]):
        x, c = _layer_decode(lp, x, caches["head"][i], cfg, cfg.pattern[0],
                             lengths, backend)
        new_head.append(c)

    new_scan = None
    if cfg.n_units > 0:
        def unit_body(x, xs):
            unit_params, unit_caches = xs
            ncs = {}
            for j, kind in enumerate(cfg.pattern):
                x, c = _layer_decode(
                    unit_params[f"l{j}"], x, unit_caches[f"l{j}"], cfg, kind,
                    lengths, backend,
                )
                ncs[f"l{j}"] = c
            return x, ncs

        x, new_scan = jax.lax.scan(
            unit_body, x, (params["scan"], caches["scan"]),
            unroll=_scan_unroll(),
        )

    new_tail = []
    for j, kind in enumerate(cfg.tail_kinds):
        x, c = _layer_decode(params["tail"][j], x, caches["tail"][j], cfg,
                             kind, lengths, backend)
        new_tail.append(c)

    logits = _unembed(params, x, cfg)
    new_caches = {"head": new_head, "tail": new_tail}
    if new_scan is not None:
        new_caches["scan"] = new_scan
    return logits, new_caches
