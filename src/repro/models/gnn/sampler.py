"""Neighbor sampler for minibatch GNN training (GraphSAGE-style fanout).

Deterministic given (seed, step): sampling is part of the data pipeline
substrate, so restart-replay reproduces the exact same subgraphs
(checkpoint/restart invariant — see runtime/fault.py).

Output is a *padded, static-shape* subgraph so the jitted train step
never recompiles: exactly ``batch_nodes · (1 + f1 + f1·f2)`` node slots
and ``batch_nodes · (f1 + f1·f2)`` edge slots, with masks.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SampledSubgraph:
    node_ids: np.ndarray  # [n_slots] global ids (padded with 0)
    node_mask: np.ndarray  # [n_slots] bool
    senders: np.ndarray  # [e_slots] local indices
    receivers: np.ndarray  # [e_slots] local indices
    edge_mask: np.ndarray  # [e_slots] bool
    seed_mask: np.ndarray  # [n_slots] bool — loss restricted to seeds


class CSRGraph:
    """Compressed neighbor lists for sampling (host-side numpy)."""

    def __init__(self, n_nodes: int, senders: np.ndarray, receivers: np.ndarray):
        self.n_nodes = n_nodes
        order = np.argsort(receivers, kind="stable")
        self.src_sorted = senders[order].astype(np.int64)
        counts = np.bincount(receivers, minlength=n_nodes)
        self.ptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(counts, out=self.ptr[1:])

    def neighbors(self, node: int) -> np.ndarray:
        return self.src_sorted[self.ptr[node]: self.ptr[node + 1]]


def sample_subgraph(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanouts: tuple[int, ...],
    rng: np.random.Generator,
) -> SampledSubgraph:
    """Multi-hop uniform neighbor sampling with replacement-free caps."""
    batch = len(seeds)
    n_slots = batch
    e_slots = 0
    per_layer = [batch]
    for f in fanouts:
        per_layer.append(per_layer[-1] * f)
        n_slots += per_layer[-1]
        e_slots += per_layer[-1]

    node_ids = np.zeros(n_slots, np.int64)
    node_mask = np.zeros(n_slots, bool)
    senders = np.zeros(e_slots, np.int32)
    receivers = np.zeros(e_slots, np.int32)
    edge_mask = np.zeros(e_slots, bool)
    seed_mask = np.zeros(n_slots, bool)

    node_ids[:batch] = seeds
    node_mask[:batch] = True
    seed_mask[:batch] = True

    frontier_start, frontier_len = 0, batch
    node_cursor, edge_cursor = batch, 0
    for f in fanouts:
        layer_nodes = frontier_len * f
        for j in range(frontier_len):
            dst_local = frontier_start + j
            if not node_mask[dst_local]:
                node_cursor += f
                edge_cursor += f
                continue
            neigh = graph.neighbors(int(node_ids[dst_local]))
            if len(neigh) == 0:
                node_cursor += f
                edge_cursor += f
                continue
            take = rng.choice(neigh, size=f, replace=len(neigh) < f)
            sl_n = slice(node_cursor, node_cursor + f)
            sl_e = slice(edge_cursor, edge_cursor + f)
            node_ids[sl_n] = take
            node_mask[sl_n] = True
            senders[sl_e] = np.arange(node_cursor, node_cursor + f)
            receivers[sl_e] = dst_local
            edge_mask[sl_e] = True
            node_cursor += f
            edge_cursor += f
        frontier_start += frontier_len
        frontier_len = layer_nodes

    return SampledSubgraph(node_ids, node_mask, senders.astype(np.int32),
                           receivers.astype(np.int32), edge_mask, seed_mask)
