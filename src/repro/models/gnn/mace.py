"""MACE — higher-order E(3)-equivariant message passing (arXiv:2206.07697).

Implementation regime (kernel_taxonomy §GNN: irrep tensor-product family):
message passing is ``jax.ops.segment_sum`` over an edge index — the JAX
sparse substrate this framework builds instead of SpMM.

Structure kept from the paper:
- radial Bessel basis (n_rbf) with polynomial cutoff envelope,
- real spherical harmonics up to l_max = 2 (explicit formulas),
- A-basis: per-node, per-channel sums of R(r)·Y_lm(r̂)·(W h_j) over
  incoming edges (the order-1 ACE features),
- product basis of correlation order 3: symmetric contractions of the
  A-features; we generate the *invariant* contractions per order
  (Σ_m A_lm² is exactly rotation-invariant because the Wigner-D mixing
  within each l is orthogonal),
- per-layer residual update + linear readout, summed per graph.

Simplification vs. full MACE (recorded here for traceability): inter-layer
messages carry the scalar channel only — the full Clebsch-Gordan
recoupling of l>0 features across layers is replaced by the complete set
of degree-≤3 invariant products.  Consequence: the model is exactly
E(3)-*invariant* end-to-end (energies) with equivariant forces via
autodiff — the property tests rotate inputs and check both.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2
    correlation_order: int = 3
    n_rbf: int = 8
    d_feat: int = 64  # input node feature dim (species embedding or graph feats)
    r_cut: float = 5.0
    n_classes: int = 8  # node-level readout width (classification shapes)
    dtype: str = "float32"

    @property
    def n_sh(self) -> int:  # 1 + 3 + 5 for l_max=2
        return (self.l_max + 1) ** 2


# --------------------------------------------------------------------------
# geometric bases
# --------------------------------------------------------------------------

def bessel_rbf(r: jnp.ndarray, n_rbf: int, r_cut: float) -> jnp.ndarray:
    """sin(nπr/rc)/r Bessel basis with smooth polynomial cutoff."""
    r = jnp.maximum(r, 1e-9)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    x = r[..., None] / r_cut
    basis = jnp.sqrt(2.0 / r_cut) * jnp.sin(n * jnp.pi * x) / r[..., None]
    # polynomial cutoff envelope (p=6), zero at r_cut with smooth derivs
    p = 6.0
    env = (
        1.0
        - (p + 1) * (p + 2) / 2 * x ** p
        + p * (p + 2) * x ** (p + 1)
        - p * (p + 1) / 2 * x ** (p + 2)
    )
    env = jnp.where(x < 1.0, env, 0.0)
    return basis * env


def real_sph_harm(unit: jnp.ndarray, l_max: int) -> jnp.ndarray:
    """Real spherical harmonics Y_lm(r̂) for l ≤ 2, [E, (l_max+1)²].

    Constant factors follow the standard real-SH normalization; exact
    values only need to be consistent (they are absorbed by weights).
    """
    x, y, z = unit[..., 0], unit[..., 1], unit[..., 2]
    out = [jnp.ones_like(x) * 0.2820948]  # l=0
    if l_max >= 1:
        c1 = 0.4886025
        out += [c1 * y, c1 * z, c1 * x]
    if l_max >= 2:
        out += [
            1.0925484 * x * y,
            1.0925484 * y * z,
            0.3153916 * (3 * z * z - 1.0),
            1.0925484 * x * z,
            0.5462742 * (x * x - y * y),
        ]
    return jnp.stack(out, axis=-1)


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def init(rng, cfg: MACEConfig) -> dict:
    ks = jax.random.split(rng, 3 + cfg.n_layers)
    c = cfg.d_hidden
    params = {
        "embed": layers.dense_init(ks[0], cfg.d_feat, c),
        "node_head": layers.dense_init(ks[1], c, cfg.n_classes),
        "energy_head": layers.dense_init(ks[2], c, 1),
        "layers": [],
    }
    n_inv = 7  # invariant product features per channel (see _products)
    for i in range(cfg.n_layers):
        lk = jax.random.split(ks[3 + i], 4)
        params["layers"].append({
            "w_radial": layers.dense_init(lk[0], cfg.n_rbf, c),
            "w_neighbor": layers.dense_init(lk[1], c, c),
            "w_product": layers.dense_init(lk[2], n_inv * c, c),
            "w_self": layers.dense_init(lk[3], c, c),
            "norm": jnp.zeros((c,), jnp.float32),
        })
    return params


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _products(a: jnp.ndarray, cfg: MACEConfig) -> jnp.ndarray:
    """Invariant product basis up to correlation order 3.

    a: [N, C, n_sh] A-basis features.  Returns [N, C, 7]:
      order 1: A_00
      order 2: |A_1|², |A_2|², A_00²
      order 3: A_00·|A_1|², A_00·|A_2|², A_00³
    Each |A_l|² = Σ_m A_lm² is exactly rotation invariant.
    """
    a0 = a[..., 0]
    b1 = jnp.sum(jnp.square(a[..., 1:4]), axis=-1) if cfg.l_max >= 1 else a0 * 0
    b2 = jnp.sum(jnp.square(a[..., 4:9]), axis=-1) if cfg.l_max >= 2 else a0 * 0
    return jnp.stack(
        [a0, b1, b2, a0 * a0, a0 * b1, a0 * b2, a0 * a0 * a0], axis=-1
    )


def forward(
    params: dict,
    node_feats: jnp.ndarray,  # [N, d_feat]
    positions: jnp.ndarray,  # [N, 3]
    senders: jnp.ndarray,  # [E] int32
    receivers: jnp.ndarray,  # [E] int32
    cfg: MACEConfig,
    edge_mask: jnp.ndarray | None = None,  # [E] bool (padding)
    graph_ids: jnp.ndarray | None = None,  # [N] int32 for batched graphs
    n_graphs: int = 1,
):
    """Returns (node_logits [N, n_classes], energies [n_graphs])."""
    n = node_feats.shape[0]
    dt = jnp.dtype(cfg.dtype)
    h = (node_feats.astype(dt) @ params["embed"].astype(dt))

    r_vec = positions[receivers] - positions[senders]  # [E, 3]
    r_len = jnp.sqrt(jnp.sum(jnp.square(r_vec), axis=-1) + 1e-12)
    unit = r_vec / r_len[..., None]
    rbf = bessel_rbf(r_len, cfg.n_rbf, cfg.r_cut)  # [E, n_rbf]
    sh = real_sph_harm(unit, cfg.l_max)  # [E, n_sh]
    # Degenerate edges (r ≈ 0: self-loops / padding) are excluded — MACE
    # has no self-interaction term, and a zero-vector "direction" would
    # inject a non-covariant constant into the l=2, m=0 channel (it
    # does not co-rotate, silently breaking E(3) invariance).
    valid = (r_len > 1e-5).astype(rbf.dtype)
    if edge_mask is not None:
        valid = valid * edge_mask
    rbf = rbf * valid[:, None]

    for lp in params["layers"]:
        radial = rbf @ lp["w_radial"].astype(dt)  # [E, C]
        hj = (h @ lp["w_neighbor"].astype(dt))[senders]  # [E, C]
        # edge message: per-channel radial gate × neighbor state × Y_lm
        msg = (radial * hj)[:, :, None] * sh[:, None, :]  # [E, C, n_sh]
        a = jax.ops.segment_sum(msg, receivers, num_segments=n)  # [N, C, n_sh]
        b = _products(a, cfg)  # [N, C, 7]
        upd = b.reshape(n, -1) @ lp["w_product"].astype(dt)
        h = h + jax.nn.silu(
            layers.rms_norm(upd + h @ lp["w_self"].astype(dt), lp["norm"],
                            unit_offset=True)
        )

    node_logits = h @ params["node_head"].astype(dt)
    node_energy = (h @ params["energy_head"].astype(dt))[:, 0]
    if graph_ids is None:
        energies = jnp.sum(node_energy, keepdims=True)
    else:
        energies = jax.ops.segment_sum(node_energy, graph_ids,
                                       num_segments=n_graphs)
    return node_logits, energies


def energy_and_forces(params, node_feats, positions, senders, receivers,
                      cfg: MACEConfig, **kw):
    """Forces = -∂E/∂pos (exactly equivariant by construction)."""
    def e(pos):
        _, energies = forward(params, node_feats, pos, senders, receivers,
                              cfg, **kw)
        return jnp.sum(energies)

    energy, neg_forces = jax.value_and_grad(e)(positions)
    return energy, -neg_forces
