"""Mixture-of-Experts layer: dropless sort + grouped-GEMM formulation.

Routing: softmax router → top-k experts per token (optionally
renormalized, qwen3 style).  Dispatch: flatten (token, slot) pairs, sort
by expert id, run both expert matmuls as ``jax.lax.ragged_dot`` grouped
GEMMs over the expert-sorted rows, unsort, combine with gate weights.

Why this formulation (vs GShard capacity dispatch):
- static shapes: the sorted buffer is exactly T·k rows — no capacity
  one-hot [T, E, C] tensor (which at qwen3 scale would be ~300 MB/layer);
- dropless: no token overflow, so loss curves match the dense-equivalent;
- TPU-native: ragged_dot is the grouped-GEMM primitive MegaBlocks-style
  kernels implement; XLA lowers it onto the MXU directly.

Sharding: expert weights [E, D, F] are sharded on F over the model axis
(TP inside each expert); tokens ride the data axis.  The second
ragged_dot contracts F → SPMD inserts one reduce-scatter/all-reduce per
layer, same as a dense FFN.  Shared experts (deepseek) are plain MLPs.

Aux: load-balancing loss (Switch-style mean(prob)·mean(assignment)·E)
returned alongside so the trainer can weight it.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers

# ---------------------------------------------------------------------------
# distribution context: when set, the dispatch/compute core runs inside a
# shard_map that is MANUAL over the token (data) axes and AUTO over the
# rest (model/TP).  This pins the expert sort + bincount + grouped GEMMs
# to be shard-local — the SPMD partitioner otherwise has no way to know
# the sort need not be global.  Set by launch/steps.py around tracing.
# ---------------------------------------------------------------------------

_CTX = threading.local()

# Cost-exact surrogate (roofline only): XLA cost_analysis charges
# lax.ragged_dot as if every row visited every expert (measured (G+1)×
# the true 2·M·K·N — probed by benchmarks/roofline.py).  When set, the
# grouped GEMMs are replaced by one dense matmul against expert 0 —
# *identical true FLOP count* (each row × one expert), counted
# correctly.  Never set outside benchmarks/roofline.py; weight-READ
# bytes are undercounted by (E−1)·D·F per call under the surrogate
# (documented).
COST_EXACT_SURROGATE = False


@contextlib.contextmanager
def sharding_ctx(mesh, token_axes: tuple[str, ...]):
    prev = getattr(_CTX, "value", None)
    _CTX.value = (mesh, tuple(token_axes))
    try:
        yield
    finally:
        _CTX.value = prev


def _get_ctx():
    return getattr(_CTX, "value", None)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    norm_topk: bool = True
    router_dtype: str = "float32"
    aux_loss_weight: float = 0.001


def init(rng, cfg: MoEConfig, d_model: int) -> dict:
    ks = jax.random.split(rng, 5)
    e, f = cfg.n_experts, cfg.d_ff_expert
    scale = (1.0 / d_model) ** 0.5
    params = {
        "router": layers.dense_init(ks[0], d_model, e),
        "w_gate": jax.random.normal(ks[1], (e, d_model, f), jnp.float32) * scale,
        "w_up": jax.random.normal(ks[2], (e, d_model, f), jnp.float32) * scale,
        "w_down": jax.random.normal(ks[3], (e, f, d_model), jnp.float32)
        * (1.0 / f) ** 0.5,
    }
    if cfg.n_shared:
        params["shared"] = layers.mlp_init(
            ks[4], d_model, f * cfg.n_shared
        )
    return params


def _dispatch_compute(x, expert_idx, gate_vals, w_gate, w_up, w_down,
                      cfg: MoEConfig):
    """Shard-local dropless MoE core: sort → grouped GEMM → combine.

    x [T, D], expert_idx [T, k], gate_vals [T, k] — T is the *local*
    token count when running under shard_map.
    """
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    flat_expert = expert_idx.reshape(-1)  # [T*k]
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_token = flat_token[order]
    xs = jnp.take(x, sorted_token, axis=0)  # [T*k, D] gather

    group_sizes = jnp.bincount(flat_expert, length=e).astype(jnp.int32)

    dtype = x.dtype
    if COST_EXACT_SURROGATE:
        # flop-equivalent dense surrogate (see flag docstring)
        gate = xs @ w_gate[0].astype(dtype)
        up = xs @ w_up[0].astype(dtype)
        h = jax.nn.silu(gate) * up
        ys = h @ w_down[0].astype(dtype)
    else:
        gate = jax.lax.ragged_dot(xs, w_gate.astype(dtype), group_sizes)
        up = jax.lax.ragged_dot(xs, w_up.astype(dtype), group_sizes)
        h = jax.nn.silu(gate) * up  # [T*k, F]
        ys = jax.lax.ragged_dot(h, w_down.astype(dtype), group_sizes)

    gates_sorted = gate_vals.reshape(-1)[order].astype(ys.dtype)
    return jax.ops.segment_sum(
        ys * gates_sorted[:, None], sorted_token, num_segments=t
    ).astype(dtype)


def _ep_compute(x, expert_idx, gate_vals, w_gate, w_up, w_down,
                cfg: MoEConfig, ep_axis: str, capacity: int):
    """Expert-parallel core (runs manual over token axes AND ep_axis).

    Each ep shard owns E/n_ep experts (weights fully resident — no FSDP
    weight gathers, the measured collective bound of MoE training).
    Tokens are replicated over ep_axis by construction (activations are
    batch-sharded over 'data' only), so "dispatch" is a local masked
    gather of the ≤capacity rows routed to resident experts; a psum over
    ep_axis re-combines the top-k contributions.  Capacity-bounded:
    overflow tokens drop (GShard semantics) — exact vs. dropless when
    capacity is not exceeded (tested).

    x [T, D]; w_gate/w_up/w_down are the LOCAL expert slices [E_loc,...].
    """
    t, d = x.shape
    k = cfg.top_k
    e_loc = w_gate.shape[0]
    shard = jax.lax.axis_index(ep_axis)
    lo = shard * e_loc

    flat_expert = expert_idx.reshape(-1)  # [T*k]
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_gate = gate_vals.reshape(-1)
    local_e = flat_expert - lo
    mine = (local_e >= 0) & (local_e < e_loc)

    # stable capacity-bounded selection of my (token, slot) pairs:
    # sort by (not-mine, expert) so resident rows come first, grouped.
    order = jnp.argsort(jnp.where(mine, local_e, e_loc + 1), stable=True)
    sel = order[:capacity]
    sel_valid = jnp.take(mine, sel)
    sel_token = jnp.take(flat_token, sel)
    sel_e = jnp.clip(jnp.take(local_e, sel), 0, e_loc - 1)
    sel_gate = jnp.take(flat_gate, sel) * sel_valid.astype(flat_gate.dtype)

    xs = jnp.take(x, sel_token, axis=0)  # [C, D]
    group_sizes = jnp.bincount(
        jnp.where(sel_valid, sel_e, e_loc), length=e_loc + 1
    ).astype(jnp.int32)[:e_loc]
    # rows are sorted by sel_e with invalid rows at the tail; pad group
    # accounting: ragged_dot processes rows per group — tail rows fall
    # outside all groups and yield zeros.
    dtype = x.dtype
    gate = jax.lax.ragged_dot(xs, w_gate.astype(dtype), group_sizes)
    up = jax.lax.ragged_dot(xs, w_up.astype(dtype), group_sizes)
    h = jax.nn.silu(gate) * up
    ys = jax.lax.ragged_dot(h, w_down.astype(dtype), group_sizes)

    out = jax.ops.segment_sum(
        ys * sel_gate[:, None].astype(ys.dtype), sel_token, num_segments=t
    )
    return jax.lax.psum(out, ep_axis).astype(dtype)


def apply_expert_parallel(params: dict, x: jnp.ndarray, cfg: MoEConfig,
                          mesh, token_axes: tuple[str, ...],
                          ep_axis: str = "model",
                          capacity_factor: float = 2.0):
    """Expert-parallel MoE layer (beyond-paper §Perf variant).

    Routing is computed under plain SPMD (cheap); the expert compute
    runs in a shard_map manual over token_axes + ep_axis with expert
    weights sharded on dim 0 over ep_axis.
    """
    e, k = cfg.n_experts, cfg.top_k
    router_logits = (
        x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    if cfg.norm_topk:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    n_ep = mesh.shape[ep_axis]
    dpn = 1
    for a in token_axes:
        dpn *= mesh.shape[a]
    t_local = x.shape[0] // max(dpn, 1)
    capacity = max(int(t_local * k / n_ep * capacity_factor), 8)

    core = jax.shard_map(
        lambda xx, ei, gv, wg, wu, wd: _ep_compute(
            xx, ei, gv, wg, wu, wd, cfg, ep_axis, capacity
        ),
        mesh=mesh,
        in_specs=(P(token_axes), P(token_axes), P(token_axes),
                  P(ep_axis), P(ep_axis), P(ep_axis)),
        out_specs=P(token_axes),
        axis_names=set(token_axes) | {ep_axis},
        check_vma=False,
    )
    out = core(x, expert_idx, gate_vals,
               params["w_gate"], params["w_up"], params["w_down"])

    if cfg.n_shared:
        out = out + layers.mlp_apply(params["shared"], x)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx, e, dtype=jnp.float32).sum(axis=1), axis=0
    ) / k
    aux = cfg.aux_loss_weight * e * jnp.sum(me * ce)
    return out, aux


def apply(params: dict, x: jnp.ndarray, cfg: MoEConfig):
    """x: [T, D] (already flattened). Returns (out [T, D], aux_loss)."""
    e, k = cfg.n_experts, cfg.top_k

    router_logits = (
        x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    )  # [T, E]
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # [T, k]
    if cfg.norm_topk:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    ctx = _get_ctx()
    if ctx is None:
        out = _dispatch_compute(
            x, expert_idx, gate_vals,
            params["w_gate"], params["w_up"], params["w_down"], cfg,
        )
    else:
        mesh, token_axes = ctx
        core = jax.shard_map(
            lambda xx, ei, gv, wg, wu, wd: _dispatch_compute(
                xx, ei, gv, wg, wu, wd, cfg
            ),
            mesh=mesh,
            in_specs=(P(token_axes), P(token_axes), P(token_axes),
                      P(), P(), P()),
            out_specs=P(token_axes),
            axis_names=set(token_axes),
            check_vma=False,
        )
        out = core(x, expert_idx, gate_vals,
                   params["w_gate"], params["w_up"], params["w_down"])

    if cfg.n_shared:
        out = out + layers.mlp_apply(params["shared"], x)

    # Switch-style load balance loss.
    me = jnp.mean(probs, axis=0)  # [E] mean router prob
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx, e, dtype=jnp.float32).sum(axis=1), axis=0
    ) / k  # [E] fraction routed
    aux = cfg.aux_loss_weight * e * jnp.sum(me * ce)
    return out, aux
