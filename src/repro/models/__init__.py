"""Model zoo: the generation plane (5 LM architectures) + the assigned
GNN and recsys families.  Pure-pytree functional style: each model module
exposes ``init(rng, cfg) -> params`` and step functions over plain dicts,
so pjit sharding specs can be written directly against the tree.
"""
