"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV compression: x → c_kv (kv_lora_rank) + a decoupled shared RoPE key
(rope_dim).  The cache stores only [c_kv ; k_rope] — (512+64) floats per
token instead of 2·H·128 = 4096 — the paper's 93 % KV-cache reduction.

Two execution paths:
- ``apply`` (train/prefill): up-project c_kv to per-head K/V and run
  ordinary attention (clearer, and the one-off up-projection amortizes
  over the whole sequence).
- ``decode_absorbed``: the production decode path.  The up-projection
  matrices are *absorbed* into the query/output projections
  (q_nope·W_uk → query in latent space; attn·W_uv → output), so each
  step reads only the compressed cache and never materializes per-head
  K/V — this is what makes MLA decode memory-bound on the small cache
  instead of the expanded one.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    q_lora_rank: int | None = None  # V2-Lite: queries uncompressed


def init(rng, cfg: MLAConfig, d_model: int, n_heads: int) -> dict:
    ks = jax.random.split(rng, 6)
    qdim = cfg.nope_head_dim + cfg.rope_head_dim
    return {
        "w_q": layers.dense_init(ks[0], d_model, n_heads * qdim),
        "w_dkv": layers.dense_init(ks[1], d_model, cfg.kv_lora_rank),
        "w_kr": layers.dense_init(ks[2], d_model, cfg.rope_head_dim),
        "kv_norm": jnp.zeros((cfg.kv_lora_rank,), jnp.float32),
        "w_uk": layers.dense_init(
            ks[3], cfg.kv_lora_rank, n_heads * cfg.nope_head_dim
        ),
        "w_uv": layers.dense_init(
            ks[4], cfg.kv_lora_rank, n_heads * cfg.v_head_dim
        ),
        "w_o": layers.dense_init(ks[5], n_heads * cfg.v_head_dim, d_model),
    }


def _project_q(params, x, cfg: MLAConfig, n_heads: int, positions, rope_base):
    b, l, _ = x.shape
    qdim = cfg.nope_head_dim + cfg.rope_head_dim
    q = (x @ params["w_q"].astype(x.dtype)).reshape(b, l, n_heads, qdim)
    q = q.transpose(0, 2, 1, 3)  # [B, H, L, qdim]
    q_nope = q[..., : cfg.nope_head_dim]
    q_rope = layers.apply_rope(
        q[..., cfg.nope_head_dim:], positions, rope_base
    )
    return q_nope, q_rope


def compress_kv(params, x, cfg: MLAConfig, positions, rope_base):
    """x → (c_kv [B, L, R] normalized, k_rope [B, 1, L, rope_dim])."""
    c_kv = x @ params["w_dkv"].astype(x.dtype)
    c_kv = layers.rms_norm(c_kv, params["kv_norm"].astype(jnp.float32) + 1.0)
    k_rope = (x @ params["w_kr"].astype(x.dtype))[:, None]  # 1 shared head
    k_rope = layers.apply_rope(k_rope, positions, rope_base)
    return c_kv, k_rope


def apply(
    params, x, cfg: MLAConfig, n_heads: int, positions, rope_base: float,
    backend: str = "xla",
):
    """Train/prefill path.  Returns (out [B, L, D], (c_kv, k_rope))."""
    b, l, _ = x.shape
    q_nope, q_rope = _project_q(params, x, cfg, n_heads, positions, rope_base)
    c_kv, k_rope = compress_kv(params, x, cfg, positions, rope_base)

    k_nope = (c_kv @ params["w_uk"].astype(x.dtype)).reshape(
        b, l, n_heads, cfg.nope_head_dim
    ).transpose(0, 2, 1, 3)
    v = (c_kv @ params["w_uv"].astype(x.dtype)).reshape(
        b, l, n_heads, cfg.v_head_dim
    ).transpose(0, 2, 1, 3)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, n_heads, l, cfg.rope_head_dim))],
        axis=-1,
    )
    scale = (cfg.nope_head_dim + cfg.rope_head_dim) ** -0.5
    o = attn.attention(q, k, v, scale=scale, causal=True, backend=backend)
    o = o.transpose(0, 2, 1, 3).reshape(b, l, n_heads * cfg.v_head_dim)
    return o @ params["w_o"].astype(x.dtype), (c_kv, k_rope)


def decode_absorbed(
    params, x, cfg: MLAConfig, n_heads: int,
    c_kv_cache: jnp.ndarray,  # [B, S, R]
    k_rope_cache: jnp.ndarray,  # [B, 1, S, rope_dim]
    length,  # scalar/[B] current fill AFTER inserting this token
    positions,  # [B, 1] position of the new token
    rope_base: float,
):
    """Absorbed decode: one token, compressed-cache-resident attention.

    Returns (out [B, 1, D], (c_kv_cache, k_rope_cache) updated).
    """
    b = x.shape[0]
    r = cfg.kv_lora_rank
    q_nope, q_rope = _project_q(params, x, cfg, n_heads, positions, rope_base)

    # insert new compressed kv at position length-1 (scatter: in-place-
    # aliasable under donation, touches one slot per sequence)
    c_new, kr_new = compress_kv(params, x, cfg, positions, rope_base)
    if isinstance(length, int):
        length = jnp.full((b,), length, jnp.int32)
    idx = length - 1  # [B]
    s_max = c_kv_cache.shape[1]
    b_idx = jnp.arange(b)
    c_kv_cache = c_kv_cache.at[b_idx, idx, :].set(
        c_new[:, 0, :].astype(c_kv_cache.dtype))
    k_rope_cache = k_rope_cache.at[b_idx, :, idx, :].set(
        kr_new[:, :, 0, :].astype(k_rope_cache.dtype))

    # absorb W_uk into the query:  q_c[b,h,r] = q_nope[b,h,d] · W_uk[r, h*d]
    w_uk = params["w_uk"].astype(x.dtype).reshape(r, n_heads, cfg.nope_head_dim)
    q_c = jnp.einsum("bhqd,rhd->bhqr", q_nope, w_uk)  # [B, H, 1, R]

    scale = (cfg.nope_head_dim + cfg.rope_head_dim) ** -0.5
    s_c = jnp.einsum("bhqr,bsr->bhqs", q_c, c_kv_cache,
                     preferred_element_type=jnp.float32)
    s_r = jnp.einsum("bhqd,bosd->bhqs", q_rope, k_rope_cache,
                     preferred_element_type=jnp.float32)
    s = (s_c + s_r) * scale  # [B, H, 1, S]
    mask = jnp.arange(s_max)[None, :] < length[:, None]
    s = jnp.where(mask[:, None, None, :], s, attn.MASK_VALUE)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * mask[:, None, None, :]
    l = jnp.sum(p, axis=-1, keepdims=True)
    attn_c = jnp.einsum("bhqs,bsr->bhqr", p.astype(c_kv_cache.dtype),
                        c_kv_cache, preferred_element_type=jnp.float32)
    attn_c = attn_c / jnp.where(l == 0.0, 1.0, l)  # [B, H, 1, R]

    # absorb W_uv into the output projection
    w_uv = params["w_uv"].astype(x.dtype).reshape(r, n_heads, cfg.v_head_dim)
    o = jnp.einsum("bhqr,rhd->bhqd", attn_c.astype(x.dtype), w_uv)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, n_heads * cfg.v_head_dim)
    return o @ params["w_o"].astype(x.dtype), (c_kv_cache, k_rope_cache)
