"""Micro-batching request scheduler: the concurrent front door.

Callers ``submit(text, k)`` and get a ``Future`` back immediately; a
single flusher thread drains the bounded admission queue, coalescing
requests into one scoring dispatch per flush.  A flush closes when
either ``max_batch`` requests have accumulated or ``flush_deadline``
seconds have passed since the first request of the window — the classic
throughput/latency knob pair (cf. Shen et al., arXiv 2412.11854: batch
formation dominates end-to-end RAG serving latency).  The engine's
power-of-two shape buckets mean a flush of 9 scores in the same jit
bucket as 16, so ``max_batch`` should be a bucket boundary.

Design points:

- **Bounded admission, explicit rejection.**  The queue has a hard
  capacity; when it is full, ``submit`` raises ``RequestRejected``
  instead of growing without bound.  Callers see backpressure as an
  exception at the door, never as silent unbounded latency.
- **Generation-consistent flushes.**  Each flush pins the *current*
  snapshot once and serves every request in the flush from it, so one
  batch never straddles a container publication (torn reads are
  structurally impossible — see serving/snapshot.py).
- **Duplicate coalescing.**  Requests in one flush that normalize to
  the same (query, k) are scored once and fanned out to all futures.
- **Result-cache compose.**  On submit, a hit in the serving-tier
  result cache (keyed with the current generation) resolves the future
  immediately — the request never enters the queue.  Flush results are
  inserted back under the generation that served them.
- **One scoring thread.**  Scoring stays single-threaded (the flusher),
  so the jit dispatch path needs no locking; concurrency lives at the
  queue, and readers scale by batching, not by fighting for the device.

The future resolves to a ``ServedResult`` carrying the results *and*
the generation that served them, so callers (and the stress tests) can
audit exactly which corpus state answered.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field

from repro.core.engine import RetrievalResult
from repro.core.tokenizer import normalize
from repro.obs import trace

from repro.serving.cache import ResultCache
from repro.serving.metrics import ServingMetrics


class RequestRejected(RuntimeError):
    """Admission queue full — explicit backpressure to the caller."""


@dataclass
class ServedResult:
    """What a resolved future holds."""

    results: list[RetrievalResult]
    generation: int
    cached: bool = False


@dataclass
class _Pending:
    text: str
    k: int
    future: Future = field(default_factory=Future)
    t_submit: float = field(default_factory=time.perf_counter)
    # observability: nonzero when this request was sampled for tracing
    # (id allocated on the submitting thread, stage spans recorded
    # against it by the flusher); t_dequeue splits queue wait from
    # flush wait
    trace_id: int = 0
    t_dequeue: float = 0.0


_STOP = object()


class MicroBatchScheduler:
    """See module docstring.  ``source`` is anything with a ``current``
    attribute yielding a snapshot that has ``generation`` and
    ``query_batch(texts, k)`` — in practice a
    ``serving.snapshot.SnapshotManager``."""

    def __init__(
        self,
        source,
        *,
        max_batch: int = 16,
        flush_deadline: float = 0.002,
        max_queue: int = 1024,
        cache: ResultCache | None = None,
        metrics: ServingMetrics | None = None,
        retrace_guard=None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.source = source
        self.max_batch = max_batch
        self.flush_deadline = flush_deadline
        self.cache = cache
        self.metrics = metrics or ServingMetrics()
        # opt-in sanitizers.RetraceGuard: checked after every flush so a
        # steady-state recompile surfaces on the batch that caused it
        self.retrace_guard = retrace_guard
        self._queue: queue.Queue = queue.Queue(maxsize=max_queue)
        self._thread: threading.Thread | None = None
        self._stopping = threading.Event()

    # ---- lifecycle ------------------------------------------------------

    def start(self) -> "MicroBatchScheduler":
        if self._thread is not None:
            return self
        self._stopping.clear()
        self._thread = threading.Thread(
            target=self._worker, name="microbatch-flusher", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        """Drain-free shutdown: in-flight flushes finish; anything still
        queued is rejected so no caller blocks forever."""
        self._stopping.set()
        if self._thread is not None:
            self._queue.put(_STOP)  # wake the flusher if it is blocked
            self._thread.join()
            self._thread = None
        self._drain_reject()

    def _drain_reject(self) -> None:
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                return
            if item is not _STOP and not item.future.done():
                item.future.set_exception(
                    RequestRejected("scheduler stopped")
                )
                self.metrics.on_reject()

    def __enter__(self) -> "MicroBatchScheduler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ---- submission -----------------------------------------------------

    def submit(self, text: str, k: int = 5) -> Future:
        """Enqueue one request; returns a Future[ServedResult].

        Raises ``RequestRejected`` when the admission queue is full or
        the scheduler is stopped (bounded memory, explicit backpressure).
        """
        t_submit = time.perf_counter()
        self.metrics.on_submit()
        tid = trace.begin_trace()  # 0 when tracing is off or unsampled
        if self._stopping.is_set():
            self.metrics.on_reject()
            raise RequestRejected("scheduler stopped")
        if self.cache is not None:
            snap = self.source.current
            hit = self.cache.get(text, k, snap.generation)
            if hit is not None:
                now = time.perf_counter()
                self.metrics.on_cache_hit(now - t_submit)
                if tid:
                    trace.record("request", t_submit, now - t_submit,
                                 trace=tid, k=k, cached=True,
                                 generation=snap.generation)
                fut: Future = Future()
                fut.set_result(
                    ServedResult(hit, snap.generation, cached=True)
                )
                return fut
            self.metrics.on_cache_miss()
        req = _Pending(text=text, k=k, t_submit=t_submit, trace_id=tid)
        try:
            self._queue.put_nowait(req)
        except queue.Full:
            self.metrics.on_reject()
            raise RequestRejected(
                f"admission queue full ({self._queue.maxsize} pending)"
            ) from None
        if self._stopping.is_set():
            # raced with stop(): its drain may already have run, leaving
            # this request in a dead queue — drain again so the future
            # is rejected, never silently stranded
            self._drain_reject()
            if req.future.done() and req.future.exception() is not None:
                raise RequestRejected("scheduler stopped") from None
        return req.future

    # ---- the flusher ----------------------------------------------------

    def _worker(self) -> None:
        while True:
            try:
                first = self._queue.get(timeout=0.1)
            except queue.Empty:
                if self._stopping.is_set():
                    return
                continue
            if first is _STOP:
                return
            first.t_dequeue = time.perf_counter()
            batch = [first]
            deadline = first.t_dequeue + self.flush_deadline
            while len(batch) < self.max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    item = self._queue.get(timeout=remaining)
                except queue.Empty:
                    break
                if item is _STOP:
                    self._flush(batch)
                    return
                item.t_dequeue = time.perf_counter()
                batch.append(item)
            self._flush(batch)

    def _flush(self, batch: list[_Pending]) -> None:
        # the flush-level span (and the engine/index spans nesting under
        # it on this thread) rides the trace of the request that OPENED
        # the flush window — so flush instrumentation is emitted for a
        # `sample` fraction of flushes, not whenever any request in the
        # batch happens to be sampled.  Per-request stage records are
        # independent of this: every sampled request gets its
        # decomposition even when its flush is not traced.
        flush_trace = batch[0].trace_id
        scored = 0
        # deferred span emission: stage timestamps are captured in the
        # fan-out loop, but SpanRecords are built only after every
        # future of the batch has resolved — tracing work overlaps the
        # next batch's accumulation window instead of delaying wakeups
        deferred: list[tuple] = []
        with trace.span("flush", trace=flush_trace,
                        batch=len(batch)) as fsp:
            try:
                with trace.span("snapshot_pin") as psp:
                    snap = self.source.current  # pinned once per flush
                    psp.set(generation=snap.generation)
                by_k: dict[int, list[_Pending]] = {}
                for req in batch:
                    by_k.setdefault(req.k, []).append(req)
                for k, group in by_k.items():
                    # duplicate coalescing: one scored column per
                    # canonical query text, fanned out to every
                    # requesting future
                    with trace.span("pack", k=k) as ksp:
                        order: dict[str, int] = {}
                        texts: list[str] = []
                        for req in group:
                            key = normalize(req.text)
                            if key not in order:
                                order[key] = len(texts)
                                texts.append(req.text)
                        ksp.set(unique=len(texts), requests=len(group))
                    t_score0 = time.perf_counter()
                    results = snap.query_batch(texts, k)
                    t_score1 = time.perf_counter()
                    scored += len(texts)
                    if self.retrace_guard is not None:
                        # raises SanitizerError on steady-state jit
                        # cache growth — checked before fan-out so the
                        # failure lands on the futures of the batch
                        # that caused it
                        self.retrace_guard.check("scheduler._flush")
                    for req in group:
                        res = results[order[normalize(req.text)]]
                        if self.cache is not None:
                            self.cache.put(
                                req.text, k, snap.generation, res)
                        t_done = time.perf_counter()
                        self.metrics.on_complete(t_done - req.t_submit)
                        req.future.set_result(
                            ServedResult(res, snap.generation)
                        )
                        if req.trace_id:
                            deferred.append(
                                (req, k, snap.generation,
                                 t_score0, t_score1, t_done, len(texts)))
            except Exception as exc:  # noqa: BLE001 — fail the batch, not the loop
                fsp.set(error=type(exc).__name__)
                for req in batch:
                    if not req.future.done():
                        self.metrics.on_fail()
                        req.future.set_exception(exc)
            finally:
                self.metrics.on_batch(len(batch), scored)
        for args in deferred:
            self._trace_request(*args)

    @staticmethod
    def _trace_request(req: _Pending, k: int, generation: int,
                       t_score0: float, t_score1: float, t_done: float,
                       batch_size: int) -> None:
        """Record the per-request stage decomposition.  The four stages
        tile [t_submit, t_done] exactly, so they sum to the end-to-end
        latency the histogram records (the acceptance invariant)."""
        rid = trace.alloc_id()  # the request root span's id
        trace.record_batch(req.trace_id, (
            ("queue_wait", req.t_submit,
             req.t_dequeue - req.t_submit, 0, rid, None),
            ("flush_wait", req.t_dequeue,
             t_score0 - req.t_dequeue, 0, rid, None),
            ("score", t_score0, t_score1 - t_score0, 0, rid,
             {"batch": batch_size}),
            ("merge", t_score1, t_done - t_score1, 0, rid, None),
            ("request", req.t_submit, t_done - req.t_submit, rid, 0,
             {"k": k, "generation": generation, "cached": False}),
        ))
