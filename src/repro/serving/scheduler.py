"""Micro-batching request scheduler: the concurrent front door.

Callers ``submit(text, k)`` and get a ``Future`` back immediately; a
single flusher thread drains the bounded admission queue, coalescing
requests into one scoring dispatch per flush.  A flush closes when
either ``max_batch`` requests have accumulated or ``flush_deadline``
seconds have passed since the first request of the window — the classic
throughput/latency knob pair (cf. Shen et al., arXiv 2412.11854: batch
formation dominates end-to-end RAG serving latency).  The engine's
power-of-two shape buckets mean a flush of 9 scores in the same jit
bucket as 16, so ``max_batch`` should be a bucket boundary.

Design points:

- **Bounded admission, explicit rejection.**  The queue has a hard
  capacity; when it is full, ``submit`` raises ``RequestRejected``
  instead of growing without bound.  Callers see backpressure as an
  exception at the door, never as silent unbounded latency.
- **Generation-consistent flushes.**  Each flush pins the *current*
  snapshot once per tenant group and serves every request of that
  group from it, so one batch never straddles a container publication
  (torn reads are structurally impossible — see serving/snapshot.py).
- **Duplicate coalescing.**  Requests in one flush that normalize to
  the same (tenant, query, k) are scored once and fanned out to all
  futures.
- **Result-cache compose.**  On submit, a hit in the serving-tier
  result cache (keyed with the current generation, in the tenant's
  keyspace) resolves the future immediately — the request never enters
  the queue.  Flush results are inserted back under the generation
  that served them.
- **One scoring thread.**  Scoring stays single-threaded (the flusher),
  so the jit dispatch path needs no locking; concurrency lives at the
  queue, and readers scale by batching, not by fighting for the device.

Tenancy (docs/ARCHITECTURE.md §13): constructed with a
``TenantRouter``, the scheduler becomes multi-tenant — ``submit``
takes a tenant id, admission additionally spends the tenant's
token-bucket quota (over-quota → ``RequestRejected`` carrying the
tenant, *before* the request can touch the shared queue or thrash the
container pool), and a flush groups requests by tenant, resolving each
group against that tenant's *pinned* mount (the pin is the
teardown barrier against pool eviction; it is held only for the
group's scoring, never across the whole batch).  A scoring failure in
one tenant's group fails only that group's futures.  Without a router
the scheduler is exactly the classic single-tenant front door — one
tenant group per flush, one snapshot pin, bit-identical results.

The future resolves to a ``ServedResult`` carrying the results *and*
the generation that served them, so callers (and the stress tests) can
audit exactly which corpus state answered.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field

from repro.core.engine import RetrievalResult
from repro.core.tokenizer import normalize
from repro.obs import trace
from repro.obs.explain import QueryPlan, finalize_plan

from repro.serving.cache import DEFAULT_KEYSPACE, ResultCache
from repro.serving.metrics import ServingMetrics

# the tenant the classic single-tenant path maps onto (== the result
# cache's default keyspace and tenancy.DEFAULT_TENANT)
DEFAULT_TENANT = DEFAULT_KEYSPACE


class RequestRejected(RuntimeError):
    """Admission refused — queue full, scheduler stopped, or tenant
    over quota — explicit backpressure to the caller.  ``tenant`` names
    the rejected tenant (None on the single-tenant path)."""

    def __init__(self, msg: str, tenant: str | None = None):
        super().__init__(msg)
        self.tenant = tenant


@dataclass
class ServedResult:
    """What a resolved future holds.  ``plan`` is the EXPLAIN record
    (obs/explain.py), available only when the request was submitted
    with ``explain=True`` — materialized lazily on first access
    (``plan_source`` holds the bound thunk), so resolving a future
    costs nothing on the traced-QPS budget when nobody reads the plan."""

    results: list[RetrievalResult]
    generation: int
    cached: bool = False
    plan_source: object = None   # zero-arg () -> QueryPlan, or None
    _plan: QueryPlan | None = field(default=None, repr=False,
                                    compare=False)

    @property
    def plan(self) -> QueryPlan | None:
        if self.plan_source is None:
            return None
        if self._plan is None:
            self._plan = self.plan_source()
        return self._plan


@dataclass
class _Pending:
    text: str
    k: int
    tenant: str = DEFAULT_TENANT
    future: Future = field(default_factory=Future)
    t_submit: float = field(default_factory=time.perf_counter)
    # observability: nonzero when this request was sampled for tracing
    # (id allocated on the submitting thread, stage spans recorded
    # against it by the flusher); t_dequeue splits queue wait from
    # flush wait
    trace_id: int = 0
    t_dequeue: float = 0.0
    explain: bool = False


_STOP = object()


def _hit_plan_thunk(text, k, generation, tenant, total_s):
    """Bind a result-cache-hit EXPLAIN plan into a zero-arg thunk for
    ``ServedResult.plan``'s lazy materialization."""
    def build():
        return QueryPlan(
            query=text, k=k, result_cache="hit",
            generation=generation, tenant=tenant, total_s=total_s,
            request_stages=(("cache_lookup", total_s),))
    return build


def _plan_thunk(qplans, idx, tenant, generation, result_cache,
                coalesced, t_submit, t_dequeue, t_score0, t_score1,
                t_done):
    """Bind one flushed request's EXPLAIN enrichment into a zero-arg
    thunk — by value, since the flush loop reuses its locals — for
    ``ServedResult.plan``'s lazy materialization.  The thunk pulls the
    engine plan out of the (itself lazy) ``PlanBatch`` and finalizes
    the per-request copy only when somebody reads the plan."""
    def build():
        return finalize_plan(
            qplans[idx],
            tenant=tenant,
            generation=generation,
            result_cache=result_cache,
            coalesced=coalesced,
            request_stages=(
                ("queue_wait", t_dequeue - t_submit),
                ("flush_wait", t_score0 - t_dequeue),
                ("score", t_score1 - t_score0),
                ("merge", t_done - t_score1),
            ),
            total_s=t_done - t_submit,
        )
    return build


class MicroBatchScheduler:
    """See module docstring.  ``source`` is anything with a ``current``
    attribute yielding a snapshot that has ``generation`` and
    ``query_batch(texts, k)`` — in practice a
    ``serving.snapshot.SnapshotManager``.  Alternatively pass
    ``router`` (a ``tenancy.TenantRouter``) for multi-tenant mode;
    exactly one of the two must be set."""

    def __init__(
        self,
        source=None,
        *,
        router=None,
        max_batch: int = 16,
        flush_deadline: float = 0.002,
        max_queue: int = 1024,
        cache: ResultCache | None = None,
        metrics: ServingMetrics | None = None,
        retrace_guard=None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if (source is None) == (router is None):
            raise ValueError(
                "pass exactly one of source= (single-tenant) or "
                "router= (multi-tenant)")
        self.source = source
        self.router = router
        self.max_batch = max_batch
        self.flush_deadline = flush_deadline
        self.cache = cache
        self.metrics = metrics or ServingMetrics()
        # opt-in sanitizers.RetraceGuard: checked after every flush so a
        # steady-state recompile surfaces on the batch that caused it
        self.retrace_guard = retrace_guard
        self._queue: queue.Queue = queue.Queue(maxsize=max_queue)
        self._thread: threading.Thread | None = None
        self._stopping = threading.Event()

    # ---- lifecycle ------------------------------------------------------

    def start(self) -> "MicroBatchScheduler":
        if self._thread is not None:
            return self
        self._stopping.clear()
        self._thread = threading.Thread(
            target=self._worker, name="microbatch-flusher", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        """Drain-free shutdown: in-flight flushes finish; anything still
        queued is rejected so no caller blocks forever."""
        self._stopping.set()
        if self._thread is not None:
            self._queue.put(_STOP)  # wake the flusher if it is blocked
            self._thread.join()
            self._thread = None
        self._drain_reject()

    def _drain_reject(self) -> None:
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                return
            if item is not _STOP and not item.future.done():
                item.future.set_exception(
                    RequestRejected("scheduler stopped",
                                    tenant=self._mt_tenant(item.tenant))
                )
                self.metrics.on_reject(self._mt_tenant(item.tenant))

    def _mt_tenant(self, tenant: str) -> str | None:
        """The tenant id for error/metrics attribution — None on the
        single-tenant path so its series/exceptions stay unlabeled
        (bit-identical to the pre-tenancy plane)."""
        return tenant if self.router is not None else None

    def __enter__(self) -> "MicroBatchScheduler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ---- submission -----------------------------------------------------

    def submit(self, text: str, k: int = 5,
               tenant: str | None = None, *,
               explain: bool = False) -> Future:
        """Enqueue one request; returns a Future[ServedResult].

        Raises ``RequestRejected`` when the admission queue is full,
        the scheduler is stopped, or (multi-tenant mode) the tenant is
        over its token-bucket quota (bounded memory, explicit
        backpressure).  ``explain=True`` attaches the per-query
        :class:`~repro.obs.explain.QueryPlan` to the resolved
        ``ServedResult.plan``.
        """
        t_submit = time.perf_counter()
        tenant = DEFAULT_TENANT if tenant is None else tenant
        mt_tenant = self._mt_tenant(tenant)
        if self.router is not None:
            self.router.validate(tenant)
        self.metrics.on_submit(mt_tenant)
        tid = trace.begin_trace()  # 0 when tracing is off or unsampled
        if self._stopping.is_set():
            self.metrics.on_reject(mt_tenant)
            raise RequestRejected("scheduler stopped", tenant=mt_tenant)
        if self.router is not None and not self.router.admit(tenant):
            # quota gate before the shared queue AND before any cache
            # or pool touch: rejected traffic cannot thrash the LRU
            self.metrics.on_reject(mt_tenant)
            raise RequestRejected(
                f"tenant {tenant!r} over admission quota", tenant=mt_tenant)
        if self.cache is not None:
            generation = self._probe_generation(tenant)
            if generation is not None:
                hit = self.cache.get(text, k, generation, keyspace=tenant)
                if hit is not None:
                    now = time.perf_counter()
                    self.metrics.on_cache_hit(now - t_submit, mt_tenant)
                    if tid:
                        trace.record("request", t_submit, now - t_submit,
                                     trace=tid, k=k, cached=True,
                                     generation=generation)
                    plan_source = None
                    if explain:
                        plan_source = _hit_plan_thunk(
                            text, k, generation, mt_tenant,
                            now - t_submit)
                    fut: Future = Future()
                    fut.set_result(
                        ServedResult(hit, generation, cached=True,
                                     plan_source=plan_source)
                    )
                    return fut
                self.metrics.on_cache_miss()
        req = _Pending(text=text, k=k, tenant=tenant,
                       t_submit=t_submit, trace_id=tid, explain=explain)
        try:
            self._queue.put_nowait(req)
        except queue.Full:
            self.metrics.on_reject(mt_tenant)
            raise RequestRejected(
                f"admission queue full ({self._queue.maxsize} pending)",
                tenant=mt_tenant,
            ) from None
        if self._stopping.is_set():
            # raced with stop(): its drain may already have run, leaving
            # this request in a dead queue — drain again so the future
            # is rejected, never silently stranded
            self._drain_reject()
            if req.future.done() and req.future.exception() is not None:
                raise RequestRejected("scheduler stopped",
                                      tenant=mt_tenant) from None
        return req.future

    def _probe_generation(self, tenant: str) -> int | None:
        """The generation a cache probe should key on: the pinned
        snapshot's (single-tenant) or the resident mount's (router
        mode; None when the tenant is cold — a cold tenant has no live
        generation to probe against, so the request goes to the flush,
        which mounts it)."""
        if self.router is None:
            return self.source.current.generation
        return self.router.peek_generation(tenant)

    # ---- the flusher ----------------------------------------------------

    def _worker(self) -> None:
        while True:
            try:
                first = self._queue.get(timeout=0.1)
            except queue.Empty:
                if self._stopping.is_set():
                    return
                continue
            if first is _STOP:
                return
            first.t_dequeue = time.perf_counter()
            batch = [first]
            deadline = first.t_dequeue + self.flush_deadline
            while len(batch) < self.max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    item = self._queue.get(timeout=remaining)
                except queue.Empty:
                    break
                if item is _STOP:
                    self._flush(batch)
                    return
                item.t_dequeue = time.perf_counter()
                batch.append(item)
            self._flush(batch)

    def _flush(self, batch: list[_Pending]) -> None:
        # the flush-level span (and the engine/index spans nesting under
        # it on this thread) rides the trace of the request that OPENED
        # the flush window — so flush instrumentation is emitted for a
        # `sample` fraction of flushes, not whenever any request in the
        # batch happens to be sampled.  Per-request stage records are
        # independent of this: every sampled request gets its
        # decomposition even when its flush is not traced.
        flush_trace = batch[0].trace_id
        scored = 0
        # deferred span emission: stage timestamps are captured in the
        # fan-out loop, but SpanRecords are built only after every
        # future of the batch has resolved — tracing work overlaps the
        # next batch's accumulation window instead of delaying wakeups
        deferred: list[tuple] = []
        with trace.span("flush", trace=flush_trace,
                        batch=len(batch)) as fsp:
            try:
                # per-tenant groups: one snapshot pin (and one pool pin,
                # in router mode) per group; the single-tenant path is
                # always exactly one group
                by_tenant: dict[str, list[_Pending]] = {}
                for req in batch:
                    by_tenant.setdefault(req.tenant, []).append(req)
                for tenant, group in by_tenant.items():
                    scored += self._flush_tenant(tenant, group, deferred)
            except Exception as exc:  # noqa: BLE001 — fail the batch, not the loop
                fsp.set(error=type(exc).__name__)
                for req in batch:
                    if not req.future.done():
                        self.metrics.on_fail()
                        req.future.set_exception(exc)
            finally:
                self.metrics.on_batch(len(batch), scored)
        for args in deferred:
            self._trace_request(*args)

    def _flush_tenant(self, tenant: str, group: list[_Pending],
                      deferred: list[tuple]) -> int:
        """Serve one tenant's group of the flush from one pinned
        snapshot; failures land on this group's futures only."""
        scored = 0
        mt_tenant = self._mt_tenant(tenant)
        pinned = False
        try:
            with trace.span("snapshot_pin") as psp:
                if self.router is not None:
                    # the pool pin: mounts the tenant if cold (the
                    # cold-start cost lands on this group's latency, by
                    # design) and bars eviction until the group is done
                    mount = self.router.pin(tenant)
                    pinned = True
                    snap = mount.snapshots.current
                    psp.set(generation=snap.generation, tenant=tenant)
                else:
                    snap = self.source.current  # pinned once per flush
                    psp.set(generation=snap.generation)
            by_k: dict[int, list[_Pending]] = {}
            for req in group:
                by_k.setdefault(req.k, []).append(req)
            for k, kgroup in by_k.items():
                # duplicate coalescing: one scored column per
                # canonical query text, fanned out to every
                # requesting future
                with trace.span("pack", k=k) as ksp:
                    order: dict[str, int] = {}
                    texts: list[str] = []
                    for req in kgroup:
                        key = normalize(req.text)
                        if key not in order:
                            order[key] = len(texts)
                            texts.append(req.text)
                    ksp.set(unique=len(texts), requests=len(kgroup))
                want_explain = any(r.explain for r in kgroup)
                t_score0 = time.perf_counter()
                if want_explain:
                    results, qplans = snap.query_batch(
                        texts, k, explain=True)
                else:
                    results = snap.query_batch(texts, k)
                    qplans = None
                t_score1 = time.perf_counter()
                scored += len(texts)
                if self.retrace_guard is not None:
                    # raises SanitizerError on steady-state jit
                    # cache growth — checked before fan-out so the
                    # failure lands on the futures of the batch
                    # that caused it
                    self.retrace_guard.check("scheduler._flush")
                if want_explain:
                    # coalesce fanout per scored column (how many
                    # requests each unique query serves)
                    fanout: dict[str, int] = {}
                    for req in kgroup:
                        key = normalize(req.text)
                        fanout[key] = fanout.get(key, 0) + 1
                for req in kgroup:
                    key = normalize(req.text)
                    res = results[order[key]]
                    if self.cache is not None:
                        self.cache.put(req.text, k, snap.generation,
                                       res, keyspace=tenant)
                    t_done = time.perf_counter()
                    self.metrics.on_complete(t_done - req.t_submit,
                                             mt_tenant)
                    plan_source = None
                    if req.explain and qplans is not None:
                        # enrich the engine plan with the scheduler
                        # view: the same timestamps _trace_request
                        # records, so EXPLAIN stage durations tile the
                        # span decomposition by construction
                        plan_source = _plan_thunk(
                            qplans, order[key], mt_tenant,
                            snap.generation,
                            ("miss" if self.cache is not None
                             else "bypass"),
                            fanout[key], req.t_submit, req.t_dequeue,
                            t_score0, t_score1, t_done)
                    req.future.set_result(
                        ServedResult(res, snap.generation,
                                     plan_source=plan_source)
                    )
                    if req.trace_id:
                        deferred.append(
                            (req, k, snap.generation,
                             t_score0, t_score1, t_done, len(texts),
                             mt_tenant))
        except Exception as exc:  # noqa: BLE001 — fail this tenant's group only
            for req in group:
                if not req.future.done():
                    self.metrics.on_fail()
                    req.future.set_exception(exc)
        finally:
            if pinned:
                self.router.unpin(tenant)
        return scored

    @staticmethod
    def _trace_request(req: _Pending, k: int, generation: int,
                       t_score0: float, t_score1: float, t_done: float,
                       batch_size: int, tenant: str | None = None) -> None:
        """Record the per-request stage decomposition.  The four stages
        tile [t_submit, t_done] exactly, so they sum to the end-to-end
        latency the histogram records (the acceptance invariant)."""
        rid = trace.alloc_id()  # the request root span's id
        request_args = {"k": k, "generation": generation, "cached": False}
        if tenant is not None:
            request_args["tenant"] = tenant
        trace.record_batch(req.trace_id, (
            ("queue_wait", req.t_submit,
             req.t_dequeue - req.t_submit, 0, rid, None),
            ("flush_wait", req.t_dequeue,
             t_score0 - req.t_dequeue, 0, rid, None),
            ("score", t_score0, t_score1 - t_score0, 0, rid,
             {"batch": batch_size}),
            ("merge", t_score1, t_done - t_score1, 0, rid, None),
            ("request", req.t_submit, t_done - req.t_submit, rid, 0,
             request_args),
        ))
