"""The concurrent serving runtime (docs/ARCHITECTURE.md §7).

Sits between callers and the batched ``QueryEngine``:

    callers ──submit()──▶ MicroBatchScheduler ──flush──▶ EngineSnapshot@g
                │   ▲           (scheduler.py)              (snapshot.py)
                │   └── Future[ServedResult]                      ▲
                │                                        publish() │ atomic swap
                ├── ResultCache (query, k, generation)   SnapshotManager
                │        (cache.py)                            ▲
                └── ServingMetrics (metrics.py)     sync()/add_text + refresh()
                                                     single writer thread

``ServingRuntime`` is the one-stop composition: construct it over a
``KnowledgeBase``, ``start()`` it (or use it as a context manager),
``submit`` queries from any number of threads, and call ``publish()``
from the (single) ingest thread after KB mutations.  Queries are
micro-batched into the engine's power-of-two buckets, served from a
generation-pinned immutable snapshot, cached per generation, and
accounted in the metrics plane.

Index-plane knobs thread straight through the engine kwargs:
``ServingRuntime(kb, index="ivf", nprobe=4)`` serves every flush from
the generation's *frozen* IVF index (snapshots pin the immutable
``IVFIndex`` reference exactly like the doc arrays — readers never see
a half-retrained index; docs/ARCHITECTURE.md §9).

Observability (docs/ARCHITECTURE.md §12): ``ServingMetrics`` is backed
by a labeled ``repro.obs`` metrics registry, and the scheduler emits
per-stage request spans (queue wait → flush wait → score → merge) into
the process tracer when ``repro.obs.trace.enable()`` (or
``RAGDB_TRACE=1``) is on.  ``render_metrics()`` returns one Prometheus
text exposition covering both the runtime's registry and the global
one (IVF search stats, journal bytes, publish lag, sanitizer trips).
"""
from __future__ import annotations

from concurrent.futures import Future

from repro.analysis import sanitizers
from repro.core.engine import QueryEngine, RetrievalResult  # noqa: F401
from repro.core.ingest import KnowledgeBase
from repro.obs import render_prometheus
from repro.obs.metrics import global_registry

from repro.serving.cache import ResultCache
from repro.serving.metrics import LatencyHistogram, ServingMetrics  # noqa: F401
from repro.serving.scheduler import (  # noqa: F401
    MicroBatchScheduler,
    RequestRejected,
    ServedResult,
)
from repro.serving.snapshot import (  # noqa: F401
    EngineSnapshot,
    SnapshotManager,
    results_equal,
)

__all__ = [
    "EngineSnapshot",
    "KnowledgeBase",
    "LatencyHistogram",
    "MicroBatchScheduler",
    "QueryEngine",
    "RequestRejected",
    "ResultCache",
    "ServedResult",
    "ServingMetrics",
    "ServingRuntime",
    "SnapshotManager",
    "results_equal",
]


class ServingRuntime:
    """Scheduler + snapshots + result cache + metrics, wired together."""

    def __init__(
        self,
        kb: KnowledgeBase | None = None,
        *,
        engine: QueryEngine | None = None,
        max_batch: int = 16,
        flush_deadline: float = 0.002,
        max_queue: int = 1024,
        result_cache_size: int = 2048,
        container_path: str | None = None,
        compact_ratio: float | None = KnowledgeBase.DEFAULT_COMPACT_RATIO,
        **engine_kwargs,
    ):
        self.metrics = ServingMetrics()
        self.snapshots = SnapshotManager(
            kb, engine=engine, container_path=container_path,
            compact_ratio=compact_ratio, **engine_kwargs,
        )
        self.cache = (
            ResultCache(result_cache_size) if result_cache_size else None
        )
        # always constructed (one dict + a lock); inert until armed, and
        # check() additionally no-ops unless RAGDB_SANITIZERS is on
        self.retrace_guard = sanitizers.RetraceGuard()
        self.scheduler = MicroBatchScheduler(
            self.snapshots,
            max_batch=max_batch,
            flush_deadline=flush_deadline,
            max_queue=max_queue,
            cache=self.cache,
            metrics=self.metrics,
            retrace_guard=self.retrace_guard,
        )

    # ---- lifecycle ------------------------------------------------------

    def start(self) -> "ServingRuntime":
        self.scheduler.start()
        return self

    def stop(self) -> None:
        self.scheduler.stop()

    def __enter__(self) -> "ServingRuntime":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ---- request plane (any thread) -------------------------------------

    def submit(self, text: str, k: int = 5) -> Future:
        """Future[ServedResult]; raises RequestRejected on backpressure."""
        return self.scheduler.submit(text, k)

    def query_batch(
        self, texts: list[str], k: int = 5
    ) -> list[list[RetrievalResult]]:
        """Blocking convenience: submit all, wait for all.  Same
        signature/result shape as ``QueryEngine.query_batch`` so drivers
        can switch entry points without restructuring."""
        futures = [self.submit(t, k) for t in texts]
        return [f.result().results for f in futures]

    # ---- ingest plane (the single writer thread) ------------------------

    def publish(self, durable: bool = False) -> int:
        """Refresh the engine from the KB's dirty log and atomically
        publish the next generation; returns the published generation.
        Call from the same thread that mutates the KB.

        ``durable=True`` (requires ``container_path``) also appends the
        O(U) delta record to the container's journal, so a crash never
        loses a published generation — restart with
        ``KnowledgeBase.load(container_path)`` to resume exactly there."""
        gen = self.snapshots.publish(durable=durable).generation
        # a new generation may legitimately trace new padded shapes
        # (corpus growth crosses a doc-rows bucket) — disarm the retrace
        # guard; callers re-arm via arm_sanitizers() once re-warmed
        self.retrace_guard.reset()
        return gen

    # ---- runtime sanitizers ----------------------------------------------

    def arm_sanitizers(self, k: int = 5) -> None:
        """Warm every query-batch jit bucket the serving loop can emit,
        then baseline the jit caches — after this, any recompile on the
        flush path raises ``sanitizers.SanitizerError`` on the batch
        that caused it (when ``RAGDB_SANITIZERS`` is on).

        Warming covers the power-of-two buckets {1, 2, 4, ..,
        max_batch} at the given ``k`` against the *current* snapshot;
        this is also the bucket-set pin that keeps steady-state serving
        recompile-free.  Re-call after every ``publish()`` (which
        disarms the guard).
        """
        snap = self.snapshots.current
        b = 1
        while True:
            snap.query_batch(["warmup bucket probe"] * b, k)
            if b >= self.scheduler.max_batch:
                break
            b *= 2
        self.retrace_guard.arm()

    # ---- introspection ---------------------------------------------------

    def render_metrics(self) -> str:
        """One Prometheus text exposition for the whole runtime: the
        per-runtime serving registry (requests, latency histogram,
        batch occupancy, cache hits) plus the process-global obs
        registry (IVF probe stats, journal bytes, publish lag,
        sanitizer trips)."""
        return render_prometheus(self.metrics.registry, global_registry())

    def index_stats(self) -> dict:
        """The engine's clustered-index health counters (probed
        fraction, widening rounds, retrains); probe fields are None
        on a flat index or before the first ivf dispatch."""
        return self.engine.index_stats()

    @property
    def engine(self) -> QueryEngine:
        return self.snapshots.engine

    @property
    def generation(self) -> int:
        return self.snapshots.generation
