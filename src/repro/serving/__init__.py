"""The concurrent serving runtime (docs/ARCHITECTURE.md §7).

Sits between callers and the batched ``QueryEngine``:

    callers ──submit()──▶ MicroBatchScheduler ──flush──▶ EngineSnapshot@g
                │   ▲           (scheduler.py)              (snapshot.py)
                │   └── Future[ServedResult]                      ▲
                │                                        publish() │ atomic swap
                ├── ResultCache (query, k, generation)   SnapshotManager
                │        (cache.py)                            ▲
                └── ServingMetrics (metrics.py)     sync()/add_text + refresh()
                                                     single writer thread

``ServingRuntime`` is the one-stop composition: construct it over a
``KnowledgeBase``, ``start()`` it (or use it as a context manager),
``submit`` queries from any number of threads, and call ``publish()``
from the (single) ingest thread after KB mutations.  Queries are
micro-batched into the engine's power-of-two buckets, served from a
generation-pinned immutable snapshot, cached per generation, and
accounted in the metrics plane.

Index-plane knobs thread straight through the engine kwargs:
``ServingRuntime(kb, index="ivf", nprobe=4)`` serves every flush from
the generation's *frozen* IVF index (snapshots pin the immutable
``IVFIndex`` reference exactly like the doc arrays — readers never see
a half-retrained index; docs/ARCHITECTURE.md §9).

Observability (docs/ARCHITECTURE.md §12): ``ServingMetrics`` is backed
by a labeled ``repro.obs`` metrics registry, and the scheduler emits
per-stage request spans (queue wait → flush wait → score → merge) into
the process tracer when ``repro.obs.trace.enable()`` (or
``RAGDB_TRACE=1``) is on.  ``render_metrics()`` returns one Prometheus
text exposition covering both the runtime's registry and the global
one (IVF search stats, journal bytes, publish lag, sanitizer trips).

Tenancy (docs/ARCHITECTURE.md §13): construct over a
``tenancy.ContainerPool`` instead of a KB —
``ServingRuntime(pool=ContainerPool(root), quotas=...)`` — and the
same runtime multiplexes N tenants: ``submit(text, k, tenant=...)``
routes through the ``TenantRouter`` (token-bucket admission, lazy
mount, refcount-pinned flushes), ``publish(tenant=...)`` drives that
tenant's writer plane, the result cache is keyspace-isolated per
tenant, and pool evictions drop the evicted tenant's cache keyspace.
The two construction modes are exclusive; the single-tenant mode is
bit-identical to the pre-tenancy runtime (parity-tested).
"""
from __future__ import annotations

from concurrent.futures import Future
from contextlib import contextmanager

from repro.analysis import sanitizers
from repro.core.engine import QueryEngine, RetrievalResult  # noqa: F401
from repro.core.ingest import KnowledgeBase
from repro.obs import render_prometheus
from repro.obs.ledger import ResourceLedger
from repro.obs.metrics import global_registry

from repro.serving.cache import ResultCache
from repro.serving.metrics import LatencyHistogram, ServingMetrics  # noqa: F401
from repro.serving.scheduler import (  # noqa: F401
    MicroBatchScheduler,
    RequestRejected,
    ServedResult,
)
from repro.serving.snapshot import (  # noqa: F401
    EngineSnapshot,
    SnapshotManager,
    results_equal,
)

__all__ = [
    "EngineSnapshot",
    "KnowledgeBase",
    "LatencyHistogram",
    "MicroBatchScheduler",
    "QueryEngine",
    "RequestRejected",
    "ResultCache",
    "ServedResult",
    "ServingMetrics",
    "ServingRuntime",
    "SnapshotManager",
    "results_equal",
]


class ServingRuntime:
    """Scheduler + snapshots + result cache + metrics, wired together."""

    def __init__(
        self,
        kb: KnowledgeBase | None = None,
        *,
        engine: QueryEngine | None = None,
        pool=None,
        quotas=None,
        max_batch: int = 16,
        flush_deadline: float = 0.002,
        max_queue: int = 1024,
        result_cache_size: int = 2048,
        container_path: str | None = None,
        compact_ratio: float | None = KnowledgeBase.DEFAULT_COMPACT_RATIO,
        slo=None,
        **engine_kwargs,
    ):
        self.metrics = ServingMetrics()
        self.cache = (
            ResultCache(result_cache_size) if result_cache_size else None
        )
        # always constructed (one dict + a lock); inert until armed, and
        # check() additionally no-ops unless RAGDB_SANITIZERS is on
        self.retrace_guard = sanitizers.RetraceGuard()
        # SLO health monitor (obs/health.py): lazily constructed on the
        # first health() call so the window clock starts at first use
        self._slo = slo
        self._health_monitor = None
        if pool is not None:
            # multi-tenant mode: the pool owns every KB/engine stack
            if kb is not None or engine is not None or container_path:
                raise ValueError(
                    "pool= is exclusive with kb=/engine=/container_path= "
                    "— per-tenant stacks are mounted by the ContainerPool")
            # deferred import: tenancy builds on serving.snapshot, so a
            # module-level import here would cycle through the package
            from repro.tenancy.router import TenantRouter
            self.pool = pool
            self.router = TenantRouter(pool, quotas=quotas)
            self.snapshots = None
            # the pool's ledger is the runtime's resource accounting
            self.ledger = pool.ledger
            # unmount hygiene: an evicted tenant's cached results AND
            # its labeled metric series leave memory with its stack —
            # without the prune, zipf tenant churn grows label
            # cardinality without bound and evicted tenants' gauges
            # (publish lag, resident bytes) go stale forever
            pool.on_evict = self._on_tenant_evict
            self.scheduler = MicroBatchScheduler(
                router=self.router,
                max_batch=max_batch,
                flush_deadline=flush_deadline,
                max_queue=max_queue,
                cache=self.cache,
                metrics=self.metrics,
                retrace_guard=self.retrace_guard,
            )
            return
        self.pool = None
        self.router = None
        self.ledger = ResourceLedger(registry=self.metrics.registry)
        self.snapshots = SnapshotManager(
            kb, engine=engine, container_path=container_path,
            compact_ratio=compact_ratio, ledger=self.ledger,
            **engine_kwargs,
        )
        self.scheduler = MicroBatchScheduler(
            self.snapshots,
            max_batch=max_batch,
            flush_deadline=flush_deadline,
            max_queue=max_queue,
            cache=self.cache,
            metrics=self.metrics,
            retrace_guard=self.retrace_guard,
        )

    def _on_tenant_evict(self, tenant: str) -> None:
        """Pool eviction hook: drop the tenant's cache keyspace and
        prune its labeled series from the runtime registry."""
        if self.cache is not None:
            self.cache.drop_keyspace(tenant)
        self.metrics.drop_tenant(tenant)

    # ---- lifecycle ------------------------------------------------------

    def start(self) -> "ServingRuntime":
        self.scheduler.start()
        return self

    def stop(self) -> None:
        self.scheduler.stop()

    def __enter__(self) -> "ServingRuntime":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ---- request plane (any thread) -------------------------------------

    def submit(self, text: str, k: int = 5,
               tenant: str | None = None, *,
               explain: bool = False) -> Future:
        """Future[ServedResult]; raises RequestRejected on backpressure
        (queue full, or — multi-tenant mode — tenant over quota).
        ``explain=True`` attaches the per-query EXPLAIN plan to the
        resolved ``ServedResult.plan`` (docs/ARCHITECTURE.md §14)."""
        return self.scheduler.submit(text, k, tenant=tenant,
                                     explain=explain)

    def query_batch(
        self, texts: list[str], k: int = 5, tenant: str | None = None
    ) -> list[list[RetrievalResult]]:
        """Blocking convenience: submit all, wait for all.  Same
        signature/result shape as ``QueryEngine.query_batch`` so drivers
        can switch entry points without restructuring."""
        futures = [self.submit(t, k, tenant=tenant) for t in texts]
        return [f.result().results for f in futures]

    # ---- ingest plane (the single writer thread) ------------------------

    def publish(self, durable: bool = False,
                tenant: str | None = None) -> int:
        """Refresh the engine from the KB's dirty log and atomically
        publish the next generation; returns the published generation.
        Call from the same thread that mutates the KB (per tenant, in
        multi-tenant mode — pass the tenant whose KB you mutated).

        ``durable=True`` (requires ``container_path``; always available
        in multi-tenant mode, where every mount has its container) also
        appends the O(U) delta record to the container's journal, so a
        crash never loses a published generation — restart with
        ``KnowledgeBase.load(container_path)`` to resume exactly there."""
        if self.router is not None:
            from repro.tenancy.router import DEFAULT_TENANT
            gen = self.router.publish(
                DEFAULT_TENANT if tenant is None else tenant,
                durable=durable)
        else:
            if tenant is not None:
                raise ValueError(
                    "tenant= requires multi-tenant mode "
                    "(ServingRuntime(pool=...))")
            gen = self.snapshots.publish(durable=durable).generation
        # a new generation may legitimately trace new padded shapes
        # (corpus growth crosses a doc-rows bucket) — disarm the retrace
        # guard; callers re-arm via arm_sanitizers() once re-warmed
        self.retrace_guard.reset()
        return gen

    # ---- tenancy plane ---------------------------------------------------

    @contextmanager
    def tenant_writer(self, tenant: str):
        """``with runtime.tenant_writer(t) as kb:`` — pin tenant ``t``
        (mounting it if cold) and yield its KnowledgeBase for a writer
        session; follow with ``publish(tenant=t)``.  The pin makes pool
        eviction of the tenant structurally impossible mid-session.
        Multi-tenant mode only."""
        if self.router is None:
            raise RuntimeError(
                "tenant_writer requires multi-tenant mode "
                "(ServingRuntime(pool=...))")
        with self.router.writer(tenant) as mount:
            yield mount.kb

    # ---- runtime sanitizers ----------------------------------------------

    def arm_sanitizers(self, k: int = 5,
                       tenants: list[str] | None = None) -> None:
        """Warm every query-batch jit bucket the serving loop can emit,
        then baseline the jit caches — after this, any recompile on the
        flush path raises ``sanitizers.SanitizerError`` on the batch
        that caused it (when ``RAGDB_SANITIZERS`` is on).

        Warming covers the power-of-two buckets {1, 2, 4, ..,
        max_batch} at the given ``k`` against the *current* snapshot —
        in multi-tenant mode, against every tenant in ``tenants``
        (default: the resident set), since each tenant's doc-array
        shapes trace their own jit entries; this is the per-tenant
        bucket-set pin that keeps steady-state serving recompile-free.
        Re-call after every ``publish()`` (which disarms the guard).
        """
        if self.router is not None:
            names = tenants if tenants is not None \
                else self.pool.resident_tenants()
            for name in names:
                with self.pool.pinned(name) as mount:
                    self._warm_buckets(mount.snapshots.current, k)
        else:
            self._warm_buckets(self.snapshots.current, k)
        self.retrace_guard.arm()

    def _warm_buckets(self, snap, k: int) -> None:
        b = 1
        while True:
            snap.query_batch(["warmup bucket probe"] * b, k)
            if b >= self.scheduler.max_batch:
                break
            b *= 2

    # ---- introspection ---------------------------------------------------

    def render_metrics(self) -> str:
        """One Prometheus text exposition for the whole runtime: the
        per-runtime serving registry (requests, latency histogram,
        batch occupancy, cache hits) plus the process-global obs
        registry (IVF probe stats, journal bytes, publish lag,
        sanitizer trips)."""
        return render_prometheus(self.metrics.registry, global_registry())

    def index_stats(self) -> dict:
        """The engine's clustered-index health counters (probed
        fraction, widening rounds, retrains); probe fields are None
        on a flat index or before the first ivf dispatch."""
        return self.engine.index_stats()

    def resources(self) -> dict:
        """Ledger snapshot of resident bytes per (tenant, plane) — the
        same numbers pool eviction budgets against, so reported
        occupancy and budget decisions can never diverge
        (docs/ARCHITECTURE.md §14).  The result-cache plane is
        refreshed from the live cache at call time."""
        if self.cache is not None:
            sizes = self.cache.keyspace_bytes()
            if self.pool is None:
                self.ledger.set_plane("default", "result_cache",
                                      sum(sizes.values()))
            else:
                for keyspace, nbytes in sizes.items():
                    self.ledger.set_plane(keyspace, "result_cache", nbytes)
        return self.ledger.snapshot()

    def health(self) -> dict:
        """One SLO health verdict: ``{"status": "ok|degraded|critical",
        "reasons": [...], "signals": {...}}`` (obs/health.py).  Each
        call takes a sample, evaluates the rolling windows, and exports
        ``ragdb_health_status`` + burn-rate gauges into the runtime
        registry (so they ship in ``render_metrics()``).  Configure
        targets via ``ServingRuntime(..., slo=SLOTargets(...))``."""
        if self._health_monitor is None:
            from repro.obs.health import HealthMonitor
            self._health_monitor = HealthMonitor(
                self.metrics, targets=self._slo,
                export_registry=self.metrics.registry)
        return self._health_monitor.check()

    def tenant_metrics(self) -> dict:
        """Per-tenant QPS/p50/p99/rejections (multi-tenant mode;
        empty dict on the single-tenant path)."""
        return self.metrics.tenant_snapshot()

    def pool_stats(self) -> dict:
        """The container pool's resident/pinned/byte accounting
        (multi-tenant mode only)."""
        if self.pool is None:
            raise RuntimeError("pool_stats requires multi-tenant mode")
        return self.pool.stats()

    @property
    def engine(self) -> QueryEngine:
        if self.snapshots is None:
            raise RuntimeError(
                "no single engine in multi-tenant mode — pin a tenant "
                "via tenant_writer()/pool.pinned() for its stack")
        return self.snapshots.engine

    @property
    def generation(self) -> int:
        if self.snapshots is None:
            raise RuntimeError(
                "no single generation in multi-tenant mode — use "
                "pool.peek_generation(tenant)")
        return self.snapshots.generation
