"""Serving-tier result cache, keyed on (canonical query, k, generation).

Distinct from — and composing with — the engine's query-*vector* LRU:
that cache skips tokenize/hash/scatter for repeated query texts; this
one skips the entire scoring dispatch for repeated *(query, k)* pairs
against the *same corpus generation*.  Putting the generation in the
key makes invalidation free: publishing generation *g+1* means new
lookups simply miss (their key differs), and entries for dead
generations age out of the LRU naturally — no epoch sweeps, no locks
held during publication.  ``evict_generations_before`` is an optional
hygiene hook for long-lived processes with tiny corpora where old-gen
entries would otherwise linger.

Values are the scheduler's result lists; they are treated as immutable
by every consumer (RetrievalResult rows are never mutated after
construction), so a hit returns the stored list without copying.
"""
from __future__ import annotations

import threading
from collections import OrderedDict

from repro.core.tokenizer import normalize


def result_key(text: str, k: int, generation: int) -> tuple[str, int, int]:
    """Canonical cache key — same normalization as the engine's
    query-vector LRU, so "INV-2024" and "inv-2024" share one entry."""
    return (normalize(text), k, generation)


class ResultCache:
    """Thread-safe LRU over full retrieval results."""

    def __init__(self, capacity: int = 2048):
        self.capacity = capacity
        self._lock = threading.Lock()
        self._data: OrderedDict[tuple, object] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, text: str, k: int, generation: int):
        key = result_key(text, k, generation)
        with self._lock:
            val = self._data.get(key)
            if val is None:
                self.misses += 1
                return None
            self._data.move_to_end(key)
            self.hits += 1
            return val

    def put(self, text: str, k: int, generation: int, results) -> None:
        key = result_key(text, k, generation)
        with self._lock:
            self._data[key] = results
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)

    def evict_generations_before(self, generation: int) -> int:
        """Drop entries pinned to generations older than ``generation``;
        returns how many were evicted."""
        with self._lock:
            dead = [key for key in self._data if key[2] < generation]
            for key in dead:
                del self._data[key]
            return len(dead)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def stats(self) -> dict:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "entries": len(self._data),
                "capacity": self.capacity,
            }
