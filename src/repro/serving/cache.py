"""Serving-tier result cache, keyed on (canonical query, k, generation)
within an isolated *keyspace* per tenant.

Distinct from — and composing with — the engine's query-*vector* LRU:
that cache skips tokenize/hash/scatter for repeated query texts; this
one skips the entire scoring dispatch for repeated *(query, k)* pairs
against the *same corpus generation*.  Putting the generation in the
key makes invalidation free: publishing generation *g+1* means new
lookups simply miss (their key differs), and entries for dead
generations age out of the LRU naturally — no epoch sweeps, no locks
held during publication.  ``evict_generations_before`` is an optional
hygiene hook for long-lived processes with tiny corpora where old-gen
entries would otherwise linger.

Keyspaces (the tenancy plane, docs/ARCHITECTURE.md §13): every entry
lives in exactly one keyspace (the tenant id; ``DEFAULT_KEYSPACE`` for
the single-tenant path), and **capacity accounting, LRU eviction, and
generation eviction are all scoped per keyspace**.  Two tenants at
"generation 3" are different corpora — a global generation sweep (the
pre-tenancy behavior) would let one tenant's publish evict another
tenant's hot entries, and a shared LRU would let one hot tenant push
every cold tenant's entries out.  ``drop_keyspace`` is the pool's
eviction hook: unmounting a tenant drops its cached results wholesale.

Values are the scheduler's result lists; they are treated as immutable
by every consumer (RetrievalResult rows are never mutated after
construction), so a hit returns the stored list without copying.
"""
from __future__ import annotations

import threading
from collections import OrderedDict

from repro.core.tokenizer import normalize

# The single-tenant keyspace: equals tenancy's DEFAULT_TENANT (defined
# here, dependency-free, and re-exported by the tenancy package) so the
# classic ServingRuntime path and a one-tenant pool share semantics.
DEFAULT_KEYSPACE = "default"


def result_key(text: str, k: int, generation: int) -> tuple[str, int, int]:
    """Canonical cache key — same normalization as the engine's
    query-vector LRU, so "INV-2024" and "inv-2024" share one entry."""
    return (normalize(text), k, generation)


class ResultCache:
    """Thread-safe LRU over full retrieval results, one LRU per
    keyspace (``capacity`` bounds each keyspace independently)."""

    def __init__(self, capacity: int = 2048):
        self.capacity = capacity
        self._lock = threading.Lock()
        self._spaces: dict[str, OrderedDict[tuple, object]] = {}
        self.hits = 0
        self.misses = 0

    def get(self, text: str, k: int, generation: int,
            keyspace: str = DEFAULT_KEYSPACE):
        key = result_key(text, k, generation)
        with self._lock:
            space = self._spaces.get(keyspace)
            val = None if space is None else space.get(key)
            if val is None:
                self.misses += 1
                return None
            space.move_to_end(key)
            self.hits += 1
            return val

    def put(self, text: str, k: int, generation: int, results,
            keyspace: str = DEFAULT_KEYSPACE) -> None:
        key = result_key(text, k, generation)
        with self._lock:
            space = self._spaces.setdefault(keyspace, OrderedDict())
            space[key] = results
            space.move_to_end(key)
            # capacity is per keyspace: a hot tenant filling its own LRU
            # can never push a cold tenant's entries out
            while len(space) > self.capacity:
                space.popitem(last=False)

    def evict_generations_before(self, generation: int,
                                 keyspace: str = DEFAULT_KEYSPACE) -> int:
        """Drop ``keyspace``'s entries pinned to generations older than
        ``generation``; returns how many were evicted.  Scoped: another
        keyspace's generation counter is a different corpus lineage, so
        its entries are never touched."""
        with self._lock:
            space = self._spaces.get(keyspace)
            if space is None:
                return 0
            dead = [key for key in space if key[2] < generation]
            for key in dead:
                del space[key]
            if not space:
                del self._spaces[keyspace]
            return len(dead)

    def drop_keyspace(self, keyspace: str) -> int:
        """Drop every entry in ``keyspace`` (tenant unmount hook);
        returns how many entries were dropped."""
        with self._lock:
            space = self._spaces.pop(keyspace, None)
            return 0 if space is None else len(space)

    def keyspace_bytes(self) -> dict:
        """Estimated resident bytes per keyspace, for the resource
        ledger's ``result_cache`` plane: per entry, the key text plus
        ~96 B of tuple/dict overhead plus ~96 B per cached
        RetrievalResult row (object header + 4 boxed fields) — a
        documented estimate, not an exact object-graph walk."""
        with self._lock:
            return {
                ks: sum(
                    96 + len(key[0]) + 96 * len(results)
                    for key, results in space.items()
                )
                for ks, space in self._spaces.items()
            }

    def __len__(self) -> int:
        with self._lock:
            return sum(len(s) for s in self._spaces.values())

    def stats(self) -> dict:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "entries": sum(len(s) for s in self._spaces.values()),
                "keyspaces": len(self._spaces),
                "capacity": self.capacity,
            }
