"""Generation-pinned snapshots: immutable read plane over a live engine.

The concurrency model mirrors the sharded container's manifest design
(core/container.py): readers pin a *generation*; the single writer
builds the next one and publishes it with one atomic reference swap.
Applied to the query plane:

- ``EngineSnapshot`` freezes everything a query needs at generation
  *g*: the device-resident doc matrix + signature matrix (jnp arrays
  are immutable — ``refresh()`` only ever *rebinds* the engine's
  attributes, so a captured array can never be half-updated), the doc
  id layout, and a **copy** of the vectorizer's idf state (df array +
  doc count) so query vectors are built against *g*'s statistics, not
  whatever the live ingest thread has mutated df to meanwhile.  Its
  ``query_batch`` is a pure function over that frozen state — safe to
  call from any number of threads, never refreshes, bit-identical to
  ``QueryEngine.query_batch`` on a KB frozen at the same generation.

- ``SnapshotManager`` owns the live engine and the current snapshot.
  ``publish()`` (writer thread only) runs the engine's incremental
  ``refresh()`` — O(changed docs), the whole point — captures a new
  snapshot, and swaps the ``current`` reference.  Readers that already
  hold generation *g* keep serving it untouched; new requests see
  *g+1*.  Queries never observe a partially refreshed matrix, and live
  ingest never blocks serving (verified under contention in
  tests/test_serving.py).

Single-writer contract (asserted by KnowledgeBase's write guard): one
thread performs all KB mutations *and* all ``publish()`` calls.  Any
number of threads may read ``current`` / call snapshot queries.
"""
from __future__ import annotations

import contextlib
import threading
import time
from dataclasses import dataclass

from repro.core import signature as sigmod
from repro.core.engine import (
    QueryEngine,
    RetrievalResult,
    pack_query_arrays,
    results_from_topk,
    score_batch_arrays,
)
from repro.core.ingest import KnowledgeBase
from repro.core.vectorizer import HashedTfIdf
from repro.obs import trace as obs_trace
from repro.obs.metrics import global_registry

# shared reentrant no-op scope for the explain=False query path
_NULL_CTX = contextlib.nullcontext()


@dataclass(frozen=True)
class EngineSnapshot:
    """An immutable view of one engine generation (see module docs)."""

    generation: int
    doc_ids: tuple[str, ...]
    doc_vecs: object          # jnp [N, D] — immutable device array
    doc_sigs: object          # jnp [N, W]
    vectorizer: HashedTfIdf   # private copy: df frozen at `generation`
    sig_words: int
    alpha: float
    beta: float
    scoring_path: str
    kernel_operands: tuple | None  # block-aligned pad, precomputed
    max_batch: int
    # index plane pin: the engine's IVFIndex / ShardedIVFIndex is
    # immutable after build (maintenance *rebinds* engine.ivf, same as
    # the arrays), so the capture is one reference — readers serve the
    # clustered index of generation g lock-free while the writer
    # retrains/reassigns g+1.  For the sharded plane that one reference
    # pins the whole replica set: every per-device resident block of
    # generation g rides the same publish protocol, so a reader's merge
    # never mixes shard blocks from two generations
    index_kind: str = "flat"
    ivf: object | None = None
    nprobe: int = 8
    guarantee: str = "probe"

    @staticmethod
    def capture(engine: QueryEngine) -> "EngineSnapshot":
        """Freeze the engine's current generation.  Caller (the writer
        thread) must have run ``engine.refresh()`` first so the arrays
        reflect ``engine.synced_version == kb.version``."""
        vec = engine.kb.vectorizer
        return EngineSnapshot(
            generation=engine.synced_version,
            doc_ids=tuple(engine.doc_ids),
            doc_vecs=engine.doc_vecs,
            doc_sigs=engine.doc_sigs,
            vectorizer=HashedTfIdf.from_state(vec.state(), vec.df.copy()),
            sig_words=engine.kb.sig_words,
            alpha=engine.alpha,
            beta=engine.beta,
            scoring_path=engine.scoring_path,
            kernel_operands=(
                engine._kernel_operands() if engine.use_kernel else None
            ),
            max_batch=engine.max_batch,
            index_kind=engine.index,
            ivf=engine.ivf,
            nprobe=engine.nprobe,
            guarantee=engine.guarantee,
        )

    @property
    def n_docs(self) -> int:
        return len(self.doc_ids)

    def query_batch(
        self, texts: list[str], k: int = 5, *, explain: bool = False
    ):
        """Score against this generation — pure, thread-safe, no refresh.

        Query vectors are built from the snapshot's own idf copy, so the
        result is bit-identical to ``QueryEngine.query_batch`` on a KB
        frozen at ``generation`` even while the live KB mutates.

        ``explain=True`` returns ``(results, plans)`` — one
        :class:`repro.obs.explain.QueryPlan` per query, pinned at this
        snapshot's generation (docs/ARCHITECTURE.md §14).
        """
        if k <= 0:
            raise ValueError(f"k must be a positive integer, got {k}")
        if not self.doc_ids or not texts:
            empty = [[] for _ in texts]
            if explain:
                from repro.obs import explain as explain_mod
                plans = explain_mod.plans_from_dispatch(
                    texts, k, index=self.index_kind,
                    scoring_path=self.scoring_path,
                    guarantee=self.guarantee, n_docs=0,
                    generation=self.generation)
                return empty, plans
            return empty
        out: list[list[RetrievalResult]] = []
        batches = []
        for start in range(0, len(texts), self.max_batch):
            chunk = texts[start: start + self.max_batch]
            if explain:
                res, ps = self._chunk(chunk, k, explain=True)
                out.extend(res)
                batches.append(ps)
            else:
                out.extend(self._chunk(chunk, k))
        if explain:
            from repro.obs.explain import PlanBatch
            return out, PlanBatch.concat(batches)
        return out

    def _chunk(self, texts: list[str], k: int, *, explain: bool = False):
        if explain:
            from repro.obs import explain as explain_mod
            col = obs_trace.StageCollector()
            scope = obs_trace.get().collect(col)
            t0 = time.perf_counter()
        else:
            scope = _NULL_CTX
        with scope:
            with obs_trace.span("query_embed", queries=len(texts)):
                pairs = [
                    (
                        self.vectorizer.query_vector(t),
                        sigmod.query_signature(t, width_words=self.sig_words),
                    )
                    for t in texts
                ]
                qv, qs = pack_query_arrays(
                    pairs, self.vectorizer.dim, self.sig_words)
            n = len(self.doc_ids)
            stats = None
            if self.index_kind != "flat" and self.ivf is not None:
                vals, idx, cos, ind, stats = self.ivf.search(
                    self.doc_vecs, self.doc_sigs, qv, qs,
                    b=len(texts), k=min(k, n), nprobe=self.nprobe,
                    guarantee=self.guarantee, scoring_path=self.scoring_path,
                    alpha=self.alpha, beta=self.beta, explain=explain,
                )
            else:
                vals, idx, cos, ind = score_batch_arrays(
                    self.doc_vecs, self.doc_sigs, qv, qs,
                    scoring_path=self.scoring_path, k=min(k, n),
                    alpha=self.alpha, beta=self.beta, n_docs=n,
                    kernel_operands=self.kernel_operands,
                )
            results = results_from_topk(self.doc_ids, len(texts),
                                        vals, idx, cos, ind)
        if not explain:
            return results
        # capture only — plan dataclasses materialize on first access
        # (PlanBatch), keeping explain inside the traced-QPS budget
        stages = tuple(col.stages)
        total_s = time.perf_counter() - t0
        kind, path, guar = self.index_kind, self.scoring_path, self.guarantee
        gen = self.generation
        return results, explain_mod.PlanBatch(
            lambda: explain_mod.plans_from_dispatch(
                texts, k, index=kind, scoring_path=path, guarantee=guar,
                n_docs=n, stats=stats, stages=stages,
                vector_cache_hits=None, generation=gen, total_s=total_s))


class SnapshotManager:
    """Owns the live engine + the current published snapshot.

    ``current`` is a single attribute read (atomic under the GIL);
    ``publish()`` serializes writers with a lock — but the lock is never
    taken on the read path, so publication cannot stall readers.
    """

    def __init__(self, kb=None, engine: QueryEngine | None = None,
                 container_path: str | None = None,
                 compact_ratio: float | None =
                 KnowledgeBase.DEFAULT_COMPACT_RATIO,
                 tenant: str | None = None,
                 ledger=None,
                 **engine_kwargs):
        if engine is None:
            if kb is None:
                raise ValueError("need a KnowledgeBase or a QueryEngine")
            engine = QueryEngine(kb, **engine_kwargs)
        self.engine = engine
        # durable-publish target: the KB's container + delta journal.
        # ``compact_ratio=None`` disables auto-compaction (same contract
        # as KnowledgeBase.save_delta — passed through verbatim).
        self.container_path = container_path
        self.compact_ratio = compact_ratio
        # tenancy label: set by ContainerPool mounts so publish spans
        # and the publish-lag gauge carry the tenant end to end; None
        # on the classic single-tenant path (unchanged series names)
        self.tenant = tenant
        # resource ledger (obs/ledger.py): re-measured at every publish
        # so resident-byte accounting always reflects the generation
        # readers can actually see
        self.ledger = ledger
        self._publish_lock = threading.Lock()
        with self._publish_lock:
            engine.refresh()
            self._current = EngineSnapshot.capture(engine)
        self._ledger_update()

    @property
    def current(self) -> EngineSnapshot:
        return self._current

    @property
    def generation(self) -> int:
        return self._current.generation

    def publish(self, durable: bool = False) -> EngineSnapshot:
        """Refresh the engine from the KB's dirty log and atomically
        swap in the new generation.  Writer thread only (the same
        thread that mutates the KB — see the single-writer contract).
        No-op (returns the live snapshot) when nothing changed.

        ``durable=True`` also persists the generation being swapped in:
        ``KnowledgeBase.save_delta(container_path)`` appends the O(U)
        delta record (or full-saves on the first publish) *before* the
        in-memory swap — persist-then-swap, so no reader can ever
        observe a generation that a crash could lose.  A crash between
        the two steps merely leaves an extra durable generation no
        reader had seen yet; on restart, ``KnowledgeBase.load`` replays
        base + journal back to exactly the last durable publish.
        Requires ``container_path`` (constructor arg)."""
        if durable and self.container_path is None:
            raise ValueError(
                "durable publish needs SnapshotManager(container_path=...)"
            )
        span_kw = {} if self.tenant is None else {"tenant": self.tenant}
        with self._publish_lock, \
                obs_trace.span("publish", durable=durable, **span_kw) as sp:
            with obs_trace.span("refresh"):
                self.engine.refresh()
            if durable:
                with obs_trace.span("delta_save"):
                    self.engine.kb.save_delta(
                        self.container_path,
                        compact_ratio=self.compact_ratio)
            if self.engine.synced_version != self._current.generation:
                with obs_trace.span("snapshot_capture"):
                    snap = EngineSnapshot.capture(self.engine)
                self._current = snap  # atomic reference swap — the publish
                # publish lag: wall time from the oldest KB mutation
                # this generation absorbs to the moment readers see it
                lag = self.engine.kb.take_publish_lag()
                if lag is not None:
                    lag_labels = ({} if self.tenant is None
                                  else {"tenant": self.tenant})
                    global_registry().gauge(
                        "ragdb_publish_lag_seconds",
                        "oldest unpublished mutation -> snapshot swap",
                        **lag_labels,
                    ).set(lag)
                    sp.set(generation=snap.generation, lag_s=round(lag, 6))
            self._ledger_update()
            return self._current

    def _ledger_update(self) -> None:
        """Re-measure this engine's resident planes into the ledger
        (mount + every publish — the points where they change)."""
        if self.ledger is None:
            return
        from repro.obs import ledger as ledger_mod
        planes = ledger_mod.measure_engine_planes(self.engine)
        if self.container_path is not None:
            planes["journal_tail"] = ledger_mod.measure_journal(
                self.container_path)
        self.ledger.update(self.tenant or "default", planes,
                           generation=self._current.generation)


def results_equal(a: list[RetrievalResult], b: list[RetrievalResult]) -> bool:
    """Bit-exact result-list equality (used by tests and examples to
    verify the pinned-generation contract)."""
    if len(a) != len(b):
        return False
    return all(
        x.doc_id == y.doc_id
        and x.score == y.score
        and x.cosine == y.cosine
        and x.boosted == y.boosted
        for x, y in zip(a, b)
    )
