"""Serving metrics plane, backed by the obs metrics registry.

``ServingMetrics`` keeps its recording-hook API (the scheduler calls
``on_submit``/``on_complete``/…) and its ``snapshot()`` dict contract,
but the storage is now a per-runtime ``obs.MetricsRegistry`` — the
same counters/gauges/histograms the rest of the pipeline records into
— so one Prometheus exposition (``render()``) covers the serving tier
alongside the engine/index/ingest signals in
``obs.global_registry()``.  ``LatencyHistogram`` is the obs
``LogHistogram`` (fixed log-spaced buckets, O(1) memory forever);
re-exported here for compatibility.

Recorded by the scheduler (serving/scheduler.py):
- ``requests`` / ``completed`` / ``rejected`` / ``failed``
- ``cache_hits`` / ``cache_misses`` (serving-tier result cache)
- ``batches`` / batch occupancy (requests per flush) / ``scored``
  (unique queries actually dispatched — occupancy minus coalesced
  duplicates)
- end-to-end request latency (submit → future resolved): p50/p99/mean
- throughput (completed / wall-clock since construction or ``reset``)
"""
from __future__ import annotations

import time

from repro.obs.export import render_prometheus
from repro.obs.metrics import LogHistogram, MetricsRegistry

# compatibility alias: the serving latency histogram is the obs
# log-bucket histogram (tests and drivers import it under this name)
LatencyHistogram = LogHistogram


class ServingMetrics:
    """Thread-safe counters + histograms for one serving runtime."""

    def __init__(self):
        self.registry = MetricsRegistry()
        self.reset()

    def reset(self) -> None:
        """Zero everything and restart the throughput clock (used by
        load generators to scope measurements to a timed window)."""
        self.registry.reset()
        reg = self.registry
        self._t0 = time.perf_counter()
        # per-tenant labeled series, memoized (one dict probe per hook
        # call instead of a registry lock round-trip); populated only
        # when the scheduler runs in router (multi-tenant) mode
        self._tenant_series: dict[str, dict] = {}
        self._requests = reg.counter(
            "ragdb_serving_requests_total", "requests submitted")
        self._completed = reg.counter(
            "ragdb_serving_completed_total", "futures resolved ok")
        self._rejected = reg.counter(
            "ragdb_serving_rejected_total", "admission-queue rejections")
        self._failed = reg.counter(
            "ragdb_serving_failed_total", "futures resolved with an error")
        self._cache_hits = reg.counter(
            "ragdb_serving_cache_hits_total", "result-cache hits at submit")
        self._cache_misses = reg.counter(
            "ragdb_serving_cache_misses_total", "result-cache misses")
        self._batches = reg.counter(
            "ragdb_serving_batches_total", "scheduler flushes")
        self._occupancy_sum = reg.counter(
            "ragdb_serving_batch_occupancy_sum", "requests across flushes")
        self._occupancy_max = reg.gauge(
            "ragdb_serving_batch_occupancy_max", "largest flush seen")
        self._scored = reg.counter(
            "ragdb_serving_scored_total",
            "unique queries dispatched (occupancy minus coalesced dups)")
        self._latency = reg.histogram(
            "ragdb_serving_latency_seconds",
            "end-to-end request latency (submit -> future resolved)")

    # ---- per-tenant labeled series (router mode) ------------------------

    def _tenant(self, tenant: str) -> dict:
        s = self._tenant_series.get(tenant)
        if s is None:
            reg = self.registry
            s = {
                "requests": reg.counter(
                    "ragdb_tenant_requests_total",
                    "requests submitted per tenant", tenant=tenant),
                "completed": reg.counter(
                    "ragdb_tenant_completed_total",
                    "futures resolved ok per tenant", tenant=tenant),
                "rejected": reg.counter(
                    "ragdb_tenant_rejected_total",
                    "quota/queue rejections per tenant", tenant=tenant),
                "latency": reg.histogram(
                    "ragdb_tenant_latency_seconds",
                    "end-to-end request latency per tenant",
                    tenant=tenant),
            }
            self._tenant_series[tenant] = s
        return s

    # ---- recording hooks (scheduler) -----------------------------------
    #
    # ``tenant=None`` (the single-tenant scheduler) records exactly the
    # pre-tenancy series — no labeled duplicates, bit-identical
    # exposition.  Router mode passes the tenant id and every hook
    # additionally records the per-tenant labeled series.

    def on_submit(self, tenant: str | None = None) -> None:
        self._requests.inc()
        if tenant is not None:
            self._tenant(tenant)["requests"].inc()

    def on_cache_hit(self, latency_s: float = 0.0,
                     tenant: str | None = None) -> None:
        """A submit-time cache hit completes immediately; its (near-zero)
        latency is recorded so the histogram covers the same request
        population as ``completed``/``qps``."""
        self._cache_hits.inc()
        self._completed.inc()
        self._latency.record(latency_s)
        if tenant is not None:
            s = self._tenant(tenant)
            s["completed"].inc()
            s["latency"].record(latency_s)

    def on_cache_miss(self) -> None:
        self._cache_misses.inc()

    def on_reject(self, tenant: str | None = None) -> None:
        self._rejected.inc()
        if tenant is not None:
            self._tenant(tenant)["rejected"].inc()

    def on_batch(self, occupancy: int, scored: int) -> None:
        self._batches.inc()
        self._occupancy_sum.inc(occupancy)
        self._scored.inc(scored)
        if occupancy > self._occupancy_max.value:
            self._occupancy_max.set(occupancy)

    def on_complete(self, latency_s: float,
                    tenant: str | None = None) -> None:
        self._completed.inc()
        self._latency.record(latency_s)
        if tenant is not None:
            s = self._tenant(tenant)
            s["completed"].inc()
            s["latency"].record(latency_s)

    def on_fail(self) -> None:
        self._failed.inc()

    # ---- tenant lifecycle ----------------------------------------------

    def drop_tenant(self, tenant: str) -> int:
        """Forget a tenant's labeled series (pool eviction path): the
        memoized handle set and every ``tenant=``-labeled series in the
        registry are pruned, so long-lived tenant churn cannot grow
        label cardinality without bound.  A remount recreates the
        series fresh from zero."""
        self._tenant_series.pop(tenant, None)
        return self.registry.prune(tenant=tenant)

    # ---- export ---------------------------------------------------------

    def health_sample(self) -> dict:
        """Raw cumulative values the SLO health monitor windows over
        (obs/health.py): counters plus one coherent latency
        bucket-snapshot."""
        return {
            "requests": self._requests.value,
            "completed": self._completed.value,
            "rejected": self._rejected.value,
            "failed": self._failed.value,
            "cache_hits": self._cache_hits.value,
            "cache_misses": self._cache_misses.value,
            "latency_buckets": self._latency.bucket_snapshot(),
        }

    @property
    def latency(self) -> LogHistogram:
        return self._latency

    def snapshot(self) -> dict:
        """One coherent dict of everything (the drivers print this)."""
        elapsed = max(time.perf_counter() - self._t0, 1e-9)
        completed = self._completed.value
        hits = self._cache_hits.value
        lookups = hits + self._cache_misses.value
        batches = self._batches.value
        lat = self._latency
        return {
            "requests": self._requests.value,
            "completed": completed,
            "rejected": self._rejected.value,
            "failed": self._failed.value,
            "qps": completed / elapsed,
            "elapsed_s": elapsed,
            "latency_p50_ms": lat.percentile(50) * 1e3,
            "latency_p99_ms": lat.percentile(99) * 1e3,
            "latency_mean_ms": lat.mean * 1e3,
            "latency_max_ms": lat.max * 1e3,
            "batches": batches,
            "batch_occupancy_mean": (
                self._occupancy_sum.value / batches if batches else 0.0
            ),
            "batch_occupancy_max": self._occupancy_max.value,
            "scored_queries": self._scored.value,
            "cache_hits": hits,
            "cache_misses": self._cache_misses.value,
            "cache_hit_rate": hits / lookups if lookups else 0.0,
        }

    def tenant_snapshot(self) -> dict[str, dict]:
        """Per-tenant view: {tenant: {completed, rejected, qps,
        latency_p50_ms, latency_p99_ms}} — the per-tenant QPS/p99 the
        tenancy plane reports (empty on the single-tenant path)."""
        elapsed = max(time.perf_counter() - self._t0, 1e-9)
        out: dict[str, dict] = {}
        for tenant, s in self._tenant_series.items():
            lat = s["latency"]
            out[tenant] = {
                "requests": s["requests"].value,
                "completed": s["completed"].value,
                "rejected": s["rejected"].value,
                "qps": s["completed"].value / elapsed,
                "latency_p50_ms": lat.percentile(50) * 1e3,
                "latency_p99_ms": lat.percentile(99) * 1e3,
            }
        return out

    def format(self) -> str:
        """Compact one-paragraph rendering for CLI drivers."""
        s = self.snapshot()
        return (
            f"served {s['completed']}/{s['requests']} requests "
            f"({s['rejected']} rejected, {s['failed']} failed) "
            f"at {s['qps']:.0f} qps | "
            f"latency p50 {s['latency_p50_ms']:.2f} ms "
            f"p99 {s['latency_p99_ms']:.2f} ms | "
            f"{s['batches']} flushes, mean occupancy "
            f"{s['batch_occupancy_mean']:.1f} "
            f"(max {s['batch_occupancy_max']}) | "
            f"result cache {s['cache_hits']}/{s['cache_hits'] + s['cache_misses']}"
            f" hits"
        )

    def render(self) -> str:
        """Prometheus text exposition of this runtime's registry."""
        return render_prometheus(self.registry)
