"""Serving metrics plane: counters + latency histograms.

Zero-dependency observability for the serving runtime: a fixed-bucket
log-spaced latency histogram (no unbounded sample lists — a serving
process must not grow memory with request count) and a small set of
counters, all behind one lock, exported as a plain dict via
``snapshot()`` so drivers can print or ship them anywhere.

Recorded by the scheduler (serving/scheduler.py):
- ``requests`` / ``completed`` / ``rejected`` / ``failed``
- ``cache_hits`` / ``cache_misses`` (serving-tier result cache)
- ``batches`` / batch occupancy (requests per flush) / ``scored``
  (unique queries actually dispatched — occupancy minus coalesced
  duplicates)
- end-to-end request latency (submit → future resolved): p50/p99/mean
- throughput (completed / wall-clock since construction or ``reset``)
"""
from __future__ import annotations

import threading
import time
from bisect import bisect_left


class LatencyHistogram:
    """Fixed log-spaced buckets, 10 µs … ~79 s (×1.25 per bucket).

    ``percentile`` returns the geometric midpoint of the bucket holding
    the requested rank — a ≤ ~12 % quantization error, plenty for
    p50/p99 serving dashboards, with O(1) memory forever.
    """

    N_BUCKETS = 72
    BASE = 10e-6
    GROWTH = 1.25

    def __init__(self):
        self.bounds = [
            self.BASE * self.GROWTH ** i for i in range(self.N_BUCKETS)
        ]
        self.counts = [0] * (self.N_BUCKETS + 1)  # +1 overflow bucket
        self.n = 0
        self.total = 0.0
        self.max = 0.0

    def record(self, seconds: float) -> None:
        self.counts[bisect_left(self.bounds, seconds)] += 1
        self.n += 1
        self.total += seconds
        if seconds > self.max:
            self.max = seconds

    def percentile(self, q: float) -> float:
        """q in [0, 100] → seconds (0.0 when empty)."""
        if self.n == 0:
            return 0.0
        rank = q / 100.0 * (self.n - 1)
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum > rank:
                if i == 0:
                    return min(self.bounds[0] / self.GROWTH ** 0.5, self.max)
                if i >= self.N_BUCKETS:
                    return self.max
                # geometric bucket midpoint, clamped to the observed max
                return min(self.bounds[i - 1] * self.GROWTH ** 0.5, self.max)
        return self.max

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0


class ServingMetrics:
    """Thread-safe counters + histograms for one serving runtime."""

    def __init__(self):
        self._lock = threading.Lock()
        self.reset()

    def reset(self) -> None:
        """Zero everything and restart the throughput clock (used by
        load generators to scope measurements to a timed window)."""
        with self._lock:
            self._t0 = time.perf_counter()
            self.requests = 0
            self.completed = 0
            self.rejected = 0
            self.failed = 0
            self.cache_hits = 0
            self.cache_misses = 0
            self.batches = 0
            self.occupancy_sum = 0
            self.occupancy_max = 0
            self.scored = 0
            self.latency = LatencyHistogram()

    # ---- recording hooks (scheduler) -----------------------------------

    def on_submit(self) -> None:
        with self._lock:
            self.requests += 1

    def on_cache_hit(self, latency_s: float = 0.0) -> None:
        """A submit-time cache hit completes immediately; its (near-zero)
        latency is recorded so the histogram covers the same request
        population as ``completed``/``qps``."""
        with self._lock:
            self.cache_hits += 1
            self.completed += 1
            self.latency.record(latency_s)

    def on_cache_miss(self) -> None:
        with self._lock:
            self.cache_misses += 1

    def on_reject(self) -> None:
        with self._lock:
            self.rejected += 1

    def on_batch(self, occupancy: int, scored: int) -> None:
        with self._lock:
            self.batches += 1
            self.occupancy_sum += occupancy
            self.scored += scored
            if occupancy > self.occupancy_max:
                self.occupancy_max = occupancy

    def on_complete(self, latency_s: float) -> None:
        with self._lock:
            self.completed += 1
            self.latency.record(latency_s)

    def on_fail(self) -> None:
        with self._lock:
            self.failed += 1

    # ---- export ---------------------------------------------------------

    def snapshot(self) -> dict:
        """One coherent dict of everything (the drivers print this)."""
        with self._lock:
            elapsed = max(time.perf_counter() - self._t0, 1e-9)
            lookups = self.cache_hits + self.cache_misses
            return {
                "requests": self.requests,
                "completed": self.completed,
                "rejected": self.rejected,
                "failed": self.failed,
                "qps": self.completed / elapsed,
                "elapsed_s": elapsed,
                "latency_p50_ms": self.latency.percentile(50) * 1e3,
                "latency_p99_ms": self.latency.percentile(99) * 1e3,
                "latency_mean_ms": self.latency.mean * 1e3,
                "latency_max_ms": self.latency.max * 1e3,
                "batches": self.batches,
                "batch_occupancy_mean": (
                    self.occupancy_sum / self.batches if self.batches else 0.0
                ),
                "batch_occupancy_max": self.occupancy_max,
                "scored_queries": self.scored,
                "cache_hits": self.cache_hits,
                "cache_misses": self.cache_misses,
                "cache_hit_rate": self.cache_hits / lookups if lookups else 0.0,
            }

    def format(self) -> str:
        """Compact one-paragraph rendering for CLI drivers."""
        s = self.snapshot()
        return (
            f"served {s['completed']}/{s['requests']} requests "
            f"({s['rejected']} rejected) at {s['qps']:.0f} qps | "
            f"latency p50 {s['latency_p50_ms']:.2f} ms "
            f"p99 {s['latency_p99_ms']:.2f} ms | "
            f"{s['batches']} flushes, mean occupancy "
            f"{s['batch_occupancy_mean']:.1f} "
            f"(max {s['batch_occupancy_max']}) | "
            f"result cache {s['cache_hits']}/{s['cache_hits'] + s['cache_misses']}"
            f" hits"
        )
