"""Row-wise Adagrad for huge embedding tables (FBGEMM/DLRM-standard).

AdamW keeps two f32 moments per parameter — for dlrm-mlperf's ~34 GB
table that is ~68 GB of optimizer state.  Row-wise Adagrad keeps ONE
f32 scalar per row (the mean squared-gradient of the row): state is
rows×4 bytes instead of rows×dim×8 — a 2·dim× reduction (256× at
dim=128) — and is the production optimizer for sparse embedding tables
(Criteo-scale DLRM training uses exactly this split: dense params on
Adam, tables on row-wise Adagrad).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class RowwiseAdagradConfig:
    lr: float = 0.02
    eps: float = 1e-8


def rowwise_init(table: jnp.ndarray) -> dict:
    return {"g2": jnp.zeros((table.shape[0],), jnp.float32)}


def rowwise_update(grad: jnp.ndarray, state: dict, table: jnp.ndarray,
                   cfg: RowwiseAdagradConfig):
    """One step.  grad/table [V, E]; state["g2"] [V]."""
    g = grad.astype(jnp.float32)
    g2 = state["g2"] + jnp.mean(jnp.square(g), axis=-1)
    step = cfg.lr * g / (jnp.sqrt(g2)[:, None] + cfg.eps)
    return (table - step).astype(table.dtype), {"g2": g2}


def split_tree(params: dict) -> tuple[dict, dict]:
    """(table leaves, everything else) — tables go to row-wise Adagrad,
    the dense remainder to AdamW."""
    tables = {k: v for k, v in params.items()
              if k in ("table", "first_order")}
    dense = {k: v for k, v in params.items() if k not in tables}
    return tables, dense
