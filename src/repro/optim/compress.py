"""Error-feedback int8 gradient compression (1-bit-Adam lineage).

Cross-pod gradient all-reduce is the only collective that traverses the
slow inter-pod links; quantizing its payload to int8 with per-leaf
scales cuts those bytes 4× (f32) / 2× (bf16).  Error feedback keeps the
quantization *unbiased over time*: the residual of step t is added back
at step t+1, so the accumulated update converges to the uncompressed one
(convergence property-tested in tests/test_optim.py).

Two entry points:
- ``quantize``/``dequantize`` + ``ef_roundtrip``: the optimizer-level
  transform (simulates the wire format, works under plain SPMD jit);
- ``compressed_psum``: the explicit wire path for shard_map regions —
  the all-reduce operand really is int8 in the lowered HLO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8: (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_roundtrip(grads, error_state):
    """Quantize-dequantize each leaf with error feedback.

    Returns (compressed-equivalent grads, new error state).  error_state
    is a pytree of f32 residuals matching grads (init = zeros).
    """
    def leaf(g, e):
        y = g.astype(jnp.float32) + e
        q, s = quantize(y)
        deq = dequantize(q, s)
        return deq.astype(g.dtype), y - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_state)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def compressed_psum(tree, axis_name: str):
    """int8 all-reduce for shard_map regions.

    Two phases per leaf: (1) a scalar pmax agrees on a GLOBAL scale
    (per-shard scales cannot be unmixed after the sum — Σqᵢ·mean(sᵢ) ≠
    Σqᵢsᵢ, a bug our wire-level test caught); (2) quantize with the
    shared scale and psum the int8 grid values (accumulated as int32 —
    127·n_shards overflows int8).  Result = mean of shard grads within
    half a quantization step.
    """
    n = jax.lax.psum(1, axis_name)

    def leaf(g):
        amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
        q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (q_sum.astype(jnp.float32) * scale / n).astype(g.dtype)

    return jax.tree.map(leaf, tree)
