"""AdamW over raw pytrees.

Optimizer state mirrors the parameter tree leaf-for-leaf, so the same
PartitionSpecs shard both (ZeRO-1 falls out of sharding m/v like the
FSDP-sharded params — no separate partitioning code path).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params) -> dict:
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 lr: jnp.ndarray | float | None = None):
    """One step; returns (new_params, new_state).  ``lr`` overrides
    cfg.lr (schedules pass the per-step value)."""
    lr = cfg.lr if lr is None else lr
    step = state["step"] + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        new_p = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * p)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}
