"""Elastic scaling plans.

Corpus shards are content-addressed container files, so moving a shard
between workers is a manifest edit + one file copy — `rebalance_corpus`
computes the minimal-move assignment.  Training elasticity rides the
checkpoint round-trip: params are saved shard-agnostically (full
logical arrays per leaf), so restoring onto a different mesh shape is
just device_put with the new sharding (plan_restart picks the shape).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShardMove:
    shard_index: int
    src: str
    dst: str


def rebalance_corpus(
    shard_owners: dict[int, str], workers: list[str]
) -> list[ShardMove]:
    """Minimal-move rebalance of n shards over the worker list.

    Keeps every shard already on a surviving worker in place when that
    worker is not over target; moves orphaned/overflow shards to the
    least-loaded survivors.  Deterministic (sorted orders) so every
    controller replica computes the same plan.
    """
    n = len(shard_owners)
    workers = sorted(set(workers))
    lo, extras = divmod(n, len(workers))  # lo or lo+1 shards per worker
    load: dict[str, int] = {w: 0 for w in workers}
    keep: dict[int, str] = {}
    extras_used = 0
    for idx in sorted(shard_owners):
        owner = shard_owners[idx]
        if owner not in load:
            continue
        if load[owner] < lo:
            keep[idx] = owner
            load[owner] += 1
        elif load[owner] == lo and extras_used < extras:
            keep[idx] = owner
            load[owner] += 1
            extras_used += 1
    moves = []
    for idx in sorted(shard_owners):
        if idx in keep:
            continue
        dst = min(workers, key=lambda w: (load[w], w))
        load[dst] += 1
        moves.append(ShardMove(idx, shard_owners[idx], dst))
    return moves
